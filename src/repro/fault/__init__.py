"""Dynamic-conditions resilience plane: fault scenarios over the planes.

All four modelling planes (analytic GEMINI core, `repro.net` channel/
MAC stack, `repro.sim` event engine, `repro.obs` tracing) assume a
static, reliable fabric.  This package injects *dynamic conditions* —
chiplet fail-stop, chiplet slow-down, mesh-link failure, and
SNR-dependent channel fading (`repro.net.channel.SnrProfile`) — into
the existing planes and measures how much of the wireless speedup
survives them:

- `scenario`   — the event dataclasses + validated `FaultScenario`
  container.  A scenario is *declarative*: what degrades, from which
  layer boundary onward, by how much.
- `apply`      — scenario -> model arrays: trace derating for chip
  events (`derate_trace`), per-(layer, cut) wired service scaling and
  forced-failover sets for link failures (`link_fault_arrays`), and
  per-(layer, channel) effective wireless bandwidth for fades
  (`wireless_bw_matrix`).  `repro.sim.engine.PacketSim(faults=...)`
  consumes these.
- `resilience` — the online-reshard controller (Heartbeat/ElasticPlan
  detection + per-era placement rebuild against the surviving
  topology) and the retained-speedup sweep behind
  `benchmarks.paper_figs.fig_resilience`.

The headline no static sweep can tell: when a mesh cut dies, the
shared wireless medium is the only path that survives by construction
— packets on a fully-dead cut are *forced* onto the wireless plane
(wired-only runs go to infinity), and the per-layer policies re-tune
around the degradation.
"""

from typing import TYPE_CHECKING

_SCENARIO_EXPORTS = (
    "ChipFailure", "ChipSlowdown", "LinkFailure", "SnrFade",
    "FaultScenario",
)
_APPLY_EXPORTS = ("derate_trace", "link_fault_arrays", "wireless_bw_matrix")
_RESILIENCE_EXPORTS = ("ReshardOutcome", "default_scenario", "degraded_run",
                       "reshard_run", "resilience_sweep")

__all__ = list(_SCENARIO_EXPORTS + _APPLY_EXPORTS + _RESILIENCE_EXPORTS)

if TYPE_CHECKING:   # pragma: no cover - static analysis only
    from .apply import (derate_trace, link_fault_arrays,  # noqa: F401
                        wireless_bw_matrix)
    from .resilience import (ReshardOutcome, default_scenario,  # noqa: F401
                             degraded_run, reshard_run, resilience_sweep)
    from .scenario import (ChipFailure, ChipSlowdown,  # noqa: F401
                           FaultScenario, LinkFailure, SnrFade)


def __getattr__(name: str):
    # lazy exports keep `repro.sim.engine`'s late `repro.fault.apply`
    # import cycle-free: importing the package must not pull in
    # `resilience` (which imports repro.sim) eagerly
    import importlib
    if name in _SCENARIO_EXPORTS:
        return getattr(importlib.import_module(f"{__name__}.scenario"), name)
    if name in _APPLY_EXPORTS:
        return getattr(importlib.import_module(f"{__name__}.apply"), name)
    if name in _RESILIENCE_EXPORTS:
        return getattr(importlib.import_module(f"{__name__}.resilience"),
                       name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
