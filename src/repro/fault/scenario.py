"""Declarative fault scenarios: what degrades, when, by how much.

Timing convention: the simulators are layer-barriered (a layer's
packets inject at its start, the next layer starts when every queue
drains), so "fail-stop at time t" maps onto the layer boundary it
falls in — every event carries ``at_layer`` and the condition holds
for all layers ``>= at_layer``.  ``at_layer=0`` is a condition present
from the start of the run (e.g. a persistently faded channel).

Events:

- `ChipFailure`   — chiplet fail-stop: its compute contribution drops
  to zero; in *degraded mode* (no reshard) its per-layer share is
  absorbed by its surviving exec-set peers, with the absorbed weight
  slice re-streamed from DRAM (the absorber has no SRAM budget
  reserved for it).  The chiplet's mesh *router* keeps forwarding —
  interposer routers are powered independently of the compute die —
  so chip death does not kill mesh links (use `LinkFailure` for that).
- `ChipSlowdown`  — thermal throttling / a flaky host: the chiplet
  computes at ``1/factor`` of its rate from ``at_layer`` on.
- `LinkFailure`   — one directed mesh link (named by its endpoint grid
  coordinates) goes down.  Striped runs serve the cut on the surviving
  stripe (``k/surviving`` service scaling); xy runs detour the
  crossing onto a surviving parallel link of the same cut; a fully
  dead cut *forces* its packets onto the wireless plane (wired-only
  runs go to infinity — wireless-as-failover).
- `SnrFade`       — ``fading_db`` of SNR degradation on one channel
  (or all), converted to an effective-capacity scale by the package's
  `repro.net.channel.SnrProfile` Shannon model.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Optional, Tuple

from repro.net.channel import SnrProfile

#: xy-model detour multiplier: a crossing remapped off its dead link
#: onto a parallel link of the same cut doglegs through the adjacent
#: row/column, traversing that neighbourhood twice.
DETOUR_FACTOR = 2.0


def _check_layer(at_layer: int) -> None:
    if not isinstance(at_layer, int) or at_layer < 0:
        raise ValueError(f"at_layer must be an int >= 0, got {at_layer!r}")


@dataclasses.dataclass(frozen=True)
class ChipFailure:
    chip: int
    at_layer: int = 0

    def __post_init__(self):
        if not isinstance(self.chip, int) or self.chip < 0:
            raise ValueError(f"chip must be an int >= 0, got {self.chip!r}")
        _check_layer(self.at_layer)


@dataclasses.dataclass(frozen=True)
class ChipSlowdown:
    chip: int
    factor: float
    at_layer: int = 0

    def __post_init__(self):
        if not isinstance(self.chip, int) or self.chip < 0:
            raise ValueError(f"chip must be an int >= 0, got {self.chip!r}")
        if not self.factor >= 1.0:
            raise ValueError(
                f"slow-down factor must be >= 1, got {self.factor!r}")
        _check_layer(self.at_layer)


@dataclasses.dataclass(frozen=True)
class LinkFailure:
    """Directed mesh link ``a -> b`` down (both directions by default)."""

    a: Tuple[int, int]
    b: Tuple[int, int]
    at_layer: int = 0
    both_directions: bool = True

    def __post_init__(self):
        for end in (self.a, self.b):
            if not (isinstance(end, tuple) and len(end) == 2):
                raise ValueError(
                    f"link endpoints are (row, col) grid tuples, got {end!r}")
        if self.a == self.b:
            raise ValueError("link endpoints must differ")
        _check_layer(self.at_layer)


@dataclasses.dataclass(frozen=True)
class SnrFade:
    """``fading_db`` of SNR loss on ``channel`` (None = every channel)."""

    fading_db: float
    channel: Optional[int] = None
    at_layer: int = 0

    def __post_init__(self):
        fade = float(self.fading_db)
        if not (fade >= 0.0 and fade == fade and fade != float("inf")):
            raise ValueError(
                f"fading_db must be finite and >= 0, got {self.fading_db!r}")
        if self.channel is not None and self.channel < 0:
            raise ValueError(f"channel must be >= 0, got {self.channel!r}")
        _check_layer(self.at_layer)


@dataclasses.dataclass(frozen=True)
class FaultScenario:
    """A validated bundle of dynamic-condition events.

    ``snr`` carries the package's link budget (distance model) used to
    convert `SnrFade` events into effective per-channel bandwidth.
    A scenario with no events (`is_null`) is structurally a no-op: the
    engine skips every fault path and stays bit-identical to the
    fault-free run; zero-*magnitude* events (factor-1 slowdowns, 0 dB
    fades) also reproduce the fault-free numbers exactly, by
    construction of the ratio forms.
    """

    chip_failures: Tuple[ChipFailure, ...] = ()
    chip_slowdowns: Tuple[ChipSlowdown, ...] = ()
    link_failures: Tuple[LinkFailure, ...] = ()
    snr_fades: Tuple[SnrFade, ...] = ()
    snr: SnrProfile = SnrProfile()

    def __post_init__(self):
        for name, typ in (("chip_failures", ChipFailure),
                          ("chip_slowdowns", ChipSlowdown),
                          ("link_failures", LinkFailure),
                          ("snr_fades", SnrFade)):
            v = tuple(getattr(self, name))
            if not all(isinstance(e, typ) for e in v):
                raise ValueError(f"{name} must contain only {typ.__name__}")
            object.__setattr__(self, name, v)
        if not isinstance(self.snr, SnrProfile):
            raise ValueError("snr must be an SnrProfile")

    @property
    def is_null(self) -> bool:
        return not (self.chip_failures or self.chip_slowdowns
                    or self.link_failures or self.snr_fades)

    @property
    def has_chip_events(self) -> bool:
        return bool(self.chip_failures or self.chip_slowdowns)

    def events(self):
        return itertools.chain(self.chip_failures, self.chip_slowdowns,
                               self.link_failures, self.snr_fades)

    def network_only(self) -> "FaultScenario":
        """The residual scenario after a reshard absorbed the chip
        events into the placement (link/SNR conditions remain)."""
        return dataclasses.replace(self, chip_failures=(),
                                   chip_slowdowns=())

    def reshard_boundaries(self) -> Tuple[int, ...]:
        """Layer boundaries where the chip-health state changes — the
        online-reshard controller's decision points."""
        return tuple(sorted({e.at_layer
                             for e in (self.chip_failures
                                       + self.chip_slowdowns)}))

    def describe(self) -> str:
        parts = []
        if self.chip_failures:
            parts.append("fail:" + ",".join(
                f"c{e.chip}@{e.at_layer}" for e in self.chip_failures))
        if self.chip_slowdowns:
            parts.append("slow:" + ",".join(
                f"c{e.chip}x{e.factor:g}@{e.at_layer}"
                for e in self.chip_slowdowns))
        if self.link_failures:
            parts.append("link:" + ",".join(
                f"{e.a}-{e.b}@{e.at_layer}" for e in self.link_failures))
        if self.snr_fades:
            parts.append("fade:" + ",".join(
                f"{e.fading_db:g}dB@" +
                ("*" if e.channel is None else f"ch{e.channel}")
                for e in self.snr_fades))
        return ";".join(parts) if parts else "null"
