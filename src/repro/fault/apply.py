"""Scenario -> model arrays: how a `FaultScenario` lands on the planes.

Three independent effect groups, matching where each condition
physically bites:

- **chip events** are *trace-level*: they inflate the per-layer
  compute (and, for fail-stop, DRAM) terms that `build_trace` derived
  from the mapping.  `derate_trace` returns a new `TrafficTrace` with
  the same packet arrays (masks stay aligned) and derated
  ``t_compute``/``t_dram``.
- **link failures** are *wired-plane-level*: per-(layer, cut) service
  scaling for the striped model, per-(layer, link) detour remaps for
  the xy model, and the forced-failover packet set for fully-dead
  cuts.  `link_fault_arrays` feeds `repro.sim.engine.PacketSim`.
- **SNR fades** are *wireless-plane-level*: per-(layer, channel)
  effective bandwidth through the `SnrProfile` Shannon capacity ratio.

Degraded-mode fail-stop model (static policies, no reshard): the dead
chiplet's share is absorbed by its surviving exec-set peers at their
rates — per layer the exec group's effective throughput is the
share-weighted capacity ``sum(share_c * g_c)`` with ``g_c`` in
``{0, 1/factor, 1}``, so the layer's compute time inflates by
``total_share / capacity``.  A fully-dead exec set falls back to one
emergency absorber at single-chiplet rate (``total / max_share``).
The absorbed weight slice is re-streamed from DRAM every inference
(the absorber has no SRAM budget reserved for it): ``dead_share *
weight_bytes / dram_bw_total`` is added to the layer's DRAM term.
Traffic geometry is unchanged — the absorber adopts the dead chip's
router position, and interposer routers survive compute-die death.

Bit-identity contract (the differential pin): zero-magnitude events
produce *exactly* the fault-free numbers — the compute inflation is
the ratio ``total / (shares * g).sum()`` which is exactly 1.0 when
every ``g`` is 1.0 (same summation order), `derate_trace` returns the
*same trace object* when nothing changed, and a 0 dB fade scales
bandwidth by exactly 1.0 (`SnrProfile.capacity_scale`).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.topology import node_grid_coords
from repro.core.traffic import TrafficTrace

from .scenario import DETOUR_FACTOR, FaultScenario

#: a failed chiplet keeps a vanishing compute rate in reshard rebuilds
#: (`AcceleratorConfig.chiplet_tops` must stay positive); rate-aware
#: mappers then assign it a vanishing share.
DEAD_CHIP_RATE_SCALE = 1e-9


# ---------------------------------------------------------------------------
# chip events -> trace derating
# ---------------------------------------------------------------------------

def derate_trace(trace: TrafficTrace,
                 scenario: FaultScenario) -> TrafficTrace:
    """Degraded-mode compute/DRAM inflation for chip events.

    Returns ``trace`` itself (same object) when no layer is affected,
    so fault-free configurations stay bit-identical.
    """
    if not scenario.has_chip_events:
        return trace
    if trace.exec_chips is None or trace.exec_shares is None:
        raise ValueError(
            "trace has no exec_chips/exec_shares metadata (hand-built?); "
            "rebuild it with repro.core.traffic.build_trace to inject "
            "chip faults")
    n_chips = trace.topo.config.n_chiplets
    for ev in scenario.chip_failures + scenario.chip_slowdowns:
        if ev.chip >= n_chips:
            raise ValueError(
                f"chip {ev.chip} out of range for a {n_chips}-chiplet "
                f"package")
    t_comp = trace.t_compute.copy()
    t_dram = trace.t_dram.copy()
    dram_bw = trace.topo.config.dram_bw_total
    changed = False
    for li in range(trace.n_layers):
        chips = trace.exec_chips[li]
        if not chips:
            continue
        shares = np.asarray(trace.exec_shares[li], float)
        g = np.ones(len(chips))
        dead = np.zeros(len(chips), bool)
        for ev in scenario.chip_slowdowns:
            if li >= ev.at_layer:
                for k, c in enumerate(chips):
                    if c == ev.chip:
                        g[k] = min(g[k], 1.0 / ev.factor)
        for ev in scenario.chip_failures:
            if li >= ev.at_layer:
                for k, c in enumerate(chips):
                    if c == ev.chip:
                        dead[k], g[k] = True, 0.0
        if not dead.any() and np.all(g == 1.0):
            continue   # zero-magnitude / out-of-exec-set: untouched
        changed = True
        total = float(shares.sum())
        capacity = float((shares * g).sum())
        if capacity > 0.0:
            t_comp[li] *= total / capacity
        else:   # fully-dead exec set: one emergency single-chip absorber
            t_comp[li] *= total / float(shares.max())
        if dead.any() and trace.weight_bytes is not None:
            dead_share = float(shares[dead].sum())
            t_dram[li] += dead_share * float(trace.weight_bytes[li]) \
                / dram_bw
    if not changed:
        return trace
    return dataclasses.replace(trace, t_compute=t_comp, t_dram=t_dram)


# ---------------------------------------------------------------------------
# link failures -> wired-plane arrays
# ---------------------------------------------------------------------------

def resolve_link_failures(trace: TrafficTrace,
                          scenario: FaultScenario
                          ) -> List[Tuple[int, int]]:
    """``(link_id, at_layer)`` pairs for the trace's link index."""
    out: List[Tuple[int, int]] = []
    for ev in scenario.link_failures:
        pairs = [(ev.a, ev.b)]
        if ev.both_directions:
            pairs.append((ev.b, ev.a))
        for pair in pairs:
            if pair not in trace.link_index:
                if ev.both_directions and pair == (ev.b, ev.a):
                    continue   # one-way topologies: forward leg suffices
                raise ValueError(
                    f"no mesh link {pair[0]} -> {pair[1]} in this trace")
            out.append((trace.link_index[pair], ev.at_layer))
    return out


def link_fault_arrays(trace: TrafficTrace, scenario: FaultScenario, *,
                      cut_of_link: np.ndarray, k_par: np.ndarray,
                      n_cuts: int
                      ) -> Tuple[Optional[np.ndarray], Optional[np.ndarray],
                                 Optional[np.ndarray], Optional[np.ndarray]]:
    """``(cut_scale, link_remap, link_cost, forced)`` for the engine.

    - ``cut_scale (L, n_cuts)``: striped service-time multiplier
      ``k / surviving`` (``inf`` on fully-dead cuts).
    - ``link_remap (L, n_links)``: xy substitute link (the
      lowest-indexed surviving parallel link of the same cut; identity
      when alive or when the whole cut is dead).
    - ``link_cost (L, n_links)``: xy service multiplier — 1 alive,
      `DETOUR_FACTOR` on remapped crossings, ``inf`` on dead cuts.
    - ``forced (M,)``: packets with a crossing on a fully-dead cut at
      their layer; the runtime diverts them to the wireless plane
      regardless of the paper's eligibility criteria (physical
      necessity), while wired-only baselines pay the infinity.

    All four are None when the scenario has no link failures.
    """
    fails = resolve_link_failures(trace, scenario)
    if not fails:
        return None, None, None, None
    L, n_links, M = trace.n_layers, trace.n_links, len(trace.nbytes)
    dead = np.zeros((L, n_links), bool)
    for lid, at in fails:
        dead[at:, lid] = True
    onehot = np.zeros((n_links, n_cuts))
    onehot[np.arange(n_links), cut_of_link] = 1.0
    dead_cnt = dead.astype(float) @ onehot            # (L, n_cuts)
    surv = k_par[None, :].astype(float) - dead_cnt
    cut_scale = np.ones((L, n_cuts))
    hit = dead_cnt > 0
    alive = surv > 0
    sel = hit & alive
    cut_scale[sel] = (k_par[None, :] / np.where(alive, surv, 1.0))[sel]
    cut_scale[hit & ~alive] = np.inf

    link_remap = np.tile(np.arange(n_links), (L, 1))
    link_cost = np.ones((L, n_links))
    for li in np.nonzero(dead.any(axis=1))[0]:
        for lid in np.nonzero(dead[li])[0]:
            cut = cut_of_link[lid]
            siblings = np.nonzero((cut_of_link == cut) & ~dead[li])[0]
            if len(siblings):
                link_remap[li, lid] = siblings[0]
                link_cost[li, lid] = DETOUR_FACTOR
            else:
                link_cost[li, lid] = np.inf

    edge_layer = trace.layer[trace.inc_msg]
    edge_dead_cut = ~alive[edge_layer, cut_of_link[trace.inc_link]]
    forced = np.zeros(M, bool)
    forced[trace.inc_msg[edge_dead_cut]] = True
    return cut_scale, link_remap, link_cost, forced


# ---------------------------------------------------------------------------
# SNR fades -> wireless-plane bandwidth
# ---------------------------------------------------------------------------

def wireless_bw_matrix(trace: TrafficTrace, net,
                       scenario: FaultScenario) -> Optional[np.ndarray]:
    """Per-(layer, channel) effective wireless bandwidth in B/s.

    Cumulative: concurrent fades on one channel add in dB.  Zero-fade
    entries carry exactly the nominal per-channel rate.  None when the
    scenario has no fades.
    """
    if not scenario.snr_fades:
        return None
    plan = net.channels
    L, C = trace.n_layers, plan.n_channels
    fade = np.zeros((L, C))
    for ev in scenario.snr_fades:
        if ev.channel is not None and ev.channel >= C:
            raise ValueError(
                f"fade channel {ev.channel} out of range for a "
                f"{C}-channel plan")
        cols = slice(None) if ev.channel is None else ev.channel
        fade[ev.at_layer:, cols] += ev.fading_db
    dist = scenario.snr.channel_distances(
        plan, trace.topo.n_nodes, node_grid_coords(trace.topo))
    scale = scenario.snr.capacity_scale(dist[None, :], fade)
    return plan.channel_bandwidth(net.bandwidth) * scale
