"""Online-reshard controller and retained-speedup resilience sweep.

This is the *placement* half of the dynamic-conditions story (the
*traffic* half is `repro.sim.policies.OnlineReshardPolicy`).  On a chip
event the runtime has two options:

- **degraded mode** — keep the deployed placement and let the surviving
  exec-set peers absorb the dead chip's share (`repro.fault.apply.
  derate_trace`, run by the engine under any policy), or
- **online reshard** — detect the failure through the `Heartbeat`
  registry, `evict` the worker, gate feasibility through
  `ElasticPlan.plan`, rebuild the placement against the survivors (the
  rate-aware mappers re-split when `AcceleratorConfig.chiplet_tops` is
  derated), pay the weight-migration restream for every layer whose
  exec set moved, and continue.

`reshard_run` prices both and keeps the cheaper one — the controller
never commits to a rebuild that loses to simply limping along, so its
total is `min(resharded, degraded)` by construction.  Combined with
`OnlineReshardPolicy`'s per-layer stitch (<= static and <= adaptive
under the same faults), the online-reshard row dominates every static
row on every sweep cell.

`resilience_sweep` produces the paper-style headline: *speedup
retained* under k fail-stops and degraded SNR — the hybrid speedup
under fault divided by the fault-free hybrid speedup, per policy, with
the wired-only counterfactual degraded by the same chip events.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.simulator import make_trace
from repro.core.traffic import TrafficTrace
from repro.net.config import as_network
from repro.runtime.fault_tolerance import ElasticPlan, Heartbeat, \
    RecoveryEvent
from repro.sim.engine import EventResult, PacketSim

from .apply import DEAD_CHIP_RATE_SCALE
from .scenario import ChipFailure, FaultScenario, SnrFade

#: logical heartbeat timeout in layer-index time: a chip that missed
#: the previous layer boundary's beat is declared dead at this one.
HEARTBEAT_TIMEOUT_LAYERS = 0.5


def degraded_run(trace: TrafficTrace, net, scenario: FaultScenario,
                 policy: str = "static",
                 link_model: str = "striped") -> EventResult:
    """One engine run under ``scenario`` with the deployed placement."""
    sim = PacketSim(trace, as_network(net), link_model=link_model,
                    faults=scenario)
    return sim.run(policy)


def default_scenario(trace: TrafficTrace, k: int = 1,
                     fade_db: float = 0.0,
                     at_layer: Optional[int] = None) -> FaultScenario:
    """The bench's canonical scenario: k fail-stops + a package fade.

    Failed chips spread across the package (centre, far corner, origin,
    thirds) so the dead set never collapses onto one mesh region;
    failures strike together at one-third of the run (``at_layer``
    overrides).  A positive ``fade_db`` degrades every channel from
    layer 0.
    """
    n = trace.topo.config.n_chiplets
    order = list(dict.fromkeys(
        [n // 2, n - 1, 0, n // 3, (2 * n) // 3]))
    if not 0 <= k <= len(order):
        raise ValueError(f"k={k} fail-stops not supported on a "
                         f"{n}-chiplet package (max {len(order)})")
    at = max(1, trace.n_layers // 3) if at_layer is None else at_layer
    return FaultScenario(
        chip_failures=tuple(ChipFailure(c, at_layer=at)
                            for c in order[:k]),
        snr_fades=(SnrFade(fade_db),) if fade_db > 0.0 else ())


@dataclasses.dataclass(frozen=True)
class ReshardOutcome:
    """What the online-reshard controller did and what it cost."""
    total_time: float            # what the controller ships: min(...)
    degraded_time: float         # keep-placement projection
    resharded_time: float        # era-stitched rebuild incl. migration
    migration_time: float        # weight restream across all rebuilds
    resharded: bool              # True when the rebuild won
    events: Tuple[RecoveryEvent, ...]
    eras: Tuple[Tuple[int, int], ...]   # [start, end) layer spans

    @property
    def reshard_gain(self) -> float:
        """Fraction of degraded-mode time the rebuild saved (>= 0)."""
        if self.degraded_time <= 0.0:
            return 0.0
        return max(0.0, 1.0 - self.resharded_time / self.degraded_time)


def _heartbeat_detect(scenario: FaultScenario, n_chips: int,
                      n_layers: int) -> Tuple[List[RecoveryEvent],
                                              List[int]]:
    """Replay the failure timeline through the liveness machinery.

    Logical clock = layer index: every surviving chip beats at each
    layer boundary; a fail-stopped chip goes silent from its
    ``at_layer`` on and is detected (timeout 0.5 layers), evicted, and
    the survivor count gated through `ElasticPlan.plan`.  Returns the
    recovery log and the boundaries where a reshard is feasible.
    """
    fail_at: Dict[int, float] = {}
    for ev in scenario.chip_failures:
        fail_at[ev.chip] = min(ev.at_layer, fail_at.get(ev.chip, np.inf))
    slow_at: Dict[int, List[int]] = {}
    for ev in scenario.chip_slowdowns:
        slow_at.setdefault(ev.at_layer, []).append(ev.chip)
    hb = Heartbeat(timeout_s=HEARTBEAT_TIMEOUT_LAYERS)
    evicted: set = set()
    events: List[RecoveryEvent] = []
    feasible: List[int] = []
    boundaries = set(scenario.reshard_boundaries())
    for li in range(n_layers):
        for c in range(n_chips):
            if c not in evicted and fail_at.get(c, np.inf) > li:
                hb.beat(c, now=float(li))
        if li not in boundaries:
            continue
        dead = hb.dead(now=float(li))
        for w in dead:
            hb.evict(w)      # without this, every later poll re-fires
            evicted.add(w)
        n_alive = n_chips - len(evicted)
        try:
            plan = ElasticPlan.plan(n_alive, model_parallel=1)
        except RuntimeError:
            continue         # no survivors: reshard infeasible here
        feasible.append(li)
        if dead:
            events.append(RecoveryEvent(step=li, kind="failure",
                                        workers=dead,
                                        new_mesh=plan.mesh_shape))
        if li in slow_at:
            events.append(RecoveryEvent(step=li, kind="straggler",
                                        workers=sorted(slow_at[li]),
                                        new_mesh=plan.mesh_shape))
    return events, feasible


def _derated_rates(cfg, scenario: FaultScenario,
                   boundary: int) -> Tuple[float, ...]:
    """`chiplet_tops` with every chip event up to ``boundary`` applied."""
    rates = np.asarray(
        cfg.chiplet_tops if cfg.chiplet_tops is not None
        else [cfg.tops_per_chiplet] * cfg.n_chiplets, float)
    base = rates.copy()
    for ev in scenario.chip_slowdowns:
        if ev.at_layer <= boundary:
            rates[ev.chip] = min(rates[ev.chip], base[ev.chip] / ev.factor)
    for ev in scenario.chip_failures:
        if ev.at_layer <= boundary:
            rates[ev.chip] = base[ev.chip] * DEAD_CHIP_RATE_SCALE
    return tuple(float(r) for r in rates)


def _moved_share(prev: TrafficTrace, new: TrafficTrace, li: int) -> float:
    """Fraction of layer ``li``'s weights that changed owner.

    Shares are aligned by chip id across the two placements; each
    chip's *gained* share is weight it must stream in (the shrinking
    side's copy is simply dropped), so the moved fraction is
    ``sum_c max(0, share_new(c) - share_old(c))``.
    """
    old = dict(zip(prev.exec_chips[li],
                   np.asarray(prev.exec_shares[li], float)))
    gained = 0.0
    for c, s in zip(new.exec_chips[li],
                    np.asarray(new.exec_shares[li], float)):
        gained += max(0.0, float(s) - old.get(c, 0.0))
    return gained


def reshard_run(workload: str, net, scenario: FaultScenario, *,
                policy: str = "online-reshard", acc=None,
                mapping: Optional[str] = None,
                link_model: str = "striped") -> ReshardOutcome:
    """Price degraded mode vs an online reshard; ship the cheaper one.

    Era machinery: each chip-event boundary that passes the
    heartbeat/eviction/`ElasticPlan` gate starts a new era whose
    placement is rebuilt with `make_trace` on a `chiplet_tops`-derated
    accelerator (dead chips keep a vanishing rate so the rate-aware
    mappers assign them a vanishing share).  Residual *network* faults
    (link failures, fades) apply in every era; the weight slice of
    every layer whose exec set moved is restreamed from DRAM once per
    rebuild.  The degraded projection runs the same ``policy`` on the
    deployed placement, so ``total_time <= degraded_time`` always.
    """
    net = as_network(net)
    trace0 = make_trace(workload, acc, mapping)
    cfg = trace0.topo.config
    deg = degraded_run(trace0, net, scenario, policy=policy,
                       link_model=link_model)
    degraded_time = float(deg.total_time)

    events, feasible = _heartbeat_detect(
        scenario, cfg.n_chiplets, trace0.n_layers)
    if not feasible:
        return ReshardOutcome(degraded_time, degraded_time, np.inf, 0.0,
                              False, tuple(events),
                              ((0, trace0.n_layers),))

    residual = scenario.network_only()
    bounds = [0] + feasible + [trace0.n_layers]
    per_layer = np.array(deg.layer_times, float)  # era 0 = deployed run
    migration = 0.0
    prev_trace = trace0
    cache: Dict[Tuple[float, ...], TrafficTrace] = {}
    eras: List[Tuple[int, int]] = []
    for start, end in zip(bounds[:-1], bounds[1:]):
        eras.append((start, end))
        if start == 0:
            continue
        rates = _derated_rates(cfg, scenario, start)
        trace_e = cache.get(rates)
        if trace_e is None:
            acc_e = dataclasses.replace(cfg, chiplet_tops=rates)
            trace_e = cache[rates] = make_trace(workload, acc_e, mapping)
        sim_e = PacketSim(trace_e, net, link_model=link_model,
                          faults=None if residual.is_null else residual)
        res_e = sim_e.run(policy)
        per_layer[start:end] = res_e.layer_times[start:end]
        if trace_e.weight_bytes is not None \
                and trace_e.exec_chips is not None \
                and prev_trace.exec_chips is not None:
            for li in range(start, trace0.n_layers):
                moved = _moved_share(prev_trace, trace_e, li)
                migration += moved * float(trace_e.weight_bytes[li]) \
                    / cfg.dram_bw_total
        prev_trace = trace_e
    resharded = float(per_layer.sum()) + migration
    total = min(resharded, degraded_time)
    return ReshardOutcome(total, degraded_time, resharded, migration,
                          resharded < degraded_time, tuple(events),
                          tuple(eras))


def resilience_sweep(workloads: Sequence[str], net, *,
                     ks: Sequence[int] = (0, 1, 2),
                     fades: Sequence[float] = (3.0, 9.0),
                     policies: Sequence[str] = ("static", "adaptive",
                                                "online-reshard"),
                     acc=None, link_model: str = "striped") -> Dict:
    """Retained-speedup grid: workloads x (k, fade) cells x policies.

    Per cell, ``retained = (wired_faulted / t_policy_faulted) /
    (wired_ff / t_policy_ff)`` — how much of the policy's fault-free
    hybrid speedup survives the scenario.  The wired-only
    counterfactual suffers the same chip events (derated trace) but has
    no wireless plane to fade or to fail over to.  The online-reshard
    row routes through `reshard_run`; every other policy keeps the
    deployed placement (`degraded_run`).
    """
    net = as_network(net)
    out: Dict[str, Dict] = {}
    for wl in workloads:
        trace = make_trace(wl, acc)
        sim_ff = PacketSim(trace, net, link_model=link_model)
        wired_ff = float(sim_ff.run_wired().total_time)
        speedup_ff = {p: wired_ff / float(sim_ff.run(p).total_time)
                      for p in policies}
        cells: Dict[str, Dict] = {}
        for k in ks:
            for fade in fades:
                sc = default_scenario(trace, k=k, fade_db=fade)
                wired_f = float(
                    PacketSim(trace, net, link_model=link_model,
                              faults=sc).run_wired().total_time)
                cell: Dict[str, Dict] = {}
                for p in policies:
                    if p == "online-reshard":
                        oc = reshard_run(wl, net, sc, policy=p, acc=acc,
                                         link_model=link_model)
                        t_pol, resharded = oc.total_time, oc.resharded
                    else:
                        t_pol = float(degraded_run(
                            trace, net, sc, policy=p,
                            link_model=link_model).total_time)
                        resharded = False
                    sp = wired_f / t_pol
                    cell[p] = {"time": t_pol, "speedup": sp,
                               "retained": sp / speedup_ff[p],
                               "resharded": resharded}
                cells[f"k{k}_fade{fade:g}"] = cell
        out[wl] = {"wired_ff": wired_ff, "speedup_ff": speedup_ff,
                   "cells": cells}
    return out
