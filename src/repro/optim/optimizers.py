"""Optimizers: AdamW and Adafactor (factored second moment), plus global
gradient-norm clipping and a cosine LR schedule.

Hand-rolled (no optax dependency in this environment).  Both optimizers
keep their state in the same tree structure as the params, so optimizer
state inherits the parameter shardings (ZeRO-style: FSDP-sharded params =>
FSDP-sharded optimizer state for free).

Adafactor matters for the 1T-parameter kimi-k2 cell: its state is O(rows +
cols) per matrix instead of O(rows x cols), which is the difference
between fitting and not fitting a pod (DESIGN.md S6).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"             # adamw | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.01
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    clip_norm: float = 1.0
    # adafactor
    decay_offset: float = 1e-30
    min_dim_factored: int = 128     # factor only matrices at least this big


def cosine_lr(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    # (step + 1): the first step must not see lr == 0
    warm = jnp.minimum((step + 1.0) / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], Tuple[Any, Any]]


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------

def adamw(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        def zeros(p):
            return jnp.zeros_like(p, jnp.float32)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        lr = cosine_lr(cfg, step)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - cfg.b1 ** t
        bc2 = 1.0 - cfg.b2 ** t

        def upd(g, mu, nu, p):
            g = g.astype(jnp.float32)
            mu = cfg.b1 * mu + (1 - cfg.b1) * g
            nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
            upd = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
            if p.ndim >= 2:
                upd = upd + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), mu, nu

        leaves, treedef = jax.tree_util.tree_flatten(params)
        gl = treedef.flatten_up_to(grads)
        mul = treedef.flatten_up_to(state["mu"])
        nul = treedef.flatten_up_to(state["nu"])
        out = [upd(g, m, n, p) for g, m, n, p in zip(gl, mul, nul, leaves)]
        return (treedef.unflatten([o[0] for o in out]),
                {"mu": treedef.unflatten([o[1] for o in out]),
                 "nu": treedef.unflatten([o[2] for o in out])})

    return Optimizer(init, update)


# --------------------------------------------------------------------------
# Adafactor (factored second moment, momentum-free)
# --------------------------------------------------------------------------

def _factored(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= 128 and p.shape[-2] >= 128


def adafactor(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        def st(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return {"v": jax.tree.map(st, params)}

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        lr = cosine_lr(cfg, step)
        t = step.astype(jnp.float32) + 1.0
        beta2 = 1.0 - t ** -0.8

        def upd(g, v, p):
            g = g.astype(jnp.float32)
            g2 = g * g + cfg.decay_offset
            if _factored(p):
                vr = beta2 * v["vr"] + (1 - beta2) * g2.mean(-1)
                vc = beta2 * v["vc"] + (1 - beta2) * g2.mean(-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(-1)[..., None, None], 1e-30))
                upd = g * jax.lax.rsqrt(denom + 1e-30)
                nv = {"vr": vr, "vc": vc}
            else:
                nv = {"v": beta2 * v["v"] + (1 - beta2) * g2}
                upd = g * jax.lax.rsqrt(nv["v"] + 1e-30)
            # update clipping (Adafactor's RMS rule)
            rms = jnp.sqrt(jnp.mean(upd * upd) + 1e-30)
            upd = upd / jnp.maximum(1.0, rms)
            if p.ndim >= 2:
                upd = upd + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), nv

        leaves, treedef = jax.tree_util.tree_flatten(params)
        gl = treedef.flatten_up_to(grads)
        vl = treedef.flatten_up_to(state["v"])
        out = [upd(g, v, p) for g, v, p in zip(gl, vl, leaves)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_v = treedef.unflatten([o[1] for o in out])
        return new_p, {"v": new_v}

    return Optimizer(init, update)


def build_optimizer(cfg: OptimizerConfig) -> Optimizer:
    return {"adamw": adamw, "adafactor": adafactor}[cfg.name](cfg)
