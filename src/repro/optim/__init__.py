from .optimizers import (OptimizerConfig, adafactor, adamw, build_optimizer,
                         clip_by_global_norm, cosine_lr)
