"""Unit constants and conversion helpers — the repo's single source of
truth for every scale factor between the quantities the four modelling
planes exchange.

The paper quotes wireless/NoP/NoC rates in **Gb/s**, DRAM rates in
**GB/s**, transceiver energy in **pJ/bit**, and the simulators account
volumes in **bytes** and times in **seconds**.  Mixing those scales
with inline ``* 1e9 / 8``-style literals is how bit-vs-byte and
Gb/s-vs-GB/s bugs creep in silently, so `repro.lint`'s ``units`` rule
family flags any arithmetic between differently-tagged quantities that
does not route through this module.

Naming convention (enforced by ``repro.lint``): a variable carrying a
unit-bearing quantity tags the unit as a suffix — ``bandwidth_gbps``,
``nbytes``/``*_bytes``, ``wall_s``, ``energy_pj`` — and conversions
between tags use the named helpers below.

Every helper is written so the replaced inline expression is
**bit-identical** to what it replaces (the golden harness pins paper
numbers bit-for-bit):

- ``GBPS_TO_BYTES_PER_S`` is ``1e9 / 8`` — exact in binary64 (1.25e8),
  and scaling by it equals ``x * 1e9 / 8`` exactly because division by
  8 is an exact power-of-two scaling that commutes with rounding.
- ``bytes_per_s_to_gbps`` keeps the ``x * 8 / 1e9`` expression shape
  instead of pre-folding ``8 / 1e9`` (whose rounding could shift the
  result by 1 ulp).

This module lives at the `repro` namespace root — **not** inside
`repro.core` — because `repro.net` needs it at import time and
`repro.core.__init__` eagerly imports `repro.net`; `repro.core.units`
re-exports everything here for core-plane callers.
"""

from __future__ import annotations

# --- decimal scale prefixes -------------------------------------------------
KILO = 1e3
MEGA = 1e6
GIGA = 1e9
TERA = 1e12

# --- information ------------------------------------------------------------
BITS_PER_BYTE = 8

#: Gb/s -> bytes/s.  ``1e9 / 8`` is exactly representable (1.25e8), and
#: ``x * GBPS_TO_BYTES_PER_S`` is bit-identical to ``x * 1e9 / 8``.
GBPS_TO_BYTES_PER_S = GIGA / BITS_PER_BYTE

# --- energy -----------------------------------------------------------------
#: picojoules -> joules (the simulators' energy constants are pJ/bit
#: and pJ/MAC; reported platform energy is joules).
PJ_TO_J = 1e-12

# --- time -------------------------------------------------------------------
S_TO_MS = 1e3
S_TO_US = 1e6    # Perfetto's trace-event timestamps are microseconds


def gbps_to_bytes_per_s(gbps: float) -> float:
    """Gb/s -> bytes/s (bit-identical to the legacy ``x * 1e9 / 8``)."""
    return gbps * GBPS_TO_BYTES_PER_S


def bytes_per_s_to_gbps(bytes_per_s: float) -> float:
    """bytes/s -> Gb/s.

    Keeps the ``* 8 / 1e9`` expression shape so the result is
    bit-identical to the inline conversions it replaces.
    """
    return bytes_per_s * BITS_PER_BYTE / GIGA


def bytes_to_bits(nbytes: float) -> float:
    return nbytes * BITS_PER_BYTE


def pj_to_j(pj: float) -> float:
    return pj * PJ_TO_J


def s_to_ms(seconds: float) -> float:
    return seconds * S_TO_MS


def s_to_us(seconds: float) -> float:
    return seconds * S_TO_US


__all__ = [
    "KILO", "MEGA", "GIGA", "TERA",
    "BITS_PER_BYTE", "GBPS_TO_BYTES_PER_S", "PJ_TO_J",
    "S_TO_MS", "S_TO_US",
    "gbps_to_bytes_per_s", "bytes_per_s_to_gbps", "bytes_to_bits",
    "pj_to_j", "s_to_ms", "s_to_us",
]
