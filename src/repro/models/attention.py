"""Attention: GQA, RoPE (partial), QKV bias, logit softcap, sliding window,
full-sequence (train/prefill) and single-token decode with KV cache.

Three interchangeable inner implementations, all numerically equivalent
(tests assert allclose):

- "naive":   materialises (B, K, G, S, T) scores — smoke tests / short seq.
- "chunked": lax.scan over KV chunks with an online softmax — O(S*chunk)
             memory, the default for long sequences (this is what makes the
             long-context cells lowerable without an S x S buffer).
- "pallas":  the flash-attention TPU kernel from repro.kernels (VMEM-tiled);
             validated in interpret mode on CPU.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import _dense_init, apply_rope, rope_frequencies

Params = Dict[str, jnp.ndarray]
NEG_INF = -2.0 ** 30


def attention_init(key, cfg: ModelConfig) -> Params:
    d, h = cfg.d_model, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(k1, (d, cfg.n_heads * h)),
        "wk": _dense_init(k2, (d, cfg.n_kv_heads * h)),
        "wv": _dense_init(k3, (d, cfg.n_kv_heads * h)),
        "wo": _dense_init(k4, (cfg.n_heads * h, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * h,), jnp.bfloat16)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * h,), jnp.bfloat16)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * h,), jnp.bfloat16)
    return p


def _project_qkv(params: Params, x: jnp.ndarray, cfg: ModelConfig
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    B, S, _ = x.shape
    h = cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, params["wq"])
    k = jnp.einsum("bsd,de->bse", x, params["wk"])
    v = jnp.einsum("bsd,de->bse", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    return (q.reshape(B, S, cfg.n_heads, h),
            k.reshape(B, S, cfg.n_kv_heads, h),
            v.reshape(B, S, cfg.n_kv_heads, h))


def _mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray,
          window: Optional[int], causal: bool = True) -> jnp.ndarray:
    """(..., S, T) boolean: causal, optionally sliding-window."""
    if not causal:
        return jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    m = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m


def _softcap(scores: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return scores
    return jnp.tanh(scores / cap) * cap


def sdpa_naive(q, k, v, q_pos, k_pos, window, softcap, scale,
               causal: bool = True) -> jnp.ndarray:
    """q: (B,S,H,D); k/v: (B,T,K,D) -> (B,S,H,D)."""
    B, S, H, D = q.shape
    K = k.shape[2]
    qg = q.reshape(B, S, K, H // K, D)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = _softcap(scores * scale, softcap)
    scores = jnp.where(_mask(q_pos, k_pos, window, causal), scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v)
    return out.reshape(B, S, H, D)


def sdpa_chunked(q, k, v, q_pos, k_pos, window, softcap, scale,
                 chunk: int = 1024, causal: bool = True) -> jnp.ndarray:
    """Online-softmax streaming over KV chunks: O(S*chunk) score memory."""
    B, S, H, D = q.shape
    K = k.shape[2]
    T = k.shape[1]
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=jnp.iinfo(jnp.int32).max)
    nc = (T + pad) // chunk
    qg = q.reshape(B, S, K, H // K, D)
    kc = k.reshape(B, nc, chunk, K, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, chunk, K, D).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(nc, chunk)

    def body(carry, xs):
        m_run, l_run, acc = carry
        kb, vb, pb = xs
        s = jnp.einsum("bskgd,btkd->bkgst", qg, kb).astype(jnp.float32)
        s = _softcap(s * scale, softcap)
        s = jnp.where(_mask(q_pos, pb, window, causal), s, NEG_INF)
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_run * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p.astype(q.dtype), vb).astype(jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, K, H // K, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, H // K, S), jnp.float32)
    a0 = jnp.zeros((B, K, H // K, S, D), jnp.float32)
    (m, lsum, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(lsum[..., None], 1e-37)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, D).astype(q.dtype)


def sdpa(q, k, v, q_pos, k_pos, window, softcap, scale,
         impl: str = "auto", causal: bool = True) -> jnp.ndarray:
    if impl == "auto":
        impl = "chunked" if k.shape[1] > 2048 else "naive"
    if impl == "pallas":
        from repro.kernels.flash_attention.ops import flash_attention
        return flash_attention(q, k, v, q_pos, k_pos, window=window,
                               softcap=softcap, scale=scale, causal=causal)
    if impl == "chunked":
        return sdpa_chunked(q, k, v, q_pos, k_pos, window, softcap, scale,
                            causal=causal)
    return sdpa_naive(q, k, v, q_pos, k_pos, window, softcap, scale,
                      causal=causal)


def attention(params: Params, x: jnp.ndarray, cfg: ModelConfig,
              positions: jnp.ndarray, window: Optional[int] = None,
              impl: str = "auto", kv_override=None,
              causal: bool = True) -> jnp.ndarray:
    """Full-sequence attention (training / prefill).

    positions: (S,) int32.  kv_override: (k, v, k_pos) for cross-attention.
    """
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, x, cfg)
    if kv_override is None:
        cos, sin = rope_frequencies(cfg.head_dim, cfg.rope_fraction,
                                    cfg.rope_theta, positions)
        q = apply_rope(q, cos, sin, cfg.rope_fraction)
        k = apply_rope(k, cos, sin, cfg.rope_fraction)
        k_pos = positions
    else:
        k, v, k_pos = kv_override
        cos, sin = rope_frequencies(cfg.head_dim, cfg.rope_fraction,
                                    cfg.rope_theta, positions)
        q = apply_rope(q, cos, sin, cfg.rope_fraction)
        window = None
    scale = cfg.head_dim ** -0.5
    out = sdpa(q, k, v, positions, k_pos, window, cfg.attn_softcap, scale,
               impl, causal=causal)
    return jnp.einsum("bse,ed->bsd", out.reshape(B, S, -1), params["wo"])


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  window: Optional[int] = None) -> Dict[str, jnp.ndarray]:
    """Ring-buffer KV cache; sliding-window layers cap it at the window."""
    L = min(max_len, window) if window else max_len
    shape = (batch, L, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, jnp.bfloat16),
            "v": jnp.zeros(shape, jnp.bfloat16)}


def decode_attention(params: Params, x: jnp.ndarray, cache: Dict,
                     cfg: ModelConfig, pos: jnp.ndarray,
                     window: Optional[int] = None,
                     cross: bool = False
                     ) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode. x: (B, 1, d); pos: scalar int32 position.

    The cache is a ring buffer of length min(max_len, window): sub-quadratic
    long-context decode for SWA layers holds O(window) state.
    """
    B = x.shape[0]
    q, k_new, v_new = _project_qkv(params, x, cfg)
    L = cache["k"].shape[1]
    if not cross:
        posv = jnp.full((1,), pos, jnp.int32)
        cos, sin = rope_frequencies(cfg.head_dim, cfg.rope_fraction,
                                    cfg.rope_theta, posv)
        q = apply_rope(q, cos, sin, cfg.rope_fraction)
        k_new = apply_rope(k_new, cos, sin, cfg.rope_fraction)
        slot = jnp.mod(pos, L)
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, 1)
        cache = {"k": ck, "v": cv}
        # absolute positions held in each ring slot
        slots = jnp.arange(L, dtype=jnp.int32)
        wrap = (pos // L) * L
        k_pos = jnp.where(slots <= jnp.mod(pos, L), wrap + slots,
                          wrap - L + slots)
        k_pos = jnp.where(k_pos < 0, jnp.iinfo(jnp.int32).max, k_pos)
    else:
        # cross-attention: cache holds the (fixed) encoder projections and
        # every encoder position is visible (no causal mask, no RoPE).
        ck, cv = cache["k"], cache["v"]
        k_pos = jnp.arange(L, dtype=jnp.int32)
    scale = cfg.head_dim ** -0.5
    q_pos = jnp.full((1,), pos, jnp.int32)
    out = sdpa_naive(q, ck, cv, q_pos, k_pos, window, cfg.attn_softcap,
                     scale, causal=not cross)
    y = jnp.einsum("bse,ed->bsd", out.reshape(B, 1, -1), params["wo"])
    return y, cache
