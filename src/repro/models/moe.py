"""Mixture-of-Experts block: top-k routing + sorted ragged_dot compute.

Dropless MoE in the TPU-idiomatic formulation: tokens are sorted by their
assigned expert and the expert matmuls run as `jax.lax.ragged_dot`
(group-wise GEMM), so compiled FLOPs equal the *active* FLOPs
(6 * N_active * D) — no dense-all-experts waste, which matters for the
roofline accounting of the 384-expert kimi config.

Expert weights are stacked (E, d, ff): the expert axis shards on the
'model' mesh axis (expert parallelism).  The token shuffle this induces is
the all-to-all-shaped multicast traffic that the paper's wireless plane
targets (see core/hybrid_schedule.py).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.mesh import get_abstract_mesh, shard_map

from .layers import _dense_init

Params = Dict[str, jnp.ndarray]


def moe_init(key, cfg: ModelConfig) -> Params:
    d, ff, E = cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": _dense_init(k1, (d, E)),
        "w_gate": _dense_init(k2, (E, d, ff)),
        "w_up": _dense_init(k3, (E, d, ff)),
        "w_down": _dense_init(k4, (E, ff, d)),
    }


def route(params: Params, x2d: jnp.ndarray, cfg: ModelConfig
          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Top-k routing. x2d: (T, d) -> (weights (T,K), experts (T,K), aux)."""
    logits = jnp.einsum("td,de->te", x2d, params["router"]
                        ).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # load-balancing auxiliary loss (Switch-style)
    E = cfg.n_experts
    me = probs.mean(0)
    ce = jnp.zeros((E,)).at[idx.reshape(-1)].add(
        jnp.ones_like(idx.reshape(-1), jnp.float32)) / idx.size
    aux = E * jnp.sum(me * ce)
    return w.astype(x2d.dtype), idx, aux


def moe_block(params: Params, x: jnp.ndarray, cfg: ModelConfig
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss).

    Two execution paths:
    - explicit expert parallelism (shard_map + all_to_all dispatch) when a
      ParallelContext is active — the production path: each device holds
      E/n_shards experts, token-rows travel to their expert's shard and
      back (this all-to-all is the multicast-shaped traffic the paper's
      hybrid plane offloads);
    - a GSPMD path otherwise (global sort + ragged_dot) — numerically
      identical (modulo capacity drops) and used as the test oracle.
    """
    from repro.runtime.parallel import get_context
    ctx = get_context()
    if ctx is not None:
        mesh = get_abstract_mesh()
        if ctx.expert_axis in getattr(mesh, "shape", {}):
            n_e = mesh.shape[ctx.expert_axis]
            n_d = 1
            for a in ctx.data_axes:
                if a in mesh.shape:
                    n_d *= mesh.shape[a]
            T = x.shape[0] * x.shape[1]
            if cfg.n_experts % n_e == 0 and T % (n_d * n_e) == 0:
                return moe_block_expert_parallel(params, x, cfg, ctx)
            if cfg.n_experts <= n_e and \
                    (cfg.moe_d_ff or cfg.d_ff) % n_e == 0 and \
                    T % max(1, n_d) == 0:
                return moe_block_tp_ff(params, x, cfg, ctx)
    return moe_block_gspmd(params, x, cfg)


def moe_block_gspmd(params: Params, x: jnp.ndarray, cfg: ModelConfig
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, S, d = x.shape
    K, E = cfg.experts_per_token, cfg.n_experts
    x2d = x.reshape(B * S, d)
    w, idx, aux = route(params, x2d, cfg)

    # expand each token K times, sort by expert id
    flat_e = idx.reshape(-1)                       # (T*K,)
    order = jnp.argsort(flat_e)
    inv = jnp.argsort(order)
    xs = jnp.repeat(x2d, K, axis=0)[order]         # (T*K, d)
    group_sizes = jnp.bincount(flat_e, length=E).astype(jnp.int32)

    gate = jax.lax.ragged_dot(xs, params["w_gate"], group_sizes)
    up = jax.lax.ragged_dot(xs, params["w_up"], group_sizes)
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    out = jax.lax.ragged_dot(h, params["w_down"], group_sizes)

    out = out[inv].reshape(B * S, K, d)            # unsort, fold K copies
    y = jnp.einsum("tkd,tk->td", out, w)
    return y.reshape(B, S, d), aux


# --------------------------------------------------------------------------
# explicit parallel paths (shard_map): see EXPERIMENTS.md SPerf H-kimi.
# GSPMD cannot partition the data-dependent global sort, so the jit path
# replicates every expanded token row; these paths keep rows sharded and
# move them explicitly.
# --------------------------------------------------------------------------

def _local_route(router, x2, cfg):
    logits = jnp.einsum("td,de->te", x2, router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    me = probs.mean(0)
    ce = jnp.zeros((cfg.n_experts,)).at[idx.reshape(-1)].add(
        jnp.ones_like(idx.reshape(-1), jnp.float32)) / idx.size
    aux = cfg.n_experts * jnp.sum(me * ce)
    return w.astype(x2.dtype), idx, aux


def _expert_ffn(xs, group_sizes, wg, wu, wd):
    gate = jax.lax.ragged_dot(xs, wg, group_sizes)
    up = jax.lax.ragged_dot(xs, wu, group_sizes)
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(xs.dtype) * up
    return jax.lax.ragged_dot(h, wd, group_sizes)


def _grouped_ffn(rows, expert_ids, n_experts, cap, wg, wu, wd):
    """Capacity-based grouped GEMM (the TPU 'dropping' formulation).

    rows: (N, d); expert_ids: (N,) in [0, n_experts] (n_experts = padding).
    Buckets rows per expert with capacity `cap`, runs batched einsum
    (e, cap, d) x (e, d, f) — true grouped-GEMM FLOPs on every backend
    (jax.lax.ragged_dot decomposes to masked dense-over-groups on the CPU
    backend, inflating compiled FLOPs n_experts-fold; see EXPERIMENTS.md
    SPerf H-kimi iteration 2) — and scatters results back to row order.
    Overflow rows are dropped (zero output), standard MoE behaviour.
    """
    N, d = rows.shape
    onehot = expert_ids[:, None] == jnp.arange(n_experts)[None, :]
    pos = jnp.cumsum(onehot, axis=0) - 1
    pos_of = jnp.where(expert_ids < n_experts,
                       jnp.take_along_axis(
                           pos, jnp.minimum(expert_ids, n_experts - 1)[:, None],
                           axis=1)[:, 0],
                       cap)
    valid = pos_of < cap
    slot = jnp.where(valid, pos_of, cap)
    e_c = jnp.minimum(expert_ids, n_experts - 1)
    buck = jnp.zeros((n_experts, cap + 1, d), rows.dtype
                     ).at[e_c, slot].set(rows)[:, :cap]
    gate = jnp.einsum("ecd,edf->ecf", buck, wg)
    up = jnp.einsum("ecd,edf->ecf", buck, wu)
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(rows.dtype) * up
    out = jnp.einsum("ecf,efd->ecd", h, wd)
    flat = out.reshape(n_experts * (cap), d)
    got = flat[e_c * cap + jnp.minimum(pos_of, cap - 1)]
    return jnp.where(valid[:, None], got, 0.0)


def moe_block_expert_parallel(params, x, cfg: ModelConfig, ctx):
    """Expert parallelism: E/n experts per model shard; token rows travel
    to their expert's shard over an explicit all_to_all and return — the
    multicast-shaped traffic the paper's hybrid plane offloads."""
    from jax.sharding import PartitionSpec as P
    mesh = get_abstract_mesh()
    ax = ctx.expert_axis
    n_e = mesh.shape[ax]
    data_axes = tuple(a for a in ("pod",) + tuple(ctx.data_axes)
                      if a in mesh.shape)
    n_d = 1
    for a in data_axes:
        n_d *= mesh.shape[a]
    B, S, d = x.shape
    T = B * S
    K, E = cfg.experts_per_token, cfg.n_experts
    E_local = E // n_e
    T_loc = T // (n_d * n_e)
    N = T_loc * K                                   # local expanded rows
    C = max(1, int(-(-N // n_e) * ctx.capacity_factor))  # per-dest budget

    tok_spec = P((*data_axes, ax), None)

    def run(wg, wu, wd, router, x2):
        idx_names = (*data_axes, ax)
        w, idx, aux = _local_route(router, x2, cfg)
        flat_e = idx.reshape(-1)                     # (N,)
        dest = flat_e // E_local
        # position of each row within its destination bucket
        onehot = dest[:, None] == jnp.arange(n_e)[None, :]
        pos = jnp.cumsum(onehot, axis=0) - 1
        pos_of = jnp.take_along_axis(pos, dest[:, None], axis=1)[:, 0]
        valid = pos_of < C
        slot = jnp.where(valid, pos_of, C)           # overflow -> dropped
        rows = jnp.repeat(x2, K, axis=0)
        send = jnp.zeros((n_e, C + 1, d), x2.dtype).at[dest, slot].set(rows)
        meta = jnp.full((n_e, C + 1), E_local, jnp.int32).at[dest, slot].set(
            flat_e % E_local)
        send, meta = send[:, :C], meta[:, :C]
        recv = jax.lax.all_to_all(send, ax, 0, 0, tiled=False)
        rmeta = jax.lax.all_to_all(meta, ax, 0, 0, tiled=False)
        rrows = recv.reshape(n_e * C, d)
        re = rmeta.reshape(n_e * C)                  # E_local == padding
        cap_e = max(1, int(-(-T_loc * K // E_local) * ctx.capacity_factor))
        out = _grouped_ffn(rrows, re, E_local, cap_e, wg, wu,
                           wd).reshape(n_e, C, d)
        back = jax.lax.all_to_all(out, ax, 0, 0, tiled=False)
        flat_back = back.reshape(n_e * C, d)
        gathered = flat_back[dest * C + jnp.minimum(pos_of, C - 1)]
        gathered = jnp.where(valid[:, None], gathered, 0.0)
        y = jnp.einsum("tkd,tk->td", gathered.reshape(T_loc, K, d), w)
        aux = jax.lax.pmean(aux, (*data_axes, ax))
        return y, aux

    shard = shard_map(
        run, mesh=mesh,
        in_specs=(P(ax, None, None), P(ax, None, None), P(ax, None, None),
                  P(None, None), tok_spec),
        out_specs=(tok_spec, P()),
        check_vma=False)
    y, aux = shard(params["w_gate"], params["w_up"], params["w_down"],
                   params["router"], x.reshape(T, d))
    return y.reshape(B, S, d), aux


def moe_block_tp_ff(params, x, cfg: ModelConfig, ctx):
    """Tensor parallelism over the expert hidden dim (few-expert MoE like
    mixtral where E < n_shards): rows stay put, every model shard computes
    its ff-slice for every row, partial results psum over the model axis."""
    from jax.sharding import PartitionSpec as P
    mesh = get_abstract_mesh()
    ax = ctx.expert_axis
    data_axes = tuple(a for a in ("pod",) + tuple(ctx.data_axes)
                      if a in mesh.shape)
    n_d = 1
    for a in data_axes:
        n_d *= mesh.shape[a]
    B, S, d = x.shape
    T = B * S
    K, E = cfg.experts_per_token, cfg.n_experts
    T_loc = T // n_d

    def run(wg, wu, wd, router, x2):
        w, idx, aux = _local_route(router, x2, cfg)
        flat_e = idx.reshape(-1)
        rows = jnp.repeat(x2, K, axis=0)
        cap = max(1, int(-(-T_loc * K // E) * ctx.capacity_factor))
        part = _grouped_ffn(rows, flat_e, E, cap, wg, wu, wd)
        out = jax.lax.psum(part, ax)                 # partial over ff slice
        y = jnp.einsum("tkd,tk->td", out.reshape(T_loc, K, d), w)
        aux = jax.lax.pmean(aux, (*data_axes, ax))
        return y, aux

    shard = shard_map(
        run, mesh=mesh,
        in_specs=(P(None, None, ax), P(None, None, ax), P(None, ax, None),
                  P(None, None), P(data_axes, None)),
        out_specs=(P(data_axes, None), P()),
        check_vma=False)
    y, aux = shard(params["w_gate"], params["w_up"], params["w_down"],
                   params["router"], x.reshape(T, d))
    return y.reshape(B, S, d), aux
