"""Model facade: one uniform interface over decoder-only and enc-dec archs.

    model = build_model(cfg)
    params = model.init(key)
    logits, aux = model.apply(params, batch)          # train / prefill
    cache = model.init_cache(batch_size, max_len)
    logits, cache = model.decode(params, cache, token, pos)

`batch` is a dict: {"tokens"} or {"embeds"} (frontend stubs), plus
{"src_embeds"} for enc-dec.  This is the surface the runtime, launcher and
dry-run all program against.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from repro.configs.base import ModelConfig
from . import encdec, transformer


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[..., Any]
    apply: Callable[..., Any]
    init_cache: Callable[..., Any]
    decode: Callable[..., Any]


def build_model(cfg: ModelConfig, impl: str = "auto",
                remat: bool = True) -> Model:
    if cfg.is_encdec:
        def init(key):
            return encdec.init_params(key, cfg)

        def apply(params, batch):
            return encdec.forward(params, batch["src_embeds"],
                                  batch["tokens"], cfg, impl, remat)

        def init_cache(batch_size, max_len, src_len=1024):
            return encdec.init_cache(cfg, batch_size, max_len, src_len)

        def decode(params, cache, token, pos):
            return encdec.decode_step(params, cache, token, pos, cfg)
    else:
        def init(key):
            return transformer.init_params(key, cfg)

        def apply(params, batch):
            inputs = batch.get("embeds", batch.get("tokens"))
            return transformer.forward(params, inputs, cfg, impl, remat)

        def init_cache(batch_size, max_len, src_len=None):
            return transformer.init_cache(cfg, batch_size, max_len)

        def decode(params, cache, token, pos):
            return transformer.decode_step(params, cache, token, pos, cfg)

    return Model(cfg, init, apply, init_cache, decode)


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def param_bytes(params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
