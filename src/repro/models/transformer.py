"""Decoder-only LM assembled from pattern units.

The repeating pattern unit (cfg.unit) is the `lax.scan` body; parameters
are stacked (n_units, ...) so a 61-layer MoE lowers as one unit body + a
scan — critical for CPU-host compile times in the 512-device dry-run and
the standard TPU practice anyway.

Hybrid (zamba2-style) models scan over super-units of `shared_attn_every`
mamba blocks followed by ONE shared attention+MLP block whose weights live
outside the scan and are reused by every application (the Zamba trick).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .attention import (attention, decode_attention, init_kv_cache,
                        attention_init)
from .layers import (embed, embedding_init, mlp, mlp_init, rmsnorm,
                     rmsnorm_init, unembed)
from .moe import moe_block, moe_init
from .ssm import decode_mamba, init_ssm_cache, mamba_block, mamba_init

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _block_init(key, spec, cfg: ModelConfig) -> Params:
    kn, kb = jax.random.split(key)
    p = {"norm": rmsnorm_init(cfg.d_model)}
    if spec.kind == "attn":
        p["attn"] = attention_init(kb, cfg)
    elif spec.kind == "mlp":
        p["mlp"] = mlp_init(kb, cfg.d_model, spec.d_ff or cfg.d_ff,
                            cfg.activation)
    elif spec.kind == "moe":
        p["moe"] = moe_init(kb, cfg)
    elif spec.kind == "mamba":
        p["mamba"] = mamba_init(kb, cfg)
    return p


def _stacked(key, n: int, init_fn) -> Params:
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def init_params(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 8)
    params: Params = {
        "embed": embedding_init(keys[0], cfg),
        "final_norm": rmsnorm_init(cfg.d_model),
    }
    if cfg.shared_attn_every:
        # hybrid: (U_outer, every) stacked mamba + one shared block
        u_outer = cfg.n_layers // cfg.shared_attn_every

        def unit_init(k):
            ks = jax.random.split(k, cfg.shared_attn_every)
            return jax.vmap(
                lambda kk: _block_init(kk, cfg.unit[0], cfg))(ks)

        params["units"] = _stacked(keys[1], u_outer, unit_init)
        params["shared"] = {
            "norm1": rmsnorm_init(cfg.d_model),
            "attn": attention_init(keys[2], cfg),
            "norm2": rmsnorm_init(cfg.d_model),
            "mlp": mlp_init(keys[3], cfg.d_model, cfg.d_ff, cfg.activation),
        }
    else:
        def unit_init(k):
            ks = jax.random.split(k, len(cfg.unit))
            return {f"b{j}": _block_init(ks[j], spec, cfg)
                    for j, spec in enumerate(cfg.unit)}

        params["units"] = _stacked(keys[1], cfg.n_units, unit_init)
    return params


# --------------------------------------------------------------------------
# full-sequence forward (training / prefill)
# --------------------------------------------------------------------------

def _apply_block(p: Params, spec, x, cfg: ModelConfig, positions, impl,
                 aux):
    from repro.runtime.parallel import shard_batch
    x = shard_batch(x)
    h = rmsnorm(p["norm"], x, cfg.norm_eps)
    if spec.kind == "attn":
        y = attention(p["attn"], h, cfg, positions, window=spec.window,
                      impl=impl)
    elif spec.kind == "mlp":
        y = mlp(p["mlp"], h, cfg.activation)
    elif spec.kind == "moe":
        y, a = moe_block(p["moe"], h, cfg)
        aux = aux + a
    elif spec.kind == "mamba":
        y = mamba_block(p["mamba"], h, cfg, impl=impl)
    return x + y, aux


def forward(params: Params, inputs: jnp.ndarray, cfg: ModelConfig,
            impl: str = "auto", remat: bool = True) -> Tuple[jnp.ndarray,
                                                             jnp.ndarray]:
    """inputs: (B, S) int tokens, or (B, S, d) embeddings for frontend
    stubs.  Returns (logits fp32 (B, S, V), aux_loss scalar)."""
    if inputs.ndim == 2:
        x = embed(params["embed"], inputs, cfg)
    else:
        x = inputs.astype(jnp.bfloat16)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)

    if cfg.shared_attn_every:
        shared = params["shared"]

        def unit_fn(x, unit_params):
            def inner(xc, mp):
                xc, _ = _apply_block(mp, cfg.unit[0], xc, cfg, positions,
                                     impl, 0.0)
                return xc, None
            x, _ = jax.lax.scan(inner, x, unit_params)
            h = rmsnorm(shared["norm1"], x, cfg.norm_eps)
            x = x + attention(shared["attn"], h, cfg, positions, impl=impl)
            h = rmsnorm(shared["norm2"], x, cfg.norm_eps)
            x = x + mlp(shared["mlp"], h, cfg.activation)
            return x, 0.0
    else:
        def unit_fn(x, unit_params):
            aux = 0.0
            for j, spec in enumerate(cfg.unit):
                x, aux = _apply_block(unit_params[f"b{j}"], spec, x, cfg,
                                      positions, impl, aux)
            return x, aux

    body = unit_fn
    if remat:
        body = jax.checkpoint(unit_fn,
                              policy=jax.checkpoint_policies.nothing_saveable)

    def scan_body(carry, unit_params):
        x, aux = carry
        x, a = body(x, unit_params)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(scan_body, (x, jnp.zeros((), jnp.float32)),
                               params["units"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return unembed(params["embed"], x, cfg), aux


# --------------------------------------------------------------------------
# decode: KV/SSM caches stacked over units, scanned
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    """Stacked per-unit caches (leading axis = scan axis)."""
    def one_block_cache(spec):
        if spec.kind == "attn":
            return init_kv_cache(cfg, batch, max_len, spec.window)
        if spec.kind == "mamba":
            return init_ssm_cache(cfg, batch)
        return None

    def stack(tree, n):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape),
                            tree)

    if cfg.shared_attn_every:
        u_outer = cfg.n_layers // cfg.shared_attn_every
        return {
            "units": stack(stack(one_block_cache(cfg.unit[0]),
                                 cfg.shared_attn_every), u_outer),
            "shared": stack(init_kv_cache(cfg, batch, max_len), u_outer),
        }
    cache = {}
    for j, spec in enumerate(cfg.unit):
        c = one_block_cache(spec)
        if c is not None:
            cache[f"b{j}"] = stack(c, cfg.n_units)
    return {"units": cache}


def _decode_block(p, spec, cache_b, x, cfg, pos):
    h = rmsnorm(p["norm"], x, cfg.norm_eps)
    if spec.kind == "attn":
        y, cache_b = decode_attention(p["attn"], h, cache_b, cfg, pos,
                                      window=spec.window)
    elif spec.kind == "mamba":
        y, cache_b = decode_mamba(p["mamba"], h, cache_b, cfg)
    elif spec.kind == "moe":
        y, _ = moe_block(p["moe"], h, cfg)
    else:
        y = mlp(p["mlp"], h, cfg.activation)
    return x + y, cache_b


def decode_step(params: Params, cache: Params, token: jnp.ndarray,
                pos: jnp.ndarray, cfg: ModelConfig
                ) -> Tuple[jnp.ndarray, Params]:
    """token: (B, 1) int32 (or (B, 1, d) embeddings); pos: scalar int32.
    Returns (logits (B, 1, V) fp32, new cache)."""
    if token.ndim == 2:
        x = embed(params["embed"], token, cfg)
    else:
        x = token.astype(jnp.bfloat16)

    if cfg.shared_attn_every:
        shared = params["shared"]

        def unit_fn(x, xs):
            unit_params, cache_u, shared_kv = xs

            def inner(xc, ys):
                mp, cb = ys
                xc, cb = _decode_block(mp, cfg.unit[0], cb, xc, cfg, pos)
                return xc, cb
            x, new_inner = jax.lax.scan(inner, x, (unit_params, cache_u))
            h = rmsnorm(shared["norm1"], x, cfg.norm_eps)
            y, shared_kv = decode_attention(shared["attn"], h, shared_kv,
                                            cfg, pos)
            x = x + y
            h = rmsnorm(shared["norm2"], x, cfg.norm_eps)
            x = x + mlp(shared["mlp"], h, cfg.activation)
            return x, (new_inner, shared_kv)

        x, (new_units, new_shared) = jax.lax.scan(
            unit_fn, x, (params["units"], cache["units"], cache["shared"]))
        new_cache = {"units": new_units, "shared": new_shared}
    else:
        def unit_fn(x, xs):
            unit_params, cache_u = xs
            new_cache_u = {}
            for j, spec in enumerate(cfg.unit):
                cb = cache_u.get(f"b{j}")
                x, cb = _decode_block(unit_params[f"b{j}"], spec, cb, x,
                                      cfg, pos)
                if f"b{j}" in cache_u:
                    new_cache_u[f"b{j}"] = cb
            return x, new_cache_u

        x, new_units = jax.lax.scan(unit_fn, x,
                                    (params["units"], cache["units"]))
        new_cache = {"units": new_units}

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return unembed(params["embed"], x, cfg), new_cache
