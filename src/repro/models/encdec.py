"""Encoder-decoder model (SeamlessM4T backbone).

Encoder: bidirectional self-attention + MLP over precomputed frame
embeddings (the speech frontend is a stub per the assignment — the
dry-run's `input_specs()` supplies (B, S_src, d) embeddings).
Decoder: causal self-attention + cross-attention + MLP, standard KV-cache
decode with the cross K/V computed once from the encoder output.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .attention import (attention, attention_init, decode_attention,
                        init_kv_cache, _project_qkv)
from .layers import (embed, embedding_init, mlp, mlp_init, rmsnorm,
                     rmsnorm_init, unembed)

Params = Dict[str, Any]


def init_params(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)

    def enc_unit(k):
        k1, k2, k3, k4 = jax.random.split(k, 4)
        return {"norm1": rmsnorm_init(cfg.d_model),
                "attn": attention_init(k1, cfg),
                "norm2": rmsnorm_init(cfg.d_model),
                "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.activation)}

    def dec_unit(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"norm1": rmsnorm_init(cfg.d_model),
                "self_attn": attention_init(k1, cfg),
                "norm_x": rmsnorm_init(cfg.d_model),
                "cross_attn": attention_init(k2, cfg),
                "norm2": rmsnorm_init(cfg.d_model),
                "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.activation)}

    return {
        "embed": embedding_init(ks[0], cfg),
        "enc_units": jax.vmap(enc_unit)(
            jax.random.split(ks[1], cfg.n_encoder_layers)),
        "dec_units": jax.vmap(dec_unit)(
            jax.random.split(ks[2], cfg.n_layers)),
        "enc_norm": rmsnorm_init(cfg.d_model),
        "final_norm": rmsnorm_init(cfg.d_model),
    }


def encode(params: Params, src_embeds: jnp.ndarray, cfg: ModelConfig,
           impl: str = "auto", remat: bool = True) -> jnp.ndarray:
    """src_embeds: (B, S_src, d) -> encoder states (B, S_src, d)."""
    x = src_embeds.astype(jnp.bfloat16)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)

    def unit(x, p):
        from repro.runtime.parallel import shard_batch
        x = shard_batch(x)
        h = rmsnorm(p["norm1"], x, cfg.norm_eps)
        # bidirectional self-attention (encoder is non-causal)
        y = attention(p["attn"], h, cfg, positions, impl=impl, causal=False)
        x = x + y
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        return x + mlp(p["mlp"], h, cfg.activation), None

    body = jax.checkpoint(unit) if remat else unit
    x, _ = jax.lax.scan(lambda c, p: body(c, p), x, params["enc_units"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def forward(params: Params, src_embeds: jnp.ndarray,
            dec_tokens: jnp.ndarray, cfg: ModelConfig,
            impl: str = "auto", remat: bool = True
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Teacher-forced training forward. Returns (logits, aux=0)."""
    enc = encode(params, src_embeds, cfg, impl, remat)
    from repro.runtime.parallel import shard_batch
    enc = shard_batch(enc)
    x = embed(params["embed"], dec_tokens, cfg)
    S = x.shape[1]
    S_src = enc.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    enc_pos = jnp.arange(S_src, dtype=jnp.int32)

    def unit(x, p):
        from repro.runtime.parallel import shard_batch
        x = shard_batch(x)
        h = rmsnorm(p["norm1"], x, cfg.norm_eps)
        x = x + attention(p["self_attn"], h, cfg, positions, impl=impl)
        h = rmsnorm(p["norm_x"], x, cfg.norm_eps)
        ck, cv = _rope_kv_cross(p["cross_attn"], enc, cfg)
        x = x + attention(p["cross_attn"], h, cfg, positions, impl=impl,
                          kv_override=(ck, cv, enc_pos), causal=False)
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        return x + mlp(p["mlp"], h, cfg.activation), None

    body = jax.checkpoint(unit) if remat else unit
    x, _ = jax.lax.scan(lambda c, p: body(c, p), x, params["dec_units"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return unembed(params["embed"], x, cfg), jnp.zeros((), jnp.float32)


def _rope_kv_cross(attn_params, enc, cfg):
    """Cross-attention keys/values from encoder states (no RoPE)."""
    _, k, v = _project_qkv(attn_params, enc, cfg)
    return k, v


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               src_len: int) -> Params:
    """Self-attention ring caches + cross K/V (filled by `prefill_cross`)."""
    def stack(tree, n):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape),
                            tree)
    self_kv = stack(init_kv_cache(cfg, batch, max_len), cfg.n_layers)
    cross_kv = stack(init_kv_cache(cfg, batch, src_len), cfg.n_layers)
    return {"self": self_kv, "cross": cross_kv}


def prefill_cross(params: Params, src_embeds: jnp.ndarray,
                  cfg: ModelConfig, cache: Params) -> Params:
    """Run the encoder once and store per-layer cross K/V."""
    enc = encode(params, src_embeds, cfg)

    def per_unit(p):
        k, v = _rope_kv_cross(p["cross_attn"], enc, cfg)
        return {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}

    cross = jax.vmap(per_unit)(params["dec_units"])
    return {"self": cache["self"], "cross": cross}


def decode_step(params: Params, cache: Params, token: jnp.ndarray,
                pos: jnp.ndarray, cfg: ModelConfig
                ) -> Tuple[jnp.ndarray, Params]:
    x = embed(params["embed"], token, cfg)

    def unit(x, xs):
        p, self_c, cross_c = xs
        h = rmsnorm(p["norm1"], x, cfg.norm_eps)
        y, self_c = decode_attention(p["self_attn"], h, self_c, cfg, pos)
        x = x + y
        h = rmsnorm(p["norm_x"], x, cfg.norm_eps)
        y, _ = decode_attention(p["cross_attn"], h, cross_c, cfg, pos,
                                cross=True)
        x = x + y
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        return x + mlp(p["mlp"], h, cfg.activation), self_c

    x, new_self = jax.lax.scan(
        unit, x, (params["dec_units"], cache["self"], cache["cross"]))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return unembed(params["embed"], x, cfg), {"self": new_self,
                                              "cross": cache["cross"]}
