"""Shared layer primitives: norms, RoPE, MLPs, embeddings.

Pure-functional: every layer is `f(params, x, ...) -> y` with params a
nested dict of jnp arrays.  Initialisers return the matching dict.
Compute dtype is bf16 with fp32 reductions (norm/softmax accumulate in
fp32), matching TPU mixed-precision practice.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, jnp.ndarray]
DTYPE = jnp.bfloat16


def _dense_init(key, shape, scale_axis=0):
    scale = 1.0 / jnp.sqrt(jnp.maximum(1, shape[scale_axis]))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(DTYPE)


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------

def rmsnorm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), DTYPE)}


def rmsnorm(params: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE (supports partial application — chatglm's "2d" rope rotates half)
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, fraction: float, theta: float,
                     positions: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables (..., rot_dim/2) for given positions (any shape)."""
    rot = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray,
               fraction: float = 1.0) -> jnp.ndarray:
    """x: (B, S, H, D); cos/sin: (B?, S, rot/2) broadcast over heads."""
    rot = cos.shape[-1] * 2
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    c = cos[..., None, :].astype(jnp.float32)
    s = sin[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    y1 = x1f * c - x2f * s
    y2 = x2f * c + x1f * s
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([yr, xp], axis=-1) if xp.shape[-1] else yr


# --------------------------------------------------------------------------
# MLP (SiLU-gated / GeGLU / plain GeLU)
# --------------------------------------------------------------------------

def mlp_init(key, d: int, d_ff: int, activation: str) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_up": _dense_init(k1, (d, d_ff)),
         "w_down": _dense_init(k2, (d_ff, d))}
    if activation in ("silu", "geglu"):
        p["w_gate"] = _dense_init(k3, (d, d_ff))
    return p


def mlp(params: Params, x: jnp.ndarray, activation: str) -> jnp.ndarray:
    up = jnp.einsum("...d,df->...f", x, params["w_up"])
    if activation in ("silu", "geglu"):
        gate = jnp.einsum("...d,df->...f", x, params["w_gate"])
        act = jax.nn.silu if activation == "silu" else jax.nn.gelu
        h = act(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


# --------------------------------------------------------------------------
# Embedding / unembedding
# --------------------------------------------------------------------------

def embedding_init(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    p = {"table": _dense_init(k1, (cfg.vocab_size, cfg.d_model), 1)}
    if not cfg.tie_embeddings:
        p["unembed"] = _dense_init(k2, (cfg.d_model, cfg.vocab_size))
    return p


def embed(params: Params, tokens: jnp.ndarray,
          cfg: ModelConfig) -> jnp.ndarray:
    x = params["table"][tokens]
    if cfg.tie_embeddings:
        # gemma-style embedding scaling keeps tied logits well-conditioned
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
    return x


def unembed(params: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, params["table"])
    else:
        logits = jnp.einsum("...d,dv->...v", x, params["unembed"])
    if cfg.final_softcap:
        cap = cfg.final_softcap
        logits = jnp.tanh(logits.astype(jnp.float32) / cap) * cap
        return logits
    return logits.astype(jnp.float32)
