"""Mamba2 (SSD — state-space duality) block, chunked-scan formulation.

Follows the SSD algorithm of Dao & Gu (arXiv:2405.21060): the sequence is
split into chunks of Q tokens; within a chunk the recurrence is evaluated
as a (masked) quadratic attention-like product, across chunks a linear
recurrence carries the (H, P, N) state.  This is exactly the structure the
Pallas kernel in repro.kernels/ssd tiles for VMEM; this module is the
lowerable-everywhere jnp implementation (and the kernel's oracle lives in
kernels/ssd/ref.py, mirroring this math).

Single B/C group (n_groups=1), which matches the assigned configs.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import _dense_init, rmsnorm, rmsnorm_init

Params = Dict[str, jnp.ndarray]


def mamba_init(key, cfg: ModelConfig) -> Params:
    d, dssm, H, N = cfg.d_model, cfg.d_inner, cfg.n_ssm_heads, cfg.ssm_state
    conv_dim = dssm + 2 * N
    k1, k2, k3 = jax.random.split(key, 3)
    # in_proj emits [z, x, B, C, dt]
    return {
        "in_proj": _dense_init(k1, (d, 2 * dssm + 2 * N + H)),
        "conv_w": _dense_init(k2, (cfg.d_conv, conv_dim), 0),
        "conv_b": jnp.zeros((conv_dim,), jnp.bfloat16),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "gate_norm": rmsnorm_init(dssm),
        "out_proj": _dense_init(k3, (dssm, d)),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jnp.ndarray):
    dssm, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    z, xBC, dt = jnp.split(zxbcdt, [dssm, 2 * dssm + 2 * N], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray
                 ) -> jnp.ndarray:
    """Depthwise causal conv1d. xBC: (B, L, C); w: (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(xBC.dtype)


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = sum_{j<k<=i} x[..., k]."""
    T = x.shape[-1]
    c = jnp.cumsum(x, axis=-1)
    seg = c[..., :, None] - c[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             B: jnp.ndarray, C: jnp.ndarray, chunk: int,
             init_state: jnp.ndarray | None = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD chunked scan.

    x: (b, L, H, P); dt: (b, L, H) (post-softplus); A: (H,) negative;
    B, C: (b, L, N) single group.  Returns (y (b,L,H,P), state (b,H,P,N)).
    """
    b, L, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q

    xc = x.reshape(b, nc, Q, H, P)
    dtc = dt.reshape(b, nc, Q, H)
    Bc = B.reshape(b, nc, Q, N)
    Cc = C.reshape(b, nc, Q, N)
    dA = dtc * A  # (b, nc, Q, H)
    dA_cum = jnp.cumsum(dA, axis=2)

    # intra-chunk (quadratic within the chunk)
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))       # (b,nc,H,Q,Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)          # (b,nc,Q,Q)
    gate = (scores[:, :, None] * Lmat).astype(x.dtype)      # (b,nc,H,Q,Q)
    xdt = (xc.astype(jnp.float32) * dtc[..., None]).astype(x.dtype)
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", gate, xdt)

    # chunk states
    decay_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)      # (b,nc,Q,H)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", Bc,
                        decay_end.astype(x.dtype) * dtc.astype(x.dtype), xc)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])              # (b,nc,H)

    def body(carry, xs):
        st_c, dec = xs
        new = carry * dec[:, :, None, None].astype(carry.dtype) + st_c
        return new, carry  # emit state BEFORE this chunk

    init = (jnp.zeros((b, H, P, N), jnp.float32) if init_state is None
            else init_state.astype(jnp.float32))
    final, prev_states = jax.lax.scan(
        body, init,
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)      # (b,nc,H,P,N)

    # inter-chunk output
    state_decay = jnp.exp(dA_cum)                            # (b,nc,Q,H)
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cc,
                       prev_states.astype(x.dtype),
                       state_decay.astype(x.dtype))
    y = (y_diag + y_off).reshape(b, L, H, P)
    return y, final.astype(x.dtype)


def mamba_block(params: Params, x: jnp.ndarray, cfg: ModelConfig,
                impl: str = "auto") -> jnp.ndarray:
    """Full-sequence Mamba2 block. x: (B, L, d) -> (B, L, d)."""
    B_, L, _ = x.shape
    dssm, N, H, P = (cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads,
                     cfg.ssm_head_dim)
    z, xBC, dt = _split_proj(cfg, jnp.einsum("bld,de->ble", x,
                                             params["in_proj"]))
    xBC = _causal_conv(xBC, params["conv_w"], params["conv_b"])
    xs, Bv, Cv = jnp.split(xBC, [dssm, dssm + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    xh = xs.reshape(B_, L, H, P)
    if impl == "pallas":
        from repro.kernels.ssd.ops import ssd
        y, _ = ssd(xh, dt, A, Bv, Cv, chunk=cfg.ssm_chunk)
    else:
        # pad L to a chunk multiple for the scan
        Q = min(cfg.ssm_chunk, max(16, L))
        pad = (-L) % Q
        if pad:
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            Bv = jnp.pad(Bv, ((0, 0), (0, pad), (0, 0)))
            Cv = jnp.pad(Cv, ((0, 0), (0, pad), (0, 0)))
        y, _ = ssd_scan(xh, dt, A, Bv, Cv, Q)
        y = y[:, :L]
    y = y + params["D"].astype(y.dtype)[:, None] * xs.reshape(B_, L, H, P)
    y = y.reshape(B_, L, dssm)
    y = rmsnorm(params["gate_norm"],
                y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                cfg.norm_eps)
    return jnp.einsum("ble,ed->bld", y, params["out_proj"])


# --------------------------------------------------------------------------
# decode: O(1) recurrent state per block
# --------------------------------------------------------------------------

def init_ssm_cache(cfg: ModelConfig, batch: int) -> Dict[str, jnp.ndarray]:
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, conv_dim), jnp.bfloat16),
        "state": jnp.zeros((batch, cfg.n_ssm_heads, cfg.ssm_head_dim,
                            cfg.ssm_state), jnp.bfloat16),
    }


def decode_mamba(params: Params, x: jnp.ndarray, cache: Dict,
                 cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    """Single-token step. x: (B, 1, d)."""
    B_ = x.shape[0]
    dssm, N, H, P = (cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads,
                     cfg.ssm_head_dim)
    z, xBC, dt = _split_proj(cfg, jnp.einsum("bld,de->ble", x,
                                             params["in_proj"]))
    xBC = xBC[:, 0]
    window = jnp.concatenate([cache["conv"], xBC[:, None]], axis=1)
    conv = (window * params["conv_w"]).sum(axis=1) + params["conv_b"]
    xBC = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    new_conv = window[:, 1:]
    xs, Bv, Cv = jnp.split(xBC, [dssm, dssm + N], axis=-1)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dtv * A)                                    # (B, H)
    xh = xs.reshape(B_, H, P)
    st = cache["state"].astype(jnp.float32)
    st = st * dA[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dtv, xh.astype(jnp.float32),
        Bv.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", st, Cv.astype(jnp.float32))
    y = y + params["D"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(B_, 1, dssm).astype(x.dtype)
    y = rmsnorm(params["gate_norm"],
                y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, params["out_proj"])
    return out, {"conv": new_conv.astype(jnp.bfloat16),
                 "state": st.astype(jnp.bfloat16)}
