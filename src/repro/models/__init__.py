from .model import Model, build_model, param_bytes, param_count

__all__ = ["Model", "build_model", "param_bytes", "param_count"]
