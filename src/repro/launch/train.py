"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 200 --batch 16 --seq 128 [--reduced] [--resume] \
        [--mesh host|pod|multipod] [--compress] [--microbatches 4]

Wires together everything the framework provides: mesh + sharding rules,
the ParallelContext (expert-parallel MoE, batch-pinned activations),
train_step under jit with state shardings, the step-indexed data
pipeline, async checkpointing, straggler tracking, and crash recovery
(restore-latest on failure).  On this CPU container use --reduced (the
default) and the host mesh; on a real pod the same flags select the
production meshes the dry-run proved out.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import (AsyncCheckpointer, latest_steps,
                                           restore)
from repro.configs import ARCHS, reduced
from repro.data.pipeline import DataConfig, batch_for_model
from repro.launch.mesh import (make_host_mesh, make_production_mesh,
                                   use_mesh)
from repro.obs.metrics import get_logger
from repro.units import MEGA
from repro.optim.optimizers import OptimizerConfig
from repro.runtime.compression import CompressionConfig
from repro.runtime.fault_tolerance import StragglerMitigator
from repro.runtime.parallel import ParallelContext, parallel_context
from repro.runtime.sharding import state_shardings
from repro.runtime.train import TrainConfig, make_train_step

log = get_logger("launch.train")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=list(ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="host",
                    choices=["host", "pod", "multipod"])
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--optimizer", default=None,
                    choices=[None, "adamw", "adafactor"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress", action="store_true",
                    help="int8 gradient compression on the DP all-reduce")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduced(cfg, vocab_size=min(cfg.vocab_size, 8192))
    opt_name = args.optimizer or (
        "adafactor" if cfg.param_count() > 100e9 else "adamw")
    tcfg = TrainConfig(
        optimizer=OptimizerConfig(name=opt_name, lr=args.lr,
                                  warmup_steps=max(1, args.steps // 20),
                                  total_steps=args.steps),
        microbatches=args.microbatches,
        compression=CompressionConfig() if args.compress else None,
        remat=not args.reduced)
    step_fn, init_fn = make_train_step(cfg, tcfg)

    mesh = (make_host_mesh() if args.mesh == "host"
            else make_production_mesh(multi_pod=args.mesh == "multipod"))
    log.info(f"arch={cfg.name} reduced={args.reduced} "
             f"params~{cfg.param_count() / MEGA:.1f}M opt={opt_name} "
             f"mesh={dict(mesh.shape)}",
             params_m=cfg.param_count() / MEGA)

    with use_mesh(mesh), parallel_context(ParallelContext()):
        abstract = jax.eval_shape(lambda: init_fn(jax.random.PRNGKey(0)))
        st_sh = state_shardings(mesh, abstract, opt_name)
        jit_init = jax.jit(init_fn, out_shardings=st_sh)
        jit_step = jax.jit(step_fn, donate_argnums=0,
                           in_shardings=(st_sh, None),
                           out_shardings=(st_sh, None))
        state = jit_init(jax.random.PRNGKey(0))

        ck = AsyncCheckpointer(args.ckpt_dir, keep=3)
        start = 0
        if args.resume and latest_steps(args.ckpt_dir):
            state = restore(args.ckpt_dir, state, shardings=st_sh)
            start = int(jax.device_get(state["step"]))
            log.info(f"resumed at step {start}", step=start)

        dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch,
                          vocab_size=cfg.vocab_size)
        straggler = StragglerMitigator()
        t_run = time.time()
        for s in range(start, args.steps):
            batch = {k: jnp.asarray(v)
                     for k, v in batch_for_model(cfg, dcfg, s).items()}
            t0 = time.time()
            try:
                state, metrics = jit_step(state, batch)
                metrics = jax.device_get(metrics)
            except Exception as e:  # noqa: BLE001 — crash recovery path
                log.error(f"step {s} failed ({e}); restoring latest "
                          "checkpoint", step=s)
                ck.wait()
                state = restore(args.ckpt_dir, abstract, shardings=st_sh)
                continue
            straggler.record(0, time.time() - t0)
            if s % args.log_every == 0 or s == args.steps - 1:
                tps = args.batch * args.seq / max(1e-9, time.time() - t0)
                ce = round(float(metrics["ce"]), 4)
                loss = round(float(metrics["loss"]), 4)
                log.info(f"step {s:5d} ce={ce:.4f} loss={loss:.4f} "
                         f"tok/s={tps:,.0f}", step=s, ce=ce, loss=loss)
            if s and s % args.ckpt_every == 0:
                ck.save_async(state, s)
            if straggler.stragglers():
                log.warning(
                    f"stragglers detected: {straggler.stragglers()}",
                    n_stragglers=len(straggler.stragglers()))
        ck.save_async(state, args.steps)
        ck.wait()
        log.info(f"finished {args.steps - start} steps in "
                 f"{time.time()-t_run:.1f}s; checkpoints: "
                 f"{latest_steps(args.ckpt_dir)}",
                 steps_run=args.steps - start, wall_s=time.time() - t_run)


if __name__ == "__main__":
    main()
