import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the abstract train/serve state (jax.eval_shape — no allocation),
  2. assigns shardings from runtime/sharding.py rules,
  3. jit(...).lower(**input_specs).compile() on the production mesh
     (16x16 single-pod / 2x16x16 multi-pod of host placeholder devices),
  4. records memory_analysis() + cost_analysis() + parsed collective bytes,
  5. lowers the single-unit programs and extrapolates the roofline
     (DESIGN.md S7),
and writes one JSON per cell under experiments/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
      --shape train_4k --mesh pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, cells, get_arch
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh, use_mesh
from repro.launch.roofline import cost_analysis
from repro.launch import roofline as RL
from repro.launch.unit_programs import (decode_unit_programs,
                                        train_unit_programs)
from repro.models import build_model
from repro.obs.metrics import get_logger
from repro.optim.optimizers import OptimizerConfig
from repro.runtime.sharding import (cache_shardings, logical_batch_shardings,
                                    params_shardings, state_shardings)
from repro.runtime.train import TrainConfig, make_train_step
from repro.runtime.parallel import ParallelContext, parallel_context
import contextlib

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

log = get_logger("launch.dryrun")


def optimizer_for(cfg: ModelConfig) -> OptimizerConfig:
    """Adafactor for >=100B params (kimi/mixtral would not fit AdamW state
    on the assigned meshes; DESIGN.md S6), AdamW otherwise."""
    big = cfg.param_count() > 100e9
    return OptimizerConfig(name="adafactor" if big else "adamw")


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.mode in ("train", "prefill"):
        batch = {}
        if cfg.is_encdec:
            batch["src_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                       jnp.bfloat16)
            batch["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        elif cfg.frontend == "embed":
            batch["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                   jnp.bfloat16)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        if shape.mode == "train":
            batch["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        return batch
    # decode: one new token against a seq_len cache
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(B, S, src_len=1024))
    return {"token": jax.ShapeDtypeStruct((B, 1), i32),
            "cache": cache,
            "pos": jax.ShapeDtypeStruct((), i32)}


def _mem_dict(ma) -> dict:
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    return {k: int(getattr(ma, k, 0)) for k in keys}


def lower_train_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
                     attention_impl: str = "auto",
                     sharding_overrides=None, train_overrides=None):
    tcfg = TrainConfig(optimizer=optimizer_for(cfg),
                       attention_impl=attention_impl,
                       **(train_overrides or {}))
    step_fn, init_fn = make_train_step(cfg, tcfg)
    abstract_state = jax.eval_shape(
        lambda: init_fn(jax.random.PRNGKey(0)))
    st_sh = state_shardings(mesh, abstract_state, tcfg.optimizer.name)
    if sharding_overrides:
        st_sh = sharding_overrides(mesh, abstract_state, st_sh)
    batch = input_specs(cfg, shape)
    b_sh = logical_batch_shardings(mesh, batch)
    with use_mesh(mesh):
        lowered = jax.jit(
            step_fn, in_shardings=(st_sh, b_sh),
            out_shardings=(st_sh, NamedSharding(mesh, P())),
        ).lower(abstract_state, batch)
        compiled = lowered.compile()
    return lowered, compiled, abstract_state


def lower_prefill_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
                       attention_impl: str = "auto"):
    """Serving prefill: full-sequence forward, last-position logits only."""
    model = build_model(cfg, impl=attention_impl, remat=True)
    batch = input_specs(cfg, shape)
    abstract_params = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0)))
    p_sh = params_shardings(mesh, abstract_params)
    b_sh = logical_batch_shardings(mesh, batch)

    def prefill(params, batch):
        logits, _ = model.apply(params, batch)
        return logits[:, -1]

    with use_mesh(mesh):
        lowered = jax.jit(prefill, in_shardings=(p_sh, b_sh)).lower(
            abstract_params, batch)
        compiled = lowered.compile()
    return lowered, compiled, abstract_params


def lower_decode_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
                      attention_impl: str = "auto"):
    model = build_model(cfg, impl=attention_impl, remat=False)
    specs = input_specs(cfg, shape)
    abstract_params = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0)))
    p_sh = params_shardings(mesh, abstract_params)
    c_sh = cache_shardings(mesh, specs["cache"])
    t_sh = logical_batch_shardings(mesh, {"t": specs["token"]})["t"]
    rep = NamedSharding(mesh, P())

    def serve_step(params, cache, token, pos):
        return model.decode(params, cache, token, pos)

    with use_mesh(mesh):
        lowered = jax.jit(
            serve_step,
            in_shardings=(p_sh, c_sh, t_sh, rep),
            out_shardings=(t_sh, c_sh),
        ).lower(abstract_params, specs["cache"], specs["token"],
                specs["pos"])
        compiled = lowered.compile()
    return lowered, compiled, abstract_params, specs


def lower_unit(fn, abstract_args, mesh):
    """Lower a unit program with rule-derived shardings for each arg."""
    from repro.runtime.sharding import batch_spec, cache_spec, param_spec

    def shard_tree(tree):
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        out = []
        for kp, x in flat:
            name = "/".join(str(getattr(k, "key", k)) for k in kp)
            if x.dtype == jnp.bfloat16 and x.ndim == 3 and not name:
                spec = batch_spec(mesh, x.shape)
            elif "k" == name.split("/")[-1] or "v" == name.split("/")[-1] \
                    or "conv" in name or "state" in name:
                spec = cache_spec(mesh, x.shape)
            else:
                spec = param_spec(mesh, name, x.shape)
            out.append(NamedSharding(mesh, spec))
        return jax.tree_util.tree_unflatten(treedef, out)

    shardings = tuple(
        shard_tree(a) if isinstance(a, dict)
        else NamedSharding(mesh, batch_spec(mesh, a.shape))
        if getattr(a, "ndim", 0) >= 2
        else NamedSharding(mesh, P())
        for a in abstract_args)
    with use_mesh(mesh):
        lowered = jax.jit(fn, in_shardings=shardings).lower(*abstract_args)
        return lowered.compile()


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             attention_impl: str = "auto", with_roofline: bool = True,
             out_dir: str = OUT_DIR, train_overrides=None,
             tag: str = "", moe_parallel: bool = True) -> dict:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    n_chips = mesh.devices.size
    t0 = time.time()
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "chips": int(n_chips), "mode": shape.mode,
              "moe_parallel": moe_parallel}
    pctx = parallel_context(ParallelContext()) if moe_parallel \
        else contextlib.nullcontext()
    try:
      with pctx:
          if shape.mode == "decode":
              lowered, compiled, abs_params, specs = lower_decode_cell(
                  cfg, shape, mesh, attention_impl)
          elif shape.mode == "prefill":
              lowered, compiled, _ = lower_prefill_cell(
                  cfg, shape, mesh, attention_impl)
          else:
              lowered, compiled, abstract_state = lower_train_cell(
                  cfg, shape, mesh, attention_impl,
                  train_overrides=train_overrides)
          result["memory"] = _mem_dict(compiled.memory_analysis())
          ca = cost_analysis(compiled)
          result["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                     if isinstance(v, (int, float))}

          if with_roofline:
              units = []
              if shape.mode == "decode":
                  progs = decode_unit_programs(cfg, abs_params,
                                               specs["cache"],
                                               shape.global_batch)
              elif shape.mode == "train":
                  progs = train_unit_programs(cfg, abstract_state,
                                              shape.global_batch,
                                              shape.seq_len, attention_impl)
              else:  # prefill: forward-only units
                  model = build_model(cfg, impl=attention_impl)
                  abs_params = jax.eval_shape(
                      lambda: model.init(jax.random.PRNGKey(0)))
                  progs = train_unit_programs(
                      cfg, {"params": abs_params}, shape.global_batch,
                      shape.seq_len, attention_impl, grad=False)
              rl = RL.extract(compiled)
              per_unit = []
              for name, fn, args, k in progs:
                  uc = lower_unit(fn, args, mesh)
                  u = RL.extract(uc)
                  per_unit.append({"name": name, "k": k, **u.as_dict()})
                  rl = RL.Roofline(
                      rl.flops + k * u.flops,
                      rl.hbm_bytes + k * u.hbm_bytes,
                      rl.coll_link_bytes + k * u.coll_link_bytes,
                      {**rl.coll_per_op,
                       **{o: rl.coll_per_op.get(o, 0.0) + k * v
                          for o, v in u.coll_per_op.items()}})
              tokens = shape.global_batch * (shape.seq_len
                                             if shape.mode != "decode" else 1)
              mf = RL.model_flops(cfg.param_count(), cfg.active_param_count(),
                                  tokens, shape.mode)
              result["roofline"] = rl.as_dict()
              result["roofline"]["units"] = per_unit
              result["roofline"]["model_flops_global"] = mf
              result["roofline"]["model_flops_per_chip"] = mf / n_chips
              result["roofline"]["useful_ratio"] = (
                  mf / n_chips / rl.flops if rl.flops else 0.0)
          result["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-2000:]
    result["seconds"] = round(time.time() - t0, 1)
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    fn = os.path.join(out_dir,
                      f"{arch}__{shape_name}__{mesh_kind}{suffix}.json")
    with open(fn, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod",
                                                      "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--attention-impl", default="auto")
    ap.add_argument("--no-roofline", action="store_true")
    ap.add_argument("--skip-existing", action="store_true",
                    help="resume: skip cells whose JSON already exists ok")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    targets = []
    archs = list(ARCHS) if (args.all or args.arch is None) else [args.arch]
    for a in archs:
        for s in cells(a):
            if args.shape and s.name != args.shape:
                continue
            targets.append((a, s.name))
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for a, s in targets:
        for mk in meshes:
            fn = os.path.join(args.out, f"{a}__{s}__{mk}.json")
            if args.skip_existing and os.path.exists(fn):
                try:
                    if json.load(open(fn)).get("status") == "ok":
                        log.info(f"{a:22s} {s:12s} {mk:8s} skip (exists)")
                        continue
                except Exception:
                    pass
            r = run_cell(a, s, mk, args.attention_impl,
                         not args.no_roofline, args.out)
            dom = r.get("roofline", {}).get("dominant", "-")
            mem = r.get("memory", {}).get("argument_size_in_bytes", 0)
            log.info(f"{a:22s} {s:12s} {mk:8s} {r['status']:5s} "
                     f"args/dev={mem/2**30:7.2f}GiB dominant={dom:10s} "
                     f"{r['seconds']:6.1f}s",
                     seconds=r["seconds"])
            if r["status"] != "ok":
                failures += 1
                log.error(r["error"])
    log.info(f"done: {len(targets) * len(meshes) - failures} ok, "
             f"{failures} failed", failures=failures)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
