"""Roofline extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, per DESIGN.md S7:

    t_compute = FLOPs_per_device / PEAK_FLOPS
    t_memory  = bytes_per_device / HBM_BW
    t_coll    = collective_bytes_per_device / (ICI_LINKS * ICI_BW)

`cost_analysis()` on this jax/XLA reports per-device cost and counts a
while (scan) body ONCE (verified in tests/test_roofline.py), so callers
pass the full program's cost plus a single-unit program's cost and we
extrapolate: total = full + (n_units - 1) * unit.

Collective bytes are parsed from the compiled HLO text: every line defines
`%name = TYPE op(...)`, so a name->bytes map recovers operand sizes, and
per-op ring-transfer multipliers convert payloads into link bytes.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict

# TPU v5e constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link
ICI_LINKS = 4                # usable links per chip in a 2D torus slice

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.-]+)\s*=\s*(.*?)\s*"
                     r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                     r"collective-permute|all-reduce-start|all-gather-start|"
                     r"collective-permute-start)\(", re.M)
_ANYDEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.-]+)\s*=\s*([^\s]+(?:\s*,\s*[^\s)]+)*?)\s+[\w-]+\(", re.M)

# link bytes per payload byte for a ring schedule over n shards (n large)
_RING_FACTOR = {
    "all-reduce": 2.0, "all-reduce-start": 2.0,
    "all-gather": 1.0, "all-gather-start": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0, "collective-permute-start": 1.0,
}


def _type_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    per_op: Dict[str, float]
    payload_bytes: float          # sum of payloads
    link_bytes: float             # ring-multiplied

    def __add__(self, o: "CollectiveStats") -> "CollectiveStats":
        per = dict(self.per_op)
        for k, v in o.per_op.items():
            per[k] = per.get(k, 0.0) + v
        return CollectiveStats(per, self.payload_bytes + o.payload_bytes,
                               self.link_bytes + o.link_bytes)

    @staticmethod
    def zero() -> "CollectiveStats":
        return CollectiveStats({}, 0.0, 0.0)


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Parse collective payload bytes out of compiled (or lowered) HLO."""
    per_op: Dict[str, float] = {}
    payload = 0.0
    link = 0.0
    for m in _DEF_RE.finditer(hlo_text):
        _, type_str, op = m.groups()
        b = _type_bytes(type_str)
        if op.startswith("all-gather"):
            pass  # result is the gathered buffer: the payload
        per_op[op] = per_op.get(op, 0.0) + b
        payload += b
        link += b * _RING_FACTOR.get(op, 1.0)
    return CollectiveStats(per_op, payload, link)


@dataclasses.dataclass
class Roofline:
    flops: float                  # per device
    hbm_bytes: float              # per device
    coll_link_bytes: float        # per device
    coll_per_op: Dict[str, float]

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_link_bytes / (ICI_LINKS * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """No-overlap upper bound; with perfect overlap it is the max."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_link_bytes": self.coll_link_bytes,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "dominant": self.dominant,
            "coll_per_op": self.coll_per_op,
        }


def cost_analysis(compiled) -> Dict:
    """`Compiled.cost_analysis()` normalized across jax versions.

    Older jax returns a list with one per-device dict, newer jax the
    dict itself; either may be empty/None for trivial programs.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def extract(compiled, n_units: int = 1,
            unit_compiled=None) -> Roofline:
    """Roofline terms from compiled artifacts with scan-body extrapolation:
    total = full + (n_units - 1) * unit."""
    ca = cost_analysis(compiled)
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    if unit_compiled is not None and n_units > 1:
        uca = cost_analysis(unit_compiled)
        ucoll = collective_bytes(unit_compiled.as_text())
        k = n_units - 1
        flops += k * float(uca.get("flops", 0.0))
        byts += k * float(uca.get("bytes accessed", 0.0))
        coll = coll + CollectiveStats(
            {o: k * v for o, v in ucoll.per_op.items()},
            k * ucoll.payload_bytes, k * ucoll.link_bytes)
    return Roofline(flops, byts, coll.link_bytes, coll.per_op)


def model_flops(param_count: int, active_param_count: int, tokens: int,
                mode: str) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params."""
    mult = 6.0 if mode == "train" else 2.0
    return mult * active_param_count * tokens
