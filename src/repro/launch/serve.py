"""Serving launcher: batched decode with continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --requests 16 --slots 4 --max-new 16
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.launch.mesh import (make_host_mesh, make_production_mesh,
                                   use_mesh)
from repro.models import build_model
from repro.obs.metrics import get_logger
from repro.runtime.parallel import ParallelContext, parallel_context
from repro.runtime.serve import ServeConfig, make_serve_fns

log = get_logger("launch.serve")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=list(ARCHS))
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--mesh", default="host",
                    choices=["host", "pod", "multipod"])
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduced(cfg, vocab_size=min(cfg.vocab_size, 4096))
    mesh = (make_host_mesh() if args.mesh == "host"
            else make_production_mesh(multi_pod=args.mesh == "multipod"))
    scfg = ServeConfig(max_len=args.max_len)

    with use_mesh(mesh), parallel_context(ParallelContext()):
        model = build_model(cfg, remat=False)
        params = model.init(jax.random.PRNGKey(0))
        _, decode_step, init_cache = make_serve_fns(cfg, scfg)
        dec = jax.jit(decode_step)

        rng = np.random.default_rng(0)
        queue = [list(rng.integers(1, cfg.vocab_size,
                                   size=int(rng.integers(2, 6))))
                 for _ in range(args.requests)]
        cache = init_cache(args.slots, args.max_len)
        active = [None] * args.slots
        results = {}
        served = 0
        pos = 0
        t0 = time.time()
        steps = 0
        while (queue or any(active)) and pos < args.max_len - 1:
            for s in range(args.slots):
                if active[s] is None and queue:
                    active[s] = [served, queue.pop(0), []]
                    served += 1
            feed = np.zeros((args.slots, 1), np.int32)
            for s, a in enumerate(active):
                if a is None:
                    continue
                _, prompt, out = a
                feed[s, 0] = prompt.pop(0) if prompt else out[-1]
            nxt, _, cache = dec(params, cache, jnp.asarray(feed),
                                jnp.int32(pos))
            nxt = np.asarray(nxt)
            steps += 1
            for s, a in enumerate(active):
                if a is None:
                    continue
                rid, prompt, out = a
                if not prompt:
                    out.append(int(nxt[s, 0]))
                    if len(out) >= args.max_new:
                        results[rid] = out
                        active[s] = None
            pos += 1
        dt = time.time() - t0
        log.info(f"served {len(results)}/{args.requests} requests, "
                 f"{steps} decode steps x {args.slots} slots in {dt:.1f}s "
                 f"({steps*args.slots/dt:.1f} tok/s)",
                 served=len(results), steps=steps, wall_s=dt,
                 tok_per_s=steps * args.slots / dt)


if __name__ == "__main__":
    main()
