"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets XLA_FLAGS before first init.
"""

from __future__ import annotations

import jax


def make_auto_mesh(shape, axes):
    """`jax.make_mesh` with Auto axis types across jax versions.

    Newer jax exposes `jax.sharding.AxisType` and `make_mesh` takes
    `axis_types`; on older versions (<= 0.4.x) every axis is Auto by
    default and the parameter does not exist.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def use_mesh(mesh):
    """`jax.set_mesh(mesh)` across jax versions.

    Older jax (<= 0.4.x) has no `jax.set_mesh`; there the `Mesh` object
    itself is the context manager that installs the ambient mesh.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def get_abstract_mesh():
    """`jax.sharding.get_abstract_mesh()` across jax versions.

    Older jax has no abstract-mesh tracking; there the ambient mesh
    installed by the `Mesh` context manager is the equivalent.  Both
    expose `.shape` as an axis-name -> size mapping (empty when no mesh
    is active), which is all callers rely on.
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        return fn()
    from jax._src import mesh as mesh_lib
    return mesh_lib.thread_resources.env.physical_mesh


def shard_map(f, **kwargs):
    """`jax.shard_map` across jax versions.

    On 0.4.x it lives in `jax.experimental.shard_map` and the replication
    check is spelled `check_rep` instead of `check_vma`.
    """
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
    return fn(f, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (one v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the 'pod' axis
    carries cross-pod data parallelism over DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host offers (tests / examples on CPU)."""
    n = len(jax.devices())
    return make_auto_mesh((1, n), ("data", "model"))
