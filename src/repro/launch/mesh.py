"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets XLA_FLAGS before first init.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (one v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the 'pod' axis
    carries cross-pod data parallelism over DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Whatever this host offers (tests / examples on CPU)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
