"""Single-unit programs for the roofline's scan-body extrapolation.

`cost_analysis()` counts a scan body once (DESIGN.md S7), so per cell we
also lower the pattern unit alone — same shardings, same remat policy —
and extrapolate  total = full + sum_i multiplier_i * unit_i.

Multipliers per family:
- uniform decoder (dense/moe/ssm/vlm): (n_units - 1) x unit
- hybrid (zamba2): the outer scan body holds an inner scan (counted once)
  plus the shared block => (n_mamba_layers - 1) x mamba_unit and
  (n_super_units - 1) x shared_block
- enc-dec: (n_enc - 1) x enc_unit + (n_dec - 1) x dec_unit
"""

from __future__ import annotations

from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.models.attention import attention, decode_attention
from repro.models.layers import mlp, rmsnorm
from repro.models.ssm import decode_mamba, mamba_block

UnitProgram = Tuple[str, Callable, Tuple, int]  # (name, fn, abstract_args, k)


def _abs_slice(tree, axes: int = 1):
    """Strip `axes` leading stacked dims from an abstract tree."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape[axes:], s.dtype), tree)


def _x_abs(cfg: ModelConfig, batch: int, seq: int):
    return jax.ShapeDtypeStruct((batch, seq, cfg.d_model), jnp.bfloat16)


def _apply_unit(cfg: ModelConfig, unit_params, x, positions, impl):
    aux = jnp.zeros((), jnp.float32)
    for j, spec in enumerate(cfg.unit):
        x, aux = T._apply_block(unit_params[f"b{j}"], spec, x, cfg,
                                positions, impl, aux)
    return x, aux


def _train_wrap(fn):
    """grad-of-checkpointed-unit: matches the full program's remat'd scan
    body (fwd + replayed fwd + bwd)."""
    ck = jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)

    def loss(params, x, *rest):
        y, aux = ck(params, x, *rest)
        return (y.astype(jnp.float32).sum() + aux).astype(jnp.float32)

    return jax.grad(loss, argnums=(0, 1))


def _fwd_wrap(fn):
    def f(params, x, *rest):
        y, aux = fn(params, x, *rest)
        return y
    return f


def train_unit_programs(cfg: ModelConfig, abstract_state, batch: int,
                        seq: int, impl: str,
                        grad: bool = True) -> List[UnitProgram]:
    wrap = _train_wrap if grad else _fwd_wrap
    params = abstract_state["params"]
    positions = jnp.arange(seq, dtype=jnp.int32)
    x = _x_abs(cfg, batch, seq)
    out: List[UnitProgram] = []

    if cfg.is_encdec:
        enc_u = _abs_slice(params["enc_units"])
        dec_u = _abs_slice(params["dec_units"])

        def enc_fn(p, xx):
            h = rmsnorm(p["norm1"], xx, cfg.norm_eps)
            xx = xx + attention(p["attn"], h, cfg, positions, impl=impl,
                                causal=False)
            h = rmsnorm(p["norm2"], xx, cfg.norm_eps)
            return xx + mlp(p["mlp"], h, cfg.activation), 0.0

        enc_abs = _x_abs(cfg, batch, seq)

        def dec_fn(p, xx, enc):
            h = rmsnorm(p["norm1"], xx, cfg.norm_eps)
            xx = xx + attention(p["self_attn"], h, cfg, positions, impl=impl)
            h = rmsnorm(p["norm_x"], xx, cfg.norm_eps)
            from repro.models.encdec import _rope_kv_cross
            ck_, cv = _rope_kv_cross(p["cross_attn"], enc, cfg)
            xx = xx + attention(p["cross_attn"], h, cfg, positions,
                                impl=impl, causal=False,
                                kv_override=(ck_, cv, positions))
            h = rmsnorm(p["norm2"], xx, cfg.norm_eps)
            return xx + mlp(p["mlp"], h, cfg.activation), 0.0

        out.append(("enc_unit", wrap(enc_fn), (enc_u, x),
                    cfg.n_encoder_layers - 1))
        out.append(("dec_unit", wrap(dec_fn), (dec_u, x, enc_abs),
                    cfg.n_layers - 1))
        return out

    if cfg.shared_attn_every:
        mamba_u = _abs_slice(params["units"], axes=2)
        shared = params["shared"]

        def mamba_fn(p, xx):
            h = rmsnorm(p["norm"], xx, cfg.norm_eps)
            return xx + mamba_block(p["mamba"], h, cfg, impl=impl), 0.0

        def shared_fn(p, xx):
            h = rmsnorm(p["norm1"], xx, cfg.norm_eps)
            xx = xx + attention(p["attn"], h, cfg, positions, impl=impl)
            h = rmsnorm(p["norm2"], xx, cfg.norm_eps)
            return xx + mlp(p["mlp"], h, cfg.activation), 0.0

        n_super = cfg.n_layers // cfg.shared_attn_every
        out.append(("mamba_unit", wrap(mamba_fn), (mamba_u, x),
                    cfg.n_layers - 1))
        out.append(("shared_unit", wrap(shared_fn), (shared, x),
                    n_super - 1))
        return out

    unit = _abs_slice(params["units"])

    def unit_fn(p, xx):
        return _apply_unit(cfg, p, xx, positions, impl)

    out.append(("unit", wrap(unit_fn), (unit, x), cfg.n_units - 1))
    return out


def decode_unit_programs(cfg: ModelConfig, abstract_params, abstract_cache,
                         batch: int) -> List[UnitProgram]:
    params = abstract_params
    x = _x_abs(cfg, batch, 1)
    pos = jnp.int32(7)
    out: List[UnitProgram] = []

    if cfg.is_encdec:
        dec_u = _abs_slice(params["dec_units"])
        self_c = _abs_slice(abstract_cache["self"])
        cross_c = _abs_slice(abstract_cache["cross"])

        def dec_fn(p, sc, cc, xx):
            h = rmsnorm(p["norm1"], xx, cfg.norm_eps)
            y, sc = decode_attention(p["self_attn"], h, sc, cfg, pos)
            xx = xx + y
            h = rmsnorm(p["norm_x"], xx, cfg.norm_eps)
            y, _ = decode_attention(p["cross_attn"], h, cc, cfg, pos,
                                    cross=True)
            xx = xx + y
            h = rmsnorm(p["norm2"], xx, cfg.norm_eps)
            return xx + mlp(p["mlp"], h, cfg.activation), sc

        out.append(("dec_unit", dec_fn, (dec_u, self_c, cross_c, x),
                    cfg.n_layers - 1))
        return out

    if cfg.shared_attn_every:
        mamba_u = _abs_slice(params["units"], axes=2)
        mamba_c = _abs_slice(abstract_cache["units"], axes=2)
        shared_c = _abs_slice(abstract_cache["shared"])

        def mamba_fn(p, c, xx):
            h = rmsnorm(p["norm"], xx, cfg.norm_eps)
            y, c = decode_mamba(p["mamba"], h, c, cfg)
            return xx + y, c

        def shared_fn(p, c, xx):
            h = rmsnorm(p["norm1"], xx, cfg.norm_eps)
            y, c = decode_attention(p["attn"], h, c, cfg, pos)
            xx = xx + y
            h = rmsnorm(p["norm2"], xx, cfg.norm_eps)
            return xx + mlp(p["mlp"], h, cfg.activation), c

        n_super = cfg.n_layers // cfg.shared_attn_every
        out.append(("mamba_unit", mamba_fn, (mamba_u, mamba_c, x),
                    cfg.n_layers - 1))
        out.append(("shared_unit", shared_fn,
                    (params["shared"], shared_c, x), n_super - 1))
        return out

    unit = _abs_slice(params["units"])
    cache_u = _abs_slice(abstract_cache["units"])

    def unit_fn(p, c, xx):
        new_c = {}
        for j, spec in enumerate(cfg.unit):
            cb = c.get(f"b{j}")
            xx, cb = T._decode_block(p[f"b{j}"], spec, cb, xx, cfg, pos)
            if f"b{j}" in c:
                new_c[f"b{j}"] = cb
        return xx, new_c

    out.append(("unit", unit_fn, (unit, cache_u, x), cfg.n_units - 1))
    return out
