"""Sharded checkpointing with integrity manifest + elastic restore.

Layout:  <dir>/step_<N>/
            manifest.json      -- tree structure, shapes, dtypes, hashes
            shard_<i>.npz      -- leaf arrays, chunked ~256 MB per file

Properties the runtime depends on:
- **atomic**: written to a temp dir, fsync'd, then renamed — a crash
  mid-write never corrupts the latest checkpoint;
- **async**: `save_async` hands the host copy to a writer thread so the
  train loop's bubble is one device->host transfer;
- **integrity**: every shard carries a sha256; restore verifies before
  handing tensors to jax;
- **elastic restore**: arrays are loaded host-side and re-placed under the
  *current* mesh's shardings (`restore(..., shardings=...)`), so a job can
  come back on a different pod count (checkpoint written on 512 chips,
  restored on 256) — resharding is a jax.device_put with new shardings;
- the data pipeline is stateless/step-indexed, so {state, step} is the
  complete restart state.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np

SHARD_BYTES = 256 * 2**20


def _flatten(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in kp) for kp, _ in flat]
    return names, [x for _, x in flat], treedef


def save(path: str, tree: Any, step: int) -> str:
    """Synchronous atomic save. Returns the final checkpoint dir."""
    names, leaves, _ = _flatten(tree)
    final = os.path.join(path, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=path, prefix=".tmp_ckpt_")
    manifest: Dict[str, Any] = {"step": step, "leaves": [], "shards": []}
    shard: Dict[str, np.ndarray] = {}
    shard_bytes, shard_idx = 0, 0

    def flush():
        nonlocal shard, shard_bytes, shard_idx
        if not shard:
            return
        fn = f"shard_{shard_idx:05d}.npz"
        fp = os.path.join(tmp, fn)
        np.savez(fp, **shard)
        with open(fp, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest["shards"].append({"file": fn, "sha256": digest})
        shard, shard_bytes = {}, 0
        shard_idx += 1

    for name, leaf in zip(names, leaves):
        arr = np.asarray(jax.device_get(leaf))
        key = name.replace("/", "__")
        manifest["leaves"].append({
            "name": name, "shard": shard_idx, "key": key,
            "shape": list(arr.shape), "dtype": str(arr.dtype)})
        if arr.dtype.name == "bfloat16":
            arr = arr.view(np.uint16)  # npz-safe; dtype kept in manifest
        shard[key] = arr
        shard_bytes += arr.nbytes
        if shard_bytes >= SHARD_BYTES:
            flush()
    flush()
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


class AsyncCheckpointer:
    """Background-thread writer: save_async returns immediately after the
    device->host copy; wait() joins the in-flight write."""

    def __init__(self, path: str, keep: int = 3):
        self.path = path
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(path, exist_ok=True)

    def save_async(self, tree: Any, step: int) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def run():
            save(self.path, host_tree, step)
            self._gc()

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(latest_steps(self.path))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"),
                          ignore_errors=True)


def latest_steps(path: str) -> List[int]:
    if not os.path.isdir(path):
        return []
    out = []
    for d in os.listdir(path):
        if d.startswith("step_") and os.path.isfile(
                os.path.join(path, d, "manifest.json")):
            out.append(int(d.split("_")[1]))
    return sorted(out)


def restore(path: str, like: Any, step: Optional[int] = None,
            shardings: Any = None, verify: bool = True) -> Any:
    """Restore into the structure of `like`; re-place under `shardings`
    (elastic restart on a different mesh)."""
    steps = latest_steps(path)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {path}")
    step = steps[-1] if step is None else step
    cdir = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(cdir, "manifest.json")) as f:
        manifest = json.load(f)
    if verify:
        for sh in manifest["shards"]:
            with open(os.path.join(cdir, sh["file"]), "rb") as f:
                if hashlib.sha256(f.read()).hexdigest() != sh["sha256"]:
                    raise IOError(f"checkpoint shard corrupt: {sh['file']}")
    shards = {}
    by_name = {}
    for leaf in manifest["leaves"]:
        si = leaf["shard"]
        if si not in shards:
            shards[si] = np.load(os.path.join(
                cdir, manifest["shards"][si]["file"]))
        arr = shards[si][leaf["key"]]
        if leaf["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        by_name[leaf["name"]] = arr
    names, leaves, treedef = _flatten(like)
    arrays = [by_name[n] for n in names]
    if shardings is not None:
        sl = treedef.flatten_up_to(shardings)
        arrays = [jax.device_put(a, s) for a, s in zip(arrays, sl)]
    return treedef.unflatten(arrays)
