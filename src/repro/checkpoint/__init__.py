from .checkpointer import AsyncCheckpointer, latest_steps, restore, save
