"""`python -m repro.lint` — the analyzer's command line.

Exit status is the CI contract: 0 when no *new* findings (after inline
suppressions and the baseline file), 1 otherwise.  ``--format=github``
emits workflow-command annotations so findings land inline on the PR
diff.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import Optional, Sequence

from . import ALL_RULES, FAMILIES
from .base import (LintReport, iter_py_files, load_baseline, run_rules,
                   write_baseline)

DEFAULT_BASELINE = "lint_baseline.txt"


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="Domain-aware static analysis (units, determinism, "
                    "trace hygiene, config hygiene).")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files/directories to scan (default: src)")
    ap.add_argument("--format", choices=("text", "github"),
                    default="text", help="finding output format")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline file of accepted fingerprints "
                         f"(default: {DEFAULT_BASELINE} when present)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings to the baseline file "
                         "and exit 0")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule or family names to run "
                         "(default: all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="list rules and exit")
    return ap


def select_rules(spec: Optional[str]):
    if not spec:
        return ALL_RULES
    wanted = {s.strip() for s in spec.split(",") if s.strip()}
    unknown = wanted - {r.name for r in ALL_RULES} - set(FAMILIES)
    if unknown:
        raise SystemExit(f"unknown rule/family: {', '.join(sorted(unknown))}")
    return tuple(r for r in ALL_RULES
                 if r.name in wanted or r.family in wanted)


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        for rule in ALL_RULES:
            print(f"{rule.name:28s} [{rule.family}] {rule.description}")
        return 0
    rules = select_rules(args.select)
    paths = [Path(p) for p in args.paths]
    missing = [p for p in paths if not p.exists()]
    if missing:
        print(f"error: no such path: "
              f"{', '.join(str(p) for p in missing)}", file=sys.stderr)
        return 2
    baseline_path = Path(args.baseline) if args.baseline else \
        Path(DEFAULT_BASELINE)
    baseline = load_baseline(baseline_path) \
        if (args.baseline or baseline_path.is_file()) else set()
    report = run_rules(rules, iter_py_files(paths),
                       baseline=set() if args.write_baseline else baseline,
                       search_roots=[p if p.is_dir() else p.parent
                                     for p in paths])
    if args.write_baseline:
        write_baseline(baseline_path, report.findings)
        print(f"wrote {len(report.findings)} fingerprint(s) to "
              f"{baseline_path}")
        return 0
    render = (lambda f: f.render_github()) if args.format == "github" \
        else (lambda f: f.render_text())
    for f in report.findings:
        print(render(f))
    summary = (f"repro.lint: {len(report.findings)} finding(s) in "
               f"{report.files_scanned} file(s)"
               f" ({report.suppressed} suppressed,"
               f" {report.baselined} baselined)")
    print(summary, file=sys.stderr)
    return 1 if report.findings else 0


if __name__ == "__main__":          # pragma: no cover
    sys.exit(main())
