"""`repro.lint`: domain-aware static analysis for the repo's modelling
planes.

Four rule families, each encoding an invariant the test suite can only
sample but the analyzer can check everywhere:

- **units** — the quantitative claims rest on byte/bandwidth/energy
  accounting that silently spans Gb/s, bytes/s, pJ and seconds; the
  family flags unit-mixing arithmetic, magic scale literals and
  call-boundary mixes that bypass `repro.units` (aka
  `repro.core.units`).
- **determinism** — the golden/differential harnesses require every
  simulation path to be a pure function of its config: no global RNG
  streams, no wall-clock reads outside the sanctioned timing surfaces,
  no set-iteration order leaking into ordered outputs.
- **trace** — observability hygiene: no bare `print` outside the
  logger, no `SimTrace` layer events left unplaced, no `recording()`
  without `with`.
- **config** — config dataclasses validate (or are registered as
  intentionally unvalidated), provenance fields carry
  ``compare=False``, and PEP 562 lazy re-export tables match the
  submodules they proxy.

Run ``python -m repro.lint src/ --format=text|github``; suppress a
finding inline with ``# lint: disable=<rule>`` plus a justification
comment.  The checked-in baseline (`lint_baseline.txt`) must stay
empty — it exists so the *mechanism* for grandfathering is exercised,
not so findings accumulate.

This package is pure stdlib on purpose: CI lints without installing
numpy/jax, and `repro.lint` can never import the code it judges.
"""

from .base import (Finding, LintReport, ModuleContext, Rule,
                   iter_py_files, load_baseline, run_rules,
                   write_baseline)
from .rules_config import RULES as _CONFIG_RULES
from .rules_determinism import RULES as _DETERMINISM_RULES
from .rules_trace import RULES as _TRACE_RULES
from .rules_units import RULES as _UNITS_RULES

#: every rule, in family order (stable: CLI/report ordering)
ALL_RULES = (_UNITS_RULES + _DETERMINISM_RULES + _TRACE_RULES
             + _CONFIG_RULES)

#: family names accepted by ``--select``
FAMILIES = tuple(dict.fromkeys(r.family for r in ALL_RULES))

__all__ = [
    "ALL_RULES", "FAMILIES", "Finding", "LintReport", "ModuleContext",
    "Rule", "iter_py_files", "load_baseline", "run_rules",
    "write_baseline",
]
