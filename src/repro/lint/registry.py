"""Domain registry: the repo-specific knowledge the rules consult.

Everything subjective about the analysis lives HERE, in one reviewable
place — unit vocabularies, per-rule path allowlists (each with its
rationale), and the intentionally-unvalidated config registry — so a
rule module only encodes mechanics, never policy.
"""

from __future__ import annotations

# ---------------------------------------------------------------------------
# units vocabulary
# ---------------------------------------------------------------------------

#: name-suffix -> unit tag.  ``bandwidth_gbps`` tags Gb/s; ``wall_s``
#: tags seconds; ``*_bw`` is the repo's bytes/s-rate suffix
#: (wireless_bw, cut_bw, chiplet_noc_bw ...).  The table is the
#: naming convention the README documents.
SUFFIX_UNITS = {
    "gbps": "gbps",
    "bw": "bytes_per_s",
    "bytes": "bytes",
    "bits": "bits",
    "pj": "pj",
    "j": "joules",
    "s": "seconds",
    "ms": "milliseconds",
    "us": "microseconds",
    "ns": "nanoseconds",
    "hops": "hops",
    "mm": "mm",
    "ghz": "ghz",
}

#: exact names whose unit carries no suffix (legacy/paper spellings).
NAME_UNITS = {
    "bandwidth": "bytes_per_s",       # NetworkConfig/WirelessConfig field
    "nbytes": "bytes",                # TrafficTrace per-message sizes
    "byte_links": "bytes",            # engine energy: bytes x traversed links
    "bits": "bits",
    "wall": "seconds",
}

#: conversion helpers (repro.units) -> the unit tag of their RESULT.
#: Routing a mixed-unit expression through one of these is what makes
#: the mix explicit — and silences `units-call-mix`.
HELPER_RESULT_UNITS = {
    "gbps_to_bytes_per_s": "bytes_per_s",
    "bytes_per_s_to_gbps": "gbps",
    "bytes_to_bits": "bits",
    "pj_to_j": "joules",
    "s_to_ms": "milliseconds",
    "s_to_us": "microseconds",
}

#: scale-factor literals that may only appear as named constants from
#: `repro.units` when multiplied/divided into a quantity.
MAGIC_SCALE_LITERALS = {
    1e3, 1e6, 1e9, 1e12, 1e-12,
    8e9, 16e9, 32e9, 64e9, 96e9,     # the paper's Gb/s points, pre-folded
}

#: unit tags for which a bare ``* 8`` / ``/ 8`` is a bit<->byte
#: conversion (use BITS_PER_BYTE / the helpers).
BYTEISH_UNITS = {"bytes", "bits", "bytes_per_s", "gbps"}

#: files exempt from the units family: the constants module IS the
#: conversion layer.
UNITS_EXEMPT_SUFFIXES = ("repro/units.py", "repro/core/units.py")

# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------

#: wall-clock reads allowed only here.  Rationale per entry:
#: - obs/metrics.py: `MetricsRegistry.span` is the ONE sanctioned
#:   wall-timer; every other module times through it.
#: - obs/profile.py: the phase profiler — measuring the framework's
#:   own wall time is its purpose; every other module profiles through
#:   `profile.phase` / `MetricsRegistry.span`, never a raw clock.
#: - launch/: CLI drivers that measure real JAX executions — wall
#:   clock is the measurement, as in benchmarks/.
#: - benchmarks/: regression timings are wall-clock by definition.
WALLCLOCK_ALLOWED_SUFFIXES = ("obs/metrics.py", "obs/profile.py")
WALLCLOCK_ALLOWED_SEGMENTS = ("launch", "benchmarks")

#: module-level numpy legacy RNG functions (seed-global state).
NP_RANDOM_LEGACY = {
    "seed", "random", "rand", "randn", "randint", "random_sample",
    "ranf", "sample", "choice", "shuffle", "permutation", "normal",
    "uniform", "standard_normal", "poisson", "beta", "binomial",
    "exponential", "gamma", "geometric", "bytes",
}

#: stdlib ``random`` module functions drawing from the global stream.
STDLIB_RANDOM_FNS = {
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "gauss", "normalvariate", "betavariate",
    "seed", "getrandbits", "triangular", "expovariate",
}

# ---------------------------------------------------------------------------
# trace / obs hygiene
# ---------------------------------------------------------------------------

#: the only modules allowed to call ``print`` directly:
#: - obs/metrics.py: `MetricsLogger` is the repo's one output funnel.
#: - lint/cli.py: the analyzer's own CLI — stdout is its interface.
PRINT_ALLOWED_SUFFIXES = ("obs/metrics.py", "lint/cli.py")

# ---------------------------------------------------------------------------
# config hygiene
# ---------------------------------------------------------------------------

#: public config-like dataclasses (``*Config`` / ``*Spec`` / ``*Plan``)
#: registered as intentionally unvalidated, with the reason.  Anything
#: config-like and public NOT listed here must validate in
#: ``__post_init__``.
UNVALIDATED_CONFIGS = {
    # jax model-plane configs: shapes are validated by jax.eval_shape
    # at init time; numeric fields have no domain beyond "positive",
    # and the dryrun harness exercises every zoo entry.
    "BlockSpec": "model-plane; shape-checked by jax at init",
    "ShapeConfig": "derived serving shapes; checked by make_serve_fns",
    "ServeConfig": "serving knobs; exercised by launch/serve drivers",
    "TrainConfig": "training knobs; exercised by launch/train drivers",
    "DataConfig": "pipeline knobs; any seed/int is valid",
    "OptimizerConfig": "optimizer knobs; validated by build_optimizer",
    "CompressionConfig": "codec knobs; validated at compress time",
    # runtime plane
    "ElasticPlan": "constructed only by ElasticPlan.plan, which validates",
    # arch plane
    "ChipletSpec": "catalog rows are literals audited in arch/catalog.py",
    "PlaneConfig": "hybrid-schedule internal; built from validated nets",
    # lint's own fixtures/config dataclasses would be false positives
    # if the analyzer is ever pointed at itself recursively; none today.
}

#: dataclass field names that stamp run metadata and must never affect
#: equality: declared with ``dataclasses.field(..., compare=False)``.
PROVENANCE_FIELD_NAMES = {"provenance"}
