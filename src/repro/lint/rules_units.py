"""Units rule family: flag unit-mixing arithmetic and magic scale
literals that bypass `repro.units`.

Unit inference is deliberately conservative — a finding requires the
unit to be *known* (suffix convention, exact-name registry, or a
``# unit: <tag>`` annotation comment), so untagged code is never
flagged.  Inference unwraps ``float(x)`` / ``int(x)`` / ``abs(x)`` and
reduction methods (``x.sum()`` ...), and propagates through ``+``/``-``
of same-unit operands; it does NOT propagate through ``*``/``/``
(a product has a new unit — that is the point of the family).
"""

from __future__ import annotations

import ast
from typing import Iterator, Optional

from .base import Finding, ModuleContext, Rule
from .registry import (BYTEISH_UNITS, HELPER_RESULT_UNITS,
                       MAGIC_SCALE_LITERALS, NAME_UNITS, SUFFIX_UNITS,
                       UNITS_EXEMPT_SUFFIXES)

_UNWRAP_CALLS = {"float", "int", "abs", "round"}
_UNWRAP_METHODS = {"sum", "max", "min", "mean", "item", "tolist"}


def _name_unit(name: str, ctx: ModuleContext,
               lineno: int = 0) -> Optional[str]:
    if name in NAME_UNITS:
        return NAME_UNITS[name]
    if "_" in name:
        suffix = name.rsplit("_", 1)[1]
        if suffix in SUFFIX_UNITS:
            return SUFFIX_UNITS[suffix]
    return None


def infer_unit(node: ast.AST, ctx: ModuleContext) -> Optional[str]:
    """The unit tag of an expression, or None when unknown."""
    # `# unit: tag` annotation on the expression's own line wins
    tag = ctx.unit_tags.get(getattr(node, "lineno", -1))
    if tag is not None and isinstance(node, (ast.Name, ast.Attribute,
                                             ast.arg)):
        return tag
    if isinstance(node, ast.Name):
        return _name_unit(node.id, ctx, node.lineno)
    if isinstance(node, ast.Attribute):
        return _name_unit(node.attr, ctx, node.lineno)
    if isinstance(node, ast.Call):
        fn = node.func
        if isinstance(fn, ast.Name):
            if fn.id in HELPER_RESULT_UNITS:
                return HELPER_RESULT_UNITS[fn.id]
            if fn.id in _UNWRAP_CALLS and node.args:
                return infer_unit(node.args[0], ctx)
            return _name_unit(fn.id, ctx)       # e.g. mac_energy_pj(...)
        if isinstance(fn, ast.Attribute):
            if fn.attr in HELPER_RESULT_UNITS:
                return HELPER_RESULT_UNITS[fn.attr]
            if fn.attr in _UNWRAP_METHODS:
                return infer_unit(fn.value, ctx)
        return None
    if isinstance(node, ast.BinOp) and isinstance(node.op,
                                                  (ast.Add, ast.Sub)):
        left = infer_unit(node.left, ctx)
        right = infer_unit(node.right, ctx)
        if left is not None and left == right:
            return left
    if isinstance(node, ast.UnaryOp):
        return infer_unit(node.operand, ctx)
    if isinstance(node, ast.Subscript):        # nbytes[mask] stays bytes
        return infer_unit(node.value, ctx)
    return None


def _units_exempt(ctx: ModuleContext) -> bool:
    return ctx.relpath.endswith(UNITS_EXEMPT_SUFFIXES)


class MixedArithRule(Rule):
    name = "units-mixed-arith"
    family = "units"
    description = ("`a + b` / `a - b` between quantities with different "
                   "unit tags and no explicit conversion")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        if _units_exempt(ctx):
            return
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.BinOp)
                    and isinstance(node.op, (ast.Add, ast.Sub))):
                continue
            left = infer_unit(node.left, ctx)
            right = infer_unit(node.right, ctx)
            if left is not None and right is not None and left != right:
                yield ctx.finding(
                    node, self.name,
                    f"adds `{left}` to `{right}`; convert one side "
                    f"through repro.units first")


class MagicLiteralRule(Rule):
    name = "units-magic-literal"
    family = "units"
    description = ("inline scale-factor literal (1e9, 1e-12, `* 8` on a "
                   "byte quantity, ...) instead of a repro.units "
                   "constant/helper")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        if _units_exempt(ctx):
            return
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.BinOp)
                    and isinstance(node.op, (ast.Mult, ast.Div))):
                continue
            for const, other in ((node.left, node.right),
                                 (node.right, node.left)):
                if not (isinstance(const, ast.Constant)
                        and isinstance(const.value, (int, float))
                        and not isinstance(const.value, bool)):
                    continue
                val = float(const.value)
                if val in MAGIC_SCALE_LITERALS:
                    yield ctx.finding(
                        node, self.name,
                        f"magic scale literal {const.value!r}; use the "
                        f"named constant/helper from repro.units")
                    break
                if val == 8.0 and infer_unit(other, ctx) in BYTEISH_UNITS:
                    yield ctx.finding(
                        node, self.name,
                        "bit<->byte conversion via bare `8`; use "
                        "repro.units.BITS_PER_BYTE / bytes_to_bits()")
                    break


class CallMixRule(Rule):
    name = "units-call-mix"
    family = "units"
    description = ("keyword argument whose unit tag differs from the "
                   "value passed (call-boundary unit mix)")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        if _units_exempt(ctx):
            return
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            for kw in node.keywords:
                if kw.arg is None:
                    continue
                want = _name_unit(kw.arg, ctx)
                if want is None:
                    continue
                got = infer_unit(kw.value, ctx)
                if got is not None and got != want:
                    yield ctx.finding(
                        kw.value, self.name,
                        f"passes `{got}` where parameter "
                        f"`{kw.arg}` expects `{want}`; convert through "
                        f"repro.units")


RULES = (MixedArithRule(), MagicLiteralRule(), CallMixRule())
