"""Determinism rule family.

The golden/differential harnesses pin simulation outputs bit-for-bit,
which is only meaningful if every simulation path is a pure function of
its config: no global RNG streams, no wall-clock reads, no set-ordering
leaks into ordered outputs.
"""

from __future__ import annotations

import ast
from typing import Iterator, Set

from .base import Finding, ModuleContext, Rule
from .registry import (NP_RANDOM_LEGACY, STDLIB_RANDOM_FNS,
                       WALLCLOCK_ALLOWED_SEGMENTS,
                       WALLCLOCK_ALLOWED_SUFFIXES)

_WALLCLOCK_TIME_FNS = {
    "time", "time_ns", "perf_counter", "perf_counter_ns", "monotonic",
    "monotonic_ns", "process_time", "process_time_ns", "clock_gettime",
}
_WALLCLOCK_DATETIME_FNS = {"now", "utcnow", "today"}


def _module_aliases(tree: ast.Module, module: str) -> Set[str]:
    """Names the module is bound to (``import numpy as np`` -> {np})."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name == module:
                    out.add(alias.asname or alias.name.split(".")[0])
    return out


def _imported_names(tree: ast.Module, module: str) -> Set[str]:
    """Names imported FROM ``module`` (``from random import choice``)."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module == module:
            for alias in node.names:
                out.add(alias.asname or alias.name)
    return out


class UnseededRngRule(Rule):
    name = "det-unseeded-rng"
    family = "determinism"
    description = ("global-stream RNG (`np.random.*` legacy functions, "
                   "stdlib `random.*`, argless `default_rng()`) in "
                   "simulation code")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        np_names = _module_aliases(ctx.tree, "numpy")
        random_names = _module_aliases(ctx.tree, "random")
        from_random = _imported_names(ctx.tree, "random") & STDLIB_RANDOM_FNS
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            # np.random.<legacy>(...) and np.random.default_rng()
            if (isinstance(fn, ast.Attribute)
                    and isinstance(fn.value, ast.Attribute)
                    and fn.value.attr == "random"
                    and isinstance(fn.value.value, ast.Name)
                    and fn.value.value.id in np_names):
                if fn.attr in NP_RANDOM_LEGACY:
                    yield ctx.finding(
                        node, self.name,
                        f"`np.random.{fn.attr}` draws from the global "
                        f"stream; use `np.random.default_rng(seed)`")
                elif fn.attr == "default_rng" and not (node.args
                                                       or node.keywords):
                    yield ctx.finding(
                        node, self.name,
                        "`default_rng()` without a seed is "
                        "entropy-seeded; thread an explicit seed")
            # random.<fn>(...) from the stdlib global stream
            elif (isinstance(fn, ast.Attribute)
                    and isinstance(fn.value, ast.Name)
                    and fn.value.id in random_names
                    and fn.attr in STDLIB_RANDOM_FNS):
                yield ctx.finding(
                    node, self.name,
                    f"stdlib `random.{fn.attr}` draws from the global "
                    f"stream; use a seeded `random.Random(seed)`")
            # from random import choice; choice(...)
            elif isinstance(fn, ast.Name) and fn.id in from_random:
                yield ctx.finding(
                    node, self.name,
                    f"`{fn.id}` (from stdlib random) draws from the "
                    f"global stream; use a seeded `random.Random(seed)`")


class WallclockRule(Rule):
    name = "det-wallclock"
    family = "determinism"
    description = ("wall-clock read (`time.time`, `perf_counter`, "
                   "`datetime.now`, ...) outside the sanctioned "
                   "timing surfaces")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        if ctx.relpath.endswith(WALLCLOCK_ALLOWED_SUFFIXES):
            return
        parts = ctx.relpath.split("/")
        if any(seg in parts for seg in WALLCLOCK_ALLOWED_SEGMENTS):
            return
        time_names = _module_aliases(ctx.tree, "time")
        from_time = _imported_names(ctx.tree, "time") & _WALLCLOCK_TIME_FNS
        dt_mod_names = _module_aliases(ctx.tree, "datetime")
        dt_cls_names = _imported_names(ctx.tree, "datetime") & {"datetime",
                                                                "date"}
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            if (isinstance(fn, ast.Attribute)
                    and isinstance(fn.value, ast.Name)
                    and fn.value.id in time_names
                    and fn.attr in _WALLCLOCK_TIME_FNS):
                yield ctx.finding(
                    node, self.name,
                    f"`time.{fn.attr}()` makes this path wall-clock "
                    f"dependent; time through "
                    f"`obs.metrics.DEFAULT_REGISTRY.span(...)` or move "
                    f"the read to an allowlisted driver")
            elif isinstance(fn, ast.Name) and fn.id in from_time:
                yield ctx.finding(
                    node, self.name,
                    f"`{fn.id}()` (from time) is a wall-clock read; "
                    f"time through `obs.metrics.DEFAULT_REGISTRY.span`")
            elif (isinstance(fn, ast.Attribute)
                    and fn.attr in _WALLCLOCK_DATETIME_FNS):
                base = fn.value
                if ((isinstance(base, ast.Name)
                     and base.id in dt_cls_names)
                        or (isinstance(base, ast.Attribute)
                            and isinstance(base.value, ast.Name)
                            and base.value.id in dt_mod_names)):
                    yield ctx.finding(
                        node, self.name,
                        f"`datetime .{fn.attr}()` is a wall-clock read; "
                        f"stamp timestamps in provenance/drivers only")


def _is_setish(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in ("set", "frozenset")):
        return True
    if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
        return _is_setish(node.left) or _is_setish(node.right)
    return False


#: builtins whose result does not depend on argument order — a
#: comprehension consumed directly by one of these is order-safe
_ORDER_INSENSITIVE_CONSUMERS = {
    "sorted", "set", "frozenset", "sum", "min", "max", "len", "any",
    "all", "dict",
}


def _consumed_order_insensitively(comp: ast.AST,
                                  ctx: ModuleContext) -> bool:
    parent = ctx.parents.get(comp)
    return (isinstance(parent, ast.Call)
            and isinstance(parent.func, ast.Name)
            and parent.func.id in _ORDER_INSENSITIVE_CONSUMERS
            and comp in parent.args)


class SetIterationRule(Rule):
    name = "det-set-iteration"
    family = "determinism"
    description = ("iteration over an unordered set expression; wrap in "
                   "`sorted(...)` before feeding ordered outputs")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        iters = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.For, ast.AsyncFor)):
                iters.append(node.iter)
            elif isinstance(node, (ast.ListComp, ast.GeneratorExp,
                                   ast.DictComp, ast.SetComp)):
                # only the outermost generator feeds ordered output; a
                # SetComp's own iteration order is irrelevant, as is a
                # comprehension handed straight to sorted()/sum()/...
                if not (isinstance(node, ast.SetComp)
                        or _consumed_order_insensitively(node, ctx)):
                    iters.extend(g.iter for g in node.generators)
        for it in iters:
            if _is_setish(it):
                yield ctx.finding(
                    it, self.name,
                    "iterates a set in arbitrary order; wrap the set in "
                    "`sorted(...)` (or justify with a suppression) so "
                    "downstream output ordering is deterministic")


RULES = (UnseededRngRule(), WallclockRule(), SetIterationRule())
