"""Trace/observability hygiene rule family.

Generalizes PR 7's ad-hoc "no bare prints" test into analyzer rules,
and adds the two `SimTrace` misuse modes that silently corrupt traces:
layer-relative events that are never placed on the absolute timeline,
and `recording(...)` called without `with` (which installs nothing).
"""

from __future__ import annotations

import ast
from typing import Iterator

from .base import Finding, ModuleContext, Rule
from .registry import PRINT_ALLOWED_SUFFIXES

_ADDERS = {"add_layer_event", "add_layer_matrix"}


class BarePrintRule(Rule):
    name = "obs-bare-print"
    family = "trace"
    description = ("`print(...)` outside the logger allowlist; report "
                   "through `obs.metrics.MetricsLogger`")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        if ctx.relpath.endswith(PRINT_ALLOWED_SUFFIXES):
            return
        for node in ast.walk(ctx.tree):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id == "print"):
                yield ctx.finding(
                    node, self.name,
                    "bare `print`; route output through "
                    "`obs.metrics.MetricsLogger` (the one allowed "
                    "`print` call site)")


class UnplacedLayerEventsRule(Rule):
    name = "obs-unplaced-layer-events"
    family = "trace"
    description = ("module builds a SimTrace and records layer-relative "
                   "events but never calls `place_layers`")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        constructs = adds = None
        places = False
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            name = (fn.id if isinstance(fn, ast.Name)
                    else fn.attr if isinstance(fn, ast.Attribute)
                    else None)
            if name == "SimTrace":
                constructs = constructs or node
            elif name in _ADDERS and isinstance(fn, ast.Attribute):
                adds = adds or node
            elif name == "place_layers" and isinstance(fn, ast.Attribute):
                places = True
        if constructs is not None and adds is not None and not places:
            yield ctx.finding(
                adds, self.name,
                "records layer-relative events on a SimTrace this module "
                "constructs, but never calls `place_layers(...)` — "
                "pending events would stay off the timeline")


class RecordingNoWithRule(Rule):
    name = "obs-recording-no-with"
    family = "trace"
    description = ("`recording(...)` used outside a `with` statement "
                   "(installs no recorder)")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and ((isinstance(node.func, ast.Name)
                          and node.func.id == "recording")
                         or (isinstance(node.func, ast.Attribute)
                             and node.func.attr == "recording"))):
                continue
            parent = ctx.parents.get(node)
            if isinstance(parent, ast.withitem):
                continue
            yield ctx.finding(
                node, self.name,
                "`recording(...)` is a context manager; outside `with` "
                "it installs nothing (the block runs unrecorded)")


RULES = (BarePrintRule(), UnplacedLayerEventsRule(), RecordingNoWithRule())
