"""Config hygiene rule family.

Config dataclasses are the contract between the planes: they must
fail fast on impossible values (`__post_init__`) or be explicitly
registered as unvalidated; provenance stamps must never leak into
equality; and the PEP 562 lazy re-export tables must stay in sync with
the submodules they proxy (a stale name raises only on first attribute
access — i.e. in user code, not in CI's import smoke).
"""

from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, List, Optional, Tuple

from .base import Finding, ModuleContext, Rule
from .registry import PROVENANCE_FIELD_NAMES, UNVALIDATED_CONFIGS

_CONFIG_SUFFIXES = ("Config", "Spec", "Plan", "Scenario", "Profile")
_EXPORTS_NAME_RE = re.compile(r"^_[A-Z0-9_]*EXPORTS$")


def _is_dataclass(node: ast.ClassDef) -> bool:
    for dec in node.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = (target.id if isinstance(target, ast.Name)
                else target.attr if isinstance(target, ast.Attribute)
                else None)
        if name == "dataclass":
            return True
    return False


class UnvalidatedDataclassRule(Rule):
    name = "cfg-unvalidated-dataclass"
    family = "config"
    description = ("public `*Config`/`*Spec`/`*Plan`/`*Scenario`/"
                   "`*Profile` dataclass without `__post_init__` "
                   "validation and not registered as intentionally "
                   "unvalidated")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.ClassDef)
                    and _is_dataclass(node)
                    and not node.name.startswith("_")
                    and node.name.endswith(_CONFIG_SUFFIXES)):
                continue
            if node.name in UNVALIDATED_CONFIGS:
                continue
            if any(isinstance(m, ast.FunctionDef)
                   and m.name == "__post_init__" for m in node.body):
                continue
            yield ctx.finding(
                node, self.name,
                f"config dataclass `{node.name}` neither validates in "
                f"`__post_init__` nor is registered in "
                f"`repro.lint.registry.UNVALIDATED_CONFIGS`")


class ProvenanceCompareRule(Rule):
    name = "cfg-provenance-compare"
    family = "config"
    description = ("provenance field on a dataclass must be declared "
                   "with `field(..., compare=False)`")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for cls in ast.walk(ctx.tree):
            if not (isinstance(cls, ast.ClassDef) and _is_dataclass(cls)):
                continue
            for stmt in cls.body:
                if not (isinstance(stmt, ast.AnnAssign)
                        and isinstance(stmt.target, ast.Name)
                        and stmt.target.id in PROVENANCE_FIELD_NAMES):
                    continue
                if self._compare_false(stmt.value):
                    continue
                yield ctx.finding(
                    stmt, self.name,
                    f"`{cls.name}.{stmt.target.id}` is run metadata; "
                    f"declare it `dataclasses.field(default=None, "
                    f"compare=False)` so stamps never break equality")

    @staticmethod
    def _compare_false(value: Optional[ast.AST]) -> bool:
        if not isinstance(value, ast.Call):
            return False
        fn = value.func
        name = (fn.id if isinstance(fn, ast.Name)
                else fn.attr if isinstance(fn, ast.Attribute) else None)
        if name != "field":
            return False
        return any(kw.arg == "compare"
                   and isinstance(kw.value, ast.Constant)
                   and kw.value.value is False
                   for kw in value.keywords)


class LazyExportMismatchRule(Rule):
    name = "cfg-lazy-export-mismatch"
    family = "config"
    description = ("PEP 562 `_*_EXPORTS` entry that the target "
                   "submodule does not define/export")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        has_getattr = any(isinstance(n, ast.FunctionDef)
                          and n.name == "__getattr__"
                          for n in ctx.tree.body)
        if not has_getattr:
            return
        tables = self._export_tables(ctx)
        targets = self._export_targets(ctx)
        for var, (node, names) in tables.items():
            dotted = targets.get(var)
            if dotted is None:
                continue
            path = ctx.resolve_module(dotted)
            if path is None:
                yield ctx.finding(
                    node, self.name,
                    f"lazy-export target module `{dotted}` not found "
                    f"under the scanned roots")
                continue
            exported = self._module_exports(path)
            if exported is None:
                continue
            for missing in [n for n in names if n not in exported]:
                yield ctx.finding(
                    node, self.name,
                    f"`{var}` re-exports `{missing}` but `{dotted}` "
                    f"does not define/export it — the name raises "
                    f"AttributeError on first access")

    @staticmethod
    def _export_tables(ctx: ModuleContext
                       ) -> Dict[str, Tuple[ast.AST, List[str]]]:
        out: Dict[str, Tuple[ast.AST, List[str]]] = {}
        for node in ctx.tree.body:
            if not (isinstance(node, ast.Assign)
                    and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and _EXPORTS_NAME_RE.match(node.targets[0].id)
                    and isinstance(node.value, (ast.Tuple, ast.List))):
                continue
            names = [e.value for e in node.value.elts
                     if isinstance(e, ast.Constant)
                     and isinstance(e.value, str)]
            out[node.targets[0].id] = (node, names)
        return out

    @staticmethod
    def _export_targets(ctx: ModuleContext) -> Dict[str, str]:
        """exports-table name -> dotted module, from the ``if name in
        _X_EXPORTS: import a.b; return getattr(a.b, name)`` pattern."""
        out: Dict[str, str] = {}
        for fn in ctx.tree.body:
            if not (isinstance(fn, ast.FunctionDef)
                    and fn.name == "__getattr__"):
                continue
            for branch in ast.walk(fn):
                if not (isinstance(branch, ast.If)
                        and isinstance(branch.test, ast.Compare)
                        and len(branch.test.ops) == 1
                        and isinstance(branch.test.ops[0], ast.In)
                        and isinstance(branch.test.comparators[0],
                                       ast.Name)):
                    continue
                var = branch.test.comparators[0].id
                for stmt in ast.walk(branch):
                    if isinstance(stmt, ast.Import) and stmt.names:
                        out[var] = stmt.names[0].name
                        break
        return out

    @staticmethod
    def _module_exports(path) -> Optional[set]:
        """Names the target module exports: its `__all__` when present,
        else every top-level binding."""
        try:
            tree = ast.parse(path.read_text(), filename=str(path))
        except (SyntaxError, UnicodeDecodeError):
            return None
        bound: set = set()
        for node in tree.body:
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        if (tgt.id == "__all__"
                                and isinstance(node.value,
                                               (ast.Tuple, ast.List))):
                            explicit = {e.value for e in node.value.elts
                                        if isinstance(e, ast.Constant)
                                        and isinstance(e.value, str)}
                            # `__all__` with starred pieces falls back
                            # to "all bindings" below
                            if len(explicit) == len(node.value.elts):
                                return explicit
                        bound.add(tgt.id)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.ClassDef)):
                bound.add(node.name)
            elif isinstance(node, ast.AnnAssign) and isinstance(
                    node.target, ast.Name):
                bound.add(node.target.id)
            elif isinstance(node, (ast.Import, ast.ImportFrom)):
                for alias in node.names:
                    bound.add(alias.asname
                              or alias.name.split(".")[0])
        return bound


RULES = (UnvalidatedDataclassRule(), ProvenanceCompareRule(),
         LazyExportMismatchRule())
