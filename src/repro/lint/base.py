"""Analyzer core: findings, suppressions, baselines, module contexts.

Pure stdlib (``ast`` + ``re``) — the analyzer must be importable and
runnable in a bare CI container with no numpy/jax installed, which is
why nothing under `repro.lint` imports any other `repro` package.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    path: str          # posix path, relative to the invocation cwd
    line: int
    col: int
    rule: str
    message: str

    def fingerprint(self) -> str:
        """Baseline identity: stable across col/message tweaks."""
        return f"{self.path}:{self.rule}:{self.line}"

    def render_text(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"

    def render_github(self) -> str:
        # one GitHub Actions workflow-command annotation per finding
        return (f"::error file={self.path},line={self.line},"
                f"col={self.col},title=repro.lint {self.rule}::{self.message}")


# `# lint: disable=rule-a,rule-b`   suppresses those rules on the line
# `# lint: disable`                 suppresses every rule on the line
_SUPPRESS_RE = re.compile(r"#\s*lint:\s*disable(?:=([\w,\-\s]+))?")

# `x: float = ...  # unit: gbps`    tags every name bound on the line
_UNIT_TAG_RE = re.compile(r"#\s*unit:\s*([\w/]+)")


def parse_suppressions(lines: Sequence[str]) -> Dict[int, Optional[Set[str]]]:
    """line (1-based) -> suppressed rule names, or None for "all"."""
    out: Dict[int, Optional[Set[str]]] = {}
    for i, line in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(line)
        if not m:
            continue
        if m.group(1) is None:
            out[i] = None
        else:
            out[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
    return out


def parse_unit_tags(lines: Sequence[str]) -> Dict[int, str]:
    """line (1-based) -> unit tag from a ``# unit: <tag>`` comment."""
    return {i: m.group(1)
            for i, line in enumerate(lines, start=1)
            if (m := _UNIT_TAG_RE.search(line))}


class ModuleContext:
    """Everything a rule needs about one parsed source file."""

    def __init__(self, path: Path, relpath: str, source: str,
                 search_roots: Sequence[Path] = ()):
        self.path = path
        self.relpath = relpath            # posix, cwd-relative (reported)
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=str(path))
        self.suppressions = parse_suppressions(self.lines)
        self.unit_tags = parse_unit_tags(self.lines)
        #: roots against which dotted module names resolve (the scanned
        #: top-level directories) — used by cross-file rules
        self.search_roots = tuple(search_roots)
        self._parents: Optional[Dict[ast.AST, ast.AST]] = None

    # built lazily: only the rules that need upward navigation pay for it
    @property
    def parents(self) -> Dict[ast.AST, ast.AST]:
        if self._parents is None:
            self._parents = {
                child: parent
                for parent in ast.walk(self.tree)
                for child in ast.iter_child_nodes(parent)
            }
        return self._parents

    def finding(self, node: ast.AST, rule: str, message: str) -> Finding:
        return Finding(self.relpath, getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0), rule, message)

    def resolve_module(self, dotted: str) -> Optional[Path]:
        """Resolve ``a.b.c`` to a source file under the search roots."""
        rel = Path(*dotted.split("."))
        for root in self.search_roots:
            for cand in (root / rel / "__init__.py",
                         root / rel.parent / (rel.name + ".py")):
                if cand.is_file():
                    return cand
        return None


class Rule:
    """One named check.  Subclasses set ``name``/``family`` and
    implement `check`."""

    name: str = ""
    family: str = ""
    description: str = ""

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        raise NotImplementedError


def iter_py_files(paths: Iterable[Path]) -> Iterator[Path]:
    for p in paths:
        if p.is_dir():
            yield from sorted(q for q in p.rglob("*.py"))
        elif p.suffix == ".py":
            yield p


@dataclasses.dataclass
class LintReport:
    """The outcome of one analyzer run."""

    findings: List[Finding]            # new findings (reported, gate CI)
    suppressed: int                    # dropped by inline suppressions
    baselined: int                     # dropped by the baseline file
    files_scanned: int

    @property
    def clean(self) -> bool:
        return not self.findings


def load_baseline(path: Path) -> Set[str]:
    """Fingerprints from a baseline file (blank/# lines ignored)."""
    if not path.is_file():
        return set()
    out = set()
    for line in path.read_text().splitlines():
        line = line.strip()
        if line and not line.startswith("#"):
            out.add(line)
    return out


def write_baseline(path: Path, findings: Iterable[Finding]) -> None:
    prints = sorted({f.fingerprint() for f in findings})
    body = "\n".join(prints)
    path.write_text(
        "# repro.lint baseline — one `path:rule:line` fingerprint per\n"
        "# line.  Policy: this file stays EMPTY; fix or inline-suppress\n"
        "# (with a justification comment) instead of baselining.\n"
        + (body + "\n" if body else ""))


def run_rules(rules: Sequence[Rule], files: Iterable[Path], *,
              baseline: Optional[Set[str]] = None,
              search_roots: Sequence[Path] = (),
              cwd: Optional[Path] = None) -> LintReport:
    """Run ``rules`` over ``files``; apply suppressions and baseline."""
    baseline = baseline if baseline is not None else set()
    cwd = cwd or Path.cwd()
    new: List[Finding] = []
    n_suppressed = n_baselined = n_files = 0
    for path in files:
        n_files += 1
        try:
            rel = path.resolve().relative_to(cwd.resolve()).as_posix()
        except ValueError:
            rel = path.as_posix()
        try:
            ctx = ModuleContext(path, rel, path.read_text(),
                                search_roots=search_roots)
        except (SyntaxError, UnicodeDecodeError) as err:
            new.append(Finding(rel, getattr(err, "lineno", 1) or 1, 0,
                               "parse-error", f"cannot parse: {err}"))
            continue
        for rule in rules:
            for f in rule.check(ctx):
                sup = ctx.suppressions.get(f.line, "missing")
                if sup != "missing" and (sup is None or f.rule in sup):
                    n_suppressed += 1
                elif f.fingerprint() in baseline:
                    n_baselined += 1
                else:
                    new.append(f)
    new.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return LintReport(new, n_suppressed, n_baselined, n_files)
