"""Per-layer wireless service times for one network configuration.

Given the flat per-packet arrays of a traffic trace and the boolean
injected set chosen by the paper's decision function, aggregate the
wireless traffic per (layer, channel) — and, under a spatial-reuse plan,
per (layer, channel, zone class) — cost each channel under the MAC
protocol, and return the per-layer wireless time as the max over the
concurrently operating channels.

Reuse costing (`ChannelPlan.reuse_zones > 1`): each packet classifies as
*zone-local* (hop span within the plan's ``reuse_distance``; occupies
its source's zone only) or *global* (heard package-wide; serializes
against every zone of its channel).  A channel's layer time is

    t = t_mac(global traffic) + max over zones of t_mac(zone traffic)

— the global phase quiesces all zones, the local phases run
concurrently.  With the degenerate plan (1 channel, 1 zone, ideal MAC)
this is exactly the paper's `volume / bandwidth` term, summed in the
same packet order as the legacy `np.add.at` implementation.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.obs.trace import active_recorder

from .config import NetworkConfig
from .mac import mac_extra_bytes, mac_times


def channel_aggregates(n_layers: int, layer: np.ndarray, nbytes: np.ndarray,
                       src: np.ndarray, ch_of_node: np.ndarray,
                       n_channels: int, injected: np.ndarray,
                       zcls: np.ndarray | None = None,
                       n_zcls: int = 1) -> Tuple[np.ndarray, np.ndarray,
                                                 np.ndarray]:
    """(bytes, msgs, active) aggregates for the injected set.

    Without ``zcls`` each is (n_layers, n_channels) — the legacy shape.
    With a per-packet zone-class array (0..K-1 zone-local, K global)
    each is (n_layers, n_channels, n_zcls); ``active`` counts distinct
    (layer, source, zone-class) transmitter appearances, since one
    source can hold both local and global traffic in a layer.
    """
    lay = layer[injected]
    nb = nbytes[injected]
    ch = ch_of_node[src[injected]]
    n_nodes = len(ch_of_node)
    if zcls is None:
        flat = lay.astype(np.int64) * n_channels + ch
        size = n_layers * n_channels
        shape = (n_layers, n_channels)
        pairs = np.unique(lay.astype(np.int64) * n_nodes + src[injected])
        pflat = (pairs // n_nodes) * n_channels + ch_of_node[pairs % n_nodes]
    else:
        zc = zcls[injected]
        flat = (lay.astype(np.int64) * n_channels + ch) * n_zcls + zc
        size = n_layers * n_channels * n_zcls
        shape = (n_layers, n_channels, n_zcls)
        key = (lay.astype(np.int64) * n_nodes + src[injected]) * n_zcls + zc
        pairs = np.unique(key)
        psrc = (pairs // n_zcls) % n_nodes
        pflat = ((pairs // n_zcls // n_nodes) * n_channels
                 + ch_of_node[psrc]) * n_zcls + pairs % n_zcls
    bytes_lc = np.bincount(flat, weights=nb, minlength=size).reshape(shape)
    msgs_lc = np.bincount(flat, minlength=size).reshape(shape)
    active_lc = np.bincount(pflat, minlength=size).reshape(shape)
    return bytes_lc, msgs_lc.astype(float), active_lc.astype(float)


def network_layer_times(n_layers: int, layer: np.ndarray, nbytes: np.ndarray,
                        src: np.ndarray, n_nodes: int, injected: np.ndarray,
                        net: NetworkConfig, *, grid=None, node_coords=None,
                        max_hops=None,
                        channel_bw=None) -> Tuple[np.ndarray, np.ndarray,
                                                  float]:
    """Per-layer wireless times under ``net``.

    Returns ``(t_wireless (L,), wl_bytes_per_layer (L,), extra_bytes)``
    where ``extra_bytes`` is the MAC's non-payload transmission overhead
    for the energy model.  A spatial-reuse plan additionally needs the
    package geometry: ``grid`` (rows, cols), ``node_coords`` (the
    (n_nodes, 2) clamped grid positions) and per-packet ``max_hops``.

    ``channel_bw`` overrides the plan's nominal per-channel rate with a
    ``(n_layers, n_channels)`` effective-bandwidth matrix — the dynamic
    SNR/fading path (`repro.fault.apply.wireless_bw_matrix`); the
    default None keeps the nominal scalar rate.
    """
    plan = net.channels
    ch_of_node = plan.assign(n_nodes)
    bw_c = plan.channel_bandwidth(net.bandwidth)
    if channel_bw is not None:
        bw_c = np.asarray(channel_bw, float)   # (L, C), broadcast below
    if plan.reuse_zones == 1:
        # single interference domain per channel: the exact legacy path
        bytes_lc, msgs_lc, active_lc = channel_aggregates(
            n_layers, layer, nbytes, src, ch_of_node, plan.n_channels,
            injected)
        t_lc = mac_times(net.mac, bytes_lc, msgs_lc, active_lc, bw_c)
        extra = float(mac_extra_bytes(net.mac, bytes_lc, msgs_lc,
                                      active_lc).sum())
        st = active_recorder()
        if st is not None:
            st.add_layer_matrix(t_lc, "ch{}", "an:wireless")
        return t_lc.max(axis=1), bytes_lc.sum(axis=1), extra
    if grid is None or node_coords is None or max_hops is None:
        raise ValueError(
            "a spatial-reuse plan (reuse_zones > 1) needs the package "
            "geometry: pass grid=, node_coords= and max_hops=")
    Z = plan.reuse_zones
    zone_of_node, rd = plan.assign_spatial(grid, node_coords)
    zcls = np.where(np.asarray(max_hops) <= rd, zone_of_node[src], Z)
    bytes_lcz, msgs_lcz, active_lcz = channel_aggregates(
        n_layers, layer, nbytes, src, ch_of_node, plan.n_channels,
        injected, zcls=zcls, n_zcls=Z + 1)
    t_lcz = mac_times(net.mac, bytes_lcz, msgs_lcz, active_lcz, bw_c)
    t_lc = t_lcz[..., Z] + t_lcz[..., :Z].max(axis=-1)
    extra = float(mac_extra_bytes(net.mac, bytes_lcz, msgs_lcz,
                                  active_lcz).sum())
    st = active_recorder()
    if st is not None:
        # global phase first (it quiesces the channel), zone phases
        # concurrently after it — the schedule the costing assumes
        for li, c in zip(*np.nonzero(t_lcz.max(axis=-1))):
            g = float(t_lcz[li, c, Z])
            if g > 0.0:
                st.add_layer_event(f"ch{c}/g", "span", int(li), 0.0, g,
                                   "an:wireless")
            for z in range(Z):
                if t_lcz[li, c, z] > 0.0:
                    st.add_layer_event(f"ch{c}/z{z}", "span", int(li), g,
                                       float(t_lcz[li, c, z]),
                                       "an:wireless")
    return t_lc.max(axis=1), bytes_lcz.sum(axis=(1, 2)), extra
