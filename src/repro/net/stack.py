"""Per-layer wireless service times for one network configuration.

Given the flat per-packet arrays of a traffic trace and the boolean
injected set chosen by the paper's decision function, aggregate the
wireless traffic per (layer, channel), cost each channel under the MAC
protocol, and return the per-layer wireless time as the max over the
concurrently operating channels.

With the degenerate plan (1 channel, ideal MAC) this is exactly the
paper's `volume / bandwidth` term, summed in the same packet order as
the legacy `np.add.at` implementation.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from .config import NetworkConfig
from .mac import mac_extra_bytes, mac_times


def channel_aggregates(n_layers: int, layer: np.ndarray, nbytes: np.ndarray,
                       src: np.ndarray, ch_of_node: np.ndarray,
                       n_channels: int,
                       injected: np.ndarray) -> Tuple[np.ndarray, np.ndarray,
                                                      np.ndarray]:
    """(bytes, msgs, active) aggregates, each (n_layers, n_channels)."""
    lay = layer[injected]
    nb = nbytes[injected]
    ch = ch_of_node[src[injected]]
    flat = lay.astype(np.int64) * n_channels + ch
    size = n_layers * n_channels
    bytes_lc = np.bincount(flat, weights=nb,
                           minlength=size).reshape(n_layers, n_channels)
    msgs_lc = np.bincount(flat, minlength=size).reshape(n_layers, n_channels)
    # active transmitters: distinct (layer, src) pairs with injected traffic
    n_nodes = len(ch_of_node)
    pairs = np.unique(lay.astype(np.int64) * n_nodes + src[injected])
    pflat = (pairs // n_nodes) * n_channels + ch_of_node[pairs % n_nodes]
    active_lc = np.bincount(pflat, minlength=size).reshape(n_layers,
                                                           n_channels)
    return bytes_lc, msgs_lc.astype(float), active_lc.astype(float)


def network_layer_times(n_layers: int, layer: np.ndarray, nbytes: np.ndarray,
                        src: np.ndarray, n_nodes: int, injected: np.ndarray,
                        net: NetworkConfig) -> Tuple[np.ndarray, np.ndarray,
                                                     float]:
    """Per-layer wireless times under ``net``.

    Returns ``(t_wireless (L,), wl_bytes_per_layer (L,), extra_bytes)``
    where ``extra_bytes`` is the MAC's non-payload transmission overhead
    for the energy model.
    """
    plan = net.channels
    ch_of_node = plan.assign(n_nodes)
    bw_c = plan.channel_bandwidth(net.bandwidth)
    bytes_lc, msgs_lc, active_lc = channel_aggregates(
        n_layers, layer, nbytes, src, ch_of_node, plan.n_channels, injected)
    t_lc = mac_times(net.mac, bytes_lc, msgs_lc, active_lc, bw_c)
    extra = float(mac_extra_bytes(net.mac, bytes_lc, msgs_lc,
                                  active_lc).sum())
    return t_lc.max(axis=1), bytes_lc.sum(axis=1), extra
