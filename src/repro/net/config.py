"""`NetworkConfig`: the full wireless-network description.

Supersedes the paper-era `core.wireless.WirelessConfig` (selection
parameters + one shared channel) by adding the channel plan and the MAC
protocol.  The selection fields carry the same names, so the paper's
decision function (`core.wireless.select_wireless`) and energy model
accept either config unchanged; `as_network` upgrades a legacy config
to the degenerate plan (1 channel, ideal MAC) that reproduces the
paper's numbers exactly.
"""

from __future__ import annotations

import dataclasses

from repro.units import bytes_per_s_to_gbps, gbps_to_bytes_per_s

from .channel import ChannelPlan
from .mac import MacConfig


@dataclasses.dataclass(frozen=True)
class NetworkConfig:
    # --- paper SIII-B2 selection + shared-medium parameters ---
    bandwidth: float = gbps_to_bytes_per_s(64)   # aggregate wireless B/s
    distance_threshold: int = 1      # NoP hops (paper sweep: 1..4)
    injection_prob: float = 0.5      # paper sweep: 0.10..0.80 step 0.05
    energy_pj_per_bit: float = 1.0   # ~1 pJ/bit mm-wave transceivers
    # --- beyond-paper network stack ---
    channels: ChannelPlan = ChannelPlan()
    mac: MacConfig = MacConfig()

    def __post_init__(self):
        if not self.bandwidth > 0:
            raise ValueError(f"bandwidth must be positive bytes/s, got "
                             f"{self.bandwidth!r}")
        if not 0.0 <= self.injection_prob <= 1.0:
            raise ValueError(f"injection_prob must be in [0, 1], got "
                             f"{self.injection_prob!r}")
        if self.distance_threshold < 0:
            raise ValueError(f"distance_threshold must be >= 0 hops, "
                             f"got {self.distance_threshold!r}")
        if self.energy_pj_per_bit < 0:
            raise ValueError(f"energy_pj_per_bit must be >= 0, got "
                             f"{self.energy_pj_per_bit!r}")

    def describe(self) -> str:
        return (f"{bytes_per_s_to_gbps(self.bandwidth):.0f}Gb/s "
                f"thr={self.distance_threshold} "
                f"p={self.injection_prob:.2f} {self.mac.protocol} "
                f"{self.channels.describe()}")


def as_network(cfg) -> NetworkConfig:
    """Upgrade any wireless config to a `NetworkConfig`.

    Accepts a `NetworkConfig` (returned as-is) or anything exposing the
    legacy `WirelessConfig` attributes, which maps to the single-channel
    ideal-MAC plan — today's behaviour as the degenerate case.
    """
    if isinstance(cfg, NetworkConfig):
        return cfg
    return NetworkConfig(
        bandwidth=cfg.bandwidth,
        distance_threshold=cfg.distance_threshold,
        injection_prob=cfg.injection_prob,
        energy_pj_per_bit=cfg.energy_pj_per_bit,
    )
