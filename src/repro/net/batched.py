"""Vectorized design-space engine for the wireless network stack.

`dse.sweep` costs every (threshold, injection) point with a full
`simulate_hybrid` call: re-scattering baseline link loads, re-selecting
the injected set and re-reducing cut loads per point — a Python double
loop over the grid.  This engine exploits the structure of the sweep:

1. The injection filter is a fixed low-discrepancy hash compared
   against the injection probability, so a packet's fate across the
   whole injection axis is summarized by ONE integer — the index of the
   first grid probability that accepts it (its *bucket*).
2. Everything the simulator needs per configuration is a sum over the
   injected set: wireless bytes per (layer, channel), removed byte
   loads per (layer, mesh cut), message and active-transmitter counts.

So per (trace, threshold) we scatter each packet's contributions into
`(segment, bucket)` bins with `np.bincount` ONCE, and a cumulative sum
along the bucket axis yields the exact per-injection-probability
aggregates for the entire axis.  Bandwidth, MAC protocol and channel
plan then act on those small `(thresholds, layers, channels, inject)`
tensors as closed-form array ops, producing the full
(threshold x injection x bandwidth x MAC x channel-plan) speedup grid
with no per-point simulation.  For the `ideal` MAC the result is
`allclose` to the per-point sweep (verified in tests/test_net.py) at
>=10x less wall clock on `dse.sweep_all`.

The module is `repro.core`-independent: the caller (e.g. `core.dse`)
supplies the per-packet arrays, eligibility masks, the injection hash
and the mesh-cut geometry.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from repro.obs import profile as obs_profile
from repro.units import gbps_to_bytes_per_s

from .channel import ChannelPlan
from .config import NetworkConfig
from .mac import MacConfig, mac_times

# The paper's sweep axes (SIV-A): single source of truth, re-exported by
# `core.dse` as THRESHOLDS / INJECTIONS / BANDWIDTHS_GBPS.
PAPER_THRESHOLDS = (1, 2, 3, 4)
PAPER_INJECTIONS = tuple(round(0.10 + 0.05 * i, 2)
                         for i in range(15))            # .10..._.80
PAPER_BANDWIDTHS_GBPS = (64, 96)


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """The axes of one design-space evaluation.

    ``injections`` must be sorted ascending (the bucket trick relies on
    it).  The default spec covers the paper's Fig. 4/5 sweep with the
    idealized network — `dse.NETWORK_MACS`/`NETWORK_PLANS` widen it.
    """

    thresholds: Tuple[int, ...] = PAPER_THRESHOLDS
    injections: Tuple[float, ...] = PAPER_INJECTIONS
    # fractional Gb/s are honoured exactly (callers anchoring an event run
    # against the grid must not round a non-integer-Gb/s network)
    bandwidths_gbps: Tuple[float, ...] = PAPER_BANDWIDTHS_GBPS
    macs: Tuple[MacConfig, ...] = (MacConfig("ideal"),)
    plans: Tuple[ChannelPlan, ...] = (ChannelPlan(1),)

    def __post_init__(self):
        inj = np.asarray(self.injections)
        if inj.size and np.any(np.diff(inj) <= 0):
            raise ValueError("injections must be strictly ascending")


@dataclasses.dataclass
class GridResult:
    """Speedup/total-time tensors indexed [mac, plan, bw, threshold, inj]."""

    spec: GridSpec
    base_time: float
    total_time: np.ndarray
    speedup: np.ndarray

    def best(self) -> Tuple[float, NetworkConfig]:
        """Best speedup over the whole grid and its `NetworkConfig`."""
        mi, pi, bi, ti, ii = np.unravel_index(int(self.speedup.argmax()),
                                              self.speedup.shape)
        cfg = NetworkConfig(
            bandwidth=gbps_to_bytes_per_s(self.spec.bandwidths_gbps[bi]),
            distance_threshold=self.spec.thresholds[ti],
            injection_prob=self.spec.injections[ii],
            channels=self.spec.plans[pi],
            mac=self.spec.macs[mi])
        return float(self.speedup[mi, pi, bi, ti, ii]), cfg

    def ideal_grid(self, bandwidth_gbps: float) -> np.ndarray:
        """(threshold, injection) speedup grid for the paper's network:
        ideal MAC, one channel, no spatial reuse."""
        mi = next(i for i, m in enumerate(self.spec.macs)
                  if m.protocol == "ideal")
        pi = next(i for i, p in enumerate(self.spec.plans)
                  if p.n_channels == 1 and p.reuse_zones == 1)
        bi = self.spec.bandwidths_gbps.index(bandwidth_gbps)
        return self.speedup[mi, pi, bi]


class BatchedDesignSpace:
    """Per-trace precomputation + grid evaluation.

    Parameters (all plain arrays; M packets, L layers, C mesh cuts):

    - ``layer``/``nbytes``/``src``: per-packet layer id, size, source.
    - ``eligibility``: threshold -> (M,) bool mask (paper criteria 1+2).
    - ``inj_hash``: (M,) low-discrepancy hash; packet injected iff
      ``hash < p`` (paper criterion 3).
    - ``pkt_cut``: (M, C) number of the packet's route links in each
      directed mesh cut.
    - ``cut_base``: (L, C) baseline (all-wired) byte load per cut.
    - ``cut_bw``: (C,) service bandwidth per cut.
    - ``t_rest``: (L,) wireless-independent floor
      ``max(compute, dram, noc)``.
    - ``base_time``: wired baseline total time (speedup denominator).
    - ``max_hops``/``grid``/``node_coords``: per-packet NoP hop span and
      the package geometry — only needed when a `GridSpec` plan uses
      spatial reuse (``reuse_zones > 1``), which gates packets on hop
      span and zones nodes by grid position.
    """

    def __init__(self, *, n_layers: int, n_nodes: int, layer: np.ndarray,
                 nbytes: np.ndarray, src: np.ndarray,
                 eligibility: Dict[int, np.ndarray], inj_hash: np.ndarray,
                 pkt_cut: np.ndarray, cut_base: np.ndarray,
                 cut_bw: np.ndarray, t_rest: np.ndarray, base_time: float,
                 max_hops: np.ndarray | None = None, grid=None,
                 node_coords: np.ndarray | None = None):
        self.n_layers = n_layers
        self.n_nodes = n_nodes
        self.layer = np.asarray(layer, np.int64)
        self.nbytes = np.asarray(nbytes, float)
        self.src = np.asarray(src, np.int64)
        self.eligibility = {t: np.asarray(e, bool)
                            for t, e in eligibility.items()}
        self.inj_hash = np.asarray(inj_hash, float)
        self.pkt_cut = np.asarray(pkt_cut, float)
        self.cut_base = np.asarray(cut_base, float)
        self.cut_bw = np.asarray(cut_bw, float)
        self.t_rest = np.asarray(t_rest, float)
        self.base_time = float(base_time)
        self.max_hops = None if max_hops is None \
            else np.asarray(max_hops, np.int64)
        self.grid = None if grid is None else tuple(grid)
        self.node_coords = None if node_coords is None \
            else np.asarray(node_coords, np.int64)
        # transmitter-group structures ((layer, src[, locality]) sorted
        # packet order + segment starts for min-bucket reductions),
        # cached by the reuse distance that splits local from global
        self._grp_cache: Dict[int | None, tuple] = {}

    def _groups(self, local: np.ndarray | None, cache_key):
        """Sorted transmitter groups, optionally split by reuse locality.

        Returns ``(order, starts, g_layer, g_src, g_local)`` where the
        ``g_*`` arrays describe each distinct (layer, src[, local])
        transmitter group; ``g_local`` is None without a locality split.
        """
        if cache_key in self._grp_cache:
            return self._grp_cache[cache_key]
        key = self.layer * self.n_nodes + self.src
        if local is not None:
            key = key * 2 + local
        order = np.argsort(key, kind="stable")
        sorted_key = key[order]
        first = np.ones(len(sorted_key), bool)
        first[1:] = sorted_key[1:] != sorted_key[:-1]
        starts = np.nonzero(first)[0]
        gkey = sorted_key[starts]
        g_local = None
        if local is not None:
            g_local = (gkey % 2).astype(bool)
            gkey = gkey // 2
        out = (order, starts, gkey // self.n_nodes, gkey % self.n_nodes,
               g_local)
        self._grp_cache[cache_key] = out
        return out

    # ------------------------------------------------------------------
    # bucketed cumulative aggregates
    # ------------------------------------------------------------------

    def _buckets(self, injections) -> np.ndarray:
        """Index of the first grid probability that injects each packet."""
        return np.searchsorted(np.asarray(injections), self.inj_hash,
                               side="right")

    def _cum(self, flat_seg, n_seg, bucket, n_inj, weights=None):
        """Scatter (segment, bucket) sums, then cumsum the bucket axis.

        Returns (n_seg, n_inj): value at injection index j is the sum of
        entries whose bucket <= j, i.e. the aggregate over the injected
        set at the j-th injection probability.
        """
        flat = flat_seg * (n_inj + 1) + bucket
        binned = np.bincount(flat, weights=weights,
                             minlength=n_seg * (n_inj + 1))
        return binned.reshape(n_seg, n_inj + 1).cumsum(axis=1)[:, :n_inj]

    # ------------------------------------------------------------------
    # evaluation
    # ------------------------------------------------------------------

    def evaluate(self, spec: GridSpec | None = None) -> GridResult:
        with obs_profile.phase("net.batched.evaluate"):
            return self._evaluate(spec)

    def _evaluate(self, spec: GridSpec | None) -> GridResult:
        spec = spec if spec is not None else GridSpec()
        missing = [t for t in spec.thresholds if t not in self.eligibility]
        if missing:
            raise ValueError(
                f"thresholds {missing} have no precomputed eligibility "
                f"mask; declare them when building the design space "
                f"(batched_design_space(trace, thresholds=...))")
        L, C = self.n_layers, len(self.cut_bw)
        NT, NI = len(spec.thresholds), len(spec.injections)
        with obs_profile.phase("net.batched.buckets"):
            bucket = self._buckets(spec.injections)

        # --- wired plane: removed cut loads and t_nop, per (thr, inj) ---
        t_nop = np.empty((NT, L, NI))
        elig = [self.eligibility[t] for t in spec.thresholds]
        with obs_profile.phase("net.batched.wired"):
            for ti, e in enumerate(elig):
                lay_e, nb_e, b_e = self.layer[e], self.nbytes[e], bucket[e]
                # one fused bincount over the (cut, layer, bucket) index
                # space
                seg = (np.arange(C)[:, None] * L + lay_e[None, :]).ravel()
                removed = self._cum(
                    seg, C * L,
                    np.broadcast_to(b_e, (C, len(b_e))).ravel(), NI,
                    weights=(self.pkt_cut[e].T * nb_e).ravel(),
                ).reshape(C, L, NI)
                residual = self.cut_base.T[:, :, None] - removed
                t_nop[ti] = (residual / self.cut_bw[:, None, None]).max(axis=0)
            obs_profile.note_ndarray(t_nop)

        # --- wireless plane: per-plan (bytes, msgs, active) aggregates,
        # with a zone-class axis (0..Z-1 zone-local, Z global) when the
        # plan spatially reuses the band; msgs/active only matter to
        # non-ideal MACs and are skipped otherwise ---
        need_counts = any(m.protocol != "ideal" for m in spec.macs)
        bmin_cache: Dict[tuple, np.ndarray] = {}
        with obs_profile.phase("net.batched.wireless"):
            per_plan = self._wireless_aggregates(
                spec, elig, bucket, bmin_cache, need_counts, L, NI)

        # --- closed-form assembly over (mac, plan, bandwidth) ---
        with obs_profile.phase("net.batched.assemble"):
            shape = (len(spec.macs), len(spec.plans),
                     len(spec.bandwidths_gbps), NT, NI)
            total = np.empty(shape)
            # floor is (NT, L, NI): the wireless-independent layer terms
            floor = np.maximum(self.t_rest[None, :, None], t_nop)
            for mi, mac in enumerate(spec.macs):
                for pi, plan in enumerate(spec.plans):
                    by, ms, ac, Z, nz = per_plan[pi]
                    for bi, bw in enumerate(spec.bandwidths_gbps):
                        bw_c = plan.channel_bandwidth(
                            gbps_to_bytes_per_s(bw))
                        t = mac_times(mac, by, ms, ac, bw_c)
                        if nz == 1:
                            t_ch = t[..., 0, :]
                        else:   # global phase + concurrent zone-local
                            t_ch = t[..., Z, :] + t[..., :Z, :].max(axis=3)
                        t_wl = t_ch.max(axis=2)
                        total[mi, pi, bi] = np.maximum(floor, t_wl) \
                            .sum(axis=1)
            obs_profile.note_ndarray(total)
        return GridResult(spec, self.base_time, total,
                          self.base_time / total)

    def _wireless_aggregates(self, spec, elig, bucket, bmin_cache,
                             need_counts, L, NI):
        """Per-plan (bytes, msgs, active) bucketed aggregates — the
        wireless half of `evaluate`, split out so the profiler can
        charge it as one phase."""
        NT = len(elig)
        per_plan = []
        for plan in spec.plans:
            n_ch = plan.n_channels
            ch_of_node = plan.assign(self.n_nodes)
            Z = plan.reuse_zones
            if Z == 1:
                nz, zcls, rd = 1, 0, None
                order, starts, g_lay, g_src, g_loc = self._groups(None, None)
                g_zc = 0
            else:
                if self.grid is None or self.node_coords is None \
                        or self.max_hops is None:
                    raise ValueError(
                        "plans with reuse_zones > 1 need the package "
                        "geometry; build the design space with max_hops, "
                        "grid and node_coords")
                zone_of_node, rd = plan.assign_spatial(self.grid,
                                                       self.node_coords)
                local = self.max_hops <= rd
                nz = Z + 1
                zcls = np.where(local, zone_of_node[self.src], Z)
                order, starts, g_lay, g_src, g_loc = self._groups(local, rd)
                g_zc = np.where(g_loc, zone_of_node[g_src], Z)
            ch = ch_of_node[self.src]
            seg_all = (self.layer * n_ch + ch) * nz + zcls
            by = np.empty((NT, L, n_ch, nz, NI))
            ms = ac = None
            if need_counts:
                ms = np.empty((NT, L, n_ch, nz, NI))
                ac = np.empty((NT, L, n_ch, nz, NI))
            gseg = (g_lay * n_ch + ch_of_node[g_src]) * nz + g_zc
            for ti, e in enumerate(elig):
                seg = seg_all[e]
                by[ti] = self._cum(seg, L * n_ch * nz, bucket[e], NI,
                                   weights=self.nbytes[e]) \
                    .reshape(L, n_ch, nz, NI)
                if need_counts:
                    ms[ti] = self._cum(seg, L * n_ch * nz, bucket[e], NI,
                                       weights=None).reshape(L, n_ch, nz, NI)
                    # a transmitter group is active from the earliest
                    # bucket of its eligible packets
                    bk = (rd, ti)
                    if bk not in bmin_cache:
                        bmin_cache[bk] = np.minimum.reduceat(
                            np.where(e, bucket, NI)[order], starts)
                    ac[ti] = self._cum(gseg, L * n_ch * nz, bmin_cache[bk],
                                       NI).reshape(L, n_ch, nz, NI)
            obs_profile.note_ndarray(by, ms, ac)
            per_plan.append((by, ms, ac, Z, nz))
        return per_plan
