"""Analytic MAC protocols for the shared wireless medium.

The paper costs the wireless plane as a perfectly arbitrated aggregate
(`volume / bandwidth`) and explicitly leaves medium-access overhead to
future work.  We cost three protocols per (layer, channel) from the
aggregates the traffic trace already exposes — bytes ``V``, message
count ``m`` and active transmitter count ``a`` — so the models stay
closed-form and vectorize across the whole design-space grid:

- ``ideal``: ``t = V / B``.  Reproduces the paper's numbers exactly.
- ``tdma``: the channel is a slotted frame.  Serving ``V`` bytes takes
  ``ceil(V / slot)`` full slots plus (pessimistically) one partial slot
  per additional active transmitter (each transmitter's tail slot is
  padded), and every slot pays a guard interval:

      n_slots = ceil(V / slot) + max(a - 1, 0)
      t       = n_slots * (slot / B + guard)

- ``token``: transmitters hold the channel per message after acquiring
  a circulating token; the expected acquisition wait grows with the
  number of stations the token visits, i.e. the active transmitter
  count on that channel:

      t = V / B + m * a * token_time

Both non-ideal protocols dominate ``ideal`` pointwise (slot padding
``n_slots * slot >= V``; the token term is non-negative), and both
shrink when a multi-channel plan splits the transmitter population —
which is exactly the trade the DSE explores.

Energy: the padded slot bytes (TDMA) and the token frames (token) are
transmitted at the same pJ/bit as payload; `mac_extra_bytes` returns
the non-payload byte overhead that `wireless_energy_joules` adds on.
"""

from __future__ import annotations

import dataclasses

import numpy as np

MAC_PROTOCOLS = ("ideal", "tdma", "token")


@dataclasses.dataclass(frozen=True)
class MacConfig:
    """MAC protocol + timing constants (mm-wave-transceiver scale)."""

    protocol: str = "ideal"
    slot_bytes: float = 64 * 1024    # TDMA slot payload (one NoP packet)
    guard_s: float = 50e-9           # TDMA guard interval per slot
    token_s: float = 20e-9           # token pass latency per station hop
    token_bytes: float = 16.0        # token frame size (energy accounting)

    def __post_init__(self):
        if self.protocol not in MAC_PROTOCOLS:
            raise ValueError(f"protocol must be one of {MAC_PROTOCOLS}")


def _tdma_slots(mac: MacConfig, nbytes, active):
    full = np.ceil(np.asarray(nbytes, float) / mac.slot_bytes)
    return full + np.maximum(np.asarray(active, float) - 1.0, 0.0)


def mac_times(mac: MacConfig, nbytes, msgs, active, bw):
    """Per-(layer, channel) wireless service time under ``mac``.

    All of ``nbytes``/``msgs``/``active`` are broadcastable arrays of
    aggregates for one channel; ``bw`` is the per-channel rate in B/s.
    Zero-traffic entries cost zero under every protocol.
    """
    nbytes = np.asarray(nbytes, float)
    if mac.protocol == "ideal":
        return nbytes / bw
    if mac.protocol == "tdma":
        n_slots = _tdma_slots(mac, nbytes, active)
        return n_slots * (mac.slot_bytes / bw + mac.guard_s)
    # token
    return (nbytes / bw
            + np.asarray(msgs, float) * np.asarray(active, float)
            * mac.token_s)


def mac_extra_bytes(mac: MacConfig, nbytes, msgs, active):
    """Non-payload bytes the protocol transmits (for the energy model)."""
    nbytes = np.asarray(nbytes, float)
    if mac.protocol == "ideal":
        return np.zeros_like(nbytes)
    if mac.protocol == "tdma":
        return _tdma_slots(mac, nbytes, active) * mac.slot_bytes - nbytes
    return np.asarray(msgs, float) * np.asarray(active, float) \
        * mac.token_bytes


# ---------------------------------------------------------------------------
# per-packet event costing (used by the repro.sim event-driven engine)
# ---------------------------------------------------------------------------
#
# The aggregate forms above cost a whole (layer, channel) population in
# closed form.  The event-driven simulator serves the channel one packet
# at a time, so it needs the *per-transmission* cost: the same protocol
# constants, charged per packet.
#
# - ``ideal``: ``v / B`` — summing over a layer reproduces the aggregate
#   exactly, so the event engine is bit-compatible with the paper model.
# - ``tdma``: every packet occupies ``ceil(v / slot)`` whole slots (its
#   tail slot is padded) plus the guard per slot.  Neither form bounds
#   the other: the event model resolves per-packet padding the
#   aggregate amortises (event higher on fragmented traffic), while
#   the aggregate pessimistically pads one tail per *transmitter*
#   (aggregate higher on slot-aligned traffic).  Both dominate the
#   ideal MAC pointwise.
# - ``token``: each transmission first waits for the circulating token,
#   ``active`` station hops away — where ``active`` is the number of
#   stations holding traffic on the channel *at that moment*, which the
#   event engine tracks as it serves (the analytic form pessimistically
#   charges the final count for every message).


def mac_packet_times(mac: MacConfig, nbytes, active, bw):
    """Service time of individual transmissions under ``mac``.

    ``nbytes`` are per-packet sizes; ``active`` is the station count
    seen by each transmission (scalar or array, ignored by ideal/tdma).
    """
    nbytes = np.asarray(nbytes, float)
    if mac.protocol == "ideal":
        return nbytes / bw
    if mac.protocol == "tdma":
        slots = np.ceil(nbytes / mac.slot_bytes)
        return slots * (mac.slot_bytes / bw + mac.guard_s)
    return nbytes / bw + np.asarray(active, float) * mac.token_s


def mac_packet_extra_bytes(mac: MacConfig, nbytes, active):
    """Per-transmission non-payload bytes (event-engine energy model)."""
    nbytes = np.asarray(nbytes, float)
    if mac.protocol == "ideal":
        return np.zeros_like(nbytes)
    if mac.protocol == "tdma":
        return np.ceil(nbytes / mac.slot_bytes) * mac.slot_bytes - nbytes
    return np.asarray(active, float) * mac.token_bytes
