"""Wireless channel plane: single shared channel or FDM multi-channel.

The paper's platform has one antenna per chiplet/DRAM module tuned to a
single shared frequency band; serialization per layer is one global
`volume / bandwidth` term.  Graphene-class agile transceivers motivate
splitting the band into several frequency channels with each node's
transmitter tuned to its zone's channel: transmissions on different
channels proceed concurrently, so the per-layer wireless time becomes a
per-channel max instead of one global sum.

Zone assignment policies (node id -> channel):

- ``contiguous``: equal blocks of consecutive node ids.  Matches a
  physical-layout zoning (neighbouring chiplets share a channel), which
  concentrates a pipeline stage's traffic on one channel.
- ``interleaved``: round-robin ``node % n_channels``.  Spreads adjacent
  (and therefore usually co-active) transmitters across channels, which
  balances per-channel load for pipeline mappings.

``n_channels == 1`` reproduces today's single-channel behaviour
bit-for-bit regardless of policy.
"""

from __future__ import annotations

import dataclasses

import numpy as np

POLICIES = ("contiguous", "interleaved")


@dataclasses.dataclass(frozen=True)
class ChannelPlan:
    """Frequency-division plan for the wireless plane.

    ``bandwidth_per_channel=None`` divides the aggregate wireless
    bandwidth evenly, i.e. the comparison against the single shared
    channel is at equal aggregate bandwidth.  A float pins each
    channel's rate instead (aggregate then scales with ``n_channels``).
    """

    n_channels: int = 1
    policy: str = "contiguous"
    bandwidth_per_channel: float | None = None

    def __post_init__(self):
        if self.n_channels < 1:
            raise ValueError(f"n_channels must be >= 1, got {self.n_channels}")
        if self.policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}")

    def channel_bandwidth(self, aggregate_bw: float) -> float:
        """Per-channel service rate in B/s."""
        if self.bandwidth_per_channel is not None:
            return self.bandwidth_per_channel
        return aggregate_bw / self.n_channels

    def assign(self, n_nodes: int) -> np.ndarray:
        """Channel id per node (compute chiplets then DRAM modules)."""
        nodes = np.arange(n_nodes)
        if self.n_channels == 1:
            return np.zeros(n_nodes, np.int64)
        if self.policy == "interleaved":
            return nodes % self.n_channels
        # contiguous equal blocks (last block absorbs the remainder)
        return np.minimum(nodes * self.n_channels // max(n_nodes, 1),
                          self.n_channels - 1)

    def describe(self) -> str:
        if self.n_channels == 1:
            return "1ch"
        return f"{self.n_channels}ch-{self.policy}"
