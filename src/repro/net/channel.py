"""Wireless channel plane: shared channel, FDM multi-channel, and
distance-gated spatial reuse.

The paper's platform has one antenna per chiplet/DRAM module tuned to a
single shared frequency band; serialization per layer is one global
`volume / bandwidth` term.  Two orthogonal ways out of that global
serialization point:

- **Frequency division** (graphene-class agile transceivers): split the
  band into several channels with each node's transmitter tuned to its
  zone's channel.  Transmissions on different channels proceed
  concurrently, so the per-layer wireless time becomes a per-channel
  max instead of one global sum.
- **Spatial reuse** (the standard answer for *large* meshes, where even
  a per-channel population saturates): tile the package into
  ``reuse_zones`` spatially-separated interference zones.  A
  transmission whose NoP hop span stays within ``reuse_distance`` only
  occupies its source's zone — zones transmit concurrently on the SAME
  frequency; a longer-range transmission is heard across zones and
  serializes globally on its channel.  Per (layer, channel) the service
  time becomes ``t(global) + max_z t(zone z)``.

Zone assignment policies (node id -> frequency channel):

- ``contiguous``: equal blocks of consecutive node ids.  Matches a
  physical-layout zoning (neighbouring chiplets share a channel), which
  concentrates a pipeline stage's traffic on one channel.
- ``interleaved``: round-robin ``node % n_channels``.  Spreads adjacent
  (and therefore usually co-active) transmitters across channels, which
  balances per-channel load for pipeline mappings.

Spatial zones are assigned by *grid position* (`assign_spatial`): the
package is tiled into a near-aspect-matched ``kr x kc`` factorization of
``reuse_zones``, and every node (DRAM modules clamped onto their edge)
belongs to the tile it sits in.

``n_channels == 1, reuse_zones == 1`` reproduces the paper's
single-shared-medium behaviour bit-for-bit regardless of policy.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

POLICIES = ("contiguous", "interleaved")


@dataclasses.dataclass(frozen=True)
class ChannelPlan:
    """Frequency-division + spatial-reuse plan for the wireless plane.

    ``bandwidth_per_channel=None`` divides the aggregate wireless
    bandwidth evenly, i.e. the comparison against the single shared
    channel is at equal aggregate bandwidth.  A float pins each
    channel's rate instead (aggregate then scales with ``n_channels``).

    ``reuse_zones`` (K) tiles the package into K spatial interference
    zones that transmit concurrently; ``reuse_distance`` is the NoP hop
    span up to which a transmission stays local to its source's zone
    (``None`` derives the zone-tile diameter, so exactly the
    transmissions that fit inside one tile-sized neighbourhood reuse
    the band).  ``reuse_zones == 1`` is the single shared medium — the
    gate is moot and every transmission is zone-local by construction.
    """

    n_channels: int = 1
    policy: str = "contiguous"
    bandwidth_per_channel: float | None = None
    reuse_zones: int = 1
    reuse_distance: int | None = None

    def __post_init__(self):
        if self.n_channels < 1:
            raise ValueError(f"n_channels must be >= 1, got {self.n_channels}")
        if self.policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}")
        if self.reuse_zones < 1:
            raise ValueError(
                f"reuse_zones must be >= 1, got {self.reuse_zones}")
        if self.reuse_distance is not None and self.reuse_distance < 0:
            raise ValueError(
                f"reuse_distance must be >= 0, got {self.reuse_distance}")

    def channel_bandwidth(self, aggregate_bw: float) -> float:
        """Per-channel service rate in B/s."""
        if self.bandwidth_per_channel is not None:
            return self.bandwidth_per_channel
        return aggregate_bw / self.n_channels

    def assign(self, n_nodes: int) -> np.ndarray:
        """Frequency channel id per node (compute chiplets then DRAM)."""
        nodes = np.arange(n_nodes)
        if self.n_channels == 1:
            return np.zeros(n_nodes, np.int64)
        if self.policy == "interleaved":
            return nodes % self.n_channels
        # contiguous equal blocks (last block absorbs the remainder)
        return np.minimum(nodes * self.n_channels // max(n_nodes, 1),
                          self.n_channels - 1)

    def zone_tiling(self, grid: Tuple[int, int]) -> Tuple[int, int]:
        """``(kr, kc)`` zone-tile factorization of ``reuse_zones``.

        Picks the divisor pair closest to the grid's aspect ratio so
        non-square grids tile sensibly; raises if no divisor pair fits
        inside the grid (e.g. 5 zones on a 2x8 mesh).
        """
        rows, cols = grid
        K = self.reuse_zones
        pairs = [(d, K // d) for d in range(1, K + 1)
                 if K % d == 0 and d <= rows and K // d <= cols]
        if not pairs:
            raise ValueError(
                f"reuse_zones={K} has no (kr x kc) factorization fitting "
                f"a {rows}x{cols} grid")
        return min(pairs, key=lambda p: abs(p[0] / p[1] - rows / cols))

    def assign_spatial(self, grid: Tuple[int, int],
                       coords: np.ndarray) -> Tuple[np.ndarray, int]:
        """``(zone_of_node, reuse_distance)`` for one package geometry.

        ``coords`` is the (n_nodes, 2) array of integer grid positions
        (DRAM modules clamped onto their edge —
        `repro.core.topology.node_grid_coords`).  The derived
        ``reuse_distance`` is the zone-tile Manhattan diameter; with a
        single zone that is the whole-package diameter, so every
        transmission classifies as zone-local and the plan degenerates
        to the shared medium exactly.
        """
        rows, cols = grid
        kr, kc = self.zone_tiling(grid)
        coords = np.asarray(coords, np.int64)
        zone = ((coords[:, 0] * kr // rows) * kc
                + coords[:, 1] * kc // cols)
        rd = self.reuse_distance
        if rd is None or self.reuse_zones == 1:
            # tile diameter: ceil(rows/kr) - 1 + ceil(cols/kc) - 1.  A
            # single zone's tile is the whole package, whose diameter
            # bounds every route — the gate never fires (and an explicit
            # reuse_distance is ignored: one zone IS the shared medium).
            rd = (-(-rows // kr) - 1) + (-(-cols // kc) - 1)
        return zone, int(rd)

    def describe(self) -> str:
        s = "1ch" if self.n_channels == 1 \
            else f"{self.n_channels}ch-{self.policy}"
        if self.reuse_zones > 1:
            s += f"-x{self.reuse_zones}reuse"
        return s


# ---------------------------------------------------------------------------
# SNR / fading -> effective capacity (the dynamic-conditions plane)
# ---------------------------------------------------------------------------

def shannon_capacity(snr_db) -> np.ndarray:
    """Normalized Shannon capacity ``log2(1 + SNR)`` in bit/s/Hz."""
    snr_db = np.asarray(snr_db, dtype=np.float64)
    return np.log2(1.0 + 10.0 ** (snr_db / 10.0))


@dataclasses.dataclass(frozen=True)
class SnrProfile:
    """Distance + degradation -> effective wireless rate, Shannon-style.

    The package has no physical scale of its own (the topology is a unit
    grid), so the profile carries it: ``pitch_mm`` converts grid hops to
    millimetres.  The link budget is a log-distance model around a
    reference point: a transmission spanning distance ``d`` sees

        ``snr_db(d) = ref_snr_db - 10 * path_loss_exp * log10(d / ref)``

    (clamped at the reference for shorter spans — the budget is set by
    the worst-case in-package reach, shorter hops don't beat it), and a
    fading event of ``fading_db`` lowers that SNR directly.  The
    *capacity scale* is the ratio of faded to clear Shannon capacity,

        ``C(snr - fade) / C(snr)``  with  ``C(s) = log2(1 + 10^(s/10))``,

    so zero fading is exactly 1.0 (the differential pin relies on this)
    and the same dB of fading costs more capacity on a longer, lower-SNR
    span — the AIMC-paper observation that wireless value tracks
    *sustained effective* bandwidth, not nominal Gb/s.
    """

    ref_snr_db: float = 15.0       # link budget at the reference span
    ref_distance_mm: float = 10.0  # span the budget is quoted at
    path_loss_exp: float = 2.0     # in-package log-distance exponent
    pitch_mm: float = 10.0         # chiplet pitch: one grid hop in mm

    def __post_init__(self):
        if self.ref_snr_db <= 0:
            raise ValueError(f"ref_snr_db must be > 0, got {self.ref_snr_db}")
        if self.ref_distance_mm <= 0 or self.pitch_mm <= 0:
            raise ValueError("ref_distance_mm and pitch_mm must be > 0")
        if self.path_loss_exp < 1.0:
            raise ValueError(
                f"path_loss_exp must be >= 1, got {self.path_loss_exp}")

    def snr_db_at(self, distance_mm) -> np.ndarray:
        """Clear-channel SNR (dB) at physical span ``distance_mm``."""
        d = np.maximum(np.asarray(distance_mm, np.float64),
                       self.ref_distance_mm)
        return (self.ref_snr_db
                - 10.0 * self.path_loss_exp
                * np.log10(d / self.ref_distance_mm))

    def capacity_scale(self, distance_mm, fading_db) -> np.ndarray:
        """Fraction of nominal capacity surviving ``fading_db`` at span
        ``distance_mm`` — exactly 1.0 when the fade is 0 dB."""
        fade = np.asarray(fading_db, np.float64)
        if np.any(fade < 0) or not np.all(np.isfinite(fade)):
            raise ValueError("fading_db must be finite and >= 0")
        snr = self.snr_db_at(distance_mm)
        scale = np.where(fade == 0.0, 1.0,
                         shannon_capacity(snr - fade)
                         / shannon_capacity(snr))
        return scale

    def channel_distances(self, plan: ChannelPlan, n_nodes: int,
                          coords: np.ndarray) -> np.ndarray:
        """Worst-case physical span (mm) served by each frequency
        channel: the Manhattan diameter of the channel's member set vs
        the whole package (a transmission must reach every listener),
        scaled by the pitch."""
        coords = np.asarray(coords, np.float64)
        ch = plan.assign(n_nodes)
        dist = np.zeros(plan.n_channels, np.float64)
        lo, hi = coords.min(axis=0), coords.max(axis=0)
        for c in range(plan.n_channels):
            m = coords[ch == c]
            if len(m) == 0:
                dist[c] = self.ref_distance_mm
                continue
            # member must reach the farthest package corner it talks to
            span = np.maximum(hi - m.min(axis=0), m.max(axis=0) - lo)
            dist[c] = max(float(span.sum()), 1.0) * self.pitch_mm
        return dist

    def effective_bandwidth(self, plan: ChannelPlan, aggregate_bw: float,
                            n_nodes: int, coords: np.ndarray,
                            fading_db) -> np.ndarray:
        """Per-channel effective rate (B/s) under ``fading_db`` (scalar
        or per-channel array)."""
        bw_c = plan.channel_bandwidth(aggregate_bw)
        dist = self.channel_distances(plan, n_nodes, coords)
        return bw_c * self.capacity_scale(dist, fading_db)
