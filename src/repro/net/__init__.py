"""Wireless NoP network subsystem.

The paper models the wireless plane as ONE idealized shared channel
(volume / bandwidth) and defers channel saturation and wired/wireless
load balancing to future work (SIV-B, SV).  This package replaces that
implicit model with a composable stack:

- `channel`  — `ChannelPlan`: single shared channel (the degenerate
  case, bit-exact with the paper), or frequency-division multi-channel
  with chiplet->channel zone assignment (contiguous / interleaved).
- `mac`      — `MacConfig`: analytic per-layer MAC costing: `ideal`
  (pure aggregate, reproduces the paper's numbers exactly), `tdma`
  (slot quantization + guard time), `token` (token-passing overhead
  proportional to the active transmitter count).
- `config`   — `NetworkConfig`: the full network description.  It is
  attribute-compatible with `core.wireless.WirelessConfig` so the
  paper's decision function applies unchanged.
- `stack`    — per-layer wireless service times + MAC energy overhead
  for one configuration.
- `batched`  — the vectorized design-space engine: per-message
  eligibility/injection tensors are bucketed once per trace, then the
  whole (threshold x injection x bandwidth x MAC x channel-plan) grid
  is evaluated as batched NumPy array ops (bincount + cumsum), >=10x
  faster than per-point `simulate_hybrid` loops at identical results.

The package is dependency-free with respect to `repro.core` (it
operates on plain arrays), so `core.simulator` can import it without
cycles.
"""

from .channel import ChannelPlan, SnrProfile, shannon_capacity
from .config import NetworkConfig, as_network
from .mac import MAC_PROTOCOLS, MacConfig, mac_extra_bytes, mac_times
from .stack import network_layer_times
from .batched import BatchedDesignSpace, GridSpec, GridResult

__all__ = [
    "ChannelPlan", "SnrProfile", "shannon_capacity",
    "MacConfig", "NetworkConfig", "as_network",
    "MAC_PROTOCOLS", "mac_times", "mac_extra_bytes",
    "network_layer_times",
    "BatchedDesignSpace", "GridSpec", "GridResult",
]
