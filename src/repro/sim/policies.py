"""Wired/wireless load-balancing policies for the event-driven engine.

The paper's evaluation fixes ONE (distance threshold x injection
probability) filter for a whole run and names "load balancing between
the wired and wireless interconnects" as the open problem.  This module
makes that problem runnable.  A policy answers one question — *which
plane does this packet take?* — at one of three information levels:

- `StaticPolicy` — the paper's SIII-B2 decision function: eligibility
  (multicast / distance threshold) gated by an injection probability.
  No state; the whole trace's assignment is known up front.
- `OraclePolicy` — the offline water-filling balancer
  (`repro.core.balancer.balance`) replayed packet-for-packet: the
  hindsight reference a causal policy is measured against.
- `GreedyPolicy` — *dynamic, per packet*: at injection time, join the
  plane that delivers this packet earliest given the instantaneous
  queue backlog (wired: its route's most-backlogged resource;
  wireless: its channel's next-free time plus MAC cost).  Pure local
  state, no lookahead.
- `AdaptivePolicy` — *dynamic, per layer*: at each layer boundary the
  runtime inspects the injection queues (the layer's enqueued packets
  and their routes) and re-tunes the filter for that layer, choosing
  among the paper's (threshold x injection) settings and a greedy
  backlog-balanced split — whichever the queue contents project
  fastest.  Since the projection is exact for static per-layer sets,
  its total is ``sum_l min_c t_l(c) <= min_c sum_l t_l(c)``: it
  provably matches or beats EVERY fixed grid point of the paper's
  sweep, on every workload.
- `OnlineReshardPolicy` — the traffic half of `repro.fault`'s
  online-reshard controller: the adaptive candidate pool plus the
  deployed static filter and the fault-aware water-filling balancer,
  stitched under the engine's (degraded) projections — never slower
  than static or adaptive under any injected failure set.
- `FixedPolicy` — replay an explicit per-packet mask (golden tests,
  external schedules).
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.wireless import injection_hash
from repro.net.batched import PAPER_INJECTIONS, PAPER_THRESHOLDS


class Policy:
    """Base: either plan the whole trace, or decide per packet."""

    name = "base"

    def plan_trace(self, sim) -> Optional[np.ndarray]:
        """Full per-packet injection mask, or None for online deciding."""
        return None

    def decide(self, sim, layer: int, pkt: int, wired_finish: float,
               wireless_finish: float, floor: float) -> bool:
        """Online choice for one eligible packet at injection time."""
        raise NotImplementedError


class FixedPolicy(Policy):
    """Replay an explicit injection mask."""

    name = "fixed"

    def __init__(self, mask: np.ndarray):
        self.mask = np.asarray(mask, bool)

    def plan_trace(self, sim) -> np.ndarray:
        return self.mask


class StaticPolicy(Policy):
    """The paper's decision function; (threshold, p) default to the net's."""

    name = "static"

    def __init__(self, threshold: Optional[int] = None,
                 injection_prob: Optional[float] = None):
        self.threshold = threshold
        self.injection_prob = injection_prob

    def plan_trace(self, sim) -> np.ndarray:
        thr = self.threshold if self.threshold is not None \
            else sim.net.distance_threshold
        p = self.injection_prob if self.injection_prob is not None \
            else sim.net.injection_prob
        return sim.elig(thr) & (injection_hash(len(sim.trace.nbytes)) < p)


class OraclePolicy(Policy):
    """Replay the offline water-filling balancer's injected set."""

    name = "oracle"

    def plan_trace(self, sim) -> np.ndarray:
        from repro.core.balancer import balance   # late: core imports sim
        return balance(sim.trace, sim.net, faults=sim.faults).injected


class GreedyPolicy(Policy):
    """Join-shortest-plane: earliest delivery for THIS packet, now.

    Injecting never slows the run down: the packet's wireless finish is
    below its wired finish, which is itself at most the all-wired
    layer's final backlog — so every layer ends no later than wired
    (speedup >= 1 by construction, verified in tests).
    """

    name = "greedy"

    def decide(self, sim, layer, pkt, wired_finish, wireless_finish,
               floor) -> bool:
        return wireless_finish < wired_finish


class AdaptivePolicy(Policy):
    """Per-layer filter re-tuning from the injection-queue contents.

    Candidates per layer: the paper's (threshold x injection) grid at
    the configured network, plus the greedy backlog split.  The engine
    executes the stitched per-layer masks; for the batched link models
    the projection used to choose equals the executed time exactly.
    """

    name = "adaptive"

    def __init__(self, thresholds=PAPER_THRESHOLDS,
                 injections=PAPER_INJECTIONS, include_greedy: bool = True):
        self.thresholds = tuple(thresholds)
        self.injections = tuple(injections)
        self.include_greedy = include_greedy

    def candidates(self, sim) -> list:
        """Per-layer candidate masks (subclasses extend the pool)."""
        hash_ = injection_hash(len(sim.trace.nbytes))
        cands = [sim.elig(t) & (hash_ < p)
                 for t in self.thresholds for p in self.injections]
        if self.include_greedy:
            cands.append(sim.run(GreedyPolicy()).injected)
        return cands

    def plan_trace(self, sim) -> np.ndarray:
        tr = sim.trace
        best_t = np.full(tr.n_layers, np.inf)
        best_mask = np.zeros(len(tr.nbytes), bool)
        for mask in self.candidates(sim):
            t = sim.layer_times(mask)
            win = t < best_t - 1e-15
            if win.any():
                best_t[win] = t[win]
                sel = win[tr.layer]
                best_mask = np.where(sel, mask, best_mask)
        return best_mask


class OnlineReshardPolicy(AdaptivePolicy):
    """Traffic half of the online-reshard controller (`repro.fault`).

    Extends the adaptive per-layer re-tune with two extra candidates:
    the network's own deployed static filter (so the stitched plan
    dominates `StaticPolicy` even when the configured (threshold, p)
    pair sits outside the paper grid), and the offline water-filling
    balancer re-run against the *surviving* topology (fault-aware
    `repro.core.balancer.balance`).  The per-layer stitch uses the
    engine's fault-aware projections, which are exact for the batched
    link models, so the total is <= every candidate's total under any
    injected failure set — the property test's guarantee.  The
    *placement* half (Heartbeat/ElasticPlan-gated trace rebuild on the
    survivors) lives in `repro.fault.resilience.reshard_run`, which
    min-anchors against this policy's no-reshard projection.
    """

    name = "online-reshard"

    def __init__(self, thresholds=PAPER_THRESHOLDS,
                 injections=PAPER_INJECTIONS, include_greedy: bool = True,
                 include_balancer: bool = True):
        super().__init__(thresholds, injections, include_greedy)
        self.include_balancer = include_balancer

    def candidates(self, sim) -> list:
        cands = super().candidates(sim)
        cands.append(StaticPolicy().plan_trace(sim))
        if self.include_balancer:
            from repro.core.balancer import balance  # late: core imports sim
            cands.append(balance(sim.trace, sim.net,
                                 faults=sim.faults).injected)
        return cands


POLICIES = {cls.name: cls for cls in
            (StaticPolicy, OraclePolicy, GreedyPolicy, AdaptivePolicy,
             OnlineReshardPolicy)}


def get_policy(policy) -> Policy:
    """Resolve a policy name or pass an instance through."""
    if isinstance(policy, Policy):
        return policy
    if policy in POLICIES:
        return POLICIES[policy]()
    raise ValueError(f"unknown policy {policy!r}; "
                     f"pick one of {sorted(POLICIES)} or pass an instance")
