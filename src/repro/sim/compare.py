"""Event-driven vs analytic comparisons: fidelity + policy reports.

Two questions the new engine answers, packaged for the benchmark
driver and the figure scripts:

1. **Fidelity** — how much does GEMINI's analytic per-layer max hide?
   Per workload, compare the analytic hybrid against the event engine
   at each wired realism level: ``striped`` (the analytic idealization,
   time-resolved — must agree), ``adaptive`` (least-backlogged parallel
   link), ``xy`` (fixed dimension-ordered path).  The analytic value is
   a lower bound for all of them.

2. **Policies** — does an online policy recover (or beat) the paper's
   offline-swept optimum?  Per workload, the best static (threshold x
   injection) grid point vs the configured static point, the greedy
   per-packet policy, the adaptive per-layer policy, and the offline
   water-filling oracle.
"""

from __future__ import annotations

from typing import Dict, Iterable, Optional

import numpy as np

from repro.core.simulator import simulate_hybrid, simulate_wired
from repro.net.config import NetworkConfig, as_network
from repro.units import gbps_to_bytes_per_s, s_to_ms

from .engine import LINK_MODELS, PacketSim

DEFAULT_NET = NetworkConfig(bandwidth=gbps_to_bytes_per_s(96))
DEFAULT_POLICIES = ("static", "greedy", "adaptive", "oracle")


def fidelity_report(traces: Dict[str, object], net=None,
                    link_models: Iterable[str] = LINK_MODELS) -> dict:
    """Event-driven vs analytic hybrid, per workload and link model."""
    net = as_network(net or DEFAULT_NET)
    link_models = tuple(link_models)
    out: dict = {}
    worst = {m: 0.0 for m in link_models}
    for wl, tr in traces.items():
        an_base = simulate_wired(tr).total_time
        an_hyb = simulate_hybrid(tr, net).total_time
        an_sp = an_base / an_hyb
        row = {"analytic": {"wired_ms": s_to_ms(an_base),
                            "hybrid_ms": s_to_ms(an_hyb),
                            "speedup": an_sp}}
        for m in link_models:
            sim = PacketSim(tr, net, link_model=m)
            ev_base = sim.run_wired().total_time
            ev_hyb = sim.run("static").total_time
            ev_sp = ev_base / ev_hyb
            rel = abs(ev_sp - an_sp) / an_sp
            worst[m] = max(worst[m], rel)
            row[m] = {"wired_ms": s_to_ms(ev_base),
                      "hybrid_ms": s_to_ms(ev_hyb),
                      "speedup": ev_sp, "speedup_rel_err": rel,
                      "hybrid_vs_analytic": ev_hyb / an_hyb}
        out[wl] = row
    out["_summary"] = {m: {"worst_speedup_rel_err": worst[m]}
                       for m in link_models}
    return out


def policy_report(traces: Dict[str, object], net=None,
                  policies: Iterable[str] = DEFAULT_POLICIES,
                  grid_best: Optional[Dict[str, float]] = None) -> dict:
    """Per-workload event-driven speedups of each policy vs the grid.

    ``grid_best`` optionally supplies the per-workload best static
    (threshold x injection) speedup (e.g. from the batched DSE engine);
    when omitted it is computed here.
    """
    from repro.core.dse import grid_best_speedup
    net = as_network(net or DEFAULT_NET)
    policies = tuple(policies)
    out: dict = {}
    wins = {p: 0 for p in policies}
    for wl, tr in traces.items():
        if grid_best and wl in grid_best:
            gbest = grid_best[wl]
        else:
            gbest = grid_best_speedup(tr, net)
        sim = PacketSim(tr, net)
        row = {"static_grid_best": gbest}
        for p in policies:
            res = sim.run(p)
            sp = sim.run_wired().total_time / res.total_time
            beats = bool(sp >= gbest - 1e-9)
            wins[p] += beats
            row[p] = {"speedup": sp,
                      "time_ms": s_to_ms(res.total_time),
                      "wireless_mb": res.wireless_bytes / 2**20,
                      "beats_grid": beats}
        out[wl] = row
    n = len(traces)
    out["_summary"] = {
        p: {"beats_grid": f"{wins[p]}/{n}",
            "mean_speedup": float(np.mean([out[wl][p]["speedup"]
                                           for wl in traces]))}
        for p in policies}
    return out
