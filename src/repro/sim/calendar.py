"""Vectorized event calendar: per-resource next-free-time arrays.

The event-driven engine advances a frontier (the layer barrier of the
GEMINI execution model: layer ``l+1``'s packets inject when layer
``l``'s timeline has fully drained) and serves each resource — a mesh
cut's striped link bundle, a single directed link, a wireless channel,
a DRAM port — as a FIFO server with a *next-free-time*.  Because every
packet of a layer is enqueued at the layer's start, an entire layer's
worth of events can be popped as ONE batch: per resource, the k-th
queued transmission completes at ``frontier + cumsum(service)[k]``, so
a segmented cumulative sum over (resource-sorted) events yields every
completion time of the batch at once — no per-event heap.

The helpers here are the shared primitives of that batched pop:
segment-wise cumulative sums, first-occurrence detection (for token-MAC
active-station counts), and the `ResourcePool` holding the next-free
and cumulative-busy arrays that per-packet (dynamic-policy) runs mutate
event by event.
"""

from __future__ import annotations

import dataclasses

import numpy as np


def segment_cumsum(values: np.ndarray, segments: np.ndarray) -> np.ndarray:
    """Inclusive cumulative sum of ``values`` within runs of ``segments``.

    ``segments`` must be grouped (all equal ids contiguous, e.g. after a
    stable sort); within each run the original order is the FIFO service
    order.
    """
    values = np.asarray(values, float)
    if values.size == 0:
        return values.copy()
    cs = np.cumsum(values)
    first = np.ones(len(values), bool)
    first[1:] = segments[1:] != segments[:-1]
    starts = np.nonzero(first)[0]
    # subtract the cumulative total *before* each segment's first entry
    base = np.repeat(cs[starts] - values[starts],
                     np.diff(np.append(starts, len(values))))
    return cs - base


def first_occurrence(keys: np.ndarray) -> np.ndarray:
    """Boolean mask marking the first appearance of each key value."""
    flags = np.zeros(len(keys), bool)
    if len(keys):
        _, idx = np.unique(keys, return_index=True)
        flags[idx] = True
    return flags


@dataclasses.dataclass
class ResourcePool:
    """Next-free-time + busy accounting for one family of resources.

    ``free`` is relative to the current layer frontier (the barrier
    resets it each layer after folding the elapsed occupancy into
    ``busy``, the cumulative busy-seconds per resource over the run).
    """

    free: np.ndarray
    busy: np.ndarray

    @classmethod
    def of(cls, n: int) -> "ResourcePool":
        return cls(np.zeros(n), np.zeros(n))

    def serve(self, ids: np.ndarray, service: np.ndarray) -> float:
        """Serve one transmission across ``ids`` simultaneously.

        Each listed resource enqueues its share ``service[i]`` (FIFO);
        the transmission completes when the slowest of them finishes.
        Returns the completion time (relative to the layer frontier).
        """
        self.free[ids] += service
        return float(self.free[ids].max())

    def peek(self, ids: np.ndarray, service: np.ndarray) -> float:
        """Completion time `serve` would return, without committing."""
        return float((self.free[ids] + service).max())

    def horizon(self) -> float:
        """Latest next-free time — when this pool's queues fully drain."""
        return float(self.free.max()) if self.free.size else 0.0

    def roll(self) -> None:
        """Barrier: fold this layer's occupancy into ``busy`` and reset."""
        self.busy += self.free
        self.free[:] = 0.0
