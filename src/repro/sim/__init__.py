"""Event-driven packet-level NoP simulator + load-balancing policies.

The third modelling plane of the repo (after `repro.core`'s analytic
GEMINI reproduction and `repro.net`'s channel/MAC stack): a discrete-
event simulator over the existing `TrafficTrace` packetisation that
resolves *time* — per-resource queues on every directed mesh link,
DRAM port, and wireless channel — so online wired/wireless
load-balancing policies (the paper's named future work) become
expressible and measurable.

- `calendar` — vectorized event-calendar primitives: per-resource
  next-free-time arrays, batched per-layer event pops via segmented
  cumulative sums.
- `engine`   — `PacketSim` / `simulate_events`: the simulator.  The
  default configuration (striped cut bundles, pooled DRAM, ideal MAC)
  reproduces the analytic model's layer times exactly; ``adaptive`` /
  ``xy`` link models, per-port DRAM, and per-packet TDMA/token MACs
  add the realism the analytic form averages away.
- `policies` — static (the paper's filter), oracle (offline
  water-filling replay), greedy (per-packet join-shortest-plane), and
  adaptive (per-layer queue-informed filter re-tuning, provably >=
  every static grid point).
- `compare`  — fidelity (event vs analytic) and policy reports for
  the benchmark driver.
"""

from .calendar import ResourcePool, first_occurrence, segment_cumsum
from .compare import fidelity_report, policy_report
from .engine import (DRAM_MODELS, LINK_MODELS, EventResult, PacketSim,
                     simulate_events)
from .policies import (POLICIES, AdaptivePolicy, FixedPolicy, GreedyPolicy,
                       OnlineReshardPolicy, OraclePolicy, Policy,
                       StaticPolicy, get_policy)

__all__ = [
    "ResourcePool", "first_occurrence", "segment_cumsum",
    "fidelity_report", "policy_report",
    "DRAM_MODELS", "LINK_MODELS", "EventResult", "PacketSim",
    "simulate_events",
    "POLICIES", "Policy", "StaticPolicy", "OraclePolicy", "GreedyPolicy",
    "AdaptivePolicy", "OnlineReshardPolicy", "FixedPolicy", "get_policy",
]
