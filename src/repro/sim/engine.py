"""Discrete-event, packet-level NoP simulator over a `TrafficTrace`.

The analytic core (`repro.core.simulator`) follows GEMINI: per layer it
takes the max of aggregate compute/DRAM/NoC/NoP/wireless terms, with
the wired NoP costed as the most-loaded directed mesh *cut* served at
the cut's pooled bandwidth.  That form cannot express anything that
depends on time — queue backlog, burst ordering, or an online policy
choosing a plane per packet.  This engine re-costs the SAME packetised
trace (same 64 KiB packets, same XY/YX routes, same link incidence)
with time-resolved occupancy of every network resource:

- **wired plane** — three link models:
  - ``striped`` (default): each cut crossing is striped across the
    cut's k parallel links, the idealized spreading the analytic cut
    model assumes.  With a static injection set this reproduces the
    analytic layer times exactly (the fidelity anchor).
  - ``adaptive``: each crossing picks the least-backlogged parallel
    link of its cut at injection time (adaptive minimal routing);
    packet granularity and imbalance emerge.
  - ``xy``: each crossing uses its fixed dimension-ordered link —
    the most contended, single-path reality.
- **wireless plane** — per-channel FIFO servers costed per packet by
  the MAC protocol (`repro.net.mac.mac_packet_times`): ideal is
  bit-compatible with the paper's volume/bandwidth aggregate; TDMA
  pays slot quantisation + guard per packet; token pays an acquisition
  wait that tracks the *instantaneous* active-station count.  Under a
  spatial-reuse plan (`ChannelPlan.reuse_zones > 1`) each channel
  splits into per-zone FIFOs serving concurrently; a packet whose hop
  span exceeds the reuse distance is heard package-wide and quiesces
  every zone of its channel.
- **DRAM ports** — ``pooled`` (default) keeps the analytic
  total-bytes/aggregate-bandwidth term; ``ports`` serves each DRAM
  module's queue at its own pin rate.

Execution keeps the GEMINI layer barrier: a layer's packets inject at
its start (in trace order) and the next layer starts when every queue
has drained — so per-layer event totals are comparable to the analytic
per-layer maxima, and the analytic value is a lower bound (each cut
must serve its bytes; pigeonhole puts one link at >= load/k).  Static
injection sets are served with ONE batched event pop per layer
(`calendar.pop_layer_batch`); only per-packet online policies walk
packets one event at a time.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.simulator import (BOTTLENECKS, PJ_PER_BIT_DRAM,
                                  PJ_PER_BIT_NOP_HOP, mac_energy_pj,
                                  noc_energy_pj)
from repro.core.topology import node_grid_coords
from repro.core.traffic import TrafficTrace
from repro.core.units import BITS_PER_BYTE, pj_to_j
from repro.core.wireless import eligibility, wireless_energy_joules
from repro.net.config import as_network
from repro.net.mac import mac_packet_extra_bytes, mac_packet_times
from repro.obs import profile as obs_profile
from repro.obs import trace as obs_trace

from .calendar import ResourcePool, first_occurrence, segment_cumsum

LINK_MODELS = ("striped", "adaptive", "xy")
DRAM_MODELS = ("pooled", "ports")


@dataclasses.dataclass
class EventResult:
    """Time-resolved outcome of one event-driven run."""

    total_time: float
    layer_times: np.ndarray        # (L,) per-layer span
    layer_finish: np.ndarray       # (L,) event-calendar finish timestamps
    bottleneck: List[str]
    injected: np.ndarray           # (M,) final per-packet plane assignment
    wireless_bytes: float
    wireless_energy_j: float
    energy_j: float
    cut_busy: np.ndarray           # (n_cuts,) wired busy-seconds per cut
    channel_busy: np.ndarray       # (n_channels,)
    dram_busy: np.ndarray          # (n_dram,)
    link_busy: Optional[np.ndarray]  # (n_links,) for the ``xy`` model
    policy: str
    link_model: str
    dram_model: str
    trace: Optional["obs_trace.SimTrace"] = None   # when record=True
    layer_terms: Optional[np.ndarray] = None       # (L, 5) stacked terms

    @property
    def edp(self) -> float:
        return self.energy_j * self.total_time

    def bottleneck_share(self) -> Dict[str, float]:
        """Fraction of total time attributed to each bottleneck.

        A degenerate (zero-time) run has no bottleneck: the explicit
        convention is an empty dict, shared with
        `repro.obs.metrics.attribution_report`'s empty list.
        """
        if not self.total_time:
            return {}
        shares = {b: 0.0 for b in BOTTLENECKS}
        for t, b in zip(self.layer_times, self.bottleneck):
            shares[b] += float(t)
        return {b: v / self.total_time for b, v in shares.items()}


class PacketSim:
    """Event-driven simulator for one (trace, network) pair.

    Precomputes the per-packet route geometry once; `run` then costs
    any policy.  ``link_model``/``dram_model`` select the realism level
    (see module docstring) — the defaults reproduce the analytic model
    for static injection sets.
    """

    def __init__(self, trace: TrafficTrace, net, *,
                 link_model: str = "striped", dram_model: str = "pooled",
                 record: bool = False, faults=None):
        if link_model not in LINK_MODELS:
            raise ValueError(f"link_model must be one of {LINK_MODELS}")
        if dram_model not in DRAM_MODELS:
            raise ValueError(f"dram_model must be one of {DRAM_MODELS}")
        self.faults = None
        if faults is not None and not faults.is_null:
            if link_model == "adaptive":
                raise NotImplementedError(
                    "faults are not supported with the 'adaptive' link "
                    "model: its per-slot backlog routing has no exact "
                    "per-layer degraded projection; use 'striped' or 'xy'")
            # chip events derate the trace itself (compute/DRAM terms);
            # late import: repro.fault.resilience imports this module
            from repro.fault.apply import derate_trace
            trace = derate_trace(trace, faults)
            self.faults = faults
        self.trace = trace
        self.net = as_network(net)
        self.link_model = link_model
        self.dram_model = dram_model
        self.record = record
        with obs_profile.phase("sim.precompute"):
            self._precompute()

    def _precompute(self) -> None:
        """Route-geometry / FIFO / eligibility precompute (init body)."""
        trace = self.trace
        cfg = trace.topo.config
        self.link_bw = cfg.nop_bw_per_side
        cut_mat, self.cut_bw = trace.cut_matrix()
        self.n_cuts = cut_mat.shape[1]
        assert np.all(cut_mat.sum(axis=1) == 1.0), \
            "every directed mesh link must belong to exactly one cut"
        self.cut_of_link = cut_mat.argmax(axis=1)
        self.k_par = np.rint(self.cut_bw / self.link_bw).astype(int)

        M = len(trace.nbytes)
        # per-packet route CSR (edges sorted by packet, route order kept)
        eorder = np.argsort(trace.inc_msg, kind="stable")
        self._pk_links = trace.inc_link[eorder]
        self._pk_cuts = self.cut_of_link[self._pk_links]
        self._pk_starts = np.searchsorted(trace.inc_msg[eorder],
                                          np.arange(M + 1))
        self.route_len = np.diff(self._pk_starts)
        # compacted cut crossings: (packet, cut) -> link multiplicity,
        # with the striped per-link-bundle service time precomputed
        key = trace.inc_msg.astype(np.int64) * self.n_cuts + \
            self.cut_of_link[trace.inc_link]
        ukey, ucnt = np.unique(key, return_counts=True)
        self._x_pkt = (ukey // self.n_cuts).astype(np.int64)
        self._x_cut = (ukey % self.n_cuts).astype(np.int64)
        self._x_add = ucnt * trace.nbytes[self._x_pkt] \
            / self.cut_bw[self._x_cut]
        self._x_starts = np.searchsorted(self._x_pkt, np.arange(M + 1))

        # per-layer packet lists (injection order = trace order)
        self._lorder = np.argsort(trace.layer, kind="stable")
        self._l_starts = np.searchsorted(trace.layer[self._lorder],
                                         np.arange(trace.n_layers + 1))

        # wireless plane: per-channel FIFOs — per (channel, zone) FIFOs
        # under a spatial-reuse plan, where a zone-local packet occupies
        # its source's zone server and a global (beyond-reuse-distance)
        # packet quiesces every zone of its channel
        plan = self.net.channels
        self.n_channels = plan.n_channels
        self.ch_of_node = plan.assign(trace.topo.n_nodes)
        self.pkt_ch = self.ch_of_node[trace.src]
        self.bw_c = plan.channel_bandwidth(self.net.bandwidth)
        self.n_zones = plan.reuse_zones
        self.n_zcls = 1 if self.n_zones == 1 else self.n_zones + 1
        if self.n_zones == 1:
            self.pkt_zc = np.zeros(M, np.int64)
        else:
            zone_of_node, rd = plan.assign_spatial(
                cfg.grid, node_grid_coords(trace.topo))
            self.pkt_zc = np.where(trace.max_hops <= rd,
                                   zone_of_node[trace.src], self.n_zones)

        # DRAM ports
        self.n_dram = max(1, len(trace.topo.dram_coords))
        self.port_bw = cfg.dram_bw_per_chiplet
        self._dram_svc = np.where(trace.dram_node >= 0,
                                  trace.nbytes / self.port_bw, 0.0)

        self.eligible = eligibility(trace, 1)   # online-policy candidacy
        self.t_rest = np.maximum.reduce(
            [trace.t_compute, trace.t_dram, trace.t_noc])
        self._elig_cache: Dict[int, np.ndarray] = {1: self.eligible}
        self._wired_cache: Optional[EventResult] = None

        # dynamic conditions (repro.fault): per-(layer, cut) wired
        # service scaling + forced wireless failover for link failures,
        # per-(layer, channel) effective bandwidth for SNR fades.  All
        # None on fault-free runs — every hot path tests for None only.
        self._cut_scale = self._link_remap = self._link_cost = None
        self._forced = self._wl_bw = None
        if self.faults is not None:
            from repro.fault.apply import (link_fault_arrays,
                                           wireless_bw_matrix)
            (self._cut_scale, self._link_remap, self._link_cost,
             self._forced) = link_fault_arrays(
                trace, self.faults, cut_of_link=self.cut_of_link,
                k_par=self.k_par, n_cuts=self.n_cuts)
            self._wl_bw = wireless_bw_matrix(trace, self.net, self.faults)

    # ------------------------------------------------------------------
    # shared pieces
    # ------------------------------------------------------------------

    def elig(self, threshold: int) -> np.ndarray:
        """Paper eligibility mask (criteria 1+2) at ``threshold``."""
        if threshold not in self._elig_cache:
            self._elig_cache[threshold] = eligibility(self.trace, threshold)
        return self._elig_cache[threshold]

    def _wireless_batch(self, injected: np.ndarray):
        """Per-packet wireless service/extra-bytes for a whole mask.

        Packets on one channel are served FIFO in trace order; the
        token MAC's acquisition wait uses the station count active *at
        serve time* (first-occurrence cumsum within each (layer,
        channel) queue).
        """
        tr, mac = self.trace, self.net.mac
        idx = np.nonzero(injected)[0]           # trace (= injection) order
        v = tr.nbytes[idx]
        grp = (tr.layer[idx].astype(np.int64) * self.n_channels
               + self.pkt_ch[idx]) * self.n_zcls + self.pkt_zc[idx]
        order = np.argsort(grp, kind="stable")
        a_now = np.empty(len(idx))
        pairs = grp[order] * tr.topo.n_nodes + tr.src[idx][order]
        a_now[order] = segment_cumsum(first_occurrence(pairs), grp[order])
        bw = (self.bw_c if self._wl_bw is None
              else self._wl_bw[tr.layer[idx], self.pkt_ch[idx]])
        svc = mac_packet_times(mac, v, a_now, bw)
        extra = mac_packet_extra_bytes(mac, v, a_now)
        return idx, grp, np.asarray(svc, float), float(np.sum(extra))

    def _dram_terms(self, busy_ld: np.ndarray) -> np.ndarray:
        if self.dram_model == "ports":
            return busy_ld.max(axis=1)
        return self.trace.t_dram

    def _finish(self, mask: np.ndarray, t_nop: np.ndarray,
                t_wl: np.ndarray, t_dram: np.ndarray, extra_bytes: float,
                busies, policy_name: str,
                st: Optional["obs_trace.SimTrace"] = None) -> EventResult:
        tr = self.trace
        stack = np.stack([tr.t_compute, t_dram, tr.t_noc, t_nop, t_wl])
        layer_times = stack.max(axis=0)
        which = stack.argmax(axis=0)
        if st is not None:
            self._finish_trace(st, mask, stack, layer_times, which,
                               policy_name)
        wl_bytes = float(tr.nbytes[mask].sum())
        # platform energy: same (per-chiplet-aware) constants as the
        # analytic model; wired NoP bits = bytes x traversed links,
        # route-exact
        byte_links = float((tr.nbytes * self.route_len)[~mask].sum())
        energy = pj_to_j(
            mac_energy_pj(tr)
            + float(tr.dram_bytes.sum()) * BITS_PER_BYTE * PJ_PER_BIT_DRAM
            + noc_energy_pj(tr)
            + byte_links * BITS_PER_BYTE * PJ_PER_BIT_NOP_HOP
            + (wl_bytes + extra_bytes) * BITS_PER_BYTE
            * self.net.energy_pj_per_bit)
        cut_busy, channel_busy, dram_busy, link_busy = busies
        return EventResult(
            total_time=float(layer_times.sum()),
            layer_times=layer_times,
            layer_finish=np.cumsum(layer_times),
            bottleneck=[BOTTLENECKS[i] for i in which],
            injected=mask,
            wireless_bytes=wl_bytes,
            wireless_energy_j=wireless_energy_joules(tr, mask, self.net,
                                                     extra_bytes),
            energy_j=energy,
            cut_busy=cut_busy, channel_busy=channel_busy,
            dram_busy=dram_busy, link_busy=link_busy,
            policy=policy_name, link_model=self.link_model,
            dram_model=self.dram_model,
            trace=st, layer_terms=stack.T.copy() if st is not None else None)

    def _finish_trace(self, st, mask: np.ndarray, stack: np.ndarray,
                      layer_times: np.ndarray, which: np.ndarray,
                      policy_name: str) -> None:
        """Coarse spans, layer spans, counters, metadata — then place
        every pending layer-relative event on the barrier timeline."""
        tr = self.trace
        L = tr.n_layers
        st.add_layer_matrix(tr.t_compute[:, None], "compute", "compute")
        st.add_layer_matrix(tr.t_noc[:, None], "noc", "noc")
        st.add_layer_matrix(stack[1][:, None], f"dram({self.dram_model})",
                            "dram-agg")
        for li in range(L):
            st.add_layer_event(
                "layers", f"L{li}:{BOTTLENECKS[which[li]]}", li, 0.0,
                float(layer_times[li]), "layer",
                **{b: float(stack[i, li])
                   for i, b in enumerate(BOTTLENECKS)})
        st.place_layers(layer_times)
        st.derive_queue_counters()
        st.derive_utilization_counters()
        finishes = np.cumsum(layer_times)
        for plane, sel in (("wireless", mask), ("wired", ~mask)):
            per_layer = np.bincount(tr.layer[sel],
                                    weights=tr.nbytes[sel], minlength=L)
            cum = np.cumsum(per_layer)
            st.add_counter(f"bytes:{plane}", 0.0, 0.0)
            for t, v in zip(finishes, cum):
                st.add_counter(f"bytes:{plane}", float(t), float(v))
        plan = self.net.channels
        cfg = tr.topo.config
        st.meta.update(policy=policy_name,
                       link_model=self.link_model,
                       dram_model=self.dram_model,
                       total_time=float(layer_times.sum()),
                       # everything `repro.obs.whatif` needs to re-bucket
                       # recorded transmissions under scaled resources
                       n_nodes=int(tr.topo.n_nodes),
                       grid=[int(cfg.grid[0]), int(cfg.grid[1])],
                       bandwidth=float(self.net.bandwidth),
                       mac=str(self.net.mac.protocol),
                       n_channels=int(self.n_channels),
                       reuse_zones=int(self.n_zones),
                       channel_policy=str(plan.policy),
                       n_dram=int(self.n_dram),
                       link_bw=float(self.link_bw),
                       cut_of_link=[int(c) for c in self.cut_of_link],
                       k_par=[int(k) for k in self.k_par],
                       node_coords=node_grid_coords(tr.topo).tolist())

    # ------------------------------------------------------------------
    # batched path: static injection sets, one event pop per layer
    # ------------------------------------------------------------------

    def _planned_parts(self, mask: np.ndarray):
        """Vectorized per-layer network terms for a fixed injection set."""
        tr = self.trace
        L = tr.n_layers
        # "adaptive" is served per event (`_run_online`); as a *planning*
        # projection it uses the striped (idealized) wired plane below
        if self.link_model != "xy":
            keep = ~mask[self._x_pkt]
            w = self._x_add[keep]
            if self._cut_scale is not None:   # degraded stripes / dead cuts
                w = w * self._cut_scale[tr.layer[self._x_pkt[keep]],
                                        self._x_cut[keep]]
            seg = tr.layer[self._x_pkt[keep]].astype(np.int64) * self.n_cuts \
                + self._x_cut[keep]
            busy = np.bincount(seg, weights=w,
                               minlength=L * self.n_cuts) \
                .reshape(L, self.n_cuts)
            # a trace can have no mesh resources at all (single-column
            # grids where every route is chiplet-local or enters at the
            # aligned edge router) — the NoP term is then zero
            t_nop = busy.max(axis=1) if busy.size else np.zeros(L)
            cut_busy, link_busy = busy.sum(axis=0), None
        else:  # "xy": fixed dimension-ordered links
            epk = tr.inc_msg
            keep = ~mask[epk]
            lnk = tr.inc_link[keep]
            lay = tr.layer[epk[keep]].astype(np.int64)
            w = tr.nbytes[epk[keep]] / self.link_bw
            if self._link_remap is not None:  # detours off dead links
                w = w * self._link_cost[lay, lnk]
                lnk = self._link_remap[lay, lnk]
            seg = lay * tr.n_links + lnk
            busy = np.bincount(seg, weights=w,
                               minlength=L * tr.n_links) \
                .reshape(L, tr.n_links)
            t_nop = busy.max(axis=1) if busy.size else np.zeros(L)
            link_busy = busy.sum(axis=0)
            cut_busy = np.bincount(self.cut_of_link, weights=link_busy,
                                   minlength=self.n_cuts)
        _, grp, svc, extra = self._wireless_batch(mask)
        busy_wl = np.bincount(grp, weights=svc,
                              minlength=L * self.n_channels * self.n_zcls) \
            .reshape(L, self.n_channels, self.n_zcls)
        if self.n_zcls == 1:
            t_wl = busy_wl[:, :, 0].max(axis=1)
        else:   # global phase quiesces the zones, locals run concurrently
            Z = self.n_zones
            t_wl = (busy_wl[:, :, Z]
                    + busy_wl[:, :, :Z].max(axis=2)).max(axis=1)
        nd = tr.dram_node
        busy_ld = np.bincount(
            tr.layer[nd >= 0].astype(np.int64) * self.n_dram + nd[nd >= 0],
            weights=self._dram_svc[nd >= 0],
            minlength=L * self.n_dram).reshape(L, self.n_dram)
        busies = (cut_busy, busy_wl.sum(axis=(0, 2)), busy_ld.sum(axis=0),
                  link_busy)
        return t_nop, t_wl, self._dram_terms(busy_ld), extra, busies

    def _with_forced(self, mask: np.ndarray) -> np.ndarray:
        """OR the forced-failover set (dead-cut packets) into a mask.

        The runtime knows its dead routes and diverts their packets to
        the wireless plane regardless of the paper's eligibility
        criteria — every policy's executed mask includes them.  Only
        `run_wired` skips this: the wired-only counterfactual pays the
        infinity instead (the wireless-as-failover headline).
        """
        if self._forced is None:
            return mask
        return mask | self._forced

    def layer_times(self, mask: np.ndarray) -> np.ndarray:
        """Per-layer event times a fixed injection set would produce.

        Exact for the batched link models; the ``adaptive`` model uses
        the striped projection (policies plan on the idealized wired
        plane, the event run resolves the real one).  Forced-failover
        packets are included, so policy projections match execution.
        """
        t_nop, t_wl, t_dram, _, _ = self._planned_parts(
            self._with_forced(mask))
        return np.maximum.reduce(
            [self.trace.t_compute, t_dram, self.trace.t_noc, t_nop, t_wl])

    def _run_planned(self, mask: np.ndarray, name: str,
                     st=None, force: bool = True) -> EventResult:
        with obs_profile.phase("sim.planned"):
            if force:
                mask = self._with_forced(mask)
            with obs_profile.phase("sim.planned_parts"):
                t_nop, t_wl, t_dram, extra, busies = \
                    self._planned_parts(mask)
            if st is not None:
                with obs_profile.phase("sim.record_planned"):
                    self._record_planned(st, mask)
            with obs_profile.phase("sim.finish"):
                return self._finish(mask, t_nop, t_wl, t_dram, extra,
                                    busies, name, st)

    def _record_planned(self, st, mask: np.ndarray) -> None:
        """Reconstruct the per-packet events a batched layer pop implies.

        The batched path never materialises an event order — per-layer
        busy totals and maxima fully determine the barrier times — so
        events are rebuilt post-hoc (only when recording) from the FIFO
        semantics: within each (layer, resource) queue, packets serve
        in injection (= trace index) order, begin = frontier +
        preceding service.  Under spatial reuse the planned costing is
        ``t_global + max_z t_zone``, i.e. the channel's global phase
        quiesces first and the zone FIFOs then run concurrently — zone
        events are offset by their channel's per-layer global busy.
        The per-resource busy integral of the reconstruction matches
        `cut_busy`/`channel_busy`/`dram_busy` exactly (pinned to 1e-12
        in tests/test_obs.py).

        Every reconstructed event carries its blocking edges (`deps`):
        the FIFO predecessor within its (layer, server) queue, and —
        for a reuse zone's head-of-queue packet — the channel's LAST
        global transmission (the quiesce it waited out).  Heads of
        queues with no deps begin at the layer barrier.  Wireless
        events also carry ``src``/``hops`` args so `repro.obs.whatif`
        can re-bucket them under a different channel/zone plan.
        """
        tr = self.trace

        def emit(pkt, res, svc, fmt, cat, seg, offset=None, first_dep=None,
                 extra=None):
            order = np.argsort(seg, kind="stable")   # FIFO: index order
            ends = segment_cumsum(svc[order], seg[order])
            sseg = seg[order]
            prev_eid, prev_seg, last = -1, None, {}
            for p, r, s, e, sg in zip(pkt[order], res[order], svc[order],
                                      ends, sseg):
                off = 0.0 if offset is None else offset(p, r)
                deps = ([prev_eid] if sg == prev_seg
                        else (first_dep(sg) if first_dep else []))
                prev_eid = st.add_layer_event(
                    fmt.format(r), f"p{p}", int(tr.layer[p]), off + e - s,
                    float(s), cat, deps=deps, bytes=float(tr.nbytes[p]),
                    **(extra(p) if extra else {}))
                prev_seg = sg
                last[sg] = prev_eid
            return last

        # wired plane
        if self.link_model != "xy":
            keep = ~mask[self._x_pkt]
            pkt, cut = self._x_pkt[keep], self._x_cut[keep]
            emit(pkt, cut, self._x_add[keep], "cut{}", "wired",
                 tr.layer[pkt].astype(np.int64) * self.n_cuts + cut)
        else:
            epk = tr.inc_msg[np.argsort(tr.inc_msg, kind="stable")]
            keep = ~mask[epk]
            pkt, lnk = epk[keep], self._pk_links[keep]
            emit(pkt, lnk, tr.nbytes[pkt] / self.link_bw, "link{}", "wired",
                 tr.layer[pkt].astype(np.int64) * tr.n_links + lnk)

        # wireless plane (decoded from the batched FIFO groups)
        idx, grp, svc, _ = self._wireless_batch(mask)
        if len(idx):
            zc = grp % self.n_zcls
            ch = (grp // self.n_zcls) % self.n_channels

            def wl_extra(p):
                return {"src": int(tr.src[p]), "hops": int(tr.max_hops[p])}

            if self.n_zcls == 1:
                tracks = np.array([f"ch{c}" for c in ch])
                emit(idx, tracks, svc, "{}", "wireless", grp,
                     extra=wl_extra)
            else:
                Z = self.n_zones
                gsel = zc == Z
                gbusy = np.bincount(
                    grp[gsel] // self.n_zcls, weights=svc[gsel],
                    minlength=tr.n_layers * self.n_channels)
                # global phase first (it quiesces the channel's zones):
                # FIFO per (layer, channel) from the barrier
                glast = emit(
                    idx[gsel],
                    np.array([f"ch{c}/g" for c in ch[gsel]]),
                    svc[gsel], "{}", "wireless", grp[gsel], extra=wl_extra)
                # zone FIFOs run concurrently after the global phase;
                # each zone queue's head blocks on the channel's last
                # global transmission
                zsel = ~gsel
                lc_of = dict(zip(idx[zsel], grp[zsel] // self.n_zcls))

                def z_offset(p, _r):
                    return float(gbusy[lc_of[p]])

                def z_first_dep(sg):
                    g_key = (sg // self.n_zcls) * self.n_zcls + Z
                    return [glast[g_key]] if g_key in glast else []

                emit(idx[zsel],
                     np.array([f"ch{c}/z{z}"
                               for c, z in zip(ch[zsel], zc[zsel])]),
                     svc[zsel], "{}", "wireless", grp[zsel],
                     offset=z_offset, first_dep=z_first_dep,
                     extra=wl_extra)

        # DRAM ports
        nd = tr.dram_node
        sel = np.nonzero(nd >= 0)[0]
        if len(sel):
            emit(sel, nd[sel], self._dram_svc[sel], "dram{}", "dram",
                 tr.layer[sel].astype(np.int64) * self.n_dram + nd[sel])

    # ------------------------------------------------------------------
    # sequential path: per-packet events (online policies / adaptive links)
    # ------------------------------------------------------------------

    def _run_online(self, policy, mask: Optional[np.ndarray],
                    name: str, st=None) -> EventResult:
        with obs_profile.phase("sim.online"):
            return self._run_online_body(policy, mask, name, st)

    def _run_online_body(self, policy, mask: Optional[np.ndarray],
                         name: str, st=None) -> EventResult:
        """The per-layer / per-packet event loop (`sim.online`'s self
        time in a profile is exactly this loop)."""
        tr, mac = self.trace, self.net.mac
        L, M = tr.n_layers, len(tr.nbytes)
        adaptive = self.link_model == "adaptive"
        xy = self.link_model == "xy"
        k_max = int(self.k_par.max()) if self.n_cuts else 1
        # physical parallel links of each cut (inf-padded, adaptive model)
        pad = np.zeros((self.n_cuts, k_max))
        pad[np.arange(k_max)[None, :] >= self.k_par[:, None]] = np.inf

        injected = np.zeros(M, bool)
        t_nop = np.zeros(L)
        t_wl = np.zeros(L)
        busy_ld = np.zeros((L, self.n_dram))
        cut_busy = np.zeros(self.n_cuts)
        # wireless airtime per channel (a global transmission's service
        # counts once, not once per quiesced zone server) — matches the
        # planned path's channel_busy accounting exactly
        wl_airtime = np.zeros(self.n_channels)
        extra_bytes = 0.0

        # per-resource next-free-time pools (barrier-rolled per layer);
        # the adaptive model keeps a raw (cut, parallel-slot) matrix so
        # the inf-padding of short cuts stays out of the busy accounting
        wired_pool = ResourcePool.of(tr.n_links if xy else self.n_cuts)
        ch_pool = ResourcePool.of(self.n_channels * self.n_zones)
        dram_pool = ResourcePool.of(self.n_dram)

        for li in range(L):
            pkts = self._lorder[self._l_starts[li]:self._l_starts[li + 1]]
            linkmat = pad.copy() if adaptive else None
            ch_srcs = [[set() for _ in range(self.n_zcls)]
                       for _ in range(self.n_channels)]
            # per-server last-recorded eid (reset at the layer barrier):
            # the FIFO/quiesce dependency edges of the online path
            last_w: Dict = {}
            last_ch: Dict[int, int] = {}
            last_dram: Dict[int, int] = {}
            for p in pkts:
                v = tr.nbytes[p]
                nd = tr.dram_node[p]
                if nd >= 0:
                    if st is not None:
                        last_dram[nd] = st.add_layer_event(
                            f"dram{nd}", f"p{p}", li,
                            float(dram_pool.free[nd]),
                            float(self._dram_svc[p]), "dram",
                            deps=[last_dram[nd]] if nd in last_dram else [],
                            bytes=float(v))
                    dram_pool.serve(np.array([nd]),
                                    np.array([self._dram_svc[p]]))
                # --- wired projection (uncommitted) ---
                if adaptive:
                    cuts = self._pk_cuts[self._pk_starts[p]:
                                         self._pk_starts[p + 1]]
                    s = v / self.link_bw
                    trial = linkmat.copy()
                    proj_w = 0.0
                    slots = [] if st is not None else None
                    for c in cuts:     # each crossing -> least-busy link
                        j = int(trial[c].argmin())
                        if slots is not None:
                            slots.append((int(c), j, float(trial[c, j])))
                        trial[c, j] += s
                        proj_w = max(proj_w, trial[c, j])
                elif xy:
                    ids = self._pk_links[self._pk_starts[p]:
                                         self._pk_starts[p + 1]]
                    svc = np.full(len(ids), v / self.link_bw)
                    if self._link_remap is not None:
                        svc = svc * self._link_cost[li, ids]
                        ids = self._link_remap[li, ids]
                    proj_w = wired_pool.peek(ids, svc) if len(ids) else 0.0
                else:
                    xs = slice(self._x_starts[p], self._x_starts[p + 1])
                    ids, svc = self._x_cut[xs], self._x_add[xs]
                    if self._cut_scale is not None:
                        svc = svc * self._cut_scale[li, ids]
                    proj_w = wired_pool.peek(ids, svc) if len(ids) else 0.0
                # --- wireless projection + decision ---
                go = False
                if self.eligible[p] or (self._forced is not None
                                        and self._forced[p]):
                    ch = int(self.pkt_ch[p])
                    zc = int(self.pkt_zc[p])
                    a_now = len(ch_srcs[ch][zc] | {int(tr.src[p])})
                    bw_li = (self.bw_c if self._wl_bw is None
                             else float(self._wl_bw[li, ch]))
                    s_wl = float(mac_packet_times(mac, v, a_now, bw_li))
                    if zc >= self.n_zones:
                        # global transmission: quiesces every zone of its
                        # channel — starts when all are free, blocks all
                        ids_wl = np.arange(ch * self.n_zones,
                                           (ch + 1) * self.n_zones)
                        proj_wl = float(ch_pool.free[ids_wl].max() + s_wl)
                    else:
                        ids_wl = np.array([ch * self.n_zones + zc])
                        proj_wl = ch_pool.peek(ids_wl, np.array([s_wl]))
                    if mask is not None:
                        go = bool(mask[p])
                    else:
                        go = policy.decide(self, li, p, proj_w, proj_wl,
                                           float(self.t_rest[li]))
                elif mask is not None and mask[p]:
                    raise ValueError("injection mask selects an ineligible "
                                     "packet")
                # --- commit ---
                if go:
                    injected[p] = True
                    if zc >= self.n_zones:
                        if st is not None:
                            # quiesce: waits on every zone server of the
                            # channel, then owns them all
                            deps = sorted({last_ch[i] for i in ids_wl
                                           if i in last_ch})
                            eid = st.add_layer_event(
                                f"ch{ch}/g", f"p{p}", li, proj_wl - s_wl,
                                s_wl, "wireless", deps=deps, bytes=float(v),
                                src=int(tr.src[p]), hops=int(tr.max_hops[p]))
                            for i in ids_wl:
                                last_ch[int(i)] = eid
                        ch_pool.free[ids_wl] = proj_wl
                    else:
                        if st is not None:
                            track = (f"ch{ch}/z{zc}" if self.n_zones > 1
                                     else f"ch{ch}")
                            sid = int(ids_wl[0])
                            last_ch[sid] = st.add_layer_event(
                                track, f"p{p}", li,
                                float(ch_pool.free[ids_wl[0]]),
                                s_wl, "wireless",
                                deps=[last_ch[sid]] if sid in last_ch
                                else [],
                                bytes=float(v), src=int(tr.src[p]),
                                hops=int(tr.max_hops[p]))
                        ch_pool.serve(ids_wl, np.array([s_wl]))
                    wl_airtime[ch] += s_wl
                    ch_srcs[ch][zc].add(int(tr.src[p]))
                    extra_bytes += float(mac_packet_extra_bytes(mac, v,
                                                                a_now))
                elif adaptive:
                    if st is not None:
                        for c, j, begin in slots:
                            last_w[(c, j)] = st.add_layer_event(
                                f"cut{c}/l{j}", f"p{p}", li, begin, s,
                                "wired",
                                deps=[last_w[(c, j)]] if (c, j) in last_w
                                else [],
                                bytes=float(v))
                    linkmat = trial
                elif len(ids):
                    if st is not None:
                        for rid, begin, s1 in zip(
                                ids, wired_pool.free[ids], svc):
                            rid = int(rid)
                            track = (f"link{rid}" if xy else f"cut{rid}")
                            last_w[rid] = st.add_layer_event(
                                track, f"p{p}", li, float(begin), float(s1),
                                "wired",
                                deps=[last_w[rid]] if rid in last_w else [],
                                bytes=float(v))
                    wired_pool.serve(ids, svc)
            # --- layer barrier: drain every queue, roll busy ---
            if adaptive:
                fin = np.where(np.isfinite(linkmat), linkmat, 0.0)
                t_nop[li] = fin.max() if fin.size else 0.0
                cut_busy += fin.sum(axis=1)
            else:
                t_nop[li] = wired_pool.horizon()
                wired_pool.roll()
            t_wl[li] = ch_pool.horizon()
            ch_pool.roll()
            busy_ld[li] = dram_pool.free
            dram_pool.roll()

        if xy:
            link_busy = wired_pool.busy
            cut_busy = np.bincount(self.cut_of_link, weights=link_busy,
                                   minlength=self.n_cuts)
        elif not adaptive:
            cut_busy, link_busy = wired_pool.busy, None
        else:
            link_busy = None
        busies = (cut_busy, wl_airtime, busy_ld.sum(axis=0), link_busy)
        with obs_profile.phase("sim.finish"):
            return self._finish(injected, t_nop, t_wl,
                                self._dram_terms(busy_ld),
                                extra_bytes, busies, name, st)

    # ------------------------------------------------------------------
    # entry points
    # ------------------------------------------------------------------

    def _recorder(self, name: str):
        """A fresh `SimTrace` when recording, else None (zero cost:
        the engine paths only ever test this for None)."""
        if not self.record:
            return None
        return obs_trace.SimTrace(label=f"event:{name}:{self.link_model}")

    def run(self, policy="static") -> EventResult:
        """Simulate under ``policy`` (name, or a `policies.Policy`)."""
        from .policies import get_policy
        pol = get_policy(policy)
        st = self._recorder(pol.name)
        with obs_profile.phase("sim.plan"):
            mask = pol.plan_trace(self)
        if mask is not None:
            mask = np.asarray(mask, bool)
            if self.link_model != "adaptive":
                return self._run_planned(mask, pol.name, st)
            return self._run_online(pol, mask, pol.name, st)
        return self._run_online(pol, None, pol.name, st)

    def run_wired(self) -> EventResult:
        """All-wired baseline (the speedup denominator), cached.

        Under faults this is the wired-only counterfactual: forced
        failover does NOT apply, so a fully-dead cut costs infinity —
        the wired-only platform simply cannot finish.
        """
        if self._wired_cache is None:
            mask = np.zeros(len(self.trace.nbytes), bool)
            st = self._recorder("wired")
            if self.link_model != "adaptive":
                self._wired_cache = self._run_planned(mask, "wired", st,
                                                      force=False)
            else:
                self._wired_cache = self._run_online(None, mask, "wired", st)
        return self._wired_cache

    def speedup(self, policy="static") -> float:
        return self.run_wired().total_time / self.run(policy).total_time


def simulate_events(trace: TrafficTrace, net, policy="static",
                    **kwargs) -> EventResult:
    """One-shot convenience: `PacketSim(trace, net, **kwargs).run(policy)`."""
    return PacketSim(trace, net, **kwargs).run(policy)
