from .pipeline import DataConfig, batch_for_model, stream, synthetic_batch
