"""Deterministic synthetic LM data pipeline.

Stateless and step-indexed: batch(step) is a pure function of (seed, step,
shape), so an elastic restart — even on a different mesh — reproduces the
exact token stream with no iterator state to checkpoint.  This is the
property real pipelines buy with expensive checkpointable readers; the
synthetic pipeline gets it for free and the training loop is written
against exactly this contract (see checkpoint/ and runtime/train.py).

The stream is a Zipf-ish unigram mix with induced bigram structure, so the
loss actually falls during the example runs (pure-uniform tokens would
pin CE at log V).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    seq_len: int = 512
    global_batch: int = 8
    vocab_size: int = 32000


def synthetic_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Pure function of (seed, step): tokens + next-token labels."""
    rng = np.random.default_rng(np.uint64(cfg.seed * 1_000_003 + step))
    V = cfg.vocab_size
    # Zipf unigram distribution over a truncated head of the vocab
    head = min(V, 4096)
    ranks = np.arange(1, head + 1)
    probs = 1.0 / ranks
    probs /= probs.sum()
    toks = rng.choice(head, size=(cfg.global_batch, cfg.seq_len + 1),
                      p=probs)
    # induced bigram structure: with p=0.5, token[t+1] = f(token[t])
    follow = (toks[:, :-1] * 7 + 11) % head
    mask = rng.random((cfg.global_batch, cfg.seq_len)) < 0.5
    toks[:, 1:][mask] = follow[mask]
    return {"tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32)}


def batch_for_model(model_cfg: ModelConfig, data_cfg: DataConfig,
                    step: int) -> Dict[str, np.ndarray]:
    """Model-aware batch: adds stub frontend embeddings where assigned."""
    base = synthetic_batch(
        dataclasses.replace(data_cfg, vocab_size=model_cfg.vocab_size), step)
    rng = np.random.default_rng(np.uint64(data_cfg.seed * 7 + step))
    if model_cfg.is_encdec:
        src = rng.standard_normal(
            (data_cfg.global_batch, max(32, data_cfg.seq_len // 4),
             model_cfg.d_model)).astype(np.float32)
        return {"src_embeds": src, **base}
    if model_cfg.frontend == "embed":
        emb = rng.standard_normal(
            (data_cfg.global_batch, data_cfg.seq_len,
             model_cfg.d_model)).astype(np.float32)
        return {"embeds": emb, "labels": base["labels"]}
    return base


def stream(model_cfg: ModelConfig, data_cfg: DataConfig,
           start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield batch_for_model(model_cfg, data_cfg, step)
        step += 1
