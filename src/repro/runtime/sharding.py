"""Divisibility-aware partition rules: param path -> PartitionSpec.

Strategy (megatron-style TP x FSDP x DP, on mesh axes
("pod",) "data", "model"):

- weight matrices: tensor-parallel on the dimension that maps to heads /
  d_ff / experts ('model'), FSDP on the complementary dimension ('data');
- a dimension is only assigned to a mesh axis when the axis size divides
  it — otherwise the rule falls back down a preference list and finally to
  replication (GSPMD would pad uneven shardings, but staying divisible
  keeps collective volumes exact and the roofline honest);
- activations: batch on ("pod","data"); long-context (batch=1) shapes
  shard the sequence axis instead (context parallelism);
- KV caches: batch on ("pod","data"), kv-heads on 'model' when divisible,
  else sequence on 'model'.

These rules actuate the wireless-paper analogue at LM scale: WHERE a
tensor is cut decides which collectives (multicast-shaped all-gathers vs
reduction traffic) the compiled step emits — see core/hybrid_schedule.py.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return int(mesh.shape[axis])


def _fits(mesh: Mesh, dim: int, axis) -> bool:
    return dim % _axis_size(mesh, axis) == 0


def _choose(mesh: Mesh, shape: Tuple[int, ...], prefs) -> P:
    """prefs: per-dim list of candidate axes in preference order."""
    taken = set()
    spec: list = []
    for dim, cands in zip(shape, prefs):
        chosen = None
        for ax in cands:
            if ax is None:
                break
            flat = ax if isinstance(ax, tuple) else (ax,)
            if any(a in taken for a in flat):
                continue
            if _fits(mesh, dim, ax):
                chosen = ax
                taken.update(flat)
                break
        spec.append(chosen)
    return P(*spec)


DATA_AXES = ("pod", "data")


def _data(mesh: Mesh):
    """The (possibly pod-extended) FSDP/data axis present in this mesh."""
    return tuple(a for a in DATA_AXES if a in mesh.shape) or (None,)


def param_spec(mesh: Mesh, path: str, shape: Tuple[int, ...]) -> P:
    """Sharding rule for one parameter tensor, by name and rank."""
    fsdp = _data(mesh)
    if fsdp == (None,):
        fsdp = None
    last = path.split("/")[-1]

    def choose(*prefs):
        # strip leading stacked-unit axes (scan axes stay unsharded)
        extra = len(shape) - len(prefs)
        return _choose(mesh, shape,
                       [[None]] * extra + [list(p) for p in prefs])

    if last in ("table",):            # (V, d): vocab-parallel embedding.
        # d stays replicated: sharding d on the batch ('data') axis makes
        # the unembed contraction compete with batch sharding and GSPMD
        # replicates the full-batch logits (EXPERIMENTS.md SPerf H-gemma).
        return choose(["model", None], [None])
    if last == "unembed":             # (d, V)
        return choose([None], ["model", None])
    if last in ("wq", "wk", "wv"):    # (d, H*hd): TP on the fused head dim
        return choose([fsdp, None], ["model", None])
    if last == "wo":                  # (H*hd, d)
        return choose(["model", None], [fsdp, None])
    if last in ("w_up", "w_gate"):    # (d, ff) or (E, d, ff)
        if len(shape) >= 3:           # expert-parallel; else TP on ff
            return choose(["model", None], [fsdp, None], ["model", None])
        return choose([fsdp, None], ["model", None])
    if last == "w_down":              # (ff, d) or (E, ff, d)
        if len(shape) >= 3:
            return choose(["model", None], ["model", None], [fsdp, None])
        return choose(["model", None], [fsdp, None])
    if last == "router":              # (d, E)
        return choose([fsdp, None], [None])
    if last in ("in_proj", "out_proj"):   # mamba: TP on d_inner side
        if last == "in_proj":
            return choose([fsdp, None], ["model", None])
        return choose(["model", None], [fsdp, None])
    if last in ("conv_w", "conv_b"):
        return choose(*[[None]] * len(shape))
    # norms, biases, scalars: replicated
    return P(*([None] * len(shape)))


def params_shardings(mesh: Mesh, params_tree: Any):
    """Tree of NamedShardings matching a params (or abstract params) tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_tree)

    def name(kp):
        return "/".join(str(getattr(k, "key", k)) for k in kp)

    specs = [NamedSharding(mesh, param_spec(mesh, name(kp), x.shape))
             for kp, x in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_shardings(mesh: Mesh, params_tree: Any, opt_name: str):
    """Optimizer-state shardings mirroring optimizers.init's structure.

    AdamW mu/nu inherit the parameter spec (ZeRO-for-free under FSDP);
    Adafactor's factored vr/vc take the parameter spec minus the reduced
    dimension."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_tree)

    def name(kp):
        return "/".join(str(getattr(k, "key", k)) for k in kp)

    def per_param(kp, x):
        spec = param_spec(mesh, name(kp), x.shape)
        ns = NamedSharding(mesh, spec)
        if opt_name == "adamw":
            return ns
        # adafactor
        parts = list(spec) + [None] * (len(x.shape) - len(spec))
        if x.ndim >= 2 and x.shape[-1] >= 128 and x.shape[-2] >= 128:
            return {
                "vr": NamedSharding(mesh, P(*parts[:-1])),
                "vc": NamedSharding(mesh, P(*(parts[:-2] + parts[-1:]))),
            }
        return {"v": ns}

    leaves = [per_param(kp, x) for kp, x in flat]
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if opt_name == "adamw":
        return {"mu": tree, "nu": tree}
    return {"v": tree}


def state_shardings(mesh: Mesh, abstract_state: Any, opt_name: str):
    """Shardings for the full train state {params, opt, step}."""
    pshard = params_shardings(mesh, abstract_state["params"])
    return {
        "params": pshard,
        "opt": opt_shardings(mesh, abstract_state["params"], opt_name),
        "step": NamedSharding(mesh, P()),
    }


def batch_spec(mesh: Mesh, shape: Tuple[int, ...],
               kind: str = "tokens") -> P:
    """Activation/batch sharding: batch over ("pod","data"); batch=1
    long-context shapes shard the sequence axis (context parallel)."""
    fsdp = _data(mesh)
    batch = shape[0]
    if batch % _axis_size(mesh, fsdp) == 0:
        rest = [None] * (len(shape) - 1)
        return P(fsdp, *rest)
    if len(shape) >= 2 and shape[1] % _axis_size(mesh, fsdp) == 0:
        return P(None, fsdp, *([None] * (len(shape) - 2)))
    return P(*([None] * len(shape)))


def cache_spec(mesh: Mesh, shape: Tuple[int, ...]) -> P:
    """KV / SSM cache sharding (leading stacked-unit axes unsharded).

    KV caches arrive as (units..., B, L, kv_heads, hd) and SSM states as
    (units..., B, H, P, N)."""
    fsdp = _data(mesh)
    n_extra = max(0, len(shape) - 4)
    body = shape[n_extra:]
    spec: list = [None] * n_extra
    # batch axis
    if body and body[0] % _axis_size(mesh, fsdp) == 0:
        spec.append(fsdp)
        used_data = True
    else:
        spec.append(None)
        used_data = False
    rest = list(body[1:])
    # shard heads (axis -2) on model if divisible, else the seq axis
    model_done = False
    for i, dim in enumerate(rest):
        axis = None
        if not model_done and i == 1 and dim % _axis_size(mesh, "model") == 0:
            axis = "model"
            model_done = True
        spec.append(axis)
    if not model_done:
        # fall back: sequence (first body-rest axis) on model when divisible
        if rest and rest[0] % _axis_size(mesh, "model") == 0:
            spec[n_extra + 1] = "model"
        elif not used_data and rest and \
                rest[0] % _axis_size(mesh, fsdp) == 0:
            spec[n_extra + 1] = fsdp
    return P(*spec)


def cache_shardings(mesh: Mesh, cache_tree: Any):
    return jax.tree.map(
        lambda x: NamedSharding(mesh, cache_spec(mesh, x.shape)), cache_tree)


def logical_batch_shardings(mesh: Mesh, batch_tree: Any):
    return jax.tree.map(
        lambda x: NamedSharding(mesh, batch_spec(mesh, x.shape)), batch_tree)
