"""Ambient parallel context: which mesh axes exist for explicit
(shard_map) parallel blocks.

jit+GSPMD handles most of the model automatically, but the MoE dispatch
needs *explicit* expert parallelism (a data-dependent global argsort is
opaque to GSPMD — it replicates the full expanded token set; see
EXPERIMENTS.md SPerf H-kimi).  The launcher sets this context; model code
reads it.  When unset, the GSPMD (replicated-sort) path is used — fine
for CPU smoke tests.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Tuple

_state = threading.local()


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    expert_axis: str = "model"          # mesh axis carrying experts
    data_axes: Tuple[str, ...] = ("data",)
    capacity_factor: float = 1.25       # per-destination-shard row budget


def get_context() -> Optional[ParallelContext]:
    return getattr(_state, "ctx", None)


def shard_batch(x):
    """Constrain an activation tensor to batch-sharded over the data axes.

    Pinning activations batch-sharded resolves GSPMD's FSDP-weight vs
    batch-sharding ambiguity toward ZeRO-3 semantics (gather the small
    weight shard, never replicate the big batch) — EXPERIMENTS.md SPerf
    H-gemma iteration 3."""
    ctx = get_context()
    if ctx is None:
        return x
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import get_abstract_mesh
    mesh = get_abstract_mesh()
    if not getattr(mesh, "shape", None):
        return x
    axes = tuple(a for a in ("pod", *ctx.data_axes) if a in mesh.shape)
    if not axes or x.ndim < 2:
        return x
    if x.shape[0] % _prod(mesh.shape[a] for a in axes) != 0:
        return x
    spec = P(axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def _prod(it):
    out = 1
    for v in it:
        out *= v
    return out


@contextlib.contextmanager
def parallel_context(ctx: ParallelContext):
    prev = get_context()
    _state.ctx = ctx
    try:
        yield
    finally:
        _state.ctx = prev
