"""Fault tolerance & elasticity machinery for 1000+-node operation.

Components (all host-side; the device program stays a pure jit step):

- `Heartbeat` — worker liveness registry with configurable timeout; the
  coordinator marks silent workers dead and triggers the restart policy.
- `ElasticPlan` — given the live worker set, picks the largest usable mesh
  (power-of-two slices along the data/pod axes; the model axis is never
  shrunk because TP state cannot be re-sharded without weight movement the
  plan can't hide) and the checkpoint-restore shardings for it.
- `StragglerMitigator` — EWMA per-step timing; a worker consistently
  slower than `threshold` x median is flagged for eviction (on TPU pods
  the usual cause is a flaky host or a thermally-throttled chip; evicting
  and shrinking the DP axis beats running the whole pod at straggler
  speed).  Mitigation = treat as failure => elastic reshard.
- `run_with_recovery` — the driver loop: step, checkpoint every K, on
  failure restore latest checkpoint on the surviving mesh and continue
  (exactly reproducible because the data pipeline is step-indexed).

The simulated-failure integration test (tests/test_fault_tolerance.py)
kills a "worker" mid-run and asserts bit-exact continuation against an
uninterrupted run.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Heartbeat:
    timeout_s: float = 30.0
    last_seen: Dict[int, float] = dataclasses.field(default_factory=dict)

    def beat(self, worker: int, now: Optional[float] = None) -> None:
        # a live heartbeat needs a real clock when the caller does not
        # inject one; tests pass `now` explicitly and stay deterministic
        self.last_seen[worker] = (time.monotonic()  # lint: disable=det-wallclock
                                  if now is None else now)

    def dead(self, now: Optional[float] = None) -> List[int]:
        t = (time.monotonic()  # lint: disable=det-wallclock (see beat)
             if now is None else now)
        return sorted(w for w, s in self.last_seen.items()
                      if t - s > self.timeout_s)

    def alive(self, now: Optional[float] = None) -> List[int]:
        t = (time.monotonic()  # lint: disable=det-wallclock (see beat)
             if now is None else now)
        return sorted(w for w, s in self.last_seen.items()
                      if t - s <= self.timeout_s)

    def evict(self, worker: int) -> None:
        """Forget a worker the coordinator has acted on.  Without this,
        `dead()` re-reports the same failed worker on every poll and the
        restart policy re-fires forever."""
        self.last_seen.pop(worker, None)


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    n_workers: int
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]

    @staticmethod
    def plan(n_alive_chips: int, model_parallel: int,
             pods: int = 1) -> "ElasticPlan":
        """Largest power-of-two data axis that fits the survivors; the
        model axis is preserved (TP weights are not re-shardable in-run).
        The pod axis IS shrinkable (pods are replicas): it participates
        in the feasibility check and is reduced before giving up, so the
        plan never claims more workers than there are alive chips."""
        if model_parallel < 1 or pods < 1:
            raise ValueError("model_parallel and pods must be >= 1")
        if n_alive_chips < model_parallel:
            raise RuntimeError(
                f"cannot keep model_parallel={model_parallel} with only "
                f"{n_alive_chips} chips")
        while pods > 1 and pods * model_parallel > n_alive_chips:
            pods -= 1
        data = 1
        while data * 2 * model_parallel * pods <= n_alive_chips:
            data *= 2
        if pods > 1:
            return ElasticPlan(pods * data * model_parallel,
                               (pods, data, model_parallel),
                               ("pod", "data", "model"))
        return ElasticPlan(data * model_parallel, (data, model_parallel),
                           ("data", "model"))


@dataclasses.dataclass
class StragglerMitigator:
    threshold: float = 1.5     # x median EWMA step time
    alpha: float = 0.3
    min_steps: int = 5
    ewma: Dict[int, float] = dataclasses.field(default_factory=dict)
    counts: Dict[int, int] = dataclasses.field(default_factory=dict)

    def record(self, worker: int, step_time: float) -> None:
        prev = self.ewma.get(worker, step_time)
        self.ewma[worker] = (1 - self.alpha) * prev + self.alpha * step_time
        self.counts[worker] = self.counts.get(worker, 0) + 1

    def stragglers(self) -> List[int]:
        ready = {w: t for w, t in self.ewma.items()
                 if self.counts[w] >= self.min_steps}
        if len(ready) < 3:
            return []
        med = float(np.median(list(ready.values())))
        return sorted(w for w, t in ready.items()
                      if t > self.threshold * med)


@dataclasses.dataclass
class RecoveryEvent:
    step: int
    kind: str          # "failure" | "straggler"
    workers: List[int]
    new_mesh: Tuple[int, ...]


def run_with_recovery(step_fn: Callable, state, n_steps: int,
                      batch_fn: Callable[[int], dict],
                      save_fn: Callable[[dict, int], None],
                      restore_fn: Callable[[], Tuple[dict, int]],
                      checkpoint_every: int = 10,
                      failure_injector: Optional[Callable[[int], bool]] = None,
                      max_restarts: int = 25,
                      ) -> Tuple[dict, List[RecoveryEvent], list]:
    """Driver loop with checkpoint/restart.  `failure_injector(step)` lets
    tests kill the run deterministically; production wires it to the
    heartbeat registry.

    Restores rewind `step` to the latest checkpoint, so any metrics
    recorded past that point are rolled back too (replayed steps would
    otherwise append duplicates); on success ``len(metrics_log) ==
    n_steps`` exactly.  `max_restarts` bounds the retry loop: a
    deterministic injector that fires again at the restored step would
    otherwise spin forever."""
    events: List[RecoveryEvent] = []
    metrics_log = []
    step = 0
    restarts = 0
    while step < n_steps:
        try:
            if failure_injector is not None and failure_injector(step):
                raise RuntimeError(f"injected worker failure at step {step}")
            state, metrics = step_fn(state, batch_fn(step))
            metrics_log.append(metrics)
            step += 1
            if step % checkpoint_every == 0:
                save_fn(state, step)
        except RuntimeError:
            restarts += 1
            if restarts > max_restarts:
                raise RuntimeError(
                    f"run_with_recovery: exceeded max_restarts="
                    f"{max_restarts} at step {step}; the failure keeps "
                    f"recurring at the restored step (deterministic "
                    f"injector or persistently bad worker) — evict the "
                    f"worker or raise max_restarts")
            state, step = restore_fn()
            # roll the metrics log back with the state: entries for steps
            # >= the restore point are about to be replayed
            del metrics_log[step:]
            events.append(RecoveryEvent(step, "failure", [], ()))
    return state, events, metrics_log
