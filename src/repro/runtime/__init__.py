from .sharding import (batch_spec, cache_shardings, cache_spec,
                       logical_batch_shardings, param_spec, params_shardings)
from .train import TrainConfig, make_train_step, make_loss_fn, cross_entropy
from .serve import ServeConfig, make_serve_fns, generate
from .compression import (CompressionConfig, compress_decompress,
                          compress_with_error_feedback, init_residual)
from .fault_tolerance import (ElasticPlan, Heartbeat, StragglerMitigator,
                              run_with_recovery)
