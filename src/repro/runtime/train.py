"""Training step: CE loss, remat, gradient accumulation, optional gradient
compression, optimizer update.  All control flow is jax.lax; the whole
step jits to one XLA program whose collectives the hybrid-plane scheduler
(core/hybrid_schedule.py) consumes.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.optim.optimizers import OptimizerConfig, build_optimizer
from .compression import CompressionConfig, compress_decompress


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: OptimizerConfig = OptimizerConfig()
    microbatches: int = 1            # gradient accumulation
    aux_loss_weight: float = 0.01    # MoE load-balance loss
    z_loss_weight: float = 1e-4      # logit normalisation loss
    compression: Optional[CompressionConfig] = None
    attention_impl: str = "auto"
    remat: bool = True
    loss_impl: str = "onehot"        # "onehot" (shard-local) | "gather"


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  z_loss_weight: float = 0.0,
                  impl: str = "onehot") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mean CE over tokens (+z-loss). logits fp32 (B,S,V), labels (B,S).

    impl="gather" (take_along_axis) makes GSPMD all-gather vocab-sharded
    logits; impl="onehot" expresses the label pick as an iota-compare
    masked reduction, which stays shard-local (+ a scalar psum).  The
    before/after is logged in EXPERIMENTS.md SPerf (hillclimb H1)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    if impl == "gather":
        ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    else:
        V = logits.shape[-1]
        hit = (jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                        logits.ndim - 1)
               == labels[..., None])
        ll = jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
    ce = (lse - ll).mean()
    zl = (lse ** 2).mean()
    return ce + z_loss_weight * zl, ce


def make_loss_fn(cfg: ModelConfig, tcfg: TrainConfig):
    model = build_model(cfg, impl=tcfg.attention_impl, remat=tcfg.remat)

    def loss_fn(params, batch):
        logits, aux = model.apply(params, batch)
        loss, ce = cross_entropy(logits, batch["labels"],
                                 tcfg.z_loss_weight, tcfg.loss_impl)
        total = loss + tcfg.aux_loss_weight * aux
        return total, {"ce": ce, "aux": aux}

    return loss_fn


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params", "opt", "step"}.  With microbatches > 1 the batch's
    leading axis is split and gradients accumulate in a lax.scan (same
    math, 1/k activation memory).
    """
    loss_fn = make_loss_fn(cfg, tcfg)
    opt = build_optimizer(tcfg.optimizer)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if tcfg.microbatches <= 1:
            (loss, m), grads = grad_fn(params, batch)
            return loss, m, grads
        k = tcfg.microbatches

        def split(x):
            return x.reshape((k, x.shape[0] // k) + x.shape[1:])

        micro = jax.tree.map(split, batch)

        def body(carry, mb):
            acc, loss_a, ce_a, aux_a = carry
            (loss, m), g = grad_fn(params, mb)
            acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32) / k, acc, g)
            return (acc, loss_a + loss / k, ce_a + m["ce"] / k,
                    aux_a + m["aux"] / k), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (grads, loss, ce, aux), _ = jax.lax.scan(
            body, (zeros, 0.0, 0.0, 0.0), micro)
        return loss, {"ce": ce, "aux": aux}, grads

    def train_step(state, batch):
        params, opt_state, step = state["params"], state["opt"], state["step"]
        loss, metrics, grads = compute_grads(params, batch)
        if tcfg.compression is not None:
            grads = compress_decompress(grads, tcfg.compression)
        new_params, new_opt = opt.update(grads, opt_state, params, step)
        metrics = dict(metrics, loss=loss)
        return {"params": new_params, "opt": new_opt, "step": step + 1}, \
            metrics

    def init_state(key):
        model = build_model(cfg, impl=tcfg.attention_impl, remat=tcfg.remat)
        params = model.init(key)
        return {"params": params, "opt": opt.init(params),
                "step": jnp.zeros((), jnp.int32)}

    return train_step, init_state
