"""Serving: batched prefill + single-token decode steps.

`serve_step` is what the decode_* dry-run shapes lower: one new token per
sequence against a KV cache of the cell's seq_len.  A tiny continuous-
batching scheduler drives it in examples/serve_lm.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import build_model


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 2048
    attention_impl: str = "auto"
    temperature: float = 0.0          # 0 => greedy


def make_serve_fns(cfg: ModelConfig, scfg: ServeConfig):
    model = build_model(cfg, impl=scfg.attention_impl, remat=False)

    def prefill(params, batch) -> Tuple[jnp.ndarray, Any]:
        """Full-sequence forward; returns last-position logits + nothing
        cache-ful (the dry-run decode cells build the cache abstractly)."""
        logits, _ = model.apply(params, batch)
        return logits[:, -1]

    def decode_step(params, cache, token, pos):
        logits, cache = model.decode(params, cache, token, pos)
        if scfg.temperature == 0.0:
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        else:
            key = jax.random.PRNGKey(0)
            nxt = jax.random.categorical(
                key, logits[:, -1] / scfg.temperature).astype(jnp.int32)
        return nxt[:, None], logits, cache

    def init_cache(batch_size: int, max_len: int = None, src_len: int = 1024):
        return model.init_cache(batch_size, max_len or scfg.max_len,
                                src_len)

    return prefill, decode_step, init_cache


def generate(params, cfg: ModelConfig, prompt: jnp.ndarray, n_tokens: int,
             scfg: ServeConfig | None = None) -> jnp.ndarray:
    """Greedy generation loop (example driver; jit per step)."""
    scfg = scfg if scfg is not None else ServeConfig()
    prefill, decode_step, init_cache = make_serve_fns(cfg, scfg)
    B, P = prompt.shape
    cache = init_cache(B, P + n_tokens + 1)
    dec = jax.jit(decode_step)
    # feed the prompt through decode steps (simple, cache-exact)
    tok = prompt[:, :1]
    out = [tok]
    for i in range(P + n_tokens - 1):
        nxt, _, cache = dec(params, cache, tok, jnp.int32(i))
        tok = prompt[:, i + 1:i + 2] if i + 1 < P else nxt
        out.append(tok)
    return jnp.concatenate(out, axis=1)
