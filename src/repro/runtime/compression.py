"""Gradient compression: int8 block quantisation with error feedback.

Distributed-optimization trick for the DP/pod axis: gradients are
quantised to int8 (per-block scales) before the data-parallel all-reduce
and dequantised after, cutting cross-pod reduction volume ~4x.  In the
jit/GSPMD formulation the quantise->dequantise pair brackets the gradient
computation so the compiler's all-reduce operates on the coarse values;
`compress_decompress` is the numerics (and the piece that is unit-tested
— error stays bounded and error-feedback residual corrects the bias over
steps when used statefully).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    block: int = 256          # values per quantisation block
    dtype: Any = jnp.int8


def _quantize(x: jnp.ndarray, block: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-30)), -127, 127)
    return q.astype(jnp.int8), scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray, shape, size) -> jnp.ndarray:
    out = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return out.reshape(shape)


def compress_decompress(grads: Any, cfg: CompressionConfig) -> Any:
    """Quantise+dequantise each gradient leaf (the all-reduce sits between
    these in the compiled program; GSPMD reduces the int8-rank values)."""
    def per_leaf(g):
        if g.size < cfg.block:
            return g
        q, s = _quantize(g, cfg.block)
        return _dequantize(q, s, g.shape, g.size).astype(g.dtype)

    return jax.tree.map(per_leaf, grads)


def compress_with_error_feedback(grads: Any, residual: Any,
                                 cfg: CompressionConfig) -> Tuple[Any, Any]:
    """Stateful variant: quantisation error accumulates in `residual` and
    is re-injected next step (unbiased in the long run)."""
    def per_leaf(g, r):
        if g.size < cfg.block:
            return g, jnp.zeros_like(r)
        corrected = g.astype(jnp.float32) + r
        q, s = _quantize(corrected, cfg.block)
        approx = _dequantize(q, s, g.shape, g.size)
        return approx.astype(g.dtype), corrected - approx

    leaves, treedef = jax.tree_util.tree_flatten(grads)
    rl = treedef.flatten_up_to(residual)
    out = [per_leaf(g, r) for g, r in zip(leaves, rl)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def init_residual(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
