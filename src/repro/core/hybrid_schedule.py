"""The paper's technique at LM scale: hybrid plane scheduling for the
collectives of the compiled training/serving step.

TPU mapping (DESIGN.md S3): the wired plane is the ICI torus; the second,
broadcast-natured plane is a shared-medium overlay (in-package wireless on
future parts, or the DCN/host network today) with single-hop semantics.
The dry-run gives us, per (arch x shape x mesh) cell, the exact per-op
collective payload bytes of the compiled XLA program; this module

1. classifies each collective as *multicast-shaped* (all-gather,
   all-to-all's broadcast half, collective-permute fan-outs) or
   *reduction-shaped* (all-reduce, reduce-scatter),
2. applies the paper's decision function — multicast => eligible;
   ring radius (the ICI analogue of NoP hop distance) over threshold =>
   eligible; injection probability caps the steered fraction,
3. costs both planes:   wired: ring schedule over ICI links,
                        overlay: volume / shared broadcast bandwidth
   and reports the collective-term speedup plus the end-to-end effect on
   the cell's roofline step time,
4. `balance_cell` water-fills volume between the planes (the paper's
   open load-balancing problem, solved the same way as core/balancer.py
   does at package scale — closed-form here because both plane costs are
   linear in volume).

The broadcast-plane constants are deliberately conservative: 100 GB/s of
shared broadcast bandwidth per pod (~2 ICI links' worth, cf. the paper's
64/96 Gb/s vs 32 Gb/s NoP sides which gave it 2-3 links' worth).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.launch.roofline import ICI_BW, ICI_LINKS

OVERLAY_BW = 100e9          # B/s shared broadcast plane, per pod
MULTICAST_OPS = ("all-gather", "all-gather-start", "all-to-all",
                 "collective-permute", "collective-permute-start")
REDUCTION_OPS = ("all-reduce", "all-reduce-start", "reduce-scatter")


@dataclasses.dataclass(frozen=True)
class PlaneConfig:
    overlay_bw: float = OVERLAY_BW
    distance_threshold: int = 1       # ring-radius hops
    injection_prob: float = 0.5
    ring_radius: int = 8              # 16-wide mesh axis => radius 8


@dataclasses.dataclass
class CollectiveFlow:
    op: str
    payload_bytes: float              # per device, per step
    multicast: bool
    hops: int

    @property
    def wired_link_bytes(self) -> float:
        # ring transfer factor (2x for AR: reduce + broadcast phases)
        f = 2.0 if self.op.startswith("all-reduce") else 1.0
        return self.payload_bytes * f


def flows_from_coll_per_op(coll_per_op: Dict[str, float],
                           ring_radius: int = 8) -> List[CollectiveFlow]:
    out = []
    for op, payload in coll_per_op.items():
        mc = op in MULTICAST_OPS
        out.append(CollectiveFlow(op, float(payload), mc, ring_radius))
    return out


def eligible_volume(flows: List[CollectiveFlow],
                    pcfg: PlaneConfig) -> float:
    """Paper decision criteria 1+2 at LM scale: multicast-shaped, or
    spanning more ring hops than the threshold.  All-reduce contributes
    its broadcast (all-gather) HALF when eligible by distance."""
    v = 0.0
    for f in flows:
        if f.multicast and f.hops >= pcfg.distance_threshold:
            v += f.payload_bytes
        elif not f.multicast and f.hops > pcfg.distance_threshold:
            v += 0.5 * f.wired_link_bytes     # the AG half of the AR ring
    return v


def wired_time(flows: List[CollectiveFlow], offloaded: float = 0.0) -> float:
    total = sum(f.wired_link_bytes for f in flows)
    return max(0.0, total - offloaded) / (ICI_LINKS * ICI_BW)


def overlay_time(volume: float, pcfg: PlaneConfig) -> float:
    return volume / pcfg.overlay_bw


@dataclasses.dataclass
class CellSchedule:
    t_coll_wired: float
    t_coll_hybrid: float
    offloaded_bytes: float
    injected_fraction: float
    coll_speedup: float
    step_speedup: float


def schedule_cell(coll_per_op: Dict[str, float], t_compute: float,
                  t_memory: float, pcfg: PlaneConfig) -> CellSchedule:
    """Paper decision function with fixed (threshold, injection)."""
    flows = flows_from_coll_per_op(coll_per_op, pcfg.ring_radius)
    elig = eligible_volume(flows, pcfg)
    off = elig * pcfg.injection_prob
    t_wired = wired_time(flows)
    t_hybrid = max(wired_time(flows, off), overlay_time(off, pcfg))
    base_step = max(t_compute, t_memory, t_wired)
    new_step = max(t_compute, t_memory, t_hybrid)
    return CellSchedule(
        t_coll_wired=t_wired, t_coll_hybrid=t_hybrid, offloaded_bytes=off,
        injected_fraction=pcfg.injection_prob,
        coll_speedup=t_wired / t_hybrid if t_hybrid else 1.0,
        step_speedup=base_step / new_step if new_step else 1.0)


def sweep_cell(coll_per_op: Dict[str, float], t_compute: float,
               t_memory: float,
               overlay_bw: float = OVERLAY_BW
               ) -> Tuple[CellSchedule, Tuple[int, float]]:
    """The paper's (threshold x injection) sweep on one LM cell."""
    best, best_cfg = None, (1, 0.1)
    for thr in (1, 2, 4, 8):
        for p in [0.1 + 0.05 * i for i in range(15)]:
            pcfg = PlaneConfig(overlay_bw, thr, round(p, 2))
            s = schedule_cell(coll_per_op, t_compute, t_memory, pcfg)
            if best is None or s.step_speedup > best.step_speedup:
                best, best_cfg = s, (thr, round(p, 2))
    return best, best_cfg


def balance_cell(coll_per_op: Dict[str, float], t_compute: float,
                 t_memory: float,
                 overlay_bw: float = OVERLAY_BW) -> CellSchedule:
    """Beyond-paper water-filling: both plane costs are linear in the
    offloaded volume v, so the balance point is closed-form:

        (L - v) / B_ici = v / B_wl  =>  v* = L * B_wl / (B_ici + B_wl)

    clipped to the eligible volume and to the point where compute/memory
    dominates anyway (no benefit past the roofline floor)."""
    pcfg = PlaneConfig(overlay_bw, 1, 1.0)
    flows = flows_from_coll_per_op(coll_per_op, pcfg.ring_radius)
    L = sum(f.wired_link_bytes for f in flows)
    elig = eligible_volume(flows, pcfg)
    b_ici = ICI_LINKS * ICI_BW
    v_star = L * overlay_bw / (b_ici + overlay_bw)
    v = min(v_star, elig)
    t_wired = wired_time(flows)
    t_hybrid = max(wired_time(flows, v), overlay_time(v, pcfg))
    base_step = max(t_compute, t_memory, t_wired)
    new_step = max(t_compute, t_memory, t_hybrid)
    return CellSchedule(
        t_coll_wired=t_wired, t_coll_hybrid=t_hybrid, offloaded_bytes=v,
        injected_fraction=v / elig if elig else 0.0,
        coll_speedup=t_wired / t_hybrid if t_hybrid else 1.0,
        step_speedup=base_step / new_step if new_step else 1.0)
