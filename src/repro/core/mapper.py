"""Spatial mapping of workload layers onto chiplets (GEMINI-style, simplified).

GEMINI co-explores mapping with architecture using SET; its headline
property for our purposes is that every layer is *spatially partitioned*
across the chiplet array (output-channel / output-row tiling) and that
tensors produced under one partitioning are multicast to the consumers of
the next.  We implement that canonical spatial mapping:

- every layer with MACs is split across all compute chiplets
  (output-channel tiling, equal shares);
- pure data-movement layers (concat/add joins) inherit the partitioning of
  their producers, so an aligned join generates no NoP traffic;
- tensors consumed "far" in program order (> `spill_window` layers after
  production) are spilled to DRAM and re-fetched — GEMINI's
  communication-aware data placement heuristic.

The mapper returns, per layer, the chiplet share vector.  The traffic
generator (`traffic.py`) turns mapping + graph into messages.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, TYPE_CHECKING

import numpy as np

from .topology import Topology
from .workloads import Layer

if TYPE_CHECKING:   # runtime import stays in-function: collectives ->
    from .collectives import CollectiveSpec   # traffic -> mapper cycle


@dataclasses.dataclass
class Mapping:
    """Per-layer chiplet placement (+ the collectives it requires)."""

    chiplets: List[Sequence[int]]      # chiplet ids executing each layer
    shares: List[np.ndarray]           # fraction of the layer per chiplet
    spill_window: int = 4              # program-order distance before DRAM spill
    # collective phases the mapping emits at layer boundaries
    # (tensor-parallel all-reduces, MoE all-to-alls, ...); lowered to
    # messages by `traffic.generate_messages` via `collectives.lower`
    collectives: List["CollectiveSpec"] = dataclasses.field(
        default_factory=list)

    def share_of(self, layer: int, chiplet: int) -> float:
        seq = list(self.chiplets[layer])
        if chiplet not in seq:
            return 0.0
        return float(self.shares[layer][seq.index(chiplet)])


def chiplet_rates(topo: Topology) -> np.ndarray | None:
    """Per-chiplet compute rates (ops/s), or `None` for a uniform package.

    Heterogeneous packages (`repro.arch.HeteroPackage`) carry a per-slot
    rate vector on the lowered `AcceleratorConfig`; a missing or
    all-equal vector means every legacy uniform-split expression applies
    unchanged (the homogeneous-parity contract).
    """
    r = topo.config.chiplet_tops
    if r is None:
        return None
    v = np.asarray(r, float)
    return None if np.all(v == v[0]) else v


def spatial_mapping(layers: List[Layer], topo: Topology,
                    spill_window: int = 4) -> Mapping:
    """Canonical GEMINI-like mapping: full spatial split of every layer.

    On a heterogeneous package the output-channel tiling is
    compute-balanced — each chiplet's share is proportional to its rate,
    so every chiplet finishes a layer at the same time (join/identity
    layers inherit the same partitioning, staying NoP-free).
    """
    n = topo.config.n_chiplets
    all_chips = tuple(range(n))
    rates = chiplet_rates(topo)
    share = (np.full((n,), 1.0 / n) if rates is None
             else rates / rates.sum())
    chiplets = [all_chips for _ in layers]
    shares = [share for _ in layers]
    return Mapping(chiplets, shares, spill_window)


def snake_order(topo: Topology) -> List[int]:
    """Boustrophedon chiplet order: consecutive pipeline stages adjacent."""
    rows, cols = topo.config.grid
    order = []
    for r in range(rows):
        cs = range(cols) if r % 2 == 0 else range(cols - 1, -1, -1)
        order.extend(r * cols + c for c in cs)
    return order


def pipeline_mapping(layers: List[Layer], topo: Topology,
                     n_stages: int | None = None,
                     spill_window: int = 6, refine: bool = True) -> Mapping:
    """GEMINI/SET-style inter-layer pipelined mapping (the default).

    Layers are packed into MAC-balanced contiguous pipeline stages; stage i
    runs on one chiplet, placed in snake order so consecutive stages are
    mesh neighbours (SET's locality-aware placement).  Cross-stage tensor
    edges become NoP transfers; *fan-out* edges reaching several stages
    become multicast — the traffic pattern the paper identifies as the NoP
    congestion source.
    """
    n = topo.config.n_chiplets
    # pipeline depth never exceeds half the layer count: a sensible mapper
    # does not spray a 10-layer workload over 9 single-layer stages
    n_stages = min(n_stages or n, n, max(1, len(layers) // 3))
    order = snake_order(topo)
    total = sum(lyr.macs for lyr in layers) or 1.0
    # every stage owns a contiguous chiplet group; when stages don't divide
    # the array the first n % n_stages stages take one extra chiplet, so
    # ALL chiplets are used (the trailing remainder used to sit idle)
    k, rem = divmod(n, n_stages)
    sizes = [k + (s < rem) for s in range(n_stages)]
    starts = [0]
    for sz in sizes:
        starts.append(starts[-1] + sz)
    groups = [tuple(order[starts[s]:starts[s + 1]]) for s in range(n_stages)]
    # MAC-balanced contiguous segmentation; on a heterogeneous package
    # the per-stage MAC target is proportional to the stage group's
    # aggregate compute rate rather than to its 1/n_stages head count
    rates = chiplet_rates(topo)
    if rates is not None:
        grp_rate = np.array([sum(rates[c] for c in g) for g in groups])
        cum_share = np.cumsum(grp_rate) / grp_rate.sum()
    acc, stage = 0.0, 0
    stage_of: List[int] = []
    for lyr in layers:
        stage_of.append(stage)
        acc += lyr.macs
        while (stage < n_stages - 1
               and acc >= (total * cum_share[stage] if rates is not None
                           else total * (stage + 1) / n_stages)):
            stage += 1
    # ...refined communication-aware: nudge each stage boundary (within a
    # small window) to the cut with the smallest crossing tensor, as a
    # mapping/communication co-optimising mapper (GEMINI/SET) would.
    W = max(1, len(layers) // (4 * n_stages)) if refine else 0
    for s in range(1, n_stages):
        if not W:
            break
        idxs = [i for i, st in enumerate(stage_of) if st == s]
        if not idxs:
            continue
        b = idxs[0]
        lo, hi = max(1, b - W), min(len(layers) - 1, b + W)
        best = min(range(lo, hi + 1),
                   key=lambda i: layers[i - 1].act_out)
        for i in range(min(b, best), max(b, best)):
            stage_of[i] = s if best < b else s - 1
    def _group_shares(g):
        """Within-group split: uniform, or rate-proportional on hetero."""
        if rates is None:
            return np.full((len(g),), 1.0 / len(g))
        v = rates[list(g)]
        return v / v.sum()

    chiplets: List[Sequence[int]] = [groups[s] for s in stage_of]
    shares = [_group_shares(groups[s]) for s in stage_of]
    # Weight-heavy layers (big FC / gate matrices) are spatially spread so
    # per-chiplet weight slices fit the SRAM budget — widening outward from
    # the layer's own stage group (GEMINI splits such layers spatially).
    # The budget is per-chiplet on heterogeneous packages (the group's
    # tightest slot, matching traffic._layer_sram's streamed-vs-resident
    # gate); uniform packages keep the calibrated global constant.
    from .traffic import WEIGHT_SRAM_BYTES  # calibrated constant
    sram_vec = topo.config.chiplet_sram
    for i, lyr in enumerate(layers):
        budget = (WEIGHT_SRAM_BYTES if sram_vec is None
                  else min(sram_vec[c] for c in chiplets[i]))
        if lyr.weights > budget:
            need = int(np.ceil(lyr.weights / budget))
            w = sizes[stage_of[i]]
            while w < min(need, n):
                w += max(1, k)
            w = min(w, n)
            start = starts[stage_of[i]]
            chiplets[i] = tuple(order[(start + j) % n] for j in range(w))
            shares[i] = _group_shares(chiplets[i])
    return Mapping(list(chiplets), shares, spill_window)


def _full_spread(layers: List[Layer], topo: Topology):
    """All layers on all chiplets, snake order (ring-adjacent neighbours).

    Shards are uniform on a homogeneous package and rate-proportional on
    a heterogeneous one (compute-balanced tensor/expert parallelism)."""
    parts = tuple(snake_order(topo))
    rates = chiplet_rates(topo)
    share = (np.full((len(parts),), 1.0 / len(parts)) if rates is None
             else rates[list(parts)] / rates[list(parts)].sum())
    return parts, [parts] * len(layers), [share] * len(layers)


def tensor_parallel_mapping(layers: List[Layer], topo: Topology,
                            spill_window: int = 4,
                            algorithm: str = "tree") -> Mapping:
    """Tensor-parallel mapping: every layer sharded across all chiplets.

    Weights are input-dim sharded (Megatron row-parallel), so layer
    outputs are *partial sums* that must be all-reduced across the
    chiplet group at layer boundaries.  Graphs that hint their sync
    points (`Layer.collective == "all_reduce"`, e.g. the o-proj / ff2
    boundaries the LLM builder marks) all-reduce only there — the
    Megatron 2-per-block pattern; unhinted graphs (the CNN zoo)
    all-reduce after every MAC layer.

    ``algorithm="tree"`` (default) reduces up a binary tree and fans the
    result out as ONE multicast — wired-suboptimal but broadcast-natured,
    i.e. the collective a hybrid NoP can serve in a single wireless slot
    (the dataflow/architecture co-design of arXiv:2011.14755).
    ``algorithm="ring"`` is the classic wired-optimal bandwidth ring
    whose neighbour unicasts stay on the mesh.

    Inter-layer activations stay chiplet-local (the group and tiling
    match producer to consumer), so the collectives ARE the mapping's
    NoP traffic — plus streamed weights and DRAM spills.
    """
    from .collectives import CollectiveSpec
    parts, chiplets, shares = _full_spread(layers, topo)
    hinted = any(lyr.collective for lyr in layers)
    specs = []
    for i, lyr in enumerate(layers):
        if hinted:
            sync = lyr.collective in ("all_reduce", "moe")
        else:
            sync = lyr.macs > 0 and lyr.act_out > 0
        if sync and lyr.act_out > 0:
            specs.append(CollectiveSpec("all_reduce", i, parts,
                                        float(lyr.act_out),
                                        algorithm=algorithm))
    return Mapping(chiplets, shares, spill_window, specs)


def expert_parallel_mapping(layers: List[Layer], topo: Topology,
                            spill_window: int = 4) -> Mapping:
    """Expert-parallel mapping for MoE graphs (hybrid EP + TP).

    Expert layers (`Layer.collective == "moe"`) spread their expert
    pool across all chiplets; each MoE boundary emits the all-to-all
    pair:

    - **dispatch**: a token goes to `experts_per_token` experts with the
      SAME activation payload, so each source chiplet's local token
      block is one multicast to the expert-owner chiplets it hits
      (`fanout = experts_per_token`) — broadcast-natured,
      wireless-eligible.  With ``experts_per_token == 1`` it decays to
      plain distinct-shard unicasts.
    - **combine**: per-token expert partial outputs are distinct per
      destination — a classic unicast all-to-all of
      ``experts_per_token``-scaled volume back to the token homes.

    Dense sublayers keep their tensor-parallel all-reduces (tree form)
    and ``"broadcast"``-hinted layers (router state) fan out from their
    first chiplet.  Raises on graphs with no ``"moe"`` layer — use
    `tensor_parallel_mapping` or `pipeline_mapping` there.
    """
    from .collectives import CollectiveSpec
    if not any(lyr.collective == "moe" for lyr in layers):
        raise ValueError("expert_parallel_mapping needs a graph with "
                         "'moe'-hinted layers (see workloads_llm); use "
                         "tensor_parallel_mapping for dense graphs")
    parts, chiplets, shares = _full_spread(layers, topo)
    k = len(parts)
    specs = []
    for i, lyr in enumerate(layers):
        if lyr.collective == "moe":
            ept = max(1, lyr.experts_per_token)
            specs.append(CollectiveSpec("all_to_all", i, parts,
                                        float(lyr.act_in) / k, fanout=ept))
            specs.append(CollectiveSpec("all_to_all", i, parts,
                                        float(lyr.act_out) * ept / k))
        elif lyr.collective == "all_reduce":
            specs.append(CollectiveSpec("all_reduce", i, parts,
                                        float(lyr.act_out),
                                        algorithm="tree"))
        elif lyr.collective == "broadcast":
            specs.append(CollectiveSpec("broadcast", i, parts,
                                        float(lyr.act_out)))
    return Mapping(chiplets, shares, spill_window, specs)
