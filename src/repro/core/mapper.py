"""Spatial mapping of workload layers onto chiplets (GEMINI-style, simplified).

GEMINI co-explores mapping with architecture using SET; its headline
property for our purposes is that every layer is *spatially partitioned*
across the chiplet array (output-channel / output-row tiling) and that
tensors produced under one partitioning are multicast to the consumers of
the next.  We implement that canonical spatial mapping:

- every layer with MACs is split across all compute chiplets
  (output-channel tiling, equal shares);
- pure data-movement layers (concat/add joins) inherit the partitioning of
  their producers, so an aligned join generates no NoP traffic;
- tensors consumed "far" in program order (> `spill_window` layers after
  production) are spilled to DRAM and re-fetched — GEMINI's
  communication-aware data placement heuristic.

The mapper returns, per layer, the chiplet share vector.  The traffic
generator (`traffic.py`) turns mapping + graph into messages.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from .topology import Topology
from .workloads import Layer


@dataclasses.dataclass
class Mapping:
    """Per-layer chiplet placement."""

    chiplets: List[Sequence[int]]      # chiplet ids executing each layer
    shares: List[np.ndarray]           # fraction of the layer per chiplet
    spill_window: int = 4              # program-order distance before DRAM spill

    def share_of(self, layer: int, chiplet: int) -> float:
        seq = list(self.chiplets[layer])
        if chiplet not in seq:
            return 0.0
        return float(self.shares[layer][seq.index(chiplet)])


def spatial_mapping(layers: List[Layer], topo: Topology,
                    spill_window: int = 4) -> Mapping:
    """Canonical GEMINI-like mapping: full spatial split of every layer."""
    n = topo.config.n_chiplets
    all_chips = tuple(range(n))
    uniform = np.full((n,), 1.0 / n)
    chiplets, shares = [], []
    for lyr in layers:
        if lyr.macs == 0 and lyr.weights == 0:
            # join/identity layer: inherits producer partitioning
            chiplets.append(all_chips)
            shares.append(uniform)
        else:
            chiplets.append(all_chips)
            shares.append(uniform)
    return Mapping(chiplets, shares, spill_window)


def snake_order(topo: Topology) -> List[int]:
    """Boustrophedon chiplet order: consecutive pipeline stages adjacent."""
    rows, cols = topo.config.grid
    order = []
    for r in range(rows):
        cs = range(cols) if r % 2 == 0 else range(cols - 1, -1, -1)
        order.extend(r * cols + c for c in cs)
    return order


def pipeline_mapping(layers: List[Layer], topo: Topology,
                     n_stages: int | None = None,
                     spill_window: int = 6, refine: bool = True) -> Mapping:
    """GEMINI/SET-style inter-layer pipelined mapping (the default).

    Layers are packed into MAC-balanced contiguous pipeline stages; stage i
    runs on one chiplet, placed in snake order so consecutive stages are
    mesh neighbours (SET's locality-aware placement).  Cross-stage tensor
    edges become NoP transfers; *fan-out* edges reaching several stages
    become multicast — the traffic pattern the paper identifies as the NoP
    congestion source.
    """
    n = topo.config.n_chiplets
    # pipeline depth never exceeds half the layer count: a sensible mapper
    # does not spray a 10-layer workload over 9 single-layer stages
    n_stages = min(n_stages or n, n, max(1, len(layers) // 3))
    order = snake_order(topo)
    total = sum(lyr.macs for lyr in layers) or 1.0
    # MAC-balanced contiguous segmentation...
    acc, stage = 0.0, 0
    stage_of: List[int] = []
    for lyr in layers:
        stage_of.append(stage)
        acc += lyr.macs
        while (stage < n_stages - 1
               and acc >= total * (stage + 1) / n_stages):
            stage += 1
    # ...refined communication-aware: nudge each stage boundary (within a
    # small window) to the cut with the smallest crossing tensor, as a
    # mapping/communication co-optimising mapper (GEMINI/SET) would.
    W = max(1, len(layers) // (4 * n_stages)) if refine else 0
    for s in range(1, n_stages):
        if not W:
            break
        idxs = [i for i, st in enumerate(stage_of) if st == s]
        if not idxs:
            continue
        b = idxs[0]
        lo, hi = max(1, b - W), min(len(layers) - 1, b + W)
        best = min(range(lo, hi + 1),
                   key=lambda i: layers[i - 1].act_out)
        for i in range(min(b, best), max(b, best)):
            stage_of[i] = s if best < b else s - 1
    # every stage owns an equal contiguous chiplet group (all chiplets are
    # used even when the pipeline is shallow)
    k = n // n_stages
    groups = [tuple(order[s * k:(s + 1) * k]) or (order[0],)
              for s in range(n_stages)]
    chiplets: List[Sequence[int]] = [groups[s] for s in stage_of]
    shares = [np.full((len(groups[s]),), 1.0 / len(groups[s]))
              for s in stage_of]
    # Weight-heavy layers (big FC / gate matrices) are spatially spread so
    # per-chiplet weight slices fit the SRAM budget — widening outward from
    # the layer's own stage group (GEMINI splits such layers spatially).
    from .traffic import WEIGHT_SRAM_BYTES  # calibrated constant
    for i, lyr in enumerate(layers):
        if lyr.weights > WEIGHT_SRAM_BYTES:
            need = int(np.ceil(lyr.weights / WEIGHT_SRAM_BYTES))
            w = k
            while w < min(need, n):
                w += k
            w = min(w, n)
            start = stage_of[i] * k
            chiplets[i] = tuple(order[(start + j) % n] for j in range(w))
            shares[i] = np.full((w,), 1.0 / w)
    return Mapping(list(chiplets), shares, spill_window)
