"""Beyond-paper: analytic wired/wireless load balancer.

The paper sweeps (distance threshold x injection probability) and notes
that a "mechanism to balance the load between the wired and wireless
planes" is needed (SIV-B, SV) but leaves it to future work.  We build it.

Observation: per layer, the hybrid layer time is

    T(v) = max(T_rest, worst_cut_wired(V - v) / BW_cut, T_mac(v))

where v is the volume steered to the wireless plane out of the eligible
volume V and T_mac is the MAC-costed service time of the hottest
wireless channel.  The wired term falls and the wireless term rises
monotonically in v, so the optimum equalises them (water-filling),
clipped by eligibility and by T_rest (compute/DRAM/NoC floor) — there
is no benefit in rebalancing past the point where another element is
the bottleneck.

Greedy realisation: per layer, repeatedly move the eligible packet that
contributes most to the currently hottest mesh cut, while the hottest
wireless *channel* (under the configured MAC protocol and channel
plan) finishes no later than the hottest wired cut and the NoP still
exceeds the layer's floor.  A packet whose acceptance would overshoot
the wired time is discarded from candidacy (the wired side only gets
cheaper and the wireless side only costlier, so it can never become
acceptable later) and the search continues with smaller contributors.

The greedy pass is then anchored against the paper's sweep: the best
static (threshold x injection) grid point is evaluated on the same
trace/network, and each layer keeps whichever injected set — greedy
water-filling or the grid optimum — projects the smaller layer time
(layers are independent in the analytic model, so the per-layer stitch
is exact).  The balancer therefore matches or beats every (threshold,
injection) grid point *by construction*, not just empirically —
verified in tests/test_paper_repro.py and tests/test_net.py.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.net.config import NetworkConfig, as_network
from repro.net.mac import mac_times
from repro.net.stack import network_layer_times
from repro.obs.trace import active_recorder, recording

from .simulator import SimResult, _finalize, energy_joules, simulate_wired
from .topology import node_grid_coords
from .traffic import TrafficTrace
from .wireless import (WirelessConfig, eligibility, injection_filter,
                       wireless_energy_joules)


def _geometry(trace: TrafficTrace) -> dict:
    """`network_layer_times` geometry kwargs (spatial-reuse plans)."""
    return dict(grid=trace.topo.config.grid,
                node_coords=node_grid_coords(trace.topo),
                max_hops=trace.max_hops)


@dataclasses.dataclass
class BalancerResult:
    sim: SimResult
    injected: np.ndarray          # bool per packet
    speedup_vs_wired: float
    injected_fraction: float      # of eligible volume


def _mask_parts(trace: TrafficTrace, mask: np.ndarray, net: NetworkConfig,
                cut_mat: np.ndarray, cut_bw: np.ndarray):
    """Per-layer (link loads, wired NoP time, wireless time) of a mask."""
    loads = trace.baseline_link_loads()
    edges = mask[trace.inc_msg]
    np.subtract.at(
        loads,
        (trace.layer[trace.inc_msg[edges]], trace.inc_link[edges]),
        trace.nbytes[trace.inc_msg[edges]])
    t_wl, _, _ = network_layer_times(
        trace.n_layers, trace.layer, trace.nbytes, trace.src,
        trace.topo.n_nodes, mask, net, **_geometry(trace))
    t_nop = ((loads @ cut_mat / cut_bw).max(axis=1) if loads.size
             else np.zeros(trace.n_layers))
    return loads, t_nop, t_wl


def _stitch_best(trace: TrafficTrace, net: NetworkConfig,
                 greedy_mask: np.ndarray, t_rest: np.ndarray,
                 cut_mat: np.ndarray, cut_bw: np.ndarray):
    """Per-layer stitch of the greedy mask against the best grid point.

    Trial evaluations (the anchor sweep and both candidate costings)
    run with the recorder masked — only the final chosen timeline is
    ever emitted into an active `SimTrace`.
    """
    from .dse import grid_anchor    # no cycle: dse doesn't import us
    with recording(None):
        _, thr, p = grid_anchor(trace, net)
        grid_mask = (eligibility(trace, thr)
                     & injection_filter(len(trace.nbytes), p))
        gl, gnop, gwl = _mask_parts(trace, grid_mask, net, cut_mat, cut_bw)
        bl, bnop, bwl = _mask_parts(trace, greedy_mask, net, cut_mat,
                                    cut_bw)
    t_grid = np.maximum.reduce([t_rest, gnop, gwl])
    t_greedy = np.maximum.reduce([t_rest, bnop, bwl])
    use_grid = t_grid < t_greedy            # prefer greedy on ties
    final = np.where(use_grid[trace.layer], grid_mask, greedy_mask)
    loads = np.where(use_grid[:, None], gl, bl)
    return final, loads, use_grid, t_grid, t_greedy


def _wl_time(mac, ch_bytes, ch_msgs, ch_active, bw_c, n_reuse):
    """Hottest-channel time of a (n_ch, n_zcls) aggregate matrix.

    With spatial reuse the last zone class is the global phase that
    quiesces every zone; a channel finishes at global + slowest zone."""
    t = mac_times(mac, ch_bytes, ch_msgs, ch_active, bw_c)
    if n_reuse == 1:
        return float(t[:, 0].max())
    return float((t[:, n_reuse] + t[:, :n_reuse].max(axis=1)).max())


def balance(trace: TrafficTrace,
            wcfg: WirelessConfig | NetworkConfig,
            faults=None) -> BalancerResult:
    """Water-filling balance; ``faults`` re-runs it against the
    *surviving* topology.

    With a `repro.fault.FaultScenario`, the greedy per-layer loop sees
    the degraded planes: cut service scaled by ``k/surviving`` (``inf``
    on dead cuts, so everything eligible drains to wireless), and
    per-(layer, channel) effective bandwidth under the SNR fades.
    Chip events act on the trace, not the network — pass a
    `derate_trace`d trace (the engine does this automatically for
    `OraclePolicy`/`OnlineReshardPolicy`).  The grid-anchor stitch and
    the returned `sim` timing fields stay fault-free projections: under
    faults the product is the ``injected`` mask (a candidate the
    fault-aware engine re-stitches exactly).
    """
    net = as_network(wcfg)
    plan, mac = net.channels, net.mac
    n_ch = plan.n_channels
    ch_of_node = plan.assign(trace.topo.n_nodes)
    pkt_ch = ch_of_node[trace.src]
    bw_c = plan.channel_bandwidth(net.bandwidth)
    # zone class per packet: its source's zone when the hop span stays
    # within the reuse distance, else the channel-global class
    Z = plan.reuse_zones
    n_zc = 1 if Z == 1 else Z + 1
    if Z == 1:
        pkt_zc = np.zeros(len(trace.nbytes), np.int64)
    else:
        zone_of_node, rd = plan.assign_spatial(trace.topo.config.grid,
                                               node_grid_coords(trace.topo))
        pkt_zc = np.where(trace.max_hops <= rd, zone_of_node[trace.src], Z)

    cut_mat, cut_bw = trace.cut_matrix()
    eligible = eligibility(trace, threshold=1)  # balancer sees everything
    loads = trace.baseline_link_loads()

    # degraded planes under a fault scenario (None entries = fault-free)
    cut_scale = bw_mat = None
    if faults is not None and not faults.is_null:
        from repro.fault.apply import (link_fault_arrays,  # no cycle
                                       wireless_bw_matrix)
        link_bw = trace.topo.config.nop_bw_per_side
        cut_scale, _, _, _ = link_fault_arrays(
            trace, faults, cut_of_link=cut_mat.argmax(axis=1),
            k_par=np.rint(cut_bw / link_bw).astype(int),
            n_cuts=cut_mat.shape[1])
        bw_mat = wireless_bw_matrix(trace, net, faults)

    # per-packet link lists from the sparse incidence
    order = np.argsort(trace.inc_msg, kind="stable")
    inc_msg = trace.inc_msg[order]
    inc_link = trace.inc_link[order]
    starts = np.searchsorted(inc_msg, np.arange(len(trace.nbytes) + 1))

    injected = np.zeros(len(trace.nbytes), bool)
    t_rest = np.maximum.reduce([trace.t_compute, trace.t_dram, trace.t_noc])

    for li in range(trace.n_layers):
        cand = np.nonzero((trace.layer == li) & eligible)[0]
        if cand.size == 0:
            continue
        layer_loads = loads[li].copy()
        # per-(channel, zone-class) aggregates on this layer's wireless
        # plane (one column per channel when the plan has no reuse)
        ch_bytes = np.zeros((n_ch, n_zc))
        ch_msgs = np.zeros((n_ch, n_zc))
        ch_srcs = [[set() for _ in range(n_zc)] for _ in range(n_ch)]
        ch_active = np.zeros((n_ch, n_zc))
        remaining = list(cand)
        bw_li = bw_c if bw_mat is None else bw_mat[li][:, None]
        scale_li = 1.0 if cut_scale is None else cut_scale[li]
        state_changed = True
        while remaining:
            if state_changed:  # rejections leave the planes untouched
                cut_loads = layer_loads @ cut_mat
                cut_t = cut_loads / cut_bw * scale_li
                hot = int(cut_t.argmax())
                t_nop = cut_t[hot]
                t_wl = _wl_time(mac, ch_bytes, ch_msgs, ch_active, bw_li, Z)
                if t_nop <= t_wl or t_nop <= t_rest[li]:
                    break  # balanced, or another element already dominates
                hot_links = np.nonzero(cut_mat[:, hot])[0]
                state_changed = False
            # eligible packet contributing most to the hot cut
            best_j, best_c = -1, 0.0
            for j, mi in enumerate(remaining):
                lks = inc_link[starts[mi]:starts[mi + 1]]
                c = trace.nbytes[mi] * np.isin(lks, hot_links).any()
                if c > best_c:
                    best_j, best_c = j, c
            if best_j < 0:
                break  # nothing eligible touches the hot cut
            mi = remaining.pop(best_j)
            ch, zc = pkt_ch[mi], pkt_zc[mi]
            # trial: this packet lands on its source's (channel, zone)
            row_b = ch_bytes[ch].copy()
            row_m = ch_msgs[ch].copy()
            row_a = ch_active[ch].copy()
            row_b[zc] += trace.nbytes[mi]
            row_m[zc] += 1
            row_a[zc] = len(ch_srcs[ch][zc] | {int(trace.src[mi])})
            t_row = mac_times(mac, row_b, row_m, row_a,
                              bw_c if bw_mat is None
                              else float(bw_mat[li, ch]))
            new_t_ch = float(t_row[0] if n_zc == 1
                             else t_row[Z] + t_row[:Z].max())
            # accept only if the wireless plane stays the earlier
            # finisher; a rejected packet can never fit later (the wired
            # side only falls, the wireless side only rises) — drop it
            # and keep searching smaller contributors
            if max(t_wl, new_t_ch) > t_nop:
                continue
            injected[mi] = True
            ch_bytes[ch] = row_b
            ch_msgs[ch] = row_m
            ch_srcs[ch][zc].add(int(trace.src[mi]))
            ch_active[ch] = row_a
            lks = inc_link[starts[mi]:starts[mi + 1]]
            layer_loads[lks] -= trace.nbytes[mi]
            state_changed = True
        loads[li] = layer_loads

    # anchor against the paper's sweep: per layer, keep whichever injected
    # set — greedy water-filling or the best static grid point — projects
    # the smaller layer time (exact: layers are independent analytically)
    injected, loads, use_grid, t_grid, t_greedy = _stitch_best(
        trace, net, injected, t_rest, cut_mat, cut_bw)

    st = active_recorder()
    if st is not None:
        # one span per layer on the "balance" track: which candidate the
        # stitch kept, and both projected times for the why
        for li in range(trace.n_layers):
            st.add_layer_event(
                "balance", "grid" if use_grid[li] else "greedy", li, 0.0,
                float(t_grid[li] if use_grid[li] else t_greedy[li]),
                "balancer", t_grid=float(t_grid[li]),
                t_greedy=float(t_greedy[li]))

    # re-derive the wireless timeline + MAC energy overhead from the final
    # injected set through the same stack the simulator uses
    t_wireless, wl_bytes, extra_bytes = network_layer_times(
        trace.n_layers, trace.layer, trace.nbytes, trace.src,
        trace.topo.n_nodes, injected, net, **_geometry(trace))
    sim = _finalize(trace, loads, t_wireless)
    sim.wireless_bytes = float(wl_bytes.sum())
    sim.wireless_energy_j = wireless_energy_joules(trace, injected, net,
                                                   extra_bytes)
    sim.energy_j = energy_joules(trace, loads,
                                 sim.wireless_bytes + extra_bytes)
    with recording(None):   # the baseline is a trial, not the timeline
        base = simulate_wired(trace).total_time
    elig_vol = float(trace.nbytes[eligible].sum()) or 1.0
    return BalancerResult(
        sim=sim, injected=injected,
        speedup_vs_wired=base / sim.total_time,
        injected_fraction=sim.wireless_bytes / elig_vol,
    )
