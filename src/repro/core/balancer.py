"""Beyond-paper: analytic wired/wireless load balancer.

The paper sweeps (distance threshold x injection probability) and notes
that a "mechanism to balance the load between the wired and wireless
planes" is needed (SIV-B, SV) but leaves it to future work.  We build it.

Observation: per layer, the hybrid layer time is

    T(v) = max(T_rest, worst_cut_wired(V - v) / BW_cut, v / B_wl)

where v is the volume steered to the wireless plane out of the eligible
volume V.  The wired term falls and the wireless term rises monotonically
in v, so the optimum equalises them (water-filling), clipped by
eligibility and by T_rest (compute/DRAM/NoC floor) — there is no benefit
in rebalancing past the point where another element is the bottleneck.

Greedy realisation: per layer, repeatedly move the eligible packet that
contributes most to the currently hottest mesh cut, while the wireless
plane finishes no later than the wired one and the NoP still exceeds the
layer's floor.  Because the balancer chooses per-packet with the exact
cut-cost model (instead of one global Bernoulli rate), it matches or beats
every (threshold, injection) grid point of the paper's sweep on the same
trace — verified in tests/test_paper_repro.py.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .simulator import SimResult, _finalize, simulate_wired
from .traffic import TrafficTrace
from .wireless import WirelessConfig, eligibility, wireless_energy_joules


@dataclasses.dataclass
class BalancerResult:
    sim: SimResult
    injected: np.ndarray          # bool per packet
    speedup_vs_wired: float
    injected_fraction: float      # of eligible volume


def balance(trace: TrafficTrace, wcfg: WirelessConfig) -> BalancerResult:
    cut_mat, cut_bw = trace.cut_matrix()
    eligible = eligibility(trace, threshold=1)  # balancer sees everything
    loads = trace.baseline_link_loads()

    # per-packet link lists from the sparse incidence
    order = np.argsort(trace.inc_msg, kind="stable")
    inc_msg = trace.inc_msg[order]
    inc_link = trace.inc_link[order]
    starts = np.searchsorted(inc_msg, np.arange(len(trace.nbytes) + 1))

    injected = np.zeros(len(trace.nbytes), bool)
    t_wireless = np.zeros(trace.n_layers)
    t_rest = np.maximum.reduce([trace.t_compute, trace.t_dram, trace.t_noc])

    for li in range(trace.n_layers):
        cand = np.nonzero((trace.layer == li) & eligible)[0]
        if cand.size == 0:
            continue
        layer_loads = loads[li].copy()
        wl_bytes = 0.0
        remaining = list(cand)
        while remaining:
            cut_loads = layer_loads @ cut_mat
            hot = int((cut_loads / cut_bw).argmax())
            t_nop = cut_loads[hot] / cut_bw[hot]
            t_wl = wl_bytes / wcfg.bandwidth
            if t_nop <= t_wl or t_nop <= t_rest[li]:
                break  # balanced, or another element already dominates
            hot_links = np.nonzero(cut_mat[:, hot])[0]
            # eligible packet contributing most to the hot cut
            best_j, best_c = -1, 0.0
            for j, mi in enumerate(remaining):
                lks = inc_link[starts[mi]:starts[mi + 1]]
                c = trace.nbytes[mi] * np.isin(lks, hot_links).any()
                if c > best_c:
                    best_j, best_c = j, c
            if best_j < 0:
                break  # nothing eligible touches the hot cut
            mi = remaining.pop(best_j)
            # accept only while the wireless plane stays the earlier finisher
            new_wl = (wl_bytes + trace.nbytes[mi]) / wcfg.bandwidth
            if new_wl > t_nop and wl_bytes > 0:
                break
            injected[mi] = True
            wl_bytes += trace.nbytes[mi]
            lks = inc_link[starts[mi]:starts[mi + 1]]
            layer_loads[lks] -= trace.nbytes[mi]
        t_wireless[li] = wl_bytes / wcfg.bandwidth
        loads[li] = layer_loads

    sim = _finalize(trace, loads, t_wireless)
    sim.wireless_bytes = float(trace.nbytes[injected].sum())
    sim.wireless_energy_j = wireless_energy_joules(trace, injected, wcfg)
    base = simulate_wired(trace).total_time
    elig_vol = float(trace.nbytes[eligible].sum()) or 1.0
    return BalancerResult(
        sim=sim, injected=injected,
        speedup_vs_wired=base / sim.total_time,
        injected_fraction=sim.wireless_bytes / elig_vol,
    )
