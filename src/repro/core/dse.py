"""Design-space exploration: the paper's parameter sweeps (SIV-A) plus
the network dimensions the paper defers (MAC protocol, channel plan).

The paper sweeps distance threshold in {1..4} x injection probability in
{0.10..0.80 step 0.05} x wireless bandwidth in {64, 96} Gb/s per
workload and reports the near-optimal configuration — the exploration
behind Fig. 4 and Fig. 5.  `sweep`/`sweep_all` reproduce it; `sweep_all`
runs on the vectorized `repro.net.batched` engine by default (identical
results, >=10x faster than the per-point loop), and `network_sweep`
widens the grid with MAC protocols and multi-channel plans to report
the best full network configuration per workload — i.e. how much of the
idealized speedup survives a real MAC.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.net.batched import (BatchedDesignSpace, GridResult, GridSpec,
                               PAPER_BANDWIDTHS_GBPS, PAPER_INJECTIONS,
                               PAPER_THRESHOLDS)
from repro.net.channel import ChannelPlan
from repro.net.config import NetworkConfig
from repro.net.mac import MacConfig

from .simulator import TrafficTrace, simulate_hybrid, simulate_wired
from .wireless import eligibility, injection_hash

# the paper's sweep axes (shared with GridSpec's defaults)
THRESHOLDS = PAPER_THRESHOLDS
INJECTIONS = PAPER_INJECTIONS
BANDWIDTHS_GBPS = PAPER_BANDWIDTHS_GBPS

# beyond-paper network axes: MAC protocols and channel plans (equal
# aggregate bandwidth, so plans trade arbitration overhead against
# per-channel load imbalance)
NETWORK_MACS = (MacConfig("ideal"), MacConfig("tdma"), MacConfig("token"))
NETWORK_PLANS = (ChannelPlan(1), ChannelPlan(2, "contiguous"),
                 ChannelPlan(2, "interleaved"), ChannelPlan(4, "interleaved"))


@dataclasses.dataclass
class SweepResult:
    workload: str
    bandwidth_gbps: int
    # speedup grid indexed [threshold, injection]
    grid: np.ndarray
    best_speedup: float
    best_threshold: int
    best_injection: float


def _result_from_grid(workload: str, bandwidth_gbps: int,
                      grid: np.ndarray) -> SweepResult:
    ti, pi = np.unravel_index(int(grid.argmax()), grid.shape)
    return SweepResult(workload, bandwidth_gbps, grid,
                       float(grid.max()), THRESHOLDS[ti], INJECTIONS[pi])


def sweep(trace: TrafficTrace, workload: str, bandwidth_gbps: int,
          mac: MacConfig = MacConfig("ideal"),
          channels: ChannelPlan = ChannelPlan(1)) -> SweepResult:
    """Per-point (threshold x injection) sweep via `simulate_hybrid`."""
    base = simulate_wired(trace).total_time
    grid = np.zeros((len(THRESHOLDS), len(INJECTIONS)))
    for ti, thr in enumerate(THRESHOLDS):
        for pi, p in enumerate(INJECTIONS):
            cfg = NetworkConfig(bandwidth=bandwidth_gbps * 1e9 / 8,
                                distance_threshold=thr, injection_prob=p,
                                channels=channels, mac=mac)
            grid[ti, pi] = base / simulate_hybrid(trace, cfg).total_time
    return _result_from_grid(workload, bandwidth_gbps, grid)


def batched_design_space(trace: TrafficTrace,
                         thresholds=THRESHOLDS) -> BatchedDesignSpace:
    """Assemble the vectorized engine's inputs from a traffic trace.

    The per-packet and per-layer cut loads are reduced straight from
    the sparse (message -> link) incidence with `np.bincount` — the
    dense per-link load matrix is never materialised.  The build is
    memoized on the trace (traces are immutable once built): a policy
    sweep touches it three times per workload (grid anchor, oracle
    balance, figure sweeps) and pays the bincount pass once.
    """
    key = tuple(thresholds)
    cached = getattr(trace, "_batched_dse", None)
    if cached is not None and cached[0] == key:
        return cached[1]
    cut_mat, cut_bw = trace.cut_matrix()
    n_msg, n_cuts = len(trace.nbytes), cut_mat.shape[1]
    inc_cut = cut_mat[trace.inc_link]                  # (E, C)
    inc_bytes = trace.nbytes[trace.inc_msg]
    inc_layer = trace.layer[trace.inc_msg]
    pkt_cut = np.stack([
        np.bincount(trace.inc_msg, weights=inc_cut[:, c], minlength=n_msg)
        for c in range(n_cuts)], axis=1)
    cut_base = np.stack([
        np.bincount(inc_layer, weights=inc_bytes * inc_cut[:, c],
                    minlength=trace.n_layers)
        for c in range(n_cuts)], axis=1)
    t_rest = np.maximum.reduce([trace.t_compute, trace.t_dram, trace.t_noc])
    base_time = float(
        np.maximum(t_rest, (cut_base / cut_bw).max(axis=1)).sum())
    built = BatchedDesignSpace(
        n_layers=trace.n_layers,
        n_nodes=trace.topo.n_nodes,
        layer=trace.layer,
        nbytes=trace.nbytes,
        src=trace.src,
        eligibility={t: eligibility(trace, t) for t in thresholds},
        inj_hash=injection_hash(n_msg),
        pkt_cut=pkt_cut,
        cut_base=cut_base,
        cut_bw=cut_bw,
        t_rest=t_rest,
        base_time=base_time,
    )
    trace._batched_dse = (key, built)
    return built


def sweep_all(traces: Dict[str, TrafficTrace],
              engine: str = "batched") -> List[SweepResult]:
    """The paper's full sweep over workloads x bandwidths.

    ``engine="batched"`` (default) evaluates every workload's whole
    (threshold x injection x bandwidth) grid with one pass of the
    vectorized engine; ``engine="loop"`` keeps the per-point
    `simulate_hybrid` double loop (the two agree to float precision).
    """
    if engine not in ("batched", "loop"):
        raise ValueError(f"unknown engine {engine!r}; use 'batched' or 'loop'")
    out = []
    if engine == "loop":
        for wl, trace in traces.items():
            for bw in BANDWIDTHS_GBPS:
                out.append(sweep(trace, wl, bw))
        return out
    spec = GridSpec()
    for wl, trace in traces.items():
        res = batched_design_space(trace).evaluate(spec)
        for bw in BANDWIDTHS_GBPS:
            out.append(_result_from_grid(wl, bw, res.ideal_grid(bw)))
    return out


@dataclasses.dataclass
class NetworkSweepResult:
    """Full network design space for one workload."""

    workload: str
    result: GridResult
    best_speedup: float
    best_config: NetworkConfig

    def best_by_network(self) -> Dict[Tuple[str, str], float]:
        """(mac protocol, plan) -> best speedup over thr/inj/bw."""
        spec = self.result.spec
        sp = self.result.speedup
        return {(m.protocol, p.describe()): float(sp[mi, pi].max())
                for mi, m in enumerate(spec.macs)
                for pi, p in enumerate(spec.plans)}


def network_sweep(trace: TrafficTrace, workload: str,
                  macs=NETWORK_MACS,
                  plans=NETWORK_PLANS) -> NetworkSweepResult:
    """Sweep MAC x channel-plan on top of the paper's grid (batched)."""
    spec = GridSpec(macs=tuple(macs), plans=tuple(plans))
    res = batched_design_space(trace).evaluate(spec)
    best, cfg = res.best()
    return NetworkSweepResult(workload, res, best, cfg)


def network_sweep_all(traces: Dict[str, TrafficTrace],
                      macs=NETWORK_MACS,
                      plans=NETWORK_PLANS) -> List[NetworkSweepResult]:
    return [network_sweep(tr, wl, macs, plans) for wl, tr in traces.items()]


@dataclasses.dataclass
class PolicySweepResult:
    """Event-driven policy comparison for one workload.

    The paper's DSE picks ONE static (threshold x injection) point per
    workload offline; the event-driven engine (`repro.sim`) lets online
    policies compete with that optimum on the same trace and network.
    """

    workload: str
    net: NetworkConfig
    base_time: float               # event-driven all-wired baseline
    grid_best_speedup: float       # best static grid point (same network)
    policy_speedups: Dict[str, float]
    policy_times: Dict[str, float]

    def best_policy(self) -> Tuple[str, float]:
        name = max(self.policy_speedups, key=self.policy_speedups.get)
        return name, self.policy_speedups[name]


def grid_anchor(trace: TrafficTrace,
                net: NetworkConfig) -> Tuple[float, int, float]:
    """(best speedup, threshold, injection) of the one-point anchor grid.

    The single (bandwidth, MAC, channel-plan) point every comparison
    anchors against — event-driven policy sweeps and the balancer's
    per-layer stitch share THIS helper so they can never anchor against
    different grids.  The exact bandwidth is threaded through
    (`GridSpec` accepts fractional Gb/s); rounding to integer Gb/s here
    used to anchor non-integer networks against the wrong grid."""
    spec = GridSpec(bandwidths_gbps=(net.bandwidth * 8 / 1e9,),
                    macs=(net.mac,), plans=(net.channels,))
    res = batched_design_space(trace).evaluate(spec)
    _, _, _, ti, ii = np.unravel_index(int(res.speedup.argmax()),
                                       res.speedup.shape)
    return (float(res.speedup.max()), spec.thresholds[ti],
            spec.injections[ii])


def grid_best_speedup(trace: TrafficTrace, net: NetworkConfig) -> float:
    """Best static (threshold x injection) speedup at ``net``'s
    bandwidth / MAC / channel plan, via the batched engine."""
    return grid_anchor(trace, net)[0]


def policy_sweep(trace: TrafficTrace, workload: str,
                 net: NetworkConfig | None = None,
                 policies=("static", "greedy", "adaptive", "oracle")
                 ) -> PolicySweepResult:
    """Event-driven sweep of load-balancing policies on one workload.

    The static grid best is evaluated with the batched engine (exact
    for the event engine's default striped/ideal configuration).
    """
    from repro.sim import PacketSim    # late import: core re-exports sim
    net = net or NetworkConfig(bandwidth=96e9 / 8)
    grid_best = grid_best_speedup(trace, net)
    sim = PacketSim(trace, net)
    base = sim.run_wired().total_time
    times = {p: sim.run(p).total_time for p in policies}
    return PolicySweepResult(
        workload=workload, net=net, base_time=base,
        grid_best_speedup=grid_best,
        policy_speedups={p: base / t for p, t in times.items()},
        policy_times=times)


def policy_sweep_all(traces: Dict[str, TrafficTrace],
                     net: NetworkConfig | None = None,
                     policies=("static", "greedy", "adaptive", "oracle")
                     ) -> List[PolicySweepResult]:
    return [policy_sweep(tr, wl, net, policies)
            for wl, tr in traces.items()]


def hetero_sweep(workloads=None,
                 mixes: Tuple[str, ...] = ("big_little", "compute_mem",
                                           "aimc_edge"),
                 net: NetworkConfig | None = None,
                 grid: Tuple[int, int] = (3, 3), seed: int = 0,
                 steps: int = 150, restarts: int = 1,
                 n_samples: int = 8) -> list:
    """The heterogeneity frontier: placement co-design per (mix, workload).

    For every catalog mix x workload, run `repro.arch.codesign` — the
    joint placement/layer-assignment search under the wired and hybrid
    objectives — and report (i) the hybrid-vs-wired speedup at the
    co-designed placement and (ii) the best-vs-worst placement spread
    with and without the wireless plane.  Defaults cover the paper's 15
    workloads; LLM frontier names work too.
    """
    from repro.arch import codesign    # arch builds on core: late import
    if workloads is None:
        from .workloads import WORKLOADS
        workloads = list(WORKLOADS)
    return [codesign(wl, mix, net, grid, seed=seed, steps=steps,
                     restarts=restarts, n_samples=n_samples)
            for mix in mixes for wl in workloads]


def hetero_summary(results) -> Dict[str, Dict[str, float]]:
    """Per-mix (and overall) aggregates of a `hetero_sweep` run."""
    out: Dict[str, Dict[str, float]] = {}
    mixes = sorted({r.mix for r in results})
    for mix in mixes + ["_overall"]:
        rs = [r for r in results if mix == "_overall" or r.mix == mix]
        if not rs:        # empty sweep: no NaN means (as in `summary`)
            continue
        out[mix] = {
            "mean_speedup_hybrid": float(
                np.mean([r.speedup_hybrid for r in rs])),
            "max_speedup_hybrid": float(
                np.max([r.speedup_hybrid for r in rs])),
            "mean_speedup_codesigned": float(
                np.mean([r.speedup_codesigned for r in rs])),
            "max_speedup_codesigned": float(
                np.max([r.speedup_codesigned for r in rs])),
            "mean_spread_wired": float(
                np.mean([r.spread_wired for r in rs])),
            "mean_spread_hybrid": float(
                np.mean([r.spread_hybrid for r in rs])),
            "spread_shrunk": sum(r.spread_hybrid < r.spread_wired
                                 for r in rs),
            "n": len(rs),
        }
    return out


def summary(results: List[SweepResult]) -> Dict[int, Tuple[float, float]]:
    """bandwidth -> (mean best speedup, max best speedup) over workloads.

    Bandwidths with no results are omitted (an empty list used to emit
    a NaN mean plus a RuntimeWarning from ``np.mean([])``)."""
    out = {}
    for bw in BANDWIDTHS_GBPS:
        sp = [r.best_speedup for r in results if r.bandwidth_gbps == bw]
        if sp:
            out[bw] = (float(np.mean(sp)), float(np.max(sp)))
    return out


def network_summary(results: List[NetworkSweepResult]
                    ) -> Dict[Tuple[str, str], Tuple[float, float]]:
    """(mac, plan) -> (mean, max) best speedup over workloads."""
    keys = results[0].best_by_network().keys() if results else []
    out = {}
    for key in keys:
        sp = [r.best_by_network()[key] for r in results]
        out[key] = (float(np.mean(sp)), float(np.max(sp)))
    return out
