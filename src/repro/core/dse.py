"""Design-space exploration: the paper's parameter sweeps (SIV-A).

Sweeps distance threshold in {1..4} x injection probability in
{0.10..0.80 step 0.05} x wireless bandwidth in {64, 96} Gb/s, per workload,
and reports the near-optimal configuration — exactly the exploration behind
the paper's Fig. 4 and Fig. 5.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from .simulator import TrafficTrace, simulate_hybrid, simulate_wired
from .wireless import WirelessConfig

THRESHOLDS = (1, 2, 3, 4)
INJECTIONS = tuple(round(0.10 + 0.05 * i, 2) for i in range(15))  # .10..._.80
BANDWIDTHS_GBPS = (64, 96)


@dataclasses.dataclass
class SweepResult:
    workload: str
    bandwidth_gbps: int
    # speedup grid indexed [threshold, injection]
    grid: np.ndarray
    best_speedup: float
    best_threshold: int
    best_injection: float


def sweep(trace: TrafficTrace, workload: str,
          bandwidth_gbps: int) -> SweepResult:
    base = simulate_wired(trace).total_time
    grid = np.zeros((len(THRESHOLDS), len(INJECTIONS)))
    for ti, thr in enumerate(THRESHOLDS):
        for pi, p in enumerate(INJECTIONS):
            cfg = WirelessConfig(bandwidth=bandwidth_gbps * 1e9 / 8,
                                 distance_threshold=thr, injection_prob=p)
            grid[ti, pi] = base / simulate_hybrid(trace, cfg).total_time
    ti, pi = np.unravel_index(int(grid.argmax()), grid.shape)
    return SweepResult(workload, bandwidth_gbps, grid,
                       float(grid.max()), THRESHOLDS[ti], INJECTIONS[pi])


def sweep_all(traces: Dict[str, TrafficTrace]) -> List[SweepResult]:
    out = []
    for wl, trace in traces.items():
        for bw in BANDWIDTHS_GBPS:
            out.append(sweep(trace, wl, bw))
    return out


def summary(results: List[SweepResult]) -> Dict[int, Tuple[float, float]]:
    """bandwidth -> (mean best speedup, max best speedup) over workloads."""
    out = {}
    for bw in BANDWIDTHS_GBPS:
        sp = [r.best_speedup for r in results if r.bandwidth_gbps == bw]
        out[bw] = (float(np.mean(sp)), float(np.max(sp)))
    return out
