"""Design-space exploration: the paper's parameter sweeps (SIV-A) plus
the network dimensions the paper defers (MAC protocol, channel plan).

The paper sweeps distance threshold in {1..4} x injection probability in
{0.10..0.80 step 0.05} x wireless bandwidth in {64, 96} Gb/s per
workload and reports the near-optimal configuration — the exploration
behind Fig. 4 and Fig. 5.  `sweep`/`sweep_all` reproduce it; `sweep_all`
runs on the vectorized `repro.net.batched` engine by default (identical
results, >=10x faster than the per-point loop), and `network_sweep`
widens the grid with MAC protocols and multi-channel plans to report
the best full network configuration per workload — i.e. how much of the
idealized speedup survives a real MAC.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.net.batched import (BatchedDesignSpace, GridResult, GridSpec,
                               PAPER_BANDWIDTHS_GBPS, PAPER_INJECTIONS,
                               PAPER_THRESHOLDS)
from repro.net.channel import ChannelPlan
from repro.net.config import NetworkConfig
from repro.net.mac import MacConfig
from repro.obs import profile as obs_profile
from repro.obs.metrics import DEFAULT_REGISTRY
from repro.obs.provenance import make_provenance

from .simulator import (TrafficTrace, make_trace, simulate_hybrid,
                        simulate_wired)
from .topology import AcceleratorConfig, node_grid_coords
from .units import bytes_per_s_to_gbps, gbps_to_bytes_per_s
from .wireless import eligibility, injection_hash

# the paper's sweep axes (shared with GridSpec's defaults)
THRESHOLDS = PAPER_THRESHOLDS
INJECTIONS = PAPER_INJECTIONS
BANDWIDTHS_GBPS = PAPER_BANDWIDTHS_GBPS

# beyond-paper network axes: MAC protocols and channel plans (equal
# aggregate bandwidth, so plans trade arbitration overhead against
# per-channel load imbalance)
NETWORK_MACS = (MacConfig("ideal"), MacConfig("tdma"), MacConfig("token"))
NETWORK_PLANS = (ChannelPlan(1), ChannelPlan(2, "contiguous"),
                 ChannelPlan(2, "interleaved"), ChannelPlan(4, "interleaved"))


@dataclasses.dataclass
class SweepResult:
    workload: str
    bandwidth_gbps: int
    # speedup grid indexed [threshold, injection]
    grid: np.ndarray
    best_speedup: float
    best_threshold: int
    best_injection: float
    provenance: Optional[dict] = dataclasses.field(
        default=None, compare=False)  # dse.provenance (sweep_all)


def _result_from_grid(workload: str, bandwidth_gbps: int,
                      grid: np.ndarray) -> SweepResult:
    ti, pi = np.unravel_index(int(grid.argmax()), grid.shape)
    return SweepResult(workload, bandwidth_gbps, grid,
                       float(grid.max()), THRESHOLDS[ti], INJECTIONS[pi])


def sweep(trace: TrafficTrace, workload: str, bandwidth_gbps: int,
          mac: MacConfig | None = None,
          channels: ChannelPlan | None = None) -> SweepResult:
    """Per-point (threshold x injection) sweep via `simulate_hybrid`."""
    mac = mac if mac is not None else MacConfig("ideal")
    channels = channels if channels is not None else ChannelPlan(1)
    base = simulate_wired(trace).total_time
    grid = np.zeros((len(THRESHOLDS), len(INJECTIONS)))
    for ti, thr in enumerate(THRESHOLDS):
        for pi, p in enumerate(INJECTIONS):
            cfg = NetworkConfig(bandwidth=gbps_to_bytes_per_s(bandwidth_gbps),
                                distance_threshold=thr, injection_prob=p,
                                channels=channels, mac=mac)
            grid[ti, pi] = base / simulate_hybrid(trace, cfg).total_time
    return _result_from_grid(workload, bandwidth_gbps, grid)


def batched_design_space(trace: TrafficTrace,
                         thresholds=THRESHOLDS) -> BatchedDesignSpace:
    """Assemble the vectorized engine's inputs from a traffic trace.

    The per-packet and per-layer cut loads are reduced straight from
    the sparse (message -> link) incidence with `np.bincount` — the
    dense per-link load matrix is never materialised.  The build is
    memoized on the trace (traces are immutable once built): a policy
    sweep touches it three times per workload (grid anchor, oracle
    balance, figure sweeps) and pays the bincount pass once.
    """
    key = tuple(thresholds)
    cached = getattr(trace, "_batched_dse", None)
    if cached is not None and cached[0] == key:
        return cached[1]
    with obs_profile.phase("dse.build_design_space"):
        built = _build_design_space(trace, thresholds)
    trace._batched_dse = (key, built)
    return built


def _build_design_space(trace: TrafficTrace,
                        thresholds) -> BatchedDesignSpace:
    cut_mat, cut_bw = trace.cut_matrix()
    n_msg, n_cuts = len(trace.nbytes), cut_mat.shape[1]
    inc_cut = cut_mat[trace.inc_link]                  # (E, C)
    inc_bytes = trace.nbytes[trace.inc_msg]
    inc_layer = trace.layer[trace.inc_msg]
    pkt_cut = np.stack([
        np.bincount(trace.inc_msg, weights=inc_cut[:, c], minlength=n_msg)
        for c in range(n_cuts)], axis=1)
    cut_base = np.stack([
        np.bincount(inc_layer, weights=inc_bytes * inc_cut[:, c],
                    minlength=trace.n_layers)
        for c in range(n_cuts)], axis=1)
    t_rest = np.maximum.reduce([trace.t_compute, trace.t_dram, trace.t_noc])
    base_time = float(
        np.maximum(t_rest, (cut_base / cut_bw).max(axis=1)).sum())
    built = BatchedDesignSpace(
        n_layers=trace.n_layers,
        n_nodes=trace.topo.n_nodes,
        layer=trace.layer,
        nbytes=trace.nbytes,
        src=trace.src,
        eligibility={t: eligibility(trace, t) for t in thresholds},
        inj_hash=injection_hash(n_msg),
        pkt_cut=pkt_cut,
        cut_base=cut_base,
        cut_bw=cut_bw,
        t_rest=t_rest,
        base_time=base_time,
        max_hops=trace.max_hops,
        grid=trace.topo.config.grid,
        node_coords=node_grid_coords(trace.topo),
    )
    obs_profile.note_ndarray(pkt_cut, cut_base)
    return built


def sweep_all(traces: Dict[str, TrafficTrace],
              engine: str = "batched") -> List[SweepResult]:
    """The paper's full sweep over workloads x bandwidths.

    ``engine="batched"`` (default) evaluates every workload's whole
    (threshold x injection x bandwidth) grid with one pass of the
    vectorized engine; ``engine="loop"`` keeps the per-point
    `simulate_hybrid` double loop (the two agree to float precision).
    """
    if engine not in ("batched", "loop"):
        raise ValueError(f"unknown engine {engine!r}; use 'batched' or 'loop'")
    out = []
    with DEFAULT_REGISTRY.span("dse.sweep_all", engine=engine) as t:
        if engine == "loop":
            for wl, trace in traces.items():
                for bw in BANDWIDTHS_GBPS:
                    out.append(sweep(trace, wl, bw))
        else:
            spec = GridSpec()
            for wl, trace in traces.items():
                res = batched_design_space(trace).evaluate(spec)
                for bw in BANDWIDTHS_GBPS:
                    out.append(_result_from_grid(wl, bw,
                                                 res.ideal_grid(bw)))
    with obs_profile.phase("dse.provenance"):
        prov = make_provenance(
            "dse.sweep_all",
            {"workloads": sorted(traces), "engine": engine,
             "thresholds": THRESHOLDS, "injections": INJECTIONS,
             "bandwidths_gbps": BANDWIDTHS_GBPS},
            points=len(traces) * len(THRESHOLDS) * len(INJECTIONS)
            * len(BANDWIDTHS_GBPS),
            wall_s=t["seconds"])
        for r in out:
            r.provenance = prov
    return out


@dataclasses.dataclass
class GuidedSweepResult:
    """`whatif_guided`'s outcome: `sweep_all`'s per-(workload,
    bandwidth) answers at a fraction of the grid evaluations.

    ``results`` matches `sweep_all`'s list shape, except that a pruned
    bandwidth's ``grid`` holds NaN at the design points the guide never
    had to evaluate (the best point and speedup are still exact — the
    pruning bound is sound, pinned in tests/test_critpath.py).
    """

    results: List[SweepResult]
    points_evaluated: int
    points_exhaustive: int
    #: "workload@bw" -> whatif-projected best speedup (the predicted
    #: incumbent the guided order starts from)
    projected_best: Dict[str, float]
    provenance: Optional[dict] = dataclasses.field(default=None,
                                                   compare=False)

    @property
    def evaluated_fraction(self) -> float:
        return self.points_evaluated / self.points_exhaustive


def whatif_guided(traces: Dict[str, TrafficTrace],
                  bandwidths_gbps=BANDWIDTHS_GBPS) -> GuidedSweepResult:
    """The paper sweep with what-if-guided pruning of the lower bands.

    Speedup is monotone non-decreasing in wireless bandwidth (the
    wireless term is the only bandwidth-dependent layer term and only
    shrinks), so a point's speedup at the highest band is a sound
    ceiling for every lower band.  The guide therefore (i) evaluates
    the full (threshold x injection) grid once at the highest
    bandwidth, (ii) records ONE event run at that optimum and projects
    its speedup to each lower band via `repro.obs.whatif`
    (``wireless_scale``) — the predicted incumbent — and (iii) walks
    the candidates in descending-ceiling order, evaluating until the
    ceiling falls to the incumbent: every unevaluated point is provably
    worse.  Same best point as exhaustive `sweep_all`, typically at
    ~55% of its evaluations for the paper's two-band sweep.
    """
    from repro.obs.whatif import WhatIf
    from repro.obs.whatif import project as whatif_project
    from repro.sim.engine import PacketSim    # core re-exports sim: late
    hi = max(bandwidths_gbps)
    lows = sorted((b for b in set(bandwidths_gbps) if b != hi),
                  reverse=True)
    results: List[SweepResult] = []
    projected: Dict[str, float] = {}
    n_eval = 0
    with DEFAULT_REGISTRY.span("dse.whatif_guided") as t:
        for wl, trace in traces.items():
            ds = batched_design_space(trace)
            grid_hi = ds.evaluate(
                GridSpec(bandwidths_gbps=(hi,))).ideal_grid(hi)
            n_eval += grid_hi.size
            r_hi = _result_from_grid(wl, int(hi), grid_hi)
            results.append(r_hi)
            if not lows:
                continue
            net = NetworkConfig(bandwidth=gbps_to_bytes_per_s(hi),
                                distance_threshold=r_hi.best_threshold,
                                injection_prob=r_hi.best_injection)
            sim = PacketSim(trace, net, record=True)
            rec = sim.run("static")
            base = sim.run_wired().total_time
            order = np.argsort(grid_hi, axis=None)[::-1]
            for lo in lows:
                proj = whatif_project(rec.trace,
                                      WhatIf(wireless_scale=lo / hi))
                projected[f"{wl}@{int(lo)}"] = \
                    base / proj.total_time if proj.total_time else 1.0
                grid_lo = np.full_like(grid_hi, np.nan)
                incumbent, best_ti, best_ii = -np.inf, 0, 0
                for flat in order:
                    ti, ii = np.unravel_index(int(flat), grid_hi.shape)
                    if grid_hi[ti, ii] <= incumbent:
                        break      # ceiling under incumbent: all pruned
                    spec = GridSpec(thresholds=(THRESHOLDS[ti],),
                                    injections=(INJECTIONS[ii],),
                                    bandwidths_gbps=(lo,))
                    val = float(ds.evaluate(spec).ideal_grid(lo)[0, 0])
                    grid_lo[ti, ii] = val
                    n_eval += 1
                    if val > incumbent:
                        incumbent, best_ti, best_ii = val, ti, ii
                results.append(SweepResult(
                    wl, int(lo), grid_lo, incumbent,
                    THRESHOLDS[best_ti], INJECTIONS[best_ii]))
    exhaustive = (len(traces) * len(THRESHOLDS) * len(INJECTIONS)
                  * len(bandwidths_gbps))
    prov = make_provenance(
        "dse.whatif_guided",
        {"workloads": sorted(traces),
         "bandwidths_gbps": list(bandwidths_gbps),
         "thresholds": THRESHOLDS, "injections": INJECTIONS},
        points=n_eval, wall_s=t["seconds"])
    for r in results:
        r.provenance = prov
    return GuidedSweepResult(results, n_eval, exhaustive, projected, prov)


@dataclasses.dataclass
class NetworkSweepResult:
    """Full network design space for one workload."""

    workload: str
    result: GridResult
    best_speedup: float
    best_config: NetworkConfig
    provenance: Optional[dict] = dataclasses.field(
        default=None, compare=False)  # dse.provenance (network_sweep_all)

    def best_by_network(self) -> Dict[Tuple[str, str], float]:
        """(mac protocol, plan) -> best speedup over thr/inj/bw."""
        spec = self.result.spec
        sp = self.result.speedup
        return {(m.protocol, p.describe()): float(sp[mi, pi].max())
                for mi, m in enumerate(spec.macs)
                for pi, p in enumerate(spec.plans)}


def network_sweep(trace: TrafficTrace, workload: str,
                  macs=NETWORK_MACS,
                  plans=NETWORK_PLANS) -> NetworkSweepResult:
    """Sweep MAC x channel-plan on top of the paper's grid (batched)."""
    spec = GridSpec(macs=tuple(macs), plans=tuple(plans))
    res = batched_design_space(trace).evaluate(spec)
    best, cfg = res.best()
    return NetworkSweepResult(workload, res, best, cfg)


def network_sweep_all(traces: Dict[str, TrafficTrace],
                      macs=NETWORK_MACS,
                      plans=NETWORK_PLANS) -> List[NetworkSweepResult]:
    with DEFAULT_REGISTRY.span("dse.network_sweep_all") as t:
        out = [network_sweep(tr, wl, macs, plans)
               for wl, tr in traces.items()]
    prov = make_provenance(
        "dse.network_sweep_all",
        {"workloads": sorted(traces), "macs": list(macs),
         "plans": [p.describe() for p in plans]},
        points=len(traces) * len(macs) * len(plans) * len(THRESHOLDS)
        * len(INJECTIONS) * len(BANDWIDTHS_GBPS),
        wall_s=t["seconds"])
    for r in out:
        r.provenance = prov
    return out


@dataclasses.dataclass
class PolicySweepResult:
    """Event-driven policy comparison for one workload.

    The paper's DSE picks ONE static (threshold x injection) point per
    workload offline; the event-driven engine (`repro.sim`) lets online
    policies compete with that optimum on the same trace and network.
    """

    workload: str
    net: NetworkConfig
    base_time: float               # event-driven all-wired baseline
    grid_best_speedup: float       # best static grid point (same network)
    policy_speedups: Dict[str, float]
    policy_times: Dict[str, float]
    provenance: Optional[dict] = dataclasses.field(
        default=None, compare=False)  # dse.provenance (policy_sweep_all)

    def best_policy(self) -> Tuple[str, float]:
        name = max(self.policy_speedups, key=self.policy_speedups.get)
        return name, self.policy_speedups[name]


def grid_anchor(trace: TrafficTrace,
                net: NetworkConfig) -> Tuple[float, int, float]:
    """(best speedup, threshold, injection) of the one-point anchor grid.

    The single (bandwidth, MAC, channel-plan) point every comparison
    anchors against — event-driven policy sweeps and the balancer's
    per-layer stitch share THIS helper so they can never anchor against
    different grids.  The exact bandwidth is threaded through
    (`GridSpec` accepts fractional Gb/s); rounding to integer Gb/s here
    used to anchor non-integer networks against the wrong grid."""
    spec = GridSpec(bandwidths_gbps=(bytes_per_s_to_gbps(net.bandwidth),),
                    macs=(net.mac,), plans=(net.channels,))
    res = batched_design_space(trace).evaluate(spec)
    _, _, _, ti, ii = np.unravel_index(int(res.speedup.argmax()),
                                       res.speedup.shape)
    return (float(res.speedup.max()), spec.thresholds[ti],
            spec.injections[ii])


def grid_best_speedup(trace: TrafficTrace, net: NetworkConfig) -> float:
    """Best static (threshold x injection) speedup at ``net``'s
    bandwidth / MAC / channel plan, via the batched engine."""
    return grid_anchor(trace, net)[0]


def policy_sweep(trace: TrafficTrace, workload: str,
                 net: NetworkConfig | None = None,
                 policies=("static", "greedy", "adaptive", "oracle")
                 ) -> PolicySweepResult:
    """Event-driven sweep of load-balancing policies on one workload.

    The static grid best is evaluated with the batched engine (exact
    for the event engine's default striped/ideal configuration).
    """
    from repro.sim import PacketSim    # late import: core re-exports sim
    net = net or NetworkConfig(bandwidth=gbps_to_bytes_per_s(96))
    grid_best = grid_best_speedup(trace, net)
    sim = PacketSim(trace, net)
    base = sim.run_wired().total_time
    times = {p: sim.run(p).total_time for p in policies}
    return PolicySweepResult(
        workload=workload, net=net, base_time=base,
        grid_best_speedup=grid_best,
        policy_speedups={p: base / t for p, t in times.items()},
        policy_times=times)


def policy_sweep_all(traces: Dict[str, TrafficTrace],
                     net: NetworkConfig | None = None,
                     policies=("static", "greedy", "adaptive", "oracle")
                     ) -> List[PolicySweepResult]:
    with DEFAULT_REGISTRY.span("dse.policy_sweep_all") as t:
        out = [policy_sweep(tr, wl, net, policies)
               for wl, tr in traces.items()]
    prov = make_provenance(
        "dse.policy_sweep_all",
        {"workloads": sorted(traces), "policies": list(policies),
         "net": net},
        points=len(traces) * (len(policies) + 1),   # +1: wired baseline
        wall_s=t["seconds"])
    for r in out:
        r.provenance = prov
    return out


def resilience_sweep_all(workloads, net: NetworkConfig | None = None,
                         ks=(0, 1, 2), fades=(3.0, 9.0),
                         policies=("static", "adaptive",
                                   "online-reshard")) -> Dict:
    """Provenance-stamped retained-speedup grid (`repro.fault`).

    Cells are (k fail-stops) x (package fade dB); each runs every
    policy against the same scenario, with the online-reshard row
    routed through the era-rebuild controller.  The returned dict is
    `repro.fault.resilience.resilience_sweep`'s, plus a
    ``"provenance"`` entry.
    """
    from repro.fault import resilience_sweep   # late: fault imports sim
    net = net or NetworkConfig(bandwidth=gbps_to_bytes_per_s(96))
    with DEFAULT_REGISTRY.span("dse.resilience_sweep_all") as t:
        out = resilience_sweep(workloads, net, ks=tuple(ks),
                               fades=tuple(fades),
                               policies=tuple(policies))
    out["provenance"] = make_provenance(
        "dse.resilience_sweep_all",
        {"workloads": list(workloads), "ks": list(ks),
         "fades": list(fades), "policies": list(policies), "net": net},
        points=len(out) * len(ks) * len(fades) * len(policies),
        wall_s=t["seconds"])
    return out


# ---------------------------------------------------------------------------
# the scale-out frontier: large meshes x spatial channel reuse
# ---------------------------------------------------------------------------

# mesh sizes of the scaling study (3x3 is the paper's baseline point)
SCALING_GRIDS = ((4, 4), (6, 6), (8, 8), (12, 12), (16, 16))


def scaled_config(grid: Tuple[int, int], n_dram: int | None = None,
                  base: AcceleratorConfig | None = None) -> AcceleratorConfig:
    """Weak-scaled platform: Table-1 per-chiplet resources on an RxC mesh.

    Every per-chiplet rate (compute, NoC, NoP link, DRAM module pin
    rate) keeps its paper value; the package totals scale with the
    chiplet count, and the DRAM module count scales with the perimeter
    (four per full 4-chiplet side span, so a 16x16 package carries 16
    modules).  The *wireless* band does NOT scale — that is the
    experiment: a single shared medium serves ever more transmitters,
    which is exactly where spatial reuse earns its keep.
    """
    rows, cols = grid
    base = base or AcceleratorConfig()
    if n_dram is None:
        n_dram = max(4, 4 * (-(-max(rows, cols) // 4)))
    per_chip = base.tops_total / (base.grid[0] * base.grid[1])
    return dataclasses.replace(
        base, grid=(rows, cols), n_dram=n_dram,
        tops_total=per_chip * rows * cols,
        # per-chiplet vectors are geometry-bound; a scaled mesh restarts
        # from the uniform package
        chiplet_tops=None, chiplet_noc_bw=None, chiplet_sram=None,
        chiplet_pj_per_mac=None, chiplet_pj_per_bit_noc=None)


def reuse_plans(grid: Tuple[int, int],
                n_channels: int = 1) -> Tuple[ChannelPlan, ...]:
    """Candidate spatial-reuse plans for one mesh: zone tiles of 4 and 2.

    Coarse tiles keep more traffic zone-local (large reuse distance);
    fine tiles buy more concurrent zones.  The scaling sweep evaluates
    both and reports the better — on a mesh too small to tile (3x3,
    4x4 with tile 4) the list may be empty: there is nothing to reuse.
    """
    rows, cols = grid
    plans = []
    seen = set()
    for tile in (4, 2):
        zones = (-(-rows // tile)) * (-(-cols // tile))
        if zones > 1 and zones not in seen:
            seen.add(zones)
            plans.append(ChannelPlan(n_channels, reuse_zones=zones))
    return tuple(plans)


@dataclasses.dataclass
class ScalingResult:
    """One (mesh, workload) point of the scale-out frontier."""

    workload: str
    grid: Tuple[int, int]
    n_chiplets: int
    wired_time: float
    best_single: float            # best speedup, single shared channel
    best_reuse: float             # best speedup over the reuse plans
    best_reuse_plan: str          # describe() of the winning plan ("1ch"
    #                               when no reuse plan fits the mesh)
    provenance: Optional[dict] = dataclasses.field(
        default=None, compare=False)  # dse.provenance (scaling_sweep)

    @property
    def recovered(self) -> float:
        """Speedup the reuse plans recover over the shared channel."""
        return self.best_reuse - self.best_single


def scaling_sweep(workloads=None, grids=SCALING_GRIDS,
                  bandwidth_gbps: float = 96,
                  engine: str = "batched") -> List[ScalingResult]:
    """The scale-out frontier: (mesh size x wireless plan) per workload.

    For every mesh in ``grids`` (weak-scaled via `scaled_config`) and
    every workload, sweep the paper's (threshold x injection) grid for
    (i) the single shared wireless channel and (ii) the spatial-reuse
    plans of `reuse_plans`, and report the best speedup of each — the
    frontier showing where the global serialization point collapses and
    how much of the speedup distance-gated reuse recovers.

    ``engine="batched"`` (default) evaluates each (mesh, workload) grid
    in one vectorized pass; ``engine="loop"`` runs the naive per-point
    `simulate_hybrid` double loop (identical results, >=10x slower —
    the contrast is pinned in tests/test_scaling.py).  Workload names
    may be paper workloads or LLM frontier names ("<model>:<phase>").
    """
    if engine not in ("batched", "loop"):
        raise ValueError(f"unknown engine {engine!r}; use 'batched' or 'loop'")
    if workloads is None:
        from .workloads import WORKLOADS
        workloads = list(WORKLOADS)
    out = []
    points = 0
    with DEFAULT_REGISTRY.span("dse.scaling_sweep", engine=engine) as t:
        out, points = _scaling_sweep_body(grids, workloads, bandwidth_gbps,
                                          engine)
    wall = t["seconds"]
    prov = make_provenance(
        "dse.scaling_sweep",
        {"workloads": list(workloads), "grids": [tuple(g) for g in grids],
         "bandwidth_gbps": bandwidth_gbps, "engine": engine},
        points=points, wall_s=wall)
    for r in out:
        r.provenance = prov
    return out


def _scaling_sweep_body(grids, workloads, bandwidth_gbps, engine):
    out: List[ScalingResult] = []
    points = 0
    for grid in grids:
        acc = scaled_config(tuple(grid))
        plans = (ChannelPlan(1),) + reuse_plans(tuple(grid))
        spec = GridSpec(bandwidths_gbps=(bandwidth_gbps,), plans=plans)
        points += (len(workloads) * len(plans) * len(spec.thresholds)
                   * len(spec.injections))
        for wl in workloads:
            trace = make_trace(wl, acc)
            if engine == "batched":
                res = batched_design_space(trace).evaluate(spec)
                sp = res.speedup[0, :, 0]            # (plan, thr, inj)
                base = res.base_time
            else:
                base = simulate_wired(trace).total_time
                sp = np.empty((len(plans), len(spec.thresholds),
                               len(spec.injections)))
                for pi, plan in enumerate(plans):
                    for ti, thr in enumerate(spec.thresholds):
                        for ii, p in enumerate(spec.injections):
                            cfg = NetworkConfig(
                                bandwidth=gbps_to_bytes_per_s(bandwidth_gbps),
                                distance_threshold=thr, injection_prob=p,
                                channels=plan)
                            sp[pi, ti, ii] = base / simulate_hybrid(
                                trace, cfg).total_time
            best_single = float(sp[0].max())
            if len(plans) > 1:
                ri = 1 + int(sp[1:].reshape(len(plans) - 1, -1)
                             .max(axis=1).argmax())
                best_reuse, plan_desc = float(sp[ri].max()), \
                    plans[ri].describe()
            else:
                best_reuse, plan_desc = best_single, plans[0].describe()
            out.append(ScalingResult(
                workload=wl, grid=tuple(grid),
                n_chiplets=acc.n_chiplets,
                wired_time=base,
                best_single=best_single, best_reuse=best_reuse,
                best_reuse_plan=plan_desc))
    return out, points


def scaling_summary(results: List[ScalingResult]
                    ) -> Dict[str, Dict[str, float]]:
    """Per-mesh aggregates of a `scaling_sweep` run."""
    out: Dict[str, Dict[str, float]] = {}
    for grid in sorted({r.grid for r in results}):
        rs = [r for r in results if r.grid == grid]
        out[f"{grid[0]}x{grid[1]}"] = {
            "mean_single": float(np.mean([r.best_single for r in rs])),
            "max_single": float(np.max([r.best_single for r in rs])),
            "mean_reuse": float(np.mean([r.best_reuse for r in rs])),
            "max_reuse": float(np.max([r.best_reuse for r in rs])),
            "mean_recovered": float(np.mean([r.recovered for r in rs])),
            "n": len(rs),
        }
    return out


def hetero_sweep(workloads=None,
                 mixes: Tuple[str, ...] = ("big_little", "compute_mem",
                                           "aimc_edge"),
                 net: NetworkConfig | None = None,
                 grid: Tuple[int, int] = (3, 3), seed: int = 0,
                 steps: int = 150, restarts: int = 1,
                 n_samples: int = 8) -> list:
    """The heterogeneity frontier: placement co-design per (mix, workload).

    For every catalog mix x workload, run `repro.arch.codesign` — the
    joint placement/layer-assignment search under the wired and hybrid
    objectives — and report (i) the hybrid-vs-wired speedup at the
    co-designed placement and (ii) the best-vs-worst placement spread
    with and without the wireless plane.  Defaults cover the paper's 15
    workloads; LLM frontier names work too.
    """
    from repro.arch import codesign    # arch builds on core: late import
    if workloads is None:
        from .workloads import WORKLOADS
        workloads = list(WORKLOADS)
    return [codesign(wl, mix, net, grid, seed=seed, steps=steps,
                     restarts=restarts, n_samples=n_samples)
            for mix in mixes for wl in workloads]


def hetero_summary(results) -> Dict[str, Dict[str, float]]:
    """Per-mix (and overall) aggregates of a `hetero_sweep` run."""
    out: Dict[str, Dict[str, float]] = {}
    mixes = sorted({r.mix for r in results})
    for mix in mixes + ["_overall"]:
        rs = [r for r in results if mix == "_overall" or r.mix == mix]
        if not rs:        # empty sweep: no NaN means (as in `summary`)
            continue
        out[mix] = {
            "mean_speedup_hybrid": float(
                np.mean([r.speedup_hybrid for r in rs])),
            "max_speedup_hybrid": float(
                np.max([r.speedup_hybrid for r in rs])),
            "mean_speedup_codesigned": float(
                np.mean([r.speedup_codesigned for r in rs])),
            "max_speedup_codesigned": float(
                np.max([r.speedup_codesigned for r in rs])),
            "mean_spread_wired": float(
                np.mean([r.spread_wired for r in rs])),
            "mean_spread_hybrid": float(
                np.mean([r.spread_hybrid for r in rs])),
            "spread_shrunk": sum(r.spread_hybrid < r.spread_wired
                                 for r in rs),
            "n": len(rs),
        }
    return out


def summary(results: List[SweepResult]) -> Dict[int, Tuple[float, float]]:
    """bandwidth -> (mean best speedup, max best speedup) over workloads.

    Bandwidths with no results are omitted (an empty list used to emit
    a NaN mean plus a RuntimeWarning from ``np.mean([])``)."""
    out = {}
    for bw in BANDWIDTHS_GBPS:
        sp = [r.best_speedup for r in results if r.bandwidth_gbps == bw]
        if sp:
            out[bw] = (float(np.mean(sp)), float(np.max(sp)))
    return out


def network_summary(results: List[NetworkSweepResult]
                    ) -> Dict[Tuple[str, str], Tuple[float, float]]:
    """(mac, plan) -> (mean, max) best speedup over workloads."""
    keys = results[0].best_by_network().keys() if results else []
    out = {}
    for key in keys:
        sp = [r.best_by_network()[key] for r in results]
        out[key] = (float(np.mean(sp)), float(np.max(sp)))
    return out
