"""Collective-traffic plane: synthesize collective phases into messages.

At multi-chiplet scale the dominant inter-chip traffic is *collective* —
all-reduce at tensor-parallel layer boundaries, all-gather /
reduce-scatter of sharded tensors, and MoE all-to-all dispatch/combine
(the communication characterization of arXiv:2410.22262).  These are
exactly the broadcast-natured patterns a wireless plane serves best
(arXiv:2011.14755): one transmission reaches every antenna, so a
multicast that costs a whole spanning tree of mesh links costs a single
channel slot.

Each `CollectiveSpec` lowers to plain `traffic.Message` records, so the
existing packetiser, the analytic simulator, the batched DSE engine and
the event-driven `repro.sim` all cost collective traffic with no new
code paths.  Wired vs wireless costing per collective step:

- **ring steps** (ring all-reduce / all-gather / reduce-scatter): each
  participant unicasts a ``nbytes / k`` chunk to its ring successor.
  Kind ``"coll"``, unicast: costed on the wired per-link loads like any
  point-to-point transfer, and wireless-INeligible at the default
  distance threshold (neighbour hops; the unicast criterion is strict
  ``hops > threshold``).  Rings are the wired plane's best case.
- **tree reduce** (``all_reduce`` with ``algorithm="tree"``): the
  ``k - 1`` up-tree partial-sum unicasts are wired like ring steps; the
  final **result fan-out** is ONE multicast from the root to all other
  participants (kind ``"coll"``, ``len(dsts) > 1``) — wired it pays the
  whole multicast tree, wireless it is eligible under the paper's
  multicast criterion (``hops >= threshold``), i.e. a single broadcast
  slot.
- **broadcast all-gather** (``algorithm="bcast"``): every participant
  multicasts its shard to all others — k wireless-eligible multicasts
  instead of ``k (k - 1)`` ring chunk unicasts.
- **MoE all-to-all dispatch** (`moe_all_to_all`): a token routed to
  ``experts_per_token > 1`` experts sends the SAME activation block to
  several expert-owner chiplets, so each source's dispatch is one
  multicast of its local token block to the owners it hits — the
  shared-payload, broadcast-natured step (shared-expert dispatch is the
  ``fanout = k - 1`` limit).  The **combine** path returns per-token
  partial outputs, which are distinct per destination: plain all-to-all
  chunk unicasts, wired-costed.
- **broadcast** (`op="broadcast"`): root multicasts the full payload to
  every other participant (weight/KV replication, router state).

`Message.layer` carries the cost on the emitting layer's timeline, so a
collective competes with its layer's compute/DRAM/NoC terms in the
GEMINI per-layer bottleneck max — the same convention activation
transport already uses.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

from .traffic import Message

OPS = ("all_reduce", "all_gather", "reduce_scatter", "all_to_all",
       "broadcast")
# per-op algorithm choices; ops not listed accept only the default ring
_ALGORITHMS = {"all_reduce": ("ring", "tree"),
               "all_gather": ("ring", "bcast")}


@dataclasses.dataclass(frozen=True)
class CollectiveSpec:
    """One collective phase attached to a workload layer.

    ``nbytes`` semantics per op:

    - ``all_reduce``: the full per-participant tensor being reduced
      (every participant holds ``nbytes`` of partial sums).
    - ``all_gather``: the full gathered tensor (each participant
      contributes a ``nbytes / k`` shard).
    - ``reduce_scatter``: the full tensor being reduced (each
      participant keeps a ``nbytes / k`` shard of the result).
    - ``all_to_all``: per-participant send volume (``fanout`` scales
      the dispatch multicast, see `moe_all_to_all`).
    - ``broadcast``: the payload replicated from ``root`` to everyone.
    """

    op: str
    layer: int                       # layer timeline carrying the cost
    participants: Tuple[int, ...]    # chiplet ids, in ring order
    nbytes: float
    algorithm: str = "ring"          # ring | tree (all_reduce) | bcast
    fanout: int = 1                  # all_to_all: destinations per source
    root: int | None = None          # tree reduce / broadcast root

    def __post_init__(self):
        if self.op not in OPS:
            raise ValueError(f"op must be one of {OPS}, got {self.op!r}")
        if len(set(self.participants)) != len(self.participants):
            raise ValueError("participants must be distinct chiplets")
        allowed = _ALGORITHMS.get(self.op, ("ring",))
        if self.algorithm not in allowed:
            raise ValueError(
                f"{self.op} supports algorithms {allowed}, got "
                f"{self.algorithm!r} (a typo here would silently lower "
                f"to the wrong collective)")
        if self.root is not None and self.root not in self.participants:
            raise ValueError(f"root {self.root} is not a participant")


def _ring_steps(spec: CollectiveSpec, n_rounds: int) -> List[Message]:
    """``n_rounds`` rounds of chunk unicasts along the participant ring."""
    k = len(spec.participants)
    chunk = spec.nbytes / k
    msgs = []
    for _ in range(n_rounds):
        for i, src in enumerate(spec.participants):
            dst = spec.participants[(i + 1) % k]
            msgs.append(Message(spec.layer, src, (dst,), chunk, "coll"))
    return msgs


def _tree_parent(i: int) -> int:
    return (i - 1) // 2


def ring_all_reduce(spec: CollectiveSpec) -> List[Message]:
    """Reduce-scatter + all-gather rings: 2(k-1) rounds of nbytes/k."""
    return _ring_steps(spec, 2 * (len(spec.participants) - 1))


def tree_all_reduce(spec: CollectiveSpec) -> List[Message]:
    """Binary-tree reduce (unicasts up) + root result fan-out (multicast)."""
    parts = list(spec.participants)
    if spec.root is not None:
        parts.remove(spec.root)
        parts.insert(0, spec.root)
    msgs = [Message(spec.layer, parts[i], (parts[_tree_parent(i)],),
                    spec.nbytes, "coll")
            for i in range(1, len(parts))]
    if len(parts) > 1:   # the broadcast-natured step: one multicast
        msgs.append(Message(spec.layer, parts[0], tuple(sorted(parts[1:])),
                            spec.nbytes, "coll"))
    return msgs


def ring_all_gather(spec: CollectiveSpec) -> List[Message]:
    """(k-1) rounds of nbytes/k shard unicasts along the ring."""
    return _ring_steps(spec, len(spec.participants) - 1)


def bcast_all_gather(spec: CollectiveSpec) -> List[Message]:
    """Each participant multicasts its shard to all others."""
    k = len(spec.participants)
    return [Message(spec.layer, src,
                    tuple(sorted(d for d in spec.participants if d != src)),
                    spec.nbytes / k, "coll")
            for src in spec.participants if k > 1]


def ring_reduce_scatter(spec: CollectiveSpec) -> List[Message]:
    return _ring_steps(spec, len(spec.participants) - 1)


def all_to_all(spec: CollectiveSpec) -> List[Message]:
    """Distinct-shard exchange (MoE combine, sequence/expert resharding).

    Each participant holds ``nbytes`` destined uniformly across all k
    participants (its own share stays local): (k-1) unicasts of
    ``nbytes / k``.
    """
    k = len(spec.participants)
    chunk = spec.nbytes / k
    return [Message(spec.layer, src, (dst,), chunk, "coll")
            for src in spec.participants
            for dst in spec.participants if dst != src]


def dispatch_multicast(spec: CollectiveSpec) -> List[Message]:
    """Shared-payload dispatch: each source multicasts its block once.

    A token routed to ``fanout`` experts sends the SAME activation to
    ``fanout`` owner chiplets; aggregated over a token block the set of
    owners hit approaches ``min(fanout * tokens, k - 1)`` distinct
    chiplets, and one tree/broadcast transmission covers them all.  The
    destination set is the ``fanout``-spread neighbourhood on the
    participant ring (deterministic, uniform-routing stand-in).
    """
    k = len(spec.participants)
    fan = max(1, min(spec.fanout, k - 1))
    msgs = []
    for i, src in enumerate(spec.participants):
        dsts = tuple(sorted(spec.participants[(i + 1 + j) % k]
                            for j in range(fan)))
        msgs.append(Message(spec.layer, src, dsts, spec.nbytes, "coll"))
    return msgs


def broadcast(spec: CollectiveSpec) -> List[Message]:
    root = spec.root if spec.root is not None else spec.participants[0]
    others = tuple(sorted(d for d in spec.participants if d != root))
    if not others:
        return []
    return [Message(spec.layer, root, others, spec.nbytes, "coll")]


def lower(spec: CollectiveSpec) -> List[Message]:
    """Lower one collective phase to `traffic.Message` records.

    Lowering is topology-independent: routes, hop counts and link
    incidence are resolved by the packetiser (`traffic.build_trace`).
    """
    if len(spec.participants) < 2:
        return []
    if spec.op == "all_reduce":
        return (tree_all_reduce(spec) if spec.algorithm == "tree"
                else ring_all_reduce(spec))
    if spec.op == "all_gather":
        return (bcast_all_gather(spec) if spec.algorithm == "bcast"
                else ring_all_gather(spec))
    if spec.op == "reduce_scatter":
        return ring_reduce_scatter(spec)
    if spec.op == "all_to_all":
        return (dispatch_multicast(spec) if spec.fanout > 1
                else all_to_all(spec))
    return broadcast(spec)


def lower_all(specs: Sequence[CollectiveSpec]) -> List[Message]:
    msgs: List[Message] = []
    for spec in specs:
        msgs.extend(lower(spec))
    return msgs


def collective_bytes(specs: Sequence[CollectiveSpec]) -> float:
    """Total bytes the lowered collective messages inject into the NoP."""
    return sum(m.nbytes for m in lower_all(specs))
