"""LLM workload frontier: transformer/MoE layer graphs from `repro.configs`.

The paper's evaluation stops at batch-1 inference over 15 CNN/RNN graphs;
its companion characterization (arXiv:2410.22262) shows that at
multi-chiplet scale the dominant traffic is *collective*.  This module
bridges the repo's LLM model zoo (`repro.configs.ARCHS`) to the traffic
generator: each `"<model>:<phase>"` workload derives a prefill- or
decode-phase layer graph directly from the `ModelConfig` (dims, GQA
heads, expert counts, sliding windows, activation arity), annotated with
the collective hints (`Layer.collective`) that
`mapper.tensor_parallel_mapping` / `expert_parallel_mapping` turn into
all-reduce and all-to-all phases at layer boundaries.

Phase semantics:

- **prefill**: one pass over ``PREFILL_SEQ`` prompt tokens (batch 1).
  Compute and collective volume both scale with the token count — the
  tensor-parallel all-reduce at each o-proj/ff2 boundary carries the
  full ``seq x d_model`` activation, the MoE dispatch/combine carry it
  ``experts_per_token``-fold.  KV-cache writes ride the activation path.
- **decode**: one token step for ``DECODE_BATCH`` concurrent sequences
  at context ``DECODE_CTX``.  Per-step activations are tiny; the
  traffic is dominated by streamed weights and KV-cache reads (modelled
  as the attention layer's fetched bytes) — the memory-bound regime.

The graphs repeat the config's pattern unit ``units`` times (default
`DEFAULT_UNITS`): traffic is periodic across identical units, so two
units capture the steady state plus the boundary while keeping the
packetised trace tractable; per-layer times simply scale with depth.
Giant models coarsen the packet granularity via `auto_packet_bytes`
(flit aggregation — aggregates are granularity-independent).

Supported families: ``dense`` and ``moe`` (the attn/mlp/moe block
kinds).  SSM/hybrid/multimodal archs raise with a pointer here.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from repro.configs import ARCHS, ModelConfig

from .topology import AcceleratorConfig, build_topology
from .traffic import PACKET_BYTES, TrafficTrace, WEIGHT_SRAM_BYTES, build_trace
from .workloads import BYTES, GraphBuilder, Layer

# LLM workload id -> repro.configs arch id
LLM_MODELS: Dict[str, str] = {
    "smollm_360m": "smollm-360m",
    "gemma2_2b": "gemma2-2b",
    "chatglm3_6b": "chatglm3-6b",
    "qwen2p5_32b": "qwen2.5-32b",
    "mixtral_8x22b": "mixtral-8x22b",
    "kimi_k2": "kimi-k2-1t-a32b",
}
PHASES = ("prefill", "decode")
LLM_WORKLOADS: Tuple[str, ...] = tuple(
    f"{m}:{p}" for m in LLM_MODELS for p in PHASES)

PREFILL_SEQ = 2048       # prompt tokens per prefill pass
DECODE_BATCH = 8         # concurrent sequences per decode step
DECODE_CTX = 2048        # KV context length at the decode step
DEFAULT_UNITS = 2        # pattern-unit repetitions in the graph
TARGET_PACKETS = 30_000  # packet-count budget steering auto granularity


class _LLMBuilder(GraphBuilder):
    """`GraphBuilder` without the CNN zoo's implicit BATCH scaling (LLM
    phases carry their token/batch counts explicitly)."""

    batch = 1


def _act_mult(cfg: ModelConfig) -> int:
    return 3 if cfg.activation in ("silu", "geglu") else 2


def _attn_block(g: _LLMBuilder, cfg: ModelConfig, tag: str, tokens: int,
                ctx: int, kv_read: float) -> None:
    """QKV -> attention core -> o-proj (all-reduce boundary)."""
    d, hd = cfg.d_model, cfg.head_dim
    q_dim, kv_dim = cfg.n_heads * hd, cfg.n_kv_heads * hd
    g.add(f"{tag}_qkv",
          macs=tokens * d * (q_dim + 2 * kv_dim),
          act_in=BYTES * tokens * d,
          weights=BYTES * d * (q_dim + 2 * kv_dim),
          act_out=BYTES * tokens * (q_dim + 2 * kv_dim))
    # attention core: QK^T + AV (two passes over the context; prefill's
    # causal half and the dual matmul fold to one ctx-wide pass per token)
    g.add(f"{tag}_attn",
          macs=2.0 * tokens * ctx * q_dim,
          act_in=BYTES * tokens * (q_dim + 2 * kv_dim),
          weights=kv_read,          # decode: streamed KV-cache bytes
          act_out=BYTES * tokens * q_dim)
    g.add(f"{tag}_o",
          macs=tokens * q_dim * d,
          act_in=BYTES * tokens * q_dim,
          weights=BYTES * q_dim * d,
          act_out=BYTES * tokens * d,
          collective="all_reduce")   # row-parallel partial sums


def _mlp_block(g: _LLMBuilder, cfg: ModelConfig, tag: str, tokens: int,
               d_ff: int) -> None:
    d, am = cfg.d_model, _act_mult(cfg)
    g.add(f"{tag}_ff_in",
          macs=tokens * d * d_ff * (am - 1),
          act_in=BYTES * tokens * d,
          weights=BYTES * (am - 1) * d * d_ff,
          act_out=BYTES * tokens * d_ff)
    g.add(f"{tag}_ff_out",
          macs=tokens * d_ff * d,
          act_in=BYTES * tokens * d_ff,
          weights=BYTES * d_ff * d,
          act_out=BYTES * tokens * d,
          collective="all_reduce")


def _moe_block(g: _LLMBuilder, cfg: ModelConfig, tag: str,
               tokens: int) -> None:
    d, am = cfg.d_model, _act_mult(cfg)
    d_ff = cfg.moe_d_ff or cfg.d_ff
    n_exp, ept = cfg.n_experts, cfg.experts_per_token
    # router: tiny matmul whose decisions fan out to every expert owner
    g.add(f"{tag}_router",
          macs=tokens * d * n_exp,
          act_in=BYTES * tokens * d,
          weights=BYTES * d * n_exp,
          act_out=BYTES * tokens * n_exp,
          collective="broadcast")
    # expert pool: each token runs `ept` experts; the pass touches (and
    # therefore fetches) at most `tokens * ept` distinct experts
    touched = min(n_exp, tokens * ept)
    g.add(f"{tag}_experts",
          macs=tokens * ept * am * d * d_ff,
          act_in=BYTES * tokens * d,
          weights=BYTES * am * d * d_ff * touched,
          act_out=BYTES * tokens * d,
          collective="moe", n_experts=n_exp, experts_per_token=ept)


def llm_layers(cfg: ModelConfig, phase: str,
               units: int | None = None) -> List[Layer]:
    """Layer graph of one prefill pass / decode step of ``cfg``."""
    if phase not in PHASES:
        raise ValueError(f"phase must be one of {PHASES}, got {phase!r}")
    if any(b.kind not in ("attn", "mlp", "moe") for b in cfg.unit):
        raise ValueError(
            f"{cfg.name}: family {cfg.family!r} has block kinds beyond "
            f"attn/mlp/moe; the LLM traffic frontier models dense and moe "
            f"archs (see workloads_llm docstring)")
    units = units if units is not None else min(cfg.n_units, DEFAULT_UNITS)
    n_seqs = 1 if phase == "prefill" else DECODE_BATCH
    tokens = PREFILL_SEQ if phase == "prefill" else DECODE_BATCH
    g = _LLMBuilder()
    for u in range(units):
        for bi, blk in enumerate(cfg.unit):
            tag = f"u{u}b{bi}"
            if blk.kind == "attn":
                window = blk.window if not blk.is_global else None
                if phase == "prefill":
                    ctx = min(PREFILL_SEQ, window or PREFILL_SEQ)
                    kv_read = 0.0      # cache is written, not re-read
                else:
                    ctx = min(DECODE_CTX, window or DECODE_CTX)
                    kv_read = (BYTES * 2 * ctx * cfg.n_kv_heads
                               * cfg.head_dim * n_seqs)
                _attn_block(g, cfg, tag, tokens, ctx, kv_read)
            elif blk.kind == "mlp":
                _mlp_block(g, cfg, tag, tokens, blk.d_ff or cfg.d_ff)
            else:
                _moe_block(g, cfg, tag, tokens)
    # LM head over the live positions only (one per sequence), vocab-
    # parallel: the logit shards are synced across the group
    g.add("lm_head",
          macs=n_seqs * cfg.d_model * cfg.vocab_size,
          act_in=BYTES * n_seqs * cfg.d_model,
          weights=BYTES * cfg.d_model * cfg.vocab_size,
          act_out=BYTES * n_seqs * cfg.vocab_size,
          collective="all_reduce")
    return g.layers


def llm_workload(name: str) -> List[Layer]:
    """`get_workload` hook: ``"<model>:<phase>"`` -> layer graph."""
    model, phase = parse_name(name)
    return llm_layers(ARCHS[LLM_MODELS[model]], phase)


def parse_name(name: str) -> Tuple[str, str]:
    model, sep, phase = name.partition(":")
    if not sep or model not in LLM_MODELS or phase not in PHASES:
        raise KeyError(
            f"unknown LLM workload {name!r}; use '<model>:<phase>' with "
            f"model in {sorted(LLM_MODELS)} and phase in {PHASES}")
    return model, phase


def auto_packet_bytes(layers: List[Layer]) -> float:
    """Packetisation granularity keeping the trace near `TARGET_PACKETS`.

    Estimates the dominant byte volume (streamed weights + a collective
    multiple of the activations) and rounds the per-packet size up to a
    power of two, never below the 64 KiB NoP packet.
    """
    streamed = sum(lyr.weights for lyr in layers
                   if lyr.weights > WEIGHT_SRAM_BYTES)
    acts = sum(lyr.act_out for lyr in layers)
    est = streamed + 4.0 * acts
    size = PACKET_BYTES
    while size * TARGET_PACKETS < est:
        size *= 2
    return size


def make_llm_trace(name: str, acc: AcceleratorConfig | None = None,
                   mapping: str | None = None,
                   units: int | None = None,
                   packet_bytes: float | None = None) -> TrafficTrace:
    """LLM workload name -> `TrafficTrace` on the (default) platform.

    ``mapping=None`` picks the family's natural parallelism: expert-
    parallel for MoE configs, tensor-parallel otherwise.  Explicit
    values accept "tensor", "tensor_ring" (wired-optimal ring
    all-reduce), "expert", "pipeline", "spatial".
    """
    from .mapper import (expert_parallel_mapping, pipeline_mapping,
                         spatial_mapping, tensor_parallel_mapping)
    model, phase = parse_name(name)
    cfg = ARCHS[LLM_MODELS[model]]
    layers = llm_layers(cfg, phase, units=units)
    topo = build_topology(acc)
    if mapping is None:
        mapping = "expert" if cfg.n_experts else "tensor"
    if mapping == "expert":
        mapped = expert_parallel_mapping(layers, topo)
    elif mapping == "tensor":
        mapped = tensor_parallel_mapping(layers, topo)
    elif mapping == "tensor_ring":
        mapped = tensor_parallel_mapping(layers, topo, algorithm="ring")
    elif mapping == "pipeline":
        mapped = pipeline_mapping(layers, topo)
    elif mapping == "spatial":
        mapped = spatial_mapping(layers, topo)
    else:
        raise ValueError(f"unknown mapping {mapping!r}")
    if packet_bytes is None:
        packet_bytes = auto_packet_bytes(layers)
    return build_trace(layers, mapped, topo, packet_bytes)
