"""The 15 AI workloads evaluated in the paper (Table 1), as layer graphs.

Each workload is a DAG of `Layer` records carrying per-layer MACs, tensor
byte sizes, and the consumer fan-out of the layer's output.  Fan-out > 1
(residual branches, inception modules, dense connectivity) is what turns
activation transport into *multicast* traffic — the phenomenon the paper's
wireless plane targets.

All sizes are batch-1 inference in fp16 (2 bytes/element), matching the
GEMINI inference setting.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List

BYTES = 2   # fp16
BATCH = 8   # batched inference (GEMINI-style EDP evaluation batch):
# activations and MACs scale with batch; weights are fetched once per batch,
# so weight streaming amortises and activation transport dominates, as in
# the paper's NoP-bottleneck characterisation (Fig. 2).


@dataclasses.dataclass
class Layer:
    name: str
    macs: float                 # multiply-accumulates
    act_in: int                 # input activation bytes (sum over input edges)
    weights: int                # weight bytes
    act_out: int                # output activation bytes
    consumers: List[int] = dataclasses.field(default_factory=list)  # layer idxs
    # collective hint for parallel mappings (`mapper.tensor_parallel_mapping`
    # / `expert_parallel_mapping`): "all_reduce" marks a partial-sum output
    # that must be reduced across the layer's chiplet group, "moe" marks an
    # expert layer whose boundary is an all-to-all dispatch/combine pair.
    # `None` leaves the choice to the mapper's fallback rule.
    collective: str | None = None
    # MoE routing metadata backing the "moe" hint (set by the LLM builder)
    n_experts: int = 0
    experts_per_token: int = 0

    @property
    def fan_out(self) -> int:
        return max(1, len(self.consumers))


class GraphBuilder:
    """Tiny helper: append layers, record producer->consumer edges.

    ``batch`` scales MACs and activations (weights load once per batch);
    the LLM builder subclasses with ``batch = 1`` and carries its token
    counts explicitly.  ``meta`` kwargs (collective hints, MoE routing
    metadata) pass through to the `Layer`.
    """

    batch: int = BATCH

    def __init__(self) -> None:
        self.layers: List[Layer] = []

    def add(self, name: str, macs: float, act_in: float, weights: float,
            act_out: float, inputs: List[int] | None = None,
            **meta) -> int:
        idx = len(self.layers)
        self.layers.append(Layer(name, macs * self.batch,
                                 int(act_in * self.batch), int(weights),
                                 int(act_out * self.batch), **meta))
        # `None` means "chain to the previous layer"; an explicit empty list
        # means "true source node, no producers" — they must not collapse
        # (an `inputs=[]` source used to silently wire to its predecessor).
        if inputs is None:
            inputs = [idx - 1] if idx else []
        for p in inputs:
            if p >= 0:
                self.layers[p].consumers.append(idx)
        return idx

    def conv(self, name: str, cin: int, cout: int, k: int, hw: int,
             stride: int = 1, groups: int = 1,
             inputs: List[int] | None = None) -> int:
        hw_out = max(1, math.ceil(hw / stride))
        macs = (k * k * cin * cout * hw_out * hw_out) / groups
        return self.add(
            name, macs,
            act_in=BYTES * cin * hw * hw,
            weights=BYTES * k * k * cin * cout // groups,
            act_out=BYTES * cout * hw_out * hw_out,
            inputs=inputs,
        )

    def fc(self, name: str, din: int, dout: int, seq: int = 1,
           inputs: List[int] | None = None) -> int:
        return self.add(
            name, float(din) * dout * seq,
            act_in=BYTES * din * seq,
            weights=BYTES * din * dout,
            act_out=BYTES * dout * seq,
            inputs=inputs,
        )

    def merge(self, name: str, inputs: List[int], cout: int, hw: int) -> int:
        """Concat/add join point: no MACs, just data movement."""
        act_in = sum(self.layers[i].act_out for i in inputs)
        return self.add(name, 0.0, act_in, 0, BYTES * cout * hw * hw,
                        inputs=inputs)


# --------------------------------------------------------------------------
# CNN families
# --------------------------------------------------------------------------

def _resnet(blocks: List[int], groups: int = 1, width: int = 64) -> List[Layer]:
    g = GraphBuilder()
    g.conv("stem", 3, 64, 7, 224, stride=2)
    hw, cin = 56, 64  # after maxpool
    for stage, n in enumerate(blocks):
        mid = width * (2 ** stage)
        cout = 64 * (2 ** stage) * 4
        for b in range(n):
            stride = 2 if (b == 0 and stage > 0) else 1
            inp = len(g.layers) - 1
            a = g.conv(f"s{stage}b{b}_1x1a", cin, mid, 1, hw, inputs=[inp])
            c = g.conv(f"s{stage}b{b}_3x3", mid, mid, 3, hw, stride=stride,
                       groups=groups)
            hw2 = max(1, hw // stride)
            d = g.conv(f"s{stage}b{b}_1x1b", mid, cout, 1, hw2)
            if b == 0:
                g.conv(f"s{stage}b{b}_proj", cin, cout, 1, hw, stride=stride,
                       inputs=[inp])  # residual fan-out from `inp`
                g.merge(f"s{stage}b{b}_add", [d, len(g.layers) - 1], cout, hw2)
            else:
                g.merge(f"s{stage}b{b}_add", [d, inp], cout, hw2)
            cin, hw = cout, hw2
    g.fc("fc", cin, 1000)
    return g.layers


def resnet50() -> List[Layer]:
    return _resnet([3, 4, 6, 3])


def resnet101() -> List[Layer]:
    return _resnet([3, 4, 23, 3])


def resnet152() -> List[Layer]:
    return _resnet([3, 8, 36, 3])


def resnext50() -> List[Layer]:
    return _resnet([3, 4, 6, 3], groups=32, width=128)


def vgg16() -> List[Layer]:
    g = GraphBuilder()
    cfg = [(3, 64), (64, 64), (64, 128), (128, 128), (128, 256), (256, 256),
           (256, 256), (256, 512), (512, 512), (512, 512), (512, 512),
           (512, 512), (512, 512)]
    hws = [224, 224, 112, 112, 56, 56, 56, 28, 28, 28, 14, 14, 14]
    for i, ((cin, cout), hw) in enumerate(zip(cfg, hws)):
        g.conv(f"conv{i}", cin, cout, 3, hw)
    g.fc("fc6", 512 * 7 * 7, 4096)
    g.fc("fc7", 4096, 4096)
    g.fc("fc8", 4096, 1000)
    return g.layers


def zfnet() -> List[Layer]:
    g = GraphBuilder()
    g.conv("conv1", 3, 96, 7, 224, stride=2)
    g.conv("conv2", 96, 256, 5, 55, stride=2)
    g.conv("conv3", 256, 384, 3, 27)
    g.conv("conv4", 384, 384, 3, 13)
    g.conv("conv5", 384, 256, 3, 13)
    g.fc("fc6", 256 * 6 * 6, 4096)
    g.fc("fc7", 4096, 4096)
    g.fc("fc8", 4096, 1000)
    return g.layers


def darknet19() -> List[Layer]:
    g = GraphBuilder()
    plan = [(3, 32, 3, 224), (32, 64, 3, 112),
            (64, 128, 3, 56), (128, 64, 1, 56), (64, 128, 3, 56),
            (128, 256, 3, 28), (256, 128, 1, 28), (128, 256, 3, 28),
            (256, 512, 3, 14), (512, 256, 1, 14), (256, 512, 3, 14),
            (512, 256, 1, 14), (256, 512, 3, 14),
            (512, 1024, 3, 7), (1024, 512, 1, 7), (512, 1024, 3, 7),
            (1024, 512, 1, 7), (512, 1024, 3, 7), (1024, 1000, 1, 7)]
    for i, (cin, cout, k, hw) in enumerate(plan):
        g.conv(f"conv{i}", cin, cout, k, hw)
    return g.layers


def googlenet() -> List[Layer]:
    g = GraphBuilder()
    g.conv("stem1", 3, 64, 7, 224, stride=2)
    g.conv("stem2", 64, 192, 3, 56)
    # (cin, 1x1, 3x3red, 3x3, 5x5red, 5x5, pool_proj, hw)
    modules = [
        (192, 64, 96, 128, 16, 32, 32, 28), (256, 128, 128, 192, 32, 96, 64, 28),
        (480, 192, 96, 208, 16, 48, 64, 14), (512, 160, 112, 224, 24, 64, 64, 14),
        (512, 128, 128, 256, 24, 64, 64, 14), (512, 112, 144, 288, 32, 64, 64, 14),
        (528, 256, 160, 320, 32, 128, 128, 14),
        (832, 256, 160, 320, 32, 128, 128, 7), (832, 384, 192, 384, 48, 128, 128, 7),
    ]
    for m, (cin, b1, r3, b3, r5, b5, bp, hw) in enumerate(modules):
        inp = len(g.layers) - 1
        p1 = g.conv(f"i{m}_1x1", cin, b1, 1, hw, inputs=[inp])
        g.conv(f"i{m}_3x3r", cin, r3, 1, hw, inputs=[inp])
        p3 = g.conv(f"i{m}_3x3", r3, b3, 3, hw)
        g.conv(f"i{m}_5x5r", cin, r5, 1, hw, inputs=[inp])
        p5 = g.conv(f"i{m}_5x5", r5, b5, 5, hw)
        pp = g.conv(f"i{m}_pool", cin, bp, 1, hw, inputs=[inp])
        g.merge(f"i{m}_cat", [p1, p3, p5, pp], b1 + b3 + b5 + bp, hw)
    g.fc("fc", 1024, 1000)
    return g.layers


def iresnet() -> List[Layer]:
    """Inception-ResNet-style: inception branches + residual add."""
    g = GraphBuilder()
    g.conv("stem", 3, 192, 3, 149, stride=2)
    hw, cin = 35, 320
    g.conv("stem2", 192, cin, 3, 71, stride=2)
    for blk, (n, hw, cin) in enumerate([(5, 35, 320), (10, 17, 1088),
                                        (5, 8, 2080)]):
        for b in range(n):
            inp = len(g.layers) - 1
            p1 = g.conv(f"b{blk}_{b}_1x1", cin, 32 * (blk + 1), 1, hw,
                        inputs=[inp])
            g.conv(f"b{blk}_{b}_3x3r", cin, 32 * (blk + 1), 1, hw, inputs=[inp])
            p3 = g.conv(f"b{blk}_{b}_3x3", 32 * (blk + 1), 48 * (blk + 1), 3, hw)
            pj = g.conv(f"b{blk}_{b}_proj", 32 * (blk + 1) + 48 * (blk + 1),
                        cin, 1, hw, inputs=[p1, p3])
            g.merge(f"b{blk}_{b}_add", [pj, inp], cin, hw)
    g.fc("fc", cin, 1000)
    return g.layers


def densenet() -> List[Layer]:
    """DenseNet-121: dense connectivity == the heaviest multicast fan-out."""
    g = GraphBuilder()
    g.conv("stem", 3, 64, 7, 224, stride=2)
    growth = 32
    cin, hw = 64, 56
    for blk, n in enumerate([6, 12, 24, 16]):
        block_outs: List[int] = [len(g.layers) - 1]
        for b in range(n):
            c_in_eff = cin + b * growth
            a = g.conv(f"d{blk}_{b}_1x1", c_in_eff, 4 * growth, 1, hw,
                       inputs=list(block_outs))
            o = g.conv(f"d{blk}_{b}_3x3", 4 * growth, growth, 3, hw)
            block_outs.append(o)
        cin = cin + n * growth
        if blk < 3:
            g.conv(f"t{blk}_1x1", cin, cin // 2, 1, hw,
                   inputs=[block_outs[-1]])
            cin, hw = cin // 2, hw // 2
    g.fc("fc", cin, 1000)
    return g.layers


def pnasnet() -> List[Layer]:
    """PNASNet-5-ish: 12 cells, 5 separable-conv branches per cell."""
    g = GraphBuilder()
    g.conv("stem", 3, 96, 3, 224, stride=2)
    hw, cin = 56, 270
    g.conv("stem2", 96, cin, 3, 112, stride=2)
    for cell in range(12):
        if cell in (4, 8):
            hw, cin = hw // 2, cin * 2
        inp = len(g.layers) - 1
        branches = []
        for br in range(5):
            k = (3, 5, 7, 3, 5)[br]
            # separable: depthwise k x k + pointwise 1x1
            d = g.conv(f"c{cell}_b{br}_dw", cin, cin, k, hw, groups=cin,
                       inputs=[inp])
            p = g.conv(f"c{cell}_b{br}_pw", cin, cin // 5, 1, hw)
            branches.append(p)
        g.merge(f"c{cell}_cat", branches, cin, hw)
    g.fc("fc", cin, 1000)
    return g.layers


# --------------------------------------------------------------------------
# Sequence models
# --------------------------------------------------------------------------

def _lstm_layer(g: GraphBuilder, name: str, d: int, seq: int,
                inputs: List[int] | None = None) -> int:
    # 4 gates, input + recurrent matmuls, per timestep
    return g.add(
        name, macs=seq * 2 * 4 * d * d,
        act_in=BYTES * seq * d,
        weights=BYTES * 2 * 4 * d * d,
        act_out=BYTES * seq * d,
        inputs=inputs,
    )


def lstm() -> List[Layer]:
    g = GraphBuilder()
    d, seq = 1024, 100
    g.fc("embed", 32000, d, seq=1)  # embedding lookup modeled as weight fetch
    for i in range(4):
        _lstm_layer(g, f"lstm{i}", d, seq)
    g.fc("proj", d, 32000, seq=seq)
    return g.layers


def gnmt() -> List[Layer]:
    g = GraphBuilder()
    d, seq = 1024, 50
    g.fc("src_embed", 32000, d, seq=1)
    enc = []
    for i in range(8):
        residual = [len(g.layers) - 1] if i < 2 else [len(g.layers) - 1,
                                                      len(g.layers) - 2]
        enc.append(_lstm_layer(g, f"enc{i}", d, seq, inputs=residual))
    for i in range(8):
        inputs = [len(g.layers) - 1]
        if i == 0:
            inputs.append(enc[-1])
        _lstm_layer(g, f"dec{i}", d, seq, inputs=inputs)
        if i == 0:
            # attention: scores + context against encoder states, consumed by
            # every subsequent decoder layer (multicast-heavy)
            g.add("attention", macs=2 * seq * seq * d,
                  act_in=BYTES * 2 * seq * d, weights=BYTES * d * d,
                  act_out=BYTES * seq * d, inputs=[enc[-1], len(g.layers) - 1])
    g.fc("softmax", d, 32000, seq=seq)
    return g.layers


def _transformer_block(g: GraphBuilder, name: str, d: int, ff: int, seq: int,
                       inp: int) -> int:
    # QKV: input fans out to three projections + the residual add
    q = g.fc(f"{name}_q", d, d, seq=seq, inputs=[inp])
    k = g.fc(f"{name}_k", d, d, seq=seq, inputs=[inp])
    v = g.fc(f"{name}_v", d, d, seq=seq, inputs=[inp])
    att = g.add(f"{name}_attn", macs=2 * seq * seq * d,
                act_in=3 * BYTES * seq * d, weights=0,
                act_out=BYTES * seq * d, inputs=[q, k, v])
    o = g.fc(f"{name}_o", d, d, seq=seq, inputs=[att])
    r1 = g.merge(f"{name}_add1", [o, inp], 1, int(math.sqrt(seq * d)))
    f1 = g.fc(f"{name}_ff1", d, ff, seq=seq, inputs=[r1])
    f2 = g.fc(f"{name}_ff2", ff, d, seq=seq, inputs=[f1])
    return g.merge(f"{name}_add2", [f2, r1], 1, int(math.sqrt(seq * d)))


def transformer() -> List[Layer]:
    g = GraphBuilder()
    d, ff, seq = 512, 2048, 512
    cur = g.fc("embed", 32000, d, seq=1)
    for i in range(6):
        cur = _transformer_block(g, f"enc{i}", d, ff, seq, cur)
    for i in range(6):
        cur = _transformer_block(g, f"dec{i}", d, ff, seq, cur)
    g.fc("lm_head", d, 32000, seq=seq, inputs=[cur])
    return g.layers


def transformer_cell() -> List[Layer]:
    g = GraphBuilder()
    d, ff, seq = 1024, 4096, 512
    cur = g.add("input", 0.0, 0, 0, BYTES * seq * d, inputs=[])
    _transformer_block(g, "cell", d, ff, seq, cur)
    return g.layers


WORKLOADS: Dict[str, Callable[[], List[Layer]]] = {
    "darknet19": darknet19,
    "densenet": densenet,
    "zfnet": zfnet,
    "gnmt": gnmt,
    "vgg": vgg16,
    "lstm": lstm,
    "resnet50": resnet50,
    "resnet101": resnet101,
    "resnet152": resnet152,
    "resnext50": resnext50,
    "pnasnet": pnasnet,
    "transformer": transformer,
    "transformer_cell": transformer_cell,
    "iresnet": iresnet,
    "googlenet": googlenet,
}


def get_workload(name: str) -> List[Layer]:
    if name in WORKLOADS:
        return WORKLOADS[name]()
    # "<model>:<phase>" names resolve against the LLM frontier registry
    # (kept separate so the paper's 15-workload sweeps stay exactly Table 1)
    from .workloads_llm import LLM_WORKLOADS, llm_workload
    if name in LLM_WORKLOADS:
        return llm_workload(name)
    raise KeyError(f"unknown workload {name!r}; pick one of "
                   f"{sorted(WORKLOADS)} or {sorted(LLM_WORKLOADS)}")
