"""Message generation: (workload graph x mapping) -> NoP message trace.

Traffic model (GEMINI/SIMBA conventions):

- **Weights** are resident in chiplet SRAM when a layer's weights fit the
  per-chiplet buffer budget (loaded once, amortised across inferences —
  SIMBA weight-stationary style).  Oversized layers (big FC / LSTM gates)
  are *streamed* per inference: slices striped across all DRAM chiplets,
  unicast to the executing chiplet (DRAM time + NoP entry links).
- **Activations** crossing pipeline stages are sent once, at production
  time, as a single message to the set of consumer chiplets — a multicast
  when the fan-out reaches >1 remote chiplet.  Same-chiplet edges are free
  (tile-local; halo traffic is folded into the NoC term).
- Tensors consumed more than `spill_window` layers after production, or
  larger than the activation buffer, are **spilled**: DRAM write at
  production + DRAM read at consumption.

Produces a flat, numpy-vectorised `TrafficTrace` so the wireless DSE
(hundreds of configurations) re-costs messages without re-walking the
graph.

Node ids: 0..C-1 compute chiplets, C..C+D-1 DRAM chiplets.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from .mapper import Mapping
from .topology import Topology, nearest_dram
from .workloads import Layer

Link = Tuple[Tuple[int, int], Tuple[int, int]]  # directed (from_xy, to_xy)

# SRAM budgets per chiplet (SIMBA-like global buffer) and model constants,
# calibrated against paper Fig. 2 (see tests/test_paper_repro.py).
WEIGHT_SRAM_BYTES = 4 * 2**20     # weights resident below this size
ACT_SRAM_BYTES = 32 * 2**20       # live-tensor buffer before DRAM spill
NOC_PARALLEL = 16.0               # concurrent NoC injection ports per chiplet
COMPUTE_EFFICIENCY = 0.90         # achieved fraction of peak MACs
PACKET_BYTES = 64 * 1024          # NoP packetisation granularity: the
# injection-probability filter operates per packet (as in the simulator's
# per-message accounting), so large tensors can be *partially* offloaded.


@dataclasses.dataclass
class Message:
    layer: int                    # layer whose timeline carries the cost
    src: int
    dsts: Tuple[int, ...]
    nbytes: float
    # "wstream" | "act" | "spill_w" | "spill_r" | "coll"
    # ("coll" = collective-phase step, see core/collectives.py: ring/tree
    # chunk unicasts stay wired-costed, multicast fan-outs are
    # wireless-eligible under the paper's multicast criterion)
    kind: str

    @property
    def is_multicast(self) -> bool:
        return len(self.dsts) > 1


@dataclasses.dataclass
class TrafficTrace:
    """Vectorised message arrays + per-layer wireless-independent costs."""

    topo: Topology
    n_layers: int
    link_index: Dict[Link, int]
    # per-message arrays
    layer: np.ndarray          # int32 (M,)
    nbytes: np.ndarray         # float64 (M,)
    src: np.ndarray            # int32 (M,) source node (chiplet or DRAM) id
    is_multicast: np.ndarray   # bool (M,)
    is_multichip: np.ndarray   # bool (M,)
    max_hops: np.ndarray       # int32 (M,) max NoP hops src->any dst
    dram_node: np.ndarray      # int32 (M,) DRAM port index served, -1 if none
    # sparse (message -> link) incidence
    inc_msg: np.ndarray        # int32 (E,)
    inc_link: np.ndarray       # int32 (E,)
    # per-layer wireless-independent times (seconds)
    t_compute: np.ndarray
    t_dram: np.ndarray
    t_noc: np.ndarray
    dram_bytes: np.ndarray
    messages: List[Message]
    total_macs: float = 0.0        # for the energy model
    noc_bytes: float = 0.0
    # per-chiplet totals (C,), for heterogeneous energy accounting
    # (`ChipletSpec` per-MAC / per-bit coefficients); `None` on traces
    # built before heterogeneity existed
    macs_per_chiplet: np.ndarray | None = None
    noc_bytes_per_chiplet: np.ndarray | None = None

    # per-layer execution metadata, for the dynamic-conditions plane
    # (`repro.fault`): which chiplets run each layer, with what shares,
    # and the layer's weight footprint (re-streamed on degraded-mode
    # absorption / reshard migration).  `None` on hand-built traces.
    exec_chips: list | None = None        # per-layer tuple of chiplet ids
    exec_shares: list | None = None       # per-layer share vector
    weight_bytes: np.ndarray | None = None  # (L,) weight footprint

    @property
    def n_links(self) -> int:
        return len(self.link_index)

    def baseline_link_loads(self) -> np.ndarray:
        """(n_layers, n_links) byte loads with everything wired."""
        loads = np.zeros((self.n_layers, self.n_links))
        np.add.at(loads, (self.layer[self.inc_msg], self.inc_link),
                  self.nbytes[self.inc_msg])
        return loads

    def cut_matrix(self) -> Tuple[np.ndarray, np.ndarray]:
        """(n_links, n_cuts) incidence + per-cut bandwidth (B/s).

        NoP congestion is evaluated per directed mesh *cut* (the paper:
        "multicast patterns leading to congested bisection links"): between
        every pair of adjacent rows/columns, per direction.  A cut of k
        parallel links serves the bytes crossing it at k * link_bw.
        """
        rows, cols = self.topo.config.grid
        bw = self.topo.config.nop_bw_per_side
        cuts = []          # (axis, boundary, direction)
        for c in range(cols - 1):
            cuts.append(("v", c, +1))
            cuts.append(("v", c, -1))
        for r in range(rows - 1):
            cuts.append(("h", r, +1))
            cuts.append(("h", r, -1))
        mat = np.zeros((len(self.link_index), len(cuts)))
        for (a, b), li in self.link_index.items():
            for ci, (axis, bnd, d) in enumerate(cuts):
                if axis == "v" and a[1] == bnd + (d < 0) and b[1] == bnd + (d > 0):
                    mat[li, ci] = 1.0
                if axis == "h" and a[0] == bnd + (d < 0) and b[0] == bnd + (d > 0):
                    mat[li, ci] = 1.0
        n_par = np.array([rows if axis == "v" else cols
                          for axis, _, _ in cuts], float)
        return mat, n_par * bw


def _streamed(lyr: Layer, sram: float = WEIGHT_SRAM_BYTES) -> bool:
    return lyr.weights > sram


def _uniform(vals) -> bool:
    """True iff every value equals the first (exact float equality —
    the gate deciding legacy-expression vs per-chiplet costing)."""
    it = iter(vals)
    first = next(it)
    return all(v == first for v in it)


def _layer_sram(cfg, chips) -> float:
    """Weight-SRAM budget governing a layer's streamed-vs-resident call.

    Uniform packages use the global calibrated constant; heterogeneous
    packages (`AcceleratorConfig.chiplet_sram`) take the tightest budget
    among the executing chiplets — a weight slice must fit everywhere
    the layer runs.  A uniform `HeteroPackage` of "standard" chiplets
    carries exactly `WEIGHT_SRAM_BYTES` per slot, so the comparison is
    unchanged.
    """
    sram = cfg.chiplet_sram
    if sram is None or not chips:
        return WEIGHT_SRAM_BYTES
    return min(sram[c] for c in chips)


def generate_messages(layers: List[Layer], mapping: Mapping,
                      topo: Topology) -> List[Message]:
    msgs: List[Message] = []
    n_dram = len(topo.dram_coords)
    n_chip = topo.config.n_chiplets

    for li, lyr in enumerate(layers):
        placed = list(mapping.chiplets[li])

        # 1) streamed weights: striped over all DRAM chiplets, unicast in.
        if lyr.weights and _streamed(lyr, _layer_sram(topo.config, placed)):
            for d in range(n_dram):
                for c in placed:
                    msgs.append(Message(
                        li, n_chip + d, (c,),
                        lyr.weights * mapping.share_of(li, c) / n_dram,
                        "wstream"))

        # 2) output activation transport, charged at production time.
        near: Dict[int, set] = {c: set() for c in placed}  # src -> dst set
        for ci in lyr.consumers:
            consumer_chips = list(mapping.chiplets[ci])
            spilled = (ci - li > mapping.spill_window
                       or lyr.act_out > ACT_SRAM_BYTES)
            if set(consumer_chips) == set(placed) and not spilled:
                # aligned partitions (same chiplet group, matching tiling):
                # tile-local consumption, no NoP transport
                continue
            if spilled:
                # DRAM spill: write once (at production), read at consumption
                for c in placed:
                    share = lyr.act_out * mapping.share_of(li, c)
                    msgs.append(Message(li, c, (nearest_dram(topo, c),),
                                        share, "spill_w"))
                for c in consumer_chips:
                    msgs.append(Message(
                        ci, nearest_dram(topo, c), (c,),
                        lyr.act_out / len(consumer_chips), "spill_r"))
                continue
            for c in placed:
                for d in consumer_chips:
                    if d != c:
                        near[c].add(d)
        # one message per source chiplet covering every near consumer —
        # multicast if the fan-out reaches more than one remote chiplet
        for c, dsts in near.items():
            if dsts:
                share = lyr.act_out * mapping.share_of(li, c)
                msgs.append(Message(li, c, tuple(sorted(dsts)), share, "act"))

    # 3) collective phases the mapping scheduled at layer boundaries
    # (tensor-parallel all-reduces, MoE all-to-alls, broadcasts)
    if mapping.collectives:
        from .collectives import lower_all   # traffic <-> collectives cycle
        msgs.extend(lower_all(mapping.collectives))
    # drop spill-writes duplicated per consumer edge: a tensor is written to
    # DRAM once even if several late consumers read it
    seen = set()
    dedup: List[Message] = []
    for m in msgs:
        if m.kind == "spill_w":
            key = (m.layer, m.src, m.dsts)
            if key in seen:
                continue
            seen.add(key)
        dedup.append(m)
    return dedup


def build_trace(layers: List[Layer], mapping: Mapping,
                topo: Topology,
                packet_bytes: float = PACKET_BYTES) -> TrafficTrace:
    """Packetise (graph x mapping) into a vectorised `TrafficTrace`.

    ``packet_bytes`` sets the packetisation granularity (default: the
    64 KiB NoP packet).  Giant-tensor workloads (the LLM frontier's
    multi-GB weight streams) pass a coarser granularity so the trace
    stays tractable — flit aggregation, not a model change: every
    per-layer aggregate is granularity-independent, only the injection
    filter's per-packet resolution coarsens.
    """
    cfg = topo.config
    msgs = generate_messages(layers, mapping, topo)
    n_layers = len(layers)

    # --- packetise: the wireless injection filter operates per packet, so
    # large tensors can be partially offloaded (as in real NoP traffic).
    link_index: Dict[Link, int] = {}
    inc_msg: List[int] = []
    inc_link: List[int] = []
    layer_l: List[int] = []
    nbytes_l: List[float] = []
    src_l: List[int] = []
    is_mc_l: List[bool] = []
    is_xchip_l: List[bool] = []
    max_hops_l: List[int] = []
    dram_l: List[int] = []

    n_chip = cfg.n_chiplets
    for m in msgs:
        hops = max(topo.nop_hops(m.src, d) for d in m.dsts)
        # DRAM port this message occupies (wstream/spill traffic), as a
        # 0-based index into the DRAM modules; -1 for chiplet-to-chiplet.
        dram = m.src - n_chip if m.src >= n_chip else \
            next((d - n_chip for d in m.dsts if d >= n_chip), -1)
        # chiplet-to-chiplet activation tensors fan out to the destination
        # chiplet's PE array: multicast in the NoC/NoP sense (paper SIII-B2)
        # even with a single destination chiplet.  DMA-style weight streams
        # and DRAM spills are point-to-point.
        mc = m.is_multicast or m.kind == "act"
        xchip = any(d != m.src for d in m.dsts)
        # activation tensors are dual-path routed (XY+YX, standard NoP load
        # balancing); DMA streams keep the single dimension-ordered path.
        orders = ("xy", "yx") if m.kind == "act" else ("xy",)
        for order in orders:
            route = [link_index.setdefault(link, len(link_index))
                     for link in topo.multicast_route(m.src, list(m.dsts),
                                                      order)]
            vol = m.nbytes / len(orders)
            n_pkt = max(1, int(np.ceil(vol / packet_bytes)))
            per = vol / n_pkt
            for _ in range(n_pkt):
                pid = len(layer_l)
                layer_l.append(m.layer)
                nbytes_l.append(per)
                src_l.append(m.src)
                is_mc_l.append(mc)
                is_xchip_l.append(xchip)
                max_hops_l.append(hops)
                dram_l.append(dram)
                inc_msg.extend([pid] * len(route))
                inc_link.extend(route)

    layer_arr = np.asarray(layer_l, np.int32)
    nbytes = np.asarray(nbytes_l)
    src_arr = np.asarray(src_l, np.int32)
    is_mc = np.asarray(is_mc_l, bool)
    is_xchip = np.asarray(is_xchip_l, bool)
    max_hops = np.asarray(max_hops_l, np.int32)

    # --- wireless-independent per-layer terms ---
    dram_bytes = np.zeros(n_layers)
    for m in msgs:
        if m.kind in ("wstream", "spill_r", "spill_w"):
            dram_bytes[m.layer] += m.nbytes
    t_dram = dram_bytes / cfg.dram_bw_total
    # compute + NoC, per layer.  A heterogeneous package
    # (`cfg.chiplet_tops` / `chiplet_noc_bw` per-slot vectors) finishes
    # at the slowest executing chiplet's share/rate; whenever the rates
    # AND shares across the executing chiplets are all equal, the exact
    # legacy uniform expression is used, so a package of identical
    # chiplets reproduces the homogeneous numbers bit for bit.
    rates, nbw = cfg.chiplet_tops, cfg.chiplet_noc_bw
    macs_pc = np.zeros(cfg.n_chiplets)
    nocb_pc = np.zeros(cfg.n_chiplets)
    t_comp = np.zeros(n_layers)
    t_noc = np.zeros(n_layers)
    for i, lyr in enumerate(layers):
        chips = list(mapping.chiplets[i])
        n_exec = max(1, len(chips))
        shares = np.asarray(mapping.shares[i], float)
        for c, s in zip(chips, shares):    # hetero energy accounting
            macs_pc[c] += lyr.macs * s
            nocb_pc[c] += (lyr.act_in + lyr.act_out) * s
        uni_share = bool(chips) and bool(np.all(shares == shares[0]))
        # compute: layer runs on its mapped chiplets at the derated peak
        if rates is None or not chips:
            t_comp[i] = 2.0 * lyr.macs / (cfg.tops_per_chiplet
                                          * n_exec * COMPUTE_EFFICIENCY)
        elif uni_share and _uniform(rates[c] for c in chips):
            t_comp[i] = 2.0 * lyr.macs / (rates[chips[0]]
                                          * n_exec * COMPUTE_EFFICIENCY)
        else:
            t_comp[i] = 2.0 * lyr.macs * max(
                s / rates[c] for c, s in zip(chips, shares)) \
                / COMPUTE_EFFICIENCY
        # NoC: tile in + tile out + (streamed) weight slice through the
        # chiplet-local mesh; chiplets operate in parallel.
        streamed = _streamed(lyr, _layer_sram(cfg, chips))
        acts = lyr.act_in + lyr.act_out
        if nbw is None or not chips:
            w_local = lyr.weights / n_exec if streamed else 0.0
            t_noc[i] = (acts / n_exec + w_local) \
                / (cfg.noc_bw_per_port * NOC_PARALLEL)
        elif uni_share and _uniform(nbw[c] for c in chips):
            w_local = lyr.weights / n_exec if streamed else 0.0
            t_noc[i] = (acts / n_exec + w_local) \
                / (nbw[chips[0]] * NOC_PARALLEL)
        else:
            t_noc[i] = max(
                (acts * s + (lyr.weights * s if streamed else 0.0))
                / (nbw[c] * NOC_PARALLEL)
                for c, s in zip(chips, shares))

    return TrafficTrace(
        topo=topo, n_layers=n_layers, link_index=link_index,
        layer=layer_arr, nbytes=nbytes, src=src_arr, is_multicast=is_mc,
        is_multichip=is_xchip, max_hops=max_hops,
        dram_node=np.asarray(dram_l, np.int32),
        inc_msg=np.asarray(inc_msg, np.int32),
        inc_link=np.asarray(inc_link, np.int32),
        t_compute=t_comp, t_dram=t_dram, t_noc=t_noc,
        dram_bytes=dram_bytes, messages=msgs,
        total_macs=float(sum(lyr.macs for lyr in layers)),
        noc_bytes=float(sum(lyr.act_in + lyr.act_out for lyr in layers)),
        macs_per_chiplet=macs_pc, noc_bytes_per_chiplet=nocb_pc,
        exec_chips=[tuple(mapping.chiplets[i]) for i in range(n_layers)],
        exec_shares=[np.asarray(mapping.shares[i], float)
                     for i in range(n_layers)],
        weight_bytes=np.asarray([lyr.weights for lyr in layers], float),
    )
