"""GEMINI-style layer-wise bottleneck simulator, wired and hybrid.

Per paper SIII-C: GEMINI is not cycle-accurate.  Per layer it computes the
compute time, the DRAM time, and aggregated NoC/NoP interconnect times,
declares the max of these the layer's bottleneck, and sums the per-layer
maxima into the total execution time.  We add the wireless channel as one
more per-layer term and keep the paper's dual-path accounting: wireless-
designated messages are ALSO costed on the wired path for the baseline, so
the speedup compares against unmodified GEMINI.

The wired NoP term models link congestion explicitly: per-layer byte loads
are accumulated on each directed XY-mesh link and the NoP time is the most
loaded link's service time — this is the "congested bisection links"
mechanism the paper identifies.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.net.config import NetworkConfig, as_network
from repro.net.stack import network_layer_times
from repro.obs.trace import active_recorder

from .mapper import pipeline_mapping, spatial_mapping
from .topology import AcceleratorConfig, build_topology, node_grid_coords
from .traffic import TrafficTrace, build_trace
from .units import BITS_PER_BYTE, pj_to_j
from .wireless import WirelessConfig, select_wireless, wireless_energy_joules
from .workloads import get_workload

BOTTLENECKS = ("compute", "dram", "noc", "nop", "wireless")

# Energy model (GEMINI/Accelergy-style constants): the paper's evaluation
# framework optimises EDP; we account energy alongside latency.
PJ_PER_MAC = 0.5            # bf16 MAC @ 7-nm class
PJ_PER_BIT_DRAM = 15.0      # DRAM access + interface
PJ_PER_BIT_NOP_HOP = 1.5    # wired D2D per hop (interposer SerDes)
PJ_PER_BIT_NOC = 0.3        # on-chip mesh, aggregate per transported bit
PJ_PER_BIT_WIRELESS = 1.0   # mm-wave transceiver (paper SI: ~1 pJ/bit)


@dataclasses.dataclass
class LayerReport:
    time: float
    bottleneck: str


@dataclasses.dataclass
class SimResult:
    total_time: float
    layer_times: np.ndarray
    bottleneck: List[str]
    wireless_bytes: float = 0.0
    wireless_energy_j: float = 0.0
    energy_j: float = 0.0            # total platform energy per inference
    layer_terms: Optional[np.ndarray] = None   # (L, 5) per-term stack

    @property
    def edp(self) -> float:
        """Energy-delay product (the GEMINI objective)."""
        return self.energy_j * self.total_time

    def bottleneck_share(self) -> Dict[str, float]:
        """Fraction of total time attributed to each bottleneck (Fig. 2).

        A degenerate (zero-time) run has no bottleneck: the explicit
        convention is an empty dict, shared with the event engine's
        `EventResult.bottleneck_share` and the obs attribution report.
        """
        if not self.total_time:
            return {}
        shares = {b: 0.0 for b in BOTTLENECKS}
        for t, b in zip(self.layer_times, self.bottleneck):
            shares[b] += float(t)
        return {b: v / self.total_time for b, v in shares.items()}


def _finalize(trace: TrafficTrace, link_loads: np.ndarray,
              t_wireless: np.ndarray) -> SimResult:
    t_cut = None
    if link_loads.size:
        cut_mat, cut_bw = trace.cut_matrix()
        # worst directed mesh-cut service time ("congested bisection links")
        t_cut = link_loads @ cut_mat / cut_bw
        t_nop = t_cut.max(axis=1)
    else:
        t_nop = np.zeros(trace.n_layers)
    stack = np.stack([trace.t_compute, trace.t_dram, trace.t_noc, t_nop,
                      t_wireless])
    layer_times = stack.max(axis=0)
    which = stack.argmax(axis=0)
    st = active_recorder()
    if st is not None:
        # analytic coarse spans: the same track names as the event
        # engine, with an ``an:`` category prefix — merged exports line
        # up track for track
        st.add_layer_matrix(trace.t_compute[:, None], "compute",
                            "an:compute")
        st.add_layer_matrix(trace.t_noc[:, None], "noc", "an:noc")
        st.add_layer_matrix(trace.t_dram[:, None], "dram(pooled)",
                            "an:dram-agg")
        if t_cut is not None:
            st.add_layer_matrix(t_cut, "cut{}", "an:wired")
        for li in range(trace.n_layers):
            st.add_layer_event(
                "layers", f"L{li}:{BOTTLENECKS[which[li]]}", li, 0.0,
                float(layer_times[li]), "layer",
                **{b: float(stack[i, li])
                   for i, b in enumerate(BOTTLENECKS)})
        st.place_layers(layer_times)
        st.meta.setdefault("plane", "analytic")
        st.meta["total_time"] = float(layer_times.sum())
    return SimResult(
        total_time=float(layer_times.sum()),
        layer_times=layer_times,
        bottleneck=[BOTTLENECKS[i] for i in which],
        layer_terms=stack.T.copy(),
    )


def mac_energy_pj(trace: TrafficTrace) -> float:
    """Compute energy (pJ), heterogeneity-aware.

    Per-MAC coefficients live on `ChipletSpec` (`AcceleratorConfig
    .chiplet_pj_per_mac`); a uniform coefficient vector collapses to the
    legacy `total_macs * pj` product (bit-identical homogeneous energy),
    a heterogeneous one charges each chiplet's MACs at its own rate.
    """
    pj = trace.topo.config.chiplet_pj_per_mac
    if pj is None or trace.macs_per_chiplet is None:
        return trace.total_macs * PJ_PER_MAC
    v = np.asarray(pj, float)
    if np.all(v == v[0]):
        return trace.total_macs * float(v[0])
    return float(trace.macs_per_chiplet @ v)


def noc_energy_pj(trace: TrafficTrace) -> float:
    """On-chip-mesh transport energy (pJ), heterogeneity-aware (see
    `mac_energy_pj`; coefficients from `chiplet_pj_per_bit_noc`)."""
    pj = trace.topo.config.chiplet_pj_per_bit_noc
    if pj is None or trace.noc_bytes_per_chiplet is None:
        return trace.noc_bytes * BITS_PER_BYTE * PJ_PER_BIT_NOC
    v = np.asarray(pj, float)
    if np.all(v == v[0]):
        return trace.noc_bytes * BITS_PER_BYTE * float(v[0])
    return float(trace.noc_bytes_per_chiplet @ v) * BITS_PER_BYTE


def energy_joules(trace: TrafficTrace, link_loads: np.ndarray,
                  wireless_bytes: float = 0.0) -> float:
    """Platform energy per inference: compute + DRAM + NoC + NoP + WL."""
    e = pj_to_j(mac_energy_pj(trace))
    e += pj_to_j(float(trace.dram_bytes.sum()) * BITS_PER_BYTE
                 * PJ_PER_BIT_DRAM)
    e += pj_to_j(noc_energy_pj(trace))
    e += pj_to_j(float(link_loads.sum()) * BITS_PER_BYTE
                 * PJ_PER_BIT_NOP_HOP)
    e += pj_to_j(wireless_bytes * BITS_PER_BYTE * PJ_PER_BIT_WIRELESS)
    return e


def simulate_wired(trace: TrafficTrace) -> SimResult:
    """Baseline: everything over the wired NoP."""
    loads = trace.baseline_link_loads()
    res = _finalize(trace, loads, np.zeros(trace.n_layers))
    res.energy_j = energy_joules(trace, loads)
    return res


def simulate_hybrid(trace: TrafficTrace,
                    wcfg: WirelessConfig | NetworkConfig) -> SimResult:
    """Hybrid wired+wireless under the paper's decision function.

    Accepts the legacy `WirelessConfig` (single shared channel, ideal
    MAC — the paper's model) or a `repro.net.NetworkConfig` with an
    explicit MAC protocol and multi-channel plan.
    """
    net = as_network(wcfg)
    injected = select_wireless(trace, net)

    # wired plane: baseline loads minus the injected messages' contributions
    loads = trace.baseline_link_loads()
    inj_edges = injected[trace.inc_msg]
    np.subtract.at(
        loads,
        (trace.layer[trace.inc_msg[inj_edges]], trace.inc_link[inj_edges]),
        trace.nbytes[trace.inc_msg[inj_edges]],
    )

    # wireless plane: per-channel MAC-costed service, max over channels
    # — per (channel, zone class) under a spatial-reuse plan
    # (degenerate 1-channel ideal plan == the paper's volume/bandwidth)
    t_wireless, wl_bytes, extra_bytes = network_layer_times(
        trace.n_layers, trace.layer, trace.nbytes, trace.src,
        trace.topo.n_nodes, injected, net,
        grid=trace.topo.config.grid,
        node_coords=node_grid_coords(trace.topo),
        max_hops=trace.max_hops)

    res = _finalize(trace, loads, t_wireless)
    res.wireless_bytes = float(wl_bytes.sum())
    res.wireless_energy_j = wireless_energy_joules(trace, injected, net,
                                                   extra_bytes)
    res.energy_j = energy_joules(trace, loads,
                                 res.wireless_bytes + extra_bytes)
    return res


def make_trace(workload: str, acc: AcceleratorConfig | None = None,
               mapping: str | None = None) -> TrafficTrace:
    """Convenience: workload name -> traffic trace on the default platform.

    The paper's 15 Table-1 workloads map with "pipeline" (GEMINI/
    SET-style, default) or "spatial" (full spatial split; the
    mapping-sensitivity contrast point).  LLM frontier names
    ("<model>:<phase>", e.g. "mixtral_8x22b:decode") route through
    `workloads_llm.make_llm_trace`, defaulting to the family's natural
    parallelism (expert-parallel for MoE, tensor-parallel otherwise)
    with its collective phases — "tensor"/"tensor_ring"/"expert" pick
    explicitly.
    """
    if ":" in workload:
        from .workloads_llm import make_llm_trace
        return make_llm_trace(workload, acc, mapping)
    topo = build_topology(acc)
    layers = get_workload(workload)
    if mapping in (None, "pipeline"):
        mapped = pipeline_mapping(layers, topo)
    elif mapping == "spatial":
        mapped = spatial_mapping(layers, topo)
    elif mapping in ("tensor", "tensor_ring"):
        from .mapper import tensor_parallel_mapping
        mapped = tensor_parallel_mapping(
            layers, topo,
            algorithm="ring" if mapping == "tensor_ring" else "tree")
    else:
        raise ValueError(f"unknown mapping {mapping!r}")
    return build_trace(layers, mapped, topo)


def speedup(trace: TrafficTrace, wcfg: WirelessConfig | NetworkConfig) -> float:
    base = simulate_wired(trace).total_time
    hybrid = simulate_hybrid(trace, wcfg).total_time
    return base / hybrid
