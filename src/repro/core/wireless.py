"""The paper's wireless plane: decision function + shared-channel model.

Decision criteria (paper SIII-B2), applied per message:

1. *Multi-chip multicast*: a multicast with >=1 destination off the source
   chiplet qualifies for wireless (broadcast-natured channel).
2. *Distance threshold*: a message whose chip-to-chip hop count exceeds the
   threshold qualifies.
3. *Injection probability*: a configurable probability gates qualified
   messages so the (single, shared) wireless channel does not saturate.

The paper uses a Bernoulli filter; for exact reproducibility we use a
low-discrepancy golden-ratio hash of the message index — the injected
fraction converges to p without an RNG stream.

Channel model (paper SIII-B3/C2): injected messages are summed per layer and
served at `wireless_bw` by a single shared channel; wireless time is
volume / bandwidth, exactly how GEMINI costs NoP/NoC aggregate times.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .traffic import TrafficTrace
from .units import bytes_to_bits, gbps_to_bytes_per_s, pj_to_j

_PHI = 0.6180339887498949  # frac(golden ratio)


@dataclasses.dataclass(frozen=True)
class WirelessConfig:
    bandwidth: float = gbps_to_bytes_per_s(64)   # B/s (paper: 64/96 Gb/s)
    distance_threshold: int = 1      # NoP hops (paper sweep: 1..4)
    injection_prob: float = 0.5      # paper sweep: 0.10..0.80 step 0.05
    energy_pj_per_bit: float = 1.0   # ~1 pJ/bit mm-wave transceivers

    def __post_init__(self):
        if not self.bandwidth > 0:
            raise ValueError(f"bandwidth must be positive bytes/s, got "
                             f"{self.bandwidth!r}")
        if not 0.0 <= self.injection_prob <= 1.0:
            raise ValueError(f"injection_prob must be in [0, 1], got "
                             f"{self.injection_prob!r}")
        if self.distance_threshold < 0:
            raise ValueError(f"distance_threshold must be >= 0 hops, "
                             f"got {self.distance_threshold!r}")
        if self.energy_pj_per_bit < 0:
            raise ValueError(f"energy_pj_per_bit must be >= 0, got "
                             f"{self.energy_pj_per_bit!r}")


def eligibility(trace: TrafficTrace, threshold: int) -> np.ndarray:
    """Boolean per-message wireless eligibility (criteria 1+2)."""
    mc = trace.is_multichip & trace.is_multicast & (trace.max_hops >= threshold)
    far_unicast = (trace.is_multichip & ~trace.is_multicast
                   & (trace.max_hops > threshold))
    return mc | far_unicast


def injection_hash(n_messages: int) -> np.ndarray:
    """Per-message low-discrepancy hash in [0, 1).

    A message is injected at probability ``p`` iff its hash is < ``p``;
    exposing the hash (rather than only the boolean filter) lets the
    batched design-space engine (`repro.net.batched`) bucket each
    message's fate across the whole injection axis at once.
    """
    idx = np.arange(n_messages, dtype=np.float64)
    return np.modf(idx * _PHI)[0]


def injection_filter(n_messages: int, prob: float) -> np.ndarray:
    """Deterministic low-discrepancy stand-in for the Bernoulli filter."""
    return injection_hash(n_messages) < prob


def select_wireless(trace: TrafficTrace, cfg) -> np.ndarray:
    """Messages designated for the wireless plane under `cfg`.

    `cfg` is a `WirelessConfig` or any config exposing the same
    selection attributes (e.g. `repro.net.NetworkConfig`).
    """
    ok = eligibility(trace, cfg.distance_threshold)
    return ok & injection_filter(len(ok), cfg.injection_prob)


def wireless_energy_joules(trace: TrafficTrace, injected: np.ndarray,
                           cfg, extra_bytes: float = 0.0) -> float:
    """Transceiver energy for the injected payload (+ MAC overhead bytes)."""
    bits = bytes_to_bits(float(trace.nbytes[injected].sum()) + extra_bytes)
    return pj_to_j(bits * cfg.energy_pj_per_bit)
