"""`repro.core.units`: the unit-constants module, core-plane spelling.

The implementation lives at `repro.units` (the `repro` namespace root)
because `repro.net` needs the constants at import time and
`repro.core.__init__` eagerly imports `repro.net` — a
`repro.net -> repro.core.units -> repro.core.__init__ -> repro.net`
import would deadlock on partially-initialised modules whenever
`repro.net` is imported first.  Core-plane modules import from here
(``from .units import ...``); everything is the same object either way.
"""

from repro.units import *            # noqa: F401,F403  (re-export)
from repro.units import __all__      # noqa: F401
