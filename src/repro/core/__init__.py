"""Core: the paper's contribution.

Package-scale reproduction (GEMINI-like simulator + wireless overlay),
the wireless NoP network subsystem (`repro.net`: MAC arbitration,
multi-channel plans, vectorized design-space engine) and the TPU-scale
adaptation (hybrid collective plane scheduler + balancer).
"""

from repro.net import ChannelPlan, MacConfig, NetworkConfig, as_network

from .topology import AcceleratorConfig, Topology, build_topology
from .wireless import (WirelessConfig, select_wireless, eligibility,
                       injection_hash)
from .simulator import (SimResult, make_trace, simulate_hybrid,
                        simulate_wired, speedup)
from .dse import (sweep, sweep_all, summary, SweepResult,
                  whatif_guided, GuidedSweepResult,
                  network_sweep, network_sweep_all, network_summary,
                  NetworkSweepResult, batched_design_space,
                  policy_sweep, policy_sweep_all, PolicySweepResult,
                  hetero_sweep, hetero_summary,
                  SCALING_GRIDS, ScalingResult, reuse_plans, scaled_config,
                  scaling_sweep, scaling_summary)
from .balancer import balance, BalancerResult
from .collectives import CollectiveSpec, collective_bytes
from .mapper import (Mapping, expert_parallel_mapping, pipeline_mapping,
                     spatial_mapping, tensor_parallel_mapping)
from .workloads_llm import LLM_WORKLOADS, make_llm_trace

# `repro.sim` (the event-driven engine) and `repro.arch` (heterogeneous
# packages + placement co-design) are re-exported lazily (PEP 562): both
# import `repro.core` submodules, so an eager import here would make the
# packages' initialisation order observable.  Attribute access resolves
# against the fully-initialised package on first use.
_SIM_EXPORTS = (
    "PacketSim", "EventResult", "simulate_events",
    "StaticPolicy", "OraclePolicy", "GreedyPolicy", "AdaptivePolicy",
    "FixedPolicy", "get_policy", "POLICIES",
    "fidelity_report", "policy_report",
)
_ARCH_EXPORTS = (
    "ChipletSpec", "HeteroPackage", "CATALOG", "MIXES",
    "PlacementProblem", "PlacementResult", "CodesignResult",
    "codesign", "anneal", "exhaustive", "greedy_seed",
)


def __getattr__(name):
    if name in _SIM_EXPORTS:
        import repro.sim
        return getattr(repro.sim, name)
    if name in _ARCH_EXPORTS:
        import repro.arch
        return getattr(repro.arch, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "AcceleratorConfig", "Topology", "build_topology",
    "WirelessConfig", "select_wireless", "eligibility", "injection_hash",
    "NetworkConfig", "ChannelPlan", "MacConfig", "as_network",
    "SimResult", "make_trace", "simulate_hybrid", "simulate_wired",
    "speedup", "sweep", "sweep_all", "summary", "SweepResult",
    "whatif_guided", "GuidedSweepResult",
    "network_sweep", "network_sweep_all", "network_summary",
    "NetworkSweepResult", "batched_design_space",
    "policy_sweep", "policy_sweep_all", "PolicySweepResult",
    "hetero_sweep", "hetero_summary",
    "SCALING_GRIDS", "ScalingResult", "reuse_plans", "scaled_config",
    "scaling_sweep", "scaling_summary",
    "balance", "BalancerResult",
    "CollectiveSpec", "collective_bytes",
    "Mapping", "pipeline_mapping", "spatial_mapping",
    "tensor_parallel_mapping", "expert_parallel_mapping",
    "LLM_WORKLOADS", "make_llm_trace",
    *_SIM_EXPORTS,
    *_ARCH_EXPORTS,
]
