"""Core: the paper's contribution.

Package-scale reproduction (GEMINI-like simulator + wireless overlay),
the wireless NoP network subsystem (`repro.net`: MAC arbitration,
multi-channel plans, vectorized design-space engine) and the TPU-scale
adaptation (hybrid collective plane scheduler + balancer).
"""

from repro.net import ChannelPlan, MacConfig, NetworkConfig, as_network

from .topology import AcceleratorConfig, Topology, build_topology
from .wireless import (WirelessConfig, select_wireless, eligibility,
                       injection_hash)
from .simulator import (SimResult, make_trace, simulate_hybrid,
                        simulate_wired, speedup)
from .dse import (sweep, sweep_all, summary, SweepResult,
                  network_sweep, network_sweep_all, network_summary,
                  NetworkSweepResult, batched_design_space)
from .balancer import balance, BalancerResult

__all__ = [
    "AcceleratorConfig", "Topology", "build_topology",
    "WirelessConfig", "select_wireless", "eligibility", "injection_hash",
    "NetworkConfig", "ChannelPlan", "MacConfig", "as_network",
    "SimResult", "make_trace", "simulate_hybrid", "simulate_wired",
    "speedup", "sweep", "sweep_all", "summary", "SweepResult",
    "network_sweep", "network_sweep_all", "network_summary",
    "NetworkSweepResult", "batched_design_space",
    "balance", "BalancerResult",
]
