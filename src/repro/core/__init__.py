"""Core: the paper's contribution.

Package-scale reproduction (GEMINI-like simulator + wireless overlay) and
the TPU-scale adaptation (hybrid collective plane scheduler + balancer).
"""

from .topology import AcceleratorConfig, Topology, build_topology
from .wireless import WirelessConfig, select_wireless, eligibility
from .simulator import (SimResult, make_trace, simulate_hybrid,
                        simulate_wired, speedup)
from .dse import sweep, sweep_all, summary, SweepResult
from .balancer import balance, BalancerResult

__all__ = [
    "AcceleratorConfig", "Topology", "build_topology",
    "WirelessConfig", "select_wireless", "eligibility",
    "SimResult", "make_trace", "simulate_hybrid", "simulate_wired",
    "speedup", "sweep", "sweep_all", "summary", "SweepResult",
    "balance", "BalancerResult",
]
