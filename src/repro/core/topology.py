"""Package-scale topology for the wireless-enabled multi-chiplet accelerator.

Faithful to the paper's Table 1 platform: an RxC grid of compute chiplets
(3x3 by default, arbitrary — and non-square — grids up to 16x16 and
beyond for the scale-out frontier), DRAM chiplets on the package
periphery, an XY-mesh NoP between chiplets, an XY-mesh NoC inside each
chiplet, and one antenna + transceiver at the geometric center of every
compute chiplet and DRAM module (paper SIII-B1).

Distances are expressed in NoP hops (the unit the paper's distance
threshold uses).  Antenna coordinates are derived from the physical layout
so the wireless plane is single-hop between any two antennas.  All-pairs
hop distances are available vectorized (`Topology.hop_matrix`) — large
meshes cost the route walk once, not per message.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Tuple

import numpy as np

from .units import gbps_to_bytes_per_s

Coord = Tuple[int, int]


@dataclasses.dataclass(frozen=True)
class AcceleratorConfig:
    """Platform parameters (paper Table 1 defaults).

    Rates are bytes/second internally; the paper quotes Gb/s for NoC/NoP/
    wireless and GB/s for DRAM.  Construction validates the package
    geometry — a mismatched per-chiplet vector or an impossible grid
    fails HERE with a clear message, not deep inside `build_trace`.
    """

    grid: Tuple[int, int] = (3, 3)          # compute chiplets
    n_dram: int = 4                          # DRAM chiplets on the perimeter
    tops_total: float = 144e12               # 144 TOPS across the package
    dram_bw_per_chiplet: float = 16e9        # 16 GB/s per DRAM chiplet
    nop_bw_per_side: float = gbps_to_bytes_per_s(32)   # per mesh side
    noc_bw_per_port: float = gbps_to_bytes_per_s(64)   # per NoC port
    wireless_bw: float = gbps_to_bytes_per_s(64)       # paper: 64 or 96
    pe_mesh: Tuple[int, int] = (16, 16)      # PEs per chiplet (NoC nodes)
    chiplet_mm: float = 5.0                  # chiplet edge length (layout only)
    freq_ghz: float = 1.0
    # --- heterogeneous package (repro.arch) ---
    # Per-chiplet vectors, indexed by chiplet id (row-major grid slot).
    # `None` (the default) keeps the uniform package: every rate derives
    # from the scalars above and every modelling plane takes the exact
    # code path it took before heterogeneity existed.  `HeteroPackage
    # .to_config()` populates them; each consumer falls back to the
    # uniform expression whenever the values it needs are all equal, so
    # a package of identical chiplets is bit-identical to the scalars.
    chiplet_tops: Tuple[float, ...] | None = None         # ops/s per slot
    chiplet_noc_bw: Tuple[float, ...] | None = None       # B/s per NoC port
    chiplet_sram: Tuple[int, ...] | None = None           # weight-SRAM bytes
    chiplet_pj_per_mac: Tuple[float, ...] | None = None
    chiplet_pj_per_bit_noc: Tuple[float, ...] | None = None

    def __post_init__(self):
        ints = (int, np.integer)   # numpy ints (e.g. from array axes) count
        rows, cols = (self.grid if isinstance(self.grid, tuple)
                      and len(self.grid) == 2 else (0, 0))
        if not (isinstance(rows, ints) and isinstance(cols, ints)
                and rows >= 1 and cols >= 1):
            raise ValueError(
                f"grid must be a (rows, cols) tuple of positive ints, "
                f"got {self.grid!r}")
        if not (isinstance(self.n_dram, ints) and self.n_dram >= 1):
            raise ValueError(
                f"n_dram must be a positive int, got {self.n_dram!r}")
        n = rows * cols
        for field in ("chiplet_tops", "chiplet_noc_bw", "chiplet_sram",
                      "chiplet_pj_per_mac", "chiplet_pj_per_bit_noc"):
            v = getattr(self, field)
            if v is None:
                continue
            if len(v) != n:
                raise ValueError(
                    f"{field} must have one entry per chiplet "
                    f"({rows}x{cols} grid -> {n}), got {len(v)}")
            if any(x <= 0 for x in v):
                raise ValueError(f"{field} entries must be positive")

    @property
    def n_chiplets(self) -> int:
        return self.grid[0] * self.grid[1]

    @property
    def tops_per_chiplet(self) -> float:
        return self.tops_total / self.n_chiplets

    @property
    def dram_bw_total(self) -> float:
        return self.dram_bw_per_chiplet * self.n_dram

    # --- NoP bisection: for an RxC XY mesh, the vertical bisection cut has
    # R links; multicast/reduction flows that cross the package midline all
    # share them (paper SI: "congested bisection links").
    @property
    def nop_bisection_bw(self) -> float:
        return self.grid[0] * self.nop_bw_per_side


@dataclasses.dataclass(frozen=True)
class Topology:
    config: AcceleratorConfig
    chiplet_coords: Tuple[Coord, ...]
    dram_coords: Tuple[Coord, ...]           # virtual grid coords off the edges
    antenna_xy_mm: Tuple[Tuple[float, float], ...]  # one per chiplet then DRAM

    @property
    def n_nodes(self) -> int:
        return len(self.chiplet_coords) + len(self.dram_coords)

    def _is_dram(self, node: int) -> bool:
        return node >= len(self.chiplet_coords)

    def route(self, src: int, dst: int,
              order: str = "xy") -> List[Tuple[Coord, Coord]]:
        """Directed XY (dimension-ordered) mesh route between two nodes.

        DRAM chiplets attach to every edge router along their package side
        with enough attach links to carry their full 16 GB/s (i.e. the
        attach hop is DRAM-bandwidth-limited, which `t_dram` already
        accounts for) — so routes to/from DRAM contribute only the *mesh*
        links beyond the aligned edge router.
        """
        dc = self._coord(dst)
        sc = self._coord(src)
        links: List[Tuple[Coord, Coord]] = []
        if self._is_dram(src):
            sc = self._grid_entry(src, dc)
        if self._is_dram(dst):
            dc = self._grid_entry(dst, sc)
        x, y = sc
        dims = (0, 1) if order == "xy" else (1, 0)
        for dim in dims:
            if dim == 0:
                step = 1 if dc[0] > x else -1
                while x != dc[0]:
                    links.append(((x, y), (x + step, y)))
                    x += step
            else:
                step = 1 if dc[1] > y else -1
                while y != dc[1]:
                    links.append(((x, y), (x, y + step)))
                    y += step
        return links

    def _grid_entry(self, dram: int, toward: Coord) -> Coord:
        """Edge-router grid coordinate where a DRAM's traffic enters."""
        r, c = self._coord(dram)
        rows, cols = self.config.grid
        if r == -1:
            return (0, min(max(toward[1], 0), cols - 1))
        if r == rows:
            return (rows - 1, min(max(toward[1], 0), cols - 1))
        if c == -1:
            return (min(max(toward[0], 0), rows - 1), 0)
        return (min(max(toward[0], 0), rows - 1), cols - 1)

    def nop_hops(self, a: int, b: int) -> int:
        """XY-route hop distance between two nodes (DRAM attach-aware)."""
        return int(self.hop_matrix()[a, b])

    def hop_matrix(self) -> np.ndarray:
        """All-pairs XY hop distances, (n_nodes, n_nodes), cached.

        Chiplet-chiplet distance is Manhattan on the grid.  A DRAM module
        attaches to every edge router along its package side (see
        `route`), so the distance to/from a DRAM is the perpendicular
        distance to that side — vectorized here so large meshes pay one
        array pass instead of a per-pair route walk.
        """
        cached = getattr(self, "_hop_matrix", None)
        if cached is not None:
            return cached
        rows, cols = self.config.grid
        n_chip = len(self.chiplet_coords)
        coords = np.array(self.chiplet_coords, np.int64)      # (n_chip, 2)
        h = np.abs(coords[:, None, :] - coords[None, :, :]).sum(axis=2)
        n = self.n_nodes
        hops = np.zeros((n, n), np.int64)
        hops[:n_chip, :n_chip] = h
        # chiplet <-> DRAM: one virtual coordinate is off-grid; the route
        # enters at the edge router aligned with the chiplet, so only the
        # perpendicular axis contributes.
        for j, (rd, cd) in enumerate(self.dram_coords):
            if 0 <= rd < rows:          # left/right side: column distance
                d = np.abs(coords[:, 1] - min(max(cd, 0), cols - 1))
            else:                        # top/bottom side: row distance
                d = np.abs(coords[:, 0] - min(max(rd, 0), rows - 1))
            hops[:n_chip, n_chip + j] = d
            hops[n_chip + j, :n_chip] = d
        # DRAM <-> DRAM (unused by traffic, kept route-exact for takers)
        for a in range(n_chip, n):
            for b in range(n_chip, n):
                if a != b:
                    hops[a, b] = len(self.route(a, b))
        object.__setattr__(self, "_hop_matrix", hops)
        return hops

    def multicast_route(self, src: int, dsts: List[int],
                        order: str = "xy") -> List[Tuple[Coord, Coord]]:
        """Directed link set of a dimension-ordered multicast tree."""
        links = set()
        for d in dsts:
            links.update(self.route(src, d, order))
        return sorted(links)

    def multicast_hops(self, src: int, dsts: List[int]) -> int:
        """Byte-hop multiplier (distinct links) of an XY multicast tree."""
        return len(self.multicast_route(src, dsts))

    def max_unicast_hops(self, src: int, dsts: List[int]) -> int:
        return max(self.nop_hops(src, d) for d in dsts)

    def _coord(self, node: int) -> Coord:
        n_chip = len(self.chiplet_coords)
        if node < n_chip:
            return self.chiplet_coords[node]
        return self.dram_coords[node - n_chip]


def dram_positions(rows: int, cols: int, n_dram: int) -> Tuple[Coord, ...]:
    """Perimeter DRAM placement, parametric in the module count.

    Up to four modules reproduce the paper's Fig. 1 exactly: one centred
    per package side, in the fixed side order (top, bottom, left, right).
    Beyond four — large-mesh packages need the aggregate DRAM bandwidth
    to scale with the perimeter — modules are dealt round-robin over the
    four sides and spread evenly along each side, so an `n_dram = 16`
    16x16 package gets four evenly-spaced modules per side.
    """
    mid_r, mid_c = rows // 2, cols // 2
    legacy = ((-1, mid_c), (rows, mid_c), (mid_r, -1), (mid_r, cols))
    if n_dram <= 4:
        return legacy[:n_dram]
    per_side = [n_dram // 4 + (s < n_dram % 4) for s in range(4)]
    out: List[Coord] = []
    for side, k in enumerate(per_side):
        span = cols if side < 2 else rows
        for i in range(k):
            pos = (2 * i + 1) * span // (2 * k)      # evenly spaced centres
            out.append(((-1, pos), (rows, pos),
                        (pos, -1), (pos, cols))[side])
    return tuple(out)


def build_topology(config: AcceleratorConfig | None = None) -> Topology:
    cfg = config or AcceleratorConfig()
    rows, cols = cfg.grid
    chiplets = tuple(itertools.product(range(rows), range(cols)))
    dram = dram_positions(rows, cols, cfg.n_dram)

    # Antenna at the centre of every chiplet / DRAM (paper SIII-B1): physical
    # coordinates derived from grid position and chiplet pitch.
    pitch = cfg.chiplet_mm + 1.0  # 1 mm inter-chiplet spacing
    ant = tuple(
        (c[1] * pitch + cfg.chiplet_mm / 2, c[0] * pitch + cfg.chiplet_mm / 2)
        for c in chiplets + dram
    )
    return Topology(cfg, chiplets, dram, ant)


def nearest_dram(topo: Topology, chiplet: int) -> int:
    """DRAM node id (global) closest to a chiplet, used for weight fetch.

    Ties break toward the lowest node id (the legacy `min` order);
    computed once for the whole package from the hop matrix and cached —
    the traffic generator calls this per spill message.
    """
    cached = getattr(topo, "_nearest_dram", None)
    if cached is None:
        n_chip = len(topo.chiplet_coords)
        cached = n_chip + topo.hop_matrix()[:n_chip, n_chip:].argmin(axis=1)
        object.__setattr__(topo, "_nearest_dram", cached)
    return int(cached[chiplet])


def node_grid_coords(topo: Topology) -> np.ndarray:
    """(n_nodes, 2) int grid coordinates, DRAM virtual coords clamped.

    The spatial channel-reuse model (`repro.net.channel`) tiles the
    package into interference zones by grid position; DRAM modules are
    clamped onto their adjacent edge row/column so every node lands in
    a zone.
    """
    rows, cols = topo.config.grid
    coords = np.array(topo.chiplet_coords + topo.dram_coords, np.int64)
    coords[:, 0] = np.clip(coords[:, 0], 0, rows - 1)
    coords[:, 1] = np.clip(coords[:, 1], 0, cols - 1)
    return coords


def chiplet_neighbourhood(topo: Topology) -> Dict[int, List[int]]:
    """Adjacency (1-hop) map over compute chiplets, for mapping locality."""
    n = len(topo.chiplet_coords)
    return {
        i: [j for j in range(n) if j != i and topo.nop_hops(i, j) == 1]
        for i in range(n)
    }
