"""Chiplet catalog: the per-slot building blocks of a heterogeneous package.

The paper frames multi-chiplet systems as assemblies of "perhaps
heterogeneous" accelerators but evaluates a uniform package; related
work argues the wireless plane is the natural interconnect for exactly
the heterogeneous case (Abadal et al., graphene-based agile
interconnects) and that the wins hide in mapping/architecture co-design
(Guirado et al., arXiv:2011.14755).  This module provides the
vocabulary: a `ChipletSpec` carries everything the modelling planes
need to rate one grid slot — peak compute, NoC port bandwidth, the
weight-SRAM budget that decides streamed-vs-resident weights, and the
energy coefficients the EDP objective charges.

The "standard" preset IS the paper's Table-1 chiplet: its values are
read off the default `AcceleratorConfig` and the calibrated traffic
constants, so a package of 9 "standard" chiplets reproduces the paper
platform bit for bit (pinned in tests/test_arch.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from repro.core.simulator import PJ_PER_BIT_NOC, PJ_PER_MAC
from repro.core.topology import AcceleratorConfig
from repro.units import TERA
from repro.core.traffic import WEIGHT_SRAM_BYTES

_DEFAULT = AcceleratorConfig()
STANDARD_TOPS = _DEFAULT.tops_per_chiplet        # 16 TOPS (144 / 3x3)
STANDARD_NOC_BW = _DEFAULT.noc_bw_per_port       # 64 Gb/s per NoC port


@dataclasses.dataclass(frozen=True)
class ChipletSpec:
    """One chiplet design point (a package grid slot's occupant)."""

    name: str
    tops: float                 # peak compute, ops/s (2 ops per MAC)
    noc_bw_per_port: float      # on-chip mesh port bandwidth, B/s
    sram_bytes: int             # weight-resident SRAM budget (global buffer)
    pj_per_mac: float           # compute energy coefficient
    pj_per_bit_noc: float       # on-chip transport energy coefficient

    def describe(self) -> str:
        return (f"{self.name}({self.tops / TERA:.0f}T,"
                f"{self.sram_bytes / 2**20:.0f}MiB)")


# Preset design points.  "standard" is the paper's Table-1 chiplet; the
# others bracket it along the axes the heterogeneity question cares
# about: a big/LITTLE compute pair (2x / 0.5x rate, SRAM and NoC scaled
# with area), a memory-heavy chiplet (half rate, 8x SRAM keeps big FC
# layers resident instead of streamed), and an AIMC-like analog
# in-memory tile (3x rate at ~0.2x the MAC energy, but a thin NoC and
# small digital buffer — the classic analog trade).
CATALOG: Dict[str, ChipletSpec] = {
    "standard": ChipletSpec("standard", STANDARD_TOPS, STANDARD_NOC_BW,
                            WEIGHT_SRAM_BYTES, PJ_PER_MAC, PJ_PER_BIT_NOC),
    "big": ChipletSpec("big", 2.0 * STANDARD_TOPS, 2.0 * STANDARD_NOC_BW,
                       2 * WEIGHT_SRAM_BYTES, 0.55, 0.35),
    "little": ChipletSpec("little", 0.5 * STANDARD_TOPS,
                          0.5 * STANDARD_NOC_BW, WEIGHT_SRAM_BYTES // 2,
                          0.40, 0.25),
    "mem": ChipletSpec("mem", 0.5 * STANDARD_TOPS, STANDARD_NOC_BW,
                       8 * WEIGHT_SRAM_BYTES, PJ_PER_MAC, PJ_PER_BIT_NOC),
    "aimc": ChipletSpec("aimc", 3.0 * STANDARD_TOPS, 0.5 * STANDARD_NOC_BW,
                        WEIGHT_SRAM_BYTES // 2, 0.10, PJ_PER_BIT_NOC),
}

# Named 3x3 package mixes (spec-name multisets; slot order is decided by
# placement, see arch/placement.py).  "big_little" keeps the paper's
# 144-TOPS package total (3x32 + 6x8); the others trade total compute
# for memory capacity / energy.
MIXES: Dict[str, Tuple[str, ...]] = {
    "big_little": ("big",) * 3 + ("little",) * 6,
    "compute_mem": ("standard",) * 6 + ("mem",) * 3,
    "aimc_edge": ("aimc",) * 3 + ("standard",) * 6,
}


def get_spec(spec: str | ChipletSpec) -> ChipletSpec:
    """Resolve a catalog name (or pass a spec through)."""
    if isinstance(spec, ChipletSpec):
        return spec
    if spec not in CATALOG:
        raise KeyError(f"unknown chiplet spec {spec!r}; pick one of "
                       f"{sorted(CATALOG)} or pass a ChipletSpec")
    return CATALOG[spec]


def get_mix(mix: str) -> Tuple[str, ...]:
    """Resolve a named mix (friendly error listing the choices)."""
    if mix not in MIXES:
        raise KeyError(f"unknown chiplet mix {mix!r}; pick one of "
                       f"{sorted(MIXES)} or pass the spec names directly")
    return MIXES[mix]
