"""Placement / mapping co-design search over heterogeneous packages.

The search state is joint:

- **placement** — which `ChipletSpec` of the mix sits at which grid
  slot.  Stages run along the snake order (consecutive pipeline stages
  stay mesh neighbours, as in `mapper.pipeline_mapping`), so a
  placement is a permutation ``order`` with snake position ``j``
  occupied by ``specs[order[j]]``.
- **layer assignment** — a contiguous segmentation ``stage_of`` of the
  layer graph into ``min(n_slots, n_layers)`` non-empty stages; stage
  ``s`` executes on a contiguous run of snake positions (one slot per
  stage when the graph is deep enough, multi-slot groups with
  rate-proportional shares otherwise — the `pipeline_mapping` scheme).

The objective is the end-to-end makespan of the analytic pipeline
(`simulate_wired`, and for the hybrid plane the best static
(threshold x injection) point of `simulate_hybrid` via the batched DSE
engine — the paper's own operating point).  Three engines share one
memoised evaluator:

- `greedy_seed` — compute-balanced: segment by MACs, match the fastest
  chiplet to the heaviest stage (largest-job/fastest-machine), then
  re-segment against the placed rates.
- `anneal` — seeded simulated annealing over swap-two-slots and
  move-one-boundary neighbourhoods, with restarts and a final
  steepest-descent polish.  Same seed => identical result (pinned in
  tests/test_arch.py).
- `exhaustive` — full joint enumeration on small problems (<= 6 slots),
  the ground truth that validates the annealer.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.dse import grid_best_speedup
from repro.obs import profile as obs_profile
from repro.obs.metrics import DEFAULT_REGISTRY
from repro.obs.provenance import make_provenance
from repro.core.mapper import Mapping, snake_order
from repro.core.simulator import simulate_wired
from repro.core.topology import AcceleratorConfig
from repro.core.traffic import PACKET_BYTES, build_trace
from repro.core.workloads import Layer, get_workload
from repro.net.config import NetworkConfig
from repro.units import gbps_to_bytes_per_s

from .catalog import ChipletSpec, get_mix, get_spec
from .package import HeteroPackage

OBJECTIVES = ("wired", "hybrid")


@dataclasses.dataclass(frozen=True)
class PlacementState:
    order: Tuple[int, ...]       # snake position j -> index into the mix
    stage_of: Tuple[int, ...]    # layer -> stage (= snake position)


@dataclasses.dataclass(frozen=True)
class PlacementResult:
    state: PlacementState
    slot_names: Tuple[str, ...]  # spec names along the snake order
    t_wired: float               # all-wired makespan (s)
    t_hybrid: float              # DSE-best hybrid makespan (s)
    objective: str
    method: str
    evaluations: int             # distinct states evaluated so far
    provenance: Optional[dict] = dataclasses.field(
        default=None, compare=False)  # dse.provenance of the search

    @property
    def makespan(self) -> float:
        return self.t_wired if self.objective == "wired" else self.t_hybrid

    @property
    def hybrid_speedup(self) -> float:
        return self.t_wired / self.t_hybrid


class PlacementProblem:
    """One (workload, chiplet mix, network) co-design instance.

    Evaluations are memoised per joint state, so the greedy seed, both
    annealing objectives and the exhaustive validator share work.
    """

    def __init__(self, workload: str | List[Layer],
                 mix: str | Sequence[str | ChipletSpec] = "big_little",
                 grid: Tuple[int, int] = (3, 3),
                 net: NetworkConfig | None = None,
                 base: AcceleratorConfig | None = None,
                 packet_bytes: float | None = None):
        if isinstance(workload, str):
            self.workload = workload
            self.layers = get_workload(workload)
            if packet_bytes is None and ":" in workload:
                from repro.core.workloads_llm import auto_packet_bytes
                packet_bytes = auto_packet_bytes(self.layers)
        else:
            self.workload = "<layers>"
            self.layers = workload
        names = get_mix(mix) if isinstance(mix, str) else tuple(mix)
        self.mix = mix if isinstance(mix, str) else "<custom>"
        self.specs: Tuple[ChipletSpec, ...] = tuple(get_spec(s)
                                                    for s in names)
        self.grid = grid
        self.n_slots = grid[0] * grid[1]
        if len(self.specs) != self.n_slots:
            raise ValueError(f"mix has {len(self.specs)} specs for a "
                             f"{self.n_slots}-slot {grid} grid")
        self.net = net or NetworkConfig(bandwidth=gbps_to_bytes_per_s(96))
        self.base = base
        self.packet_bytes = packet_bytes or PACKET_BYTES
        self.snake = snake_order(
            HeteroPackage.uniform("standard", grid).build_topology(base))
        # stage s owns a contiguous run of snake positions; shallow
        # graphs get multi-slot stages (first remainder stages one extra)
        self.n_stages = min(self.n_slots, len(self.layers))
        k, rem = divmod(self.n_slots, self.n_stages)
        starts = [0]
        for s in range(self.n_stages):
            starts.append(starts[-1] + k + (s < rem))
        self.stage_pos: Tuple[Tuple[int, ...], ...] = tuple(
            tuple(range(starts[s], starts[s + 1]))
            for s in range(self.n_stages))
        self._memo: Dict[PlacementState, Tuple[float, float]] = {}

    # ------------------------------------------------------------------

    @property
    def evaluations(self) -> int:
        return len(self._memo)

    def package(self, order: Sequence[int]) -> HeteroPackage:
        """Slots along the snake: snake position j gets specs[order[j]]."""
        slots = [None] * self.n_slots
        for j, k in enumerate(order):
            slots[self.snake[j]] = self.specs[k]
        return HeteroPackage(self.grid, tuple(slots))

    def stage_rates(self, order: Sequence[int]) -> List[float]:
        """Aggregate compute rate of each stage's slot group."""
        return [sum(self.specs[order[j]].tops for j in pos)
                for pos in self.stage_pos]

    def mapping(self, state: PlacementState) -> Mapping:
        """Stage s -> its snake slot group, rate-proportional shares."""
        chiplets, shares = [], []
        for s in state.stage_of:
            pos = self.stage_pos[s]
            chips = tuple(self.snake[j] for j in pos)
            r = np.array([self.specs[state.order[j]].tops for j in pos])
            chiplets.append(chips)
            shares.append(np.full(len(pos), 1.0 / len(pos))
                          if np.all(r == r[0]) else r / r.sum())
        return Mapping(chiplets, shares, spill_window=6)

    def evaluate(self, state: PlacementState) -> Tuple[float, float]:
        """(wired makespan, DSE-best hybrid makespan) of a joint state."""
        if state in self._memo:
            return self._memo[state]
        # one phase per *distinct* evaluation: the profiler's call count
        # on "arch.evaluate" is the annealer's true evaluation count
        with obs_profile.phase("arch.evaluate"):
            topo = self.package(state.order).build_topology(self.base)
            trace = build_trace(self.layers, self.mapping(state),
                                topo, self.packet_bytes)
            t_wired = simulate_wired(trace).total_time
            t_hybrid = t_wired / grid_best_speedup(trace, self.net)
        self._memo[state] = (t_wired, t_hybrid)
        return t_wired, t_hybrid

    def cost(self, state: PlacementState, objective: str) -> float:
        if objective not in OBJECTIVES:
            raise ValueError(f"objective must be one of {OBJECTIVES}")
        t_wired, t_hybrid = self.evaluate(state)
        return t_wired if objective == "wired" else t_hybrid

    def result(self, state: PlacementState, objective: str,
               method: str,
               provenance: Optional[dict] = None) -> PlacementResult:
        t_wired, t_hybrid = self.evaluate(state)
        return PlacementResult(
            state=state,
            slot_names=tuple(self.specs[k].name for k in state.order),
            t_wired=t_wired, t_hybrid=t_hybrid,
            objective=objective, method=method,
            evaluations=self.evaluations,
            provenance=provenance)

    def provenance_config(self, objective: str, **extra) -> dict:
        """The hashed search configuration of this problem instance."""
        return {"workload": self.workload, "mix": self.mix,
                "grid": self.grid, "objective": objective,
                "net": self.net, "packet_bytes": self.packet_bytes,
                **extra}


# ----------------------------------------------------------------------
# segmentation + seeds
# ----------------------------------------------------------------------

def balanced_stages(macs: Sequence[float],
                    rates: Sequence[float]) -> List[int]:
    """Contiguous layer->stage split targeting rate-proportional MACs.

    Stage ``s`` closes once the running MAC total reaches the cumulative
    rate share of stages ``0..s``; every stage keeps at least one layer
    (the tail guard force-advances when the remaining stages would
    starve).  Deterministic, used as the seed and re-used by the greedy
    matcher after specs are placed.
    """
    L, n = len(macs), len(rates)
    if L < n:
        raise ValueError(f"{L} layers cannot fill {n} non-empty stages")
    total = float(sum(macs)) or 1.0
    cum = np.cumsum(np.asarray(rates, float))
    cum /= cum[-1]
    stage_of: List[int] = []
    stage, acc, in_stage = 0, 0.0, 0
    for i, m in enumerate(macs):
        starving = (L - i) <= (n - 1 - stage)
        if in_stage > 0 and stage < n - 1 and (
                starving or acc >= total * cum[stage]):
            stage += 1
            in_stage = 0
        stage_of.append(stage)
        in_stage += 1
        acc += float(m)
    return stage_of


def greedy_seed(problem: PlacementProblem) -> PlacementState:
    """Compute-balanced deterministic seed (largest job, fastest machine).

    1. Segment layers into MAC-balanced stages (rate-blind).
    2. Give the heaviest stage the fastest chiplet, second-heaviest the
       second-fastest, ... (stable sorts, so ties break by index).
    3. Re-segment against the placed per-stage rates.
    """
    macs = [lyr.macs for lyr in problem.layers]
    s0 = balanced_stages(macs, np.ones(problem.n_stages))
    load = np.zeros(problem.n_stages)
    for i, s in enumerate(s0):
        load[s] += macs[i]
    by_load = np.argsort(-load, kind="stable")
    by_rate = np.argsort([-s.tops for s in problem.specs], kind="stable")
    order = np.empty(problem.n_slots, int)
    nxt = 0          # heaviest stage group takes the fastest specs
    for stage in by_load:
        for j in problem.stage_pos[stage]:
            order[j] = by_rate[nxt]
            nxt += 1
    order_t = tuple(int(k) for k in order)
    return PlacementState(
        order_t, tuple(balanced_stages(macs, problem.stage_rates(order_t))))


# ----------------------------------------------------------------------
# neighbourhood moves
# ----------------------------------------------------------------------

def _swap_moves(problem: PlacementProblem,
                state: PlacementState) -> List[PlacementState]:
    """All placements one slot-swap away (distinct specs only)."""
    out = []
    order = state.order
    for i in range(len(order)):
        for j in range(i + 1, len(order)):
            if problem.specs[order[i]] != problem.specs[order[j]]:
                new = list(order)
                new[i], new[j] = new[j], new[i]
                out.append(PlacementState(tuple(new), state.stage_of))
    return out


def _boundary_moves(problem: PlacementProblem,
                    state: PlacementState) -> List[PlacementState]:
    """All segmentations one boundary shift away (stages stay non-empty)."""
    out = []
    stage_of = list(state.stage_of)
    n = problem.n_stages
    sizes = np.bincount(stage_of, minlength=n)
    first = np.searchsorted(stage_of, np.arange(n))
    for s in range(1, n):
        if sizes[s - 1] > 1:        # grow stage s leftwards
            new = list(stage_of)
            new[first[s] - 1] = s
            out.append(PlacementState(state.order, tuple(new)))
        if sizes[s] > 1:            # shrink stage s from the left
            new = list(stage_of)
            new[first[s]] = s - 1
            out.append(PlacementState(state.order, tuple(new)))
    return out


def _random_state(problem: PlacementProblem,
                  rng: np.random.Generator) -> PlacementState:
    order = tuple(int(k) for k in rng.permutation(problem.n_slots))
    # random non-empty contiguous segmentation
    L, n = len(problem.layers), problem.n_stages
    cuts = rng.choice(L - 1, size=n - 1, replace=False) + 1
    cuts = np.sort(cuts)
    stage_of = np.searchsorted(cuts, np.arange(L), side="right")
    return PlacementState(order, tuple(int(s) for s in stage_of))


def _polish(problem: PlacementProblem, state: PlacementState,
            objective: str, max_rounds: int = 200) -> PlacementState:
    """Steepest-descent over the full single-move neighbourhood."""
    cur, cost = state, problem.cost(state, objective)
    for _ in range(max_rounds):
        moves = (_swap_moves(problem, cur)
                 + _boundary_moves(problem, cur))
        costs = [problem.cost(m, objective) for m in moves]
        if not costs or min(costs) >= cost:
            return cur
        best = int(np.argmin(costs))
        cur, cost = moves[best], costs[best]
    return cur


# ----------------------------------------------------------------------
# search engines
# ----------------------------------------------------------------------

def anneal(problem: PlacementProblem, objective: str = "hybrid",
           seed: int = 0, steps: int = 300, restarts: int = 2,
           t_start: float = 0.05, t_end: float = 1e-3) -> PlacementResult:
    """Seeded simulated annealing + steepest-descent polish.

    Restart 0 starts from the greedy seed; later restarts from random
    joint states.  Deterministic for a fixed seed — the RNG stream is
    the only source of randomness.
    """
    evals0 = problem.evaluations
    with DEFAULT_REGISTRY.span("arch.anneal", objective=objective) as t:
        best = _anneal_search(problem, objective, seed, steps, restarts,
                              t_start, t_end)
    prov = make_provenance(
        "arch.anneal",
        problem.provenance_config(objective, steps=steps,
                                  restarts=restarts),
        seed=seed, points=problem.evaluations - evals0,
        wall_s=t["seconds"])
    return problem.result(best, objective, "anneal", provenance=prov)


def _anneal_search(problem: PlacementProblem, objective: str, seed: int,
                   steps: int, restarts: int, t_start: float,
                   t_end: float) -> PlacementState:
    rng = np.random.default_rng(seed)
    best = greedy_seed(problem)
    best_cost = problem.cost(best, objective)
    scale = best_cost or 1.0
    decay = (t_end / t_start) ** (1.0 / max(1, steps - 1))
    for restart in range(max(1, restarts)):
        cur = best if restart == 0 else _random_state(problem, rng)
        cur_cost = problem.cost(cur, objective)
        if cur_cost < best_cost:
            best, best_cost = cur, cur_cost
        temp = t_start
        for _ in range(steps):
            moves = (_swap_moves(problem, cur) if rng.random() < 0.5
                     else _boundary_moves(problem, cur))
            if not moves:    # degenerate axis (uniform mix / 1-layer stages)
                moves = (_swap_moves(problem, cur)
                         + _boundary_moves(problem, cur))
            if not moves:
                break        # single-state space: the seed is the optimum
            cand = moves[int(rng.integers(len(moves)))]
            c = problem.cost(cand, objective)
            de = (c - cur_cost) / scale
            if de <= 0 or rng.random() < math.exp(-de / temp):
                cur, cur_cost = cand, c
                if cur_cost < best_cost:
                    best, best_cost = cur, cur_cost
            temp *= decay
    return _polish(problem, best, objective)


def exhaustive(problem: PlacementProblem, objective: str = "hybrid",
               max_evals: int = 200_000) -> PlacementResult:
    """Full joint enumeration — ground truth on <= 6-slot packages."""
    n, L = problem.n_slots, len(problem.layers)
    ns = problem.n_stages
    if n > 6:
        raise ValueError("exhaustive enumeration is for <= 6-slot "
                         f"packages (got {n}); use anneal()")
    evals0 = problem.evaluations
    with DEFAULT_REGISTRY.span("arch.exhaustive",
                               objective=objective) as t:
        best = _exhaustive_search(problem, objective, max_evals)
    prov = make_provenance(
        "arch.exhaustive", problem.provenance_config(objective),
        points=problem.evaluations - evals0, wall_s=t["seconds"])
    return problem.result(best, objective, "exhaustive", provenance=prov)


def _exhaustive_search(problem: PlacementProblem, objective: str,
                       max_evals: int) -> PlacementState:
    n, L = problem.n_slots, len(problem.layers)
    ns = problem.n_stages
    seen, orders = set(), []
    for perm in itertools.permutations(range(n)):
        key = tuple(problem.specs[k].name for k in perm)
        if key not in seen:
            seen.add(key)
            orders.append(perm)
    n_seg = math.comb(L - 1, ns - 1)
    if len(orders) * n_seg > max_evals:
        raise ValueError(f"joint space {len(orders)} x {n_seg} exceeds "
                         f"max_evals={max_evals}")
    best, best_cost = None, math.inf
    for order in orders:
        for cuts in itertools.combinations(range(1, L), ns - 1):
            stage_of = np.searchsorted(np.asarray(cuts), np.arange(L),
                                       side="right")
            state = PlacementState(tuple(order),
                                   tuple(int(s) for s in stage_of))
            c = problem.cost(state, objective)
            if c < best_cost:
                best, best_cost = state, c
    return best


# ----------------------------------------------------------------------
# co-design driver
# ----------------------------------------------------------------------

@dataclasses.dataclass
class CodesignResult:
    """One (workload, mix) co-design cell of the hetero sweep."""

    workload: str
    mix: str
    package: str                 # describe() of the hybrid-best package
    greedy: PlacementResult
    wired: PlacementResult       # annealed under the wired objective
    hybrid: PlacementResult      # annealed under the hybrid objective
    spread_wired: float          # worst/best wired makespan over the pool
    spread_hybrid: float         # worst/best hybrid makespan, same pool
    speedup_hybrid: float        # wireless gain at the co-designed placement
    speedup_codesigned: float    # best-wired-package vs best-hybrid-package
    n_evaluations: int
    provenance: Optional[dict] = dataclasses.field(
        default=None, compare=False)  # dse.provenance of the whole cell


def balanced_state(problem: PlacementProblem,
                   order: Sequence[int]) -> PlacementState:
    """A placement with its deterministic compute-balanced segmentation."""
    macs = [lyr.macs for lyr in problem.layers]
    order_t = tuple(int(k) for k in order)
    return PlacementState(
        order_t, tuple(balanced_stages(macs, problem.stage_rates(order_t))))


def placement_pool(problem: PlacementProblem, seed: int,
                   n_samples: int) -> List[PlacementState]:
    """Placement-sensitivity pool: ``n_samples`` seeded random slot
    permutations, each with its compute-balanced segmentation.

    Only the PLACEMENT varies; every pool member keeps a sensibly
    balanced layer split (any real mapper re-balances after a
    re-placement).  The best-vs-worst spread over this pool therefore
    isolates what placement alone costs — the communication-distance
    sensitivity the wireless plane is hypothesised to erase.
    """
    rng = np.random.default_rng(seed)
    return [balanced_state(problem, rng.permutation(problem.n_slots))
            for _ in range(n_samples)]


def codesign(workload: str | List[Layer], mix: str = "big_little",
             net: NetworkConfig | None = None,
             grid: Tuple[int, int] = (3, 3),
             base: AcceleratorConfig | None = None,
             seed: int = 0, steps: int = 300, restarts: int = 2,
             n_samples: int = 10) -> CodesignResult:
    """Search one (workload, mix) cell under both planes.

    The two annealed optima are cross-polished (each plane's winner is
    hill-climbed under the other objective), so the co-designed hybrid
    can never lose to the wired optimum through search noise.  The
    spread pool (greedy + both optima + `placement_pool` samples) is
    evaluated under BOTH planes, so the wired and hybrid spreads are
    measured over the same placements.
    """
    problem = PlacementProblem(workload, mix, grid, net, base)
    with DEFAULT_REGISTRY.span("arch.codesign", mix=mix) as t:
        wired = anneal(problem, "wired", seed=seed, steps=steps,
                       restarts=restarts)
        hybrid = anneal(problem, "hybrid", seed=seed, steps=steps,
                        restarts=restarts)
        cross_h = _polish(problem, wired.state, "hybrid")
        if problem.cost(cross_h, "hybrid") < hybrid.makespan:
            hybrid = problem.result(cross_h, "hybrid", "anneal+cross")
        cross_w = _polish(problem, hybrid.state, "wired")
        if problem.cost(cross_w, "wired") < wired.makespan:
            wired = problem.result(cross_w, "wired", "anneal+cross")
        pool = [greedy_seed(problem), wired.state, hybrid.state]
        pool += placement_pool(problem, seed + 1, n_samples)
        evals = np.array([problem.evaluate(s) for s in pool])
        t_w, t_h = evals[:, 0], evals[:, 1]
    return CodesignResult(
        workload=problem.workload, mix=problem.mix,
        package=problem.package(hybrid.state.order).describe(),
        greedy=problem.result(pool[0], "hybrid", "greedy"),
        wired=wired, hybrid=hybrid,
        spread_wired=float(t_w.max() / t_w.min()),
        spread_hybrid=float(t_h.max() / t_h.min()),
        speedup_hybrid=hybrid.hybrid_speedup,
        speedup_codesigned=wired.t_wired / hybrid.t_hybrid,
        n_evaluations=problem.evaluations,
        provenance=make_provenance(
            "arch.codesign",
            problem.provenance_config("both", steps=steps,
                                      restarts=restarts,
                                      n_samples=n_samples),
            seed=seed, points=problem.evaluations,
            wall_s=t["seconds"]))
