"""`HeteroPackage`: a chiplet spec for every grid slot, lowered to the
existing platform description.

The package is the fourth modelling plane's state: WHICH chiplet sits
WHERE.  It lowers to an (extended) `AcceleratorConfig` — the per-slot
rate/SRAM/energy vectors ride on optional config fields — so every
existing consumer (`build_topology`, `build_trace`, `simulate_hybrid`,
`PacketSim`, the batched DSE engine) works unchanged.  A package of
identical chiplets lowers to vectors whose consumers all collapse to
the legacy uniform expressions, keeping the homogeneous reproduction
bit-identical (tests/test_arch.py pins this on all 15 paper workloads).
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Sequence, Tuple

from repro.core.topology import AcceleratorConfig, Topology, build_topology

from .catalog import ChipletSpec, get_mix, get_spec


@dataclasses.dataclass(frozen=True)
class HeteroPackage:
    """Per-slot chiplet assignment on a rows x cols compute grid.

    ``slots[i]`` is the spec of chiplet id ``i`` — the same row-major
    slot numbering `Topology` uses, so slot vectors index directly by
    chiplet id everywhere downstream.
    """

    grid: Tuple[int, int]
    slots: Tuple[ChipletSpec, ...]

    def __post_init__(self):
        if len(self.slots) != self.grid[0] * self.grid[1]:
            raise ValueError(
                f"{self.grid[0]}x{self.grid[1]} grid needs "
                f"{self.grid[0] * self.grid[1]} slots, got {len(self.slots)}")

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------

    @classmethod
    def uniform(cls, spec: str | ChipletSpec = "standard",
                grid: Tuple[int, int] = (3, 3)) -> "HeteroPackage":
        """Homogeneous package (the paper platform when ``standard``)."""
        s = get_spec(spec)
        return cls(grid, (s,) * (grid[0] * grid[1]))

    @classmethod
    def from_mix(cls, mix: str | Sequence[str | ChipletSpec],
                 grid: Tuple[int, int] = (3, 3),
                 order: Sequence[int] | None = None) -> "HeteroPackage":
        """Package from a named catalog mix (or explicit spec sequence).

        ``order`` permutes the mix over the slots (``slots[i] =
        mix[order[i]]``) — the placement engine's knob; identity when
        omitted.
        """
        names = get_mix(mix) if isinstance(mix, str) else tuple(mix)
        specs = tuple(get_spec(s) for s in names)
        if order is not None:
            if sorted(order) != list(range(len(specs))):
                raise ValueError(f"order must permute 0..{len(specs) - 1}")
            specs = tuple(specs[j] for j in order)
        return cls(grid, specs)

    def placed(self, order: Sequence[int]) -> "HeteroPackage":
        """Re-placement: slot i takes the current ``slots[order[i]]``."""
        return HeteroPackage(self.grid,
                             tuple(self.slots[j] for j in order))

    # ------------------------------------------------------------------
    # views
    # ------------------------------------------------------------------

    @property
    def n_slots(self) -> int:
        return len(self.slots)

    @property
    def is_uniform(self) -> bool:
        return all(s == self.slots[0] for s in self.slots)

    @property
    def tops_total(self) -> float:
        return float(sum(s.tops for s in self.slots))

    def describe(self) -> str:
        counts = Counter(s.name for s in self.slots)
        body = "+".join(f"{n}x{name}" for name, n in sorted(counts.items()))
        return f"{self.grid[0]}x{self.grid[1]}[{body}]"

    # ------------------------------------------------------------------
    # lowering
    # ------------------------------------------------------------------

    def to_config(self,
                  base: AcceleratorConfig | None = None) -> AcceleratorConfig:
        """Lower to an `AcceleratorConfig` carrying the per-slot vectors.

        Package-level parameters (DRAM, NoP mesh, wireless band) come
        from ``base`` (the paper's Table-1 defaults when omitted) — the
        heterogeneity question varies the chiplets, not the package
        substrate.
        """
        base = base or AcceleratorConfig()
        return dataclasses.replace(
            base, grid=self.grid,
            tops_total=self.tops_total,
            chiplet_tops=tuple(s.tops for s in self.slots),
            chiplet_noc_bw=tuple(s.noc_bw_per_port for s in self.slots),
            chiplet_sram=tuple(int(s.sram_bytes) for s in self.slots),
            chiplet_pj_per_mac=tuple(s.pj_per_mac for s in self.slots),
            chiplet_pj_per_bit_noc=tuple(s.pj_per_bit_noc
                                         for s in self.slots))

    def build_topology(self,
                       base: AcceleratorConfig | None = None) -> Topology:
        return build_topology(self.to_config(base))
