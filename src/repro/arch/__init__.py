"""`repro.arch`: heterogeneous chiplet packages + placement co-design.

The fourth modelling plane.  `core` asks "what does the wireless plane
buy a FIXED uniform package"; `arch` makes the package itself a search
variable: a catalog of chiplet design points (`catalog.ChipletSpec`),
a per-slot package description that lowers onto the existing platform
config (`package.HeteroPackage`), and a deterministic placement/mapping
co-design engine whose objective is end-to-end makespan
(`placement.codesign`).  `dse.hetero_sweep` runs the headline study:
how much does the wireless plane shrink the best-vs-worst-placement
spread on heterogeneous packages?
"""

from .catalog import CATALOG, MIXES, ChipletSpec, get_mix, get_spec
from .package import HeteroPackage
from .placement import (CodesignResult, PlacementProblem, PlacementResult,
                        PlacementState, anneal, balanced_stages, codesign,
                        exhaustive, greedy_seed)

__all__ = [
    "CATALOG", "MIXES", "ChipletSpec", "get_mix", "get_spec",
    "HeteroPackage",
    "CodesignResult", "PlacementProblem", "PlacementResult",
    "PlacementState", "anneal", "balanced_stages", "codesign",
    "exhaustive", "greedy_seed",
]
