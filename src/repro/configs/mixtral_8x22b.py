"""Mixtral-8x22B: 8 experts top-2, sliding-window attention
[arXiv:2401.04088]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    moe_d_ff=16384,
    vocab_size=32768,
    n_experts=8,
    experts_per_token=2,
    sliding_window=4096,
    subquadratic=True,    # SWA: KV cache capped at the window
)
