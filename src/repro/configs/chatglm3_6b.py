"""ChatGLM3-6B: dense, GQA kv=2, 2d-RoPE (rotary on half the head dims),
QKV bias [arXiv:2406.12793]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope_fraction=0.5,          # 2d rope: rotary applied to half the dims
    qkv_bias=True,
)
