"""Architecture registry: every assigned arch + the paper's platform."""

from .base import ModelConfig, ShapeConfig, SHAPES, BlockSpec
from .zamba2_2p7b import CONFIG as zamba2_2p7b
from .chatglm3_6b import CONFIG as chatglm3_6b
from .gemma2_2b import CONFIG as gemma2_2b
from .smollm_360m import CONFIG as smollm_360m
from .qwen2p5_32b import CONFIG as qwen2p5_32b
from .mamba2_130m import CONFIG as mamba2_130m
from .kimi_k2_1t import CONFIG as kimi_k2_1t
from .mixtral_8x22b import CONFIG as mixtral_8x22b
from .pixtral_12b import CONFIG as pixtral_12b
from .seamless_m4t_v2 import CONFIG as seamless_m4t_v2

ARCHS = {
    "zamba2-2.7b": zamba2_2p7b,
    "chatglm3-6b": chatglm3_6b,
    "gemma2-2b": gemma2_2b,
    "smollm-360m": smollm_360m,
    "qwen2.5-32b": qwen2p5_32b,
    "mamba2-130m": mamba2_130m,
    "kimi-k2-1t-a32b": kimi_k2_1t,
    "mixtral-8x22b": mixtral_8x22b,
    "pixtral-12b": pixtral_12b,
    "seamless-m4t-large-v2": seamless_m4t_v2,
}


def get_arch(name: str) -> ModelConfig:
    return ARCHS[name]


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test variant: same family/pattern, tiny dimensions."""
    import dataclasses
    layers_per_unit = max(1, sum(1 for b in cfg.unit
                                 if b.kind in ("attn", "mamba")))
    small = dict(
        n_layers=2 * layers_per_unit if cfg.shared_attn_every == 0
        else 2 * cfg.shared_attn_every,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2),
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2)
        if cfg.n_experts else 0,
        moe_d_ff=32 if cfg.moe_d_ff else None,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16,
        ssm_chunk=16,
        sliding_window=32 if cfg.sliding_window else None,
        n_encoder_layers=2 if cfg.n_encoder_layers else 0,
        unit=(),  # rebuilt for the reduced dims
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)


def cells(arch: str):
    """The (arch x shape) cells assigned to this arch (skips documented in
    DESIGN.md SArch-applicability: long_500k only for sub-quadratic archs)."""
    cfg = get_arch(arch)
    out = []
    for shape in SHAPES.values():
        if shape.name == "long_500k" and not cfg.subquadratic:
            continue
        out.append(shape)
    return out


ALL_CELLS = [(a, s.name) for a in ARCHS for s in cells(a)]
