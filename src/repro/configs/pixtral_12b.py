"""Pixtral-12B: Pixtral-ViT frontend (STUB: precomputed patch embeddings
enter via input_specs) + Mistral-NeMo-style decoder backbone
[hf:mistralai/Pixtral-12B-2409]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    frontend="embed",     # patch embeddings precomputed by the stub
)
