"""The paper's own platform config (Table 1), for the package-scale sim."""

from repro.core.topology import AcceleratorConfig
from repro.units import gbps_to_bytes_per_s

CONFIG_64G = AcceleratorConfig(wireless_bw=gbps_to_bytes_per_s(64))
CONFIG_96G = AcceleratorConfig(wireless_bw=gbps_to_bytes_per_s(96))
