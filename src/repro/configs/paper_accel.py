"""The paper's own platform config (Table 1), for the package-scale sim."""

from repro.core.topology import AcceleratorConfig

CONFIG_64G = AcceleratorConfig(wireless_bw=64e9 / 8)
CONFIG_96G = AcceleratorConfig(wireless_bw=96e9 / 8)
