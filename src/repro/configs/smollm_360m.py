"""SmolLM-360M: llama-architecture small model
[hf:HuggingFaceTB/SmolLM-360M]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    tie_embeddings=True,
)
