"""Config system: architecture + run configuration.

Every assigned architecture is a `ModelConfig` in its own module under
`repro.configs`, selectable by ``--arch <id>`` everywhere (launcher,
dry-run, benchmarks).  A config fully determines the model: the repeating
pattern unit (the `lax.scan` body), attention flavour, MoE/SSM settings,
and the modality frontend stub.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One sub-block inside the repeating pattern unit."""

    kind: str                    # "attn" | "mlp" | "moe" | "mamba"
    # attention options
    window: Optional[int] = None          # sliding-window size (None = full)
    is_global: bool = True                # False => local/sliding layer
    # mlp options — d_ff taken from the model config unless overridden
    d_ff: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None        # default d_model // n_heads

    # pattern unit: the scan body covers `unit` and repeats n_units times.
    # Built by `build_unit()` if left empty.
    unit: Tuple[BlockSpec, ...] = ()

    # attention variants
    rope_theta: float = 1e4
    rope_fraction: float = 1.0            # chatglm 2d-RoPE: 0.5
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None  # gemma2: 50.0
    final_softcap: Optional[float] = None  # gemma2: 30.0
    sliding_window: Optional[int] = None  # mixtral SWA / gemma2 local
    tie_embeddings: bool = False
    activation: str = "silu"              # silu | geglu | gelu

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: Optional[int] = None        # per-expert hidden (kimi: 2048)

    # SSM (Mamba2/SSD)
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256

    # hybrid (zamba2): one SHARED attention block applied every
    # `shared_attn_every` layers (weights reused — the Zamba trick)
    shared_attn_every: int = 0

    # encoder-decoder (seamless)
    n_encoder_layers: int = 0

    # modality frontend stub: "none" => token ids in; "embed" => the
    # dry-run feeds precomputed frame/patch embeddings (B, S, d_model)
    frontend: str = "none"
    encoder_frontend: str = "none"

    norm_eps: float = 1e-6
    # whether this arch can run the 524k-token long-context decode shape
    subquadratic: bool = False

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if not self.unit:
            object.__setattr__(self, "unit", self.build_unit())
        layers_per_unit = max(
            1, sum(1 for b in self.unit if b.kind in ("attn", "mamba")))
        assert self.n_layers % layers_per_unit == 0, (
            self.name, self.n_layers, layers_per_unit)

    def build_unit(self) -> Tuple[BlockSpec, ...]:
        if self.family == "ssm":
            return (BlockSpec("mamba"),)
        if self.family == "hybrid":
            # zamba-style: shared_attn handled outside the unit list
            return (BlockSpec("mamba"),)
        if self.family == "moe":
            blocks = [BlockSpec("attn", window=self.sliding_window,
                                is_global=self.sliding_window is None),
                      BlockSpec("moe")]
            return tuple(blocks)
        return (BlockSpec("attn", window=self.sliding_window,
                          is_global=self.sliding_window is None),
                BlockSpec("mlp"))

    @property
    def n_units(self) -> int:
        """Scan trip count: layers grouped into identical pattern units."""
        layers_per_unit = max(
            1, sum(1 for b in self.unit if b.kind in ("attn", "mamba")))
        return self.n_layers // layers_per_unit

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def kv_cache_dtype_bytes(self) -> int:
        return 2  # bf16

    def param_count(self) -> int:
        """Analytic parameter count (cross-checked against the real tree in
        tests); used for MODEL_FLOPS = 6*N*D."""
        d, h = self.d_model, self.head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_attn = d * (self.n_heads * h) + 2 * d * (self.n_kv_heads * h) \
            + (self.n_heads * h) * d
        if self.qkv_bias:
            per_attn += (self.n_heads + 2 * self.n_kv_heads) * h
        act_mult = 3 if self.activation in ("silu", "geglu") else 2
        per_mlp = act_mult * d * self.d_ff
        per_moe = (self.n_experts * act_mult * d * (self.moe_d_ff or self.d_ff)
                   + d * self.n_experts)
        dssm = self.d_inner
        g_n = 2 * self.ssm_state  # single B/C group
        per_mamba = (d * (2 * dssm + g_n + self.n_ssm_heads)  # in_proj
                     + self.d_conv * (dssm + g_n)             # conv
                     + 3 * self.n_ssm_heads                   # A, D, dt_bias
                     + dssm * d)                              # out_proj
        total = emb
        norms = 2 * d
        n_dec = self.n_layers
        kinds = {"attn": per_attn + norms, "mlp": per_mlp + norms,
                 "moe": per_moe + norms, "mamba": per_mamba + norms}
        per_unit = sum(kinds[b.kind] for b in self.unit)
        total += self.n_units * per_unit
        if self.shared_attn_every:
            total += per_attn + per_mlp + 2 * norms
        if self.is_encdec:
            total += self.n_encoder_layers * (per_attn + per_mlp + 2 * norms)
            total += self.n_layers * (per_attn + norms)  # cross attention
        return int(total)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top-k of the expert pool)."""
        if not self.n_experts:
            return self.param_count()
        act_mult = 3 if self.activation in ("silu", "geglu") else 2
        per_moe_total = self.n_experts * act_mult * self.d_model * \
            (self.moe_d_ff or self.d_ff)
        per_moe_active = self.experts_per_token * act_mult * self.d_model * \
            (self.moe_d_ff or self.d_ff)
        n_moe_layers = self.n_units * sum(1 for b in self.unit
                                          if b.kind == "moe")
        return self.param_count() - n_moe_layers * (per_moe_total -
                                                    per_moe_active)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    mode: str           # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
