"""Kimi K2: trillion-parameter MoE, 384 experts top-8, GQA kv=8
[arXiv:2501.kimi2 paper-table]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,
    d_ff=2048,            # per-expert hidden width (paper table)
    moe_d_ff=2048,
    vocab_size=163840,
    n_experts=384,
    experts_per_token=8,
)
