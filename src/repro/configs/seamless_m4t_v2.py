"""SeamlessM4T-large-v2: speech encoder (STUB frontend: precomputed frame
embeddings) + text decoder, encoder-decoder [arXiv:2308.11596]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,            # decoder layers
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    encoder_frontend="embed",
)
