"""Gemma2-2B: alternating local(4096)/global attention, logit softcapping,
GeGLU, tied embeddings, head_dim=256 [arXiv:2408.00118]."""

from .base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    unit=(BlockSpec("attn", window=4096, is_global=False), BlockSpec("mlp"),
          BlockSpec("attn", is_global=True), BlockSpec("mlp")),
    attn_softcap=50.0,
    final_softcap=30.0,
    sliding_window=4096,
    tie_embeddings=True,
    activation="geglu",
)
