"""Mamba2-130M: attention-free SSD (state-space duality)
[arXiv:2405.21060]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=1,           # no attention heads (attn-free)
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    tie_embeddings=True,
    subquadratic=True,
)
