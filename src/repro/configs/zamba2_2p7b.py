"""Zamba2-2.7B: Mamba2 backbone + one shared attention block applied every
6th layer (weights reused across applications) [arXiv:2411.15242]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    shared_attn_every=6,
    subquadratic=True,          # SSM backbone; only the shared block keeps KV
    tie_embeddings=True,
)
