"""Label-keyed metrics registry, logging adapter, and the attribution
report that turns `bottleneck_share`'s "which resource" into "why".

- `MetricsRegistry` — counters / gauges / histograms keyed by (name,
  labels); `span(...)` is a wall-time context manager feeding a
  histogram; `logger(...)` returns the structured-print adapter the
  launch drivers route their progress output through.
- `utilization_timeline` — time-binned per-resource occupancy from a
  recorded `SimTrace`.
- `attribution_report` — per (layer, resource) decomposition of where
  the layer's span went:

  ==============  =========================================================
  column          meaning
  ==============  =========================================================
  ``service_s``   payload serving time (sum of event durations)
  ``queue_s``     packet waiting: sum over packets of (service begin -
                  layer start); for reuse-zone tracks this includes the
                  wait behind the channel's global phase
  ``quiesce_s``   the slice of ``queue_s`` explained by long-range
                  (channel-global) traffic quiescing the zone
  ``finish_s``    when the resource drained, relative to layer start
  ``idle_s``      layer span minus ``finish_s`` (the resource was done,
                  another plane was the bottleneck)
  ``busy_frac``   service_s / finish_s
  ``why``         "service" | "queueing" | "queueing behind long-range
                  quiesce" — which component dominates
  ==============  =========================================================

  Degenerate (zero-time / empty) traces return ``[]`` — the same
  explicit empty convention `SimResult.bottleneck_share` /
  `EventResult.bottleneck_share` use for zero-time runs.
"""

from __future__ import annotations

import contextlib
import sys
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.units import s_to_ms

from . import profile as _profile
from .trace import RESOURCE_CATS, SimTrace


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

class Metric:
    """One (name, labels) series: counter, gauge, or histogram."""

    def __init__(self, kind: str, name: str, labels: Tuple[Tuple[str, str],
                                                           ...]):
        self.kind = kind
        self.name = name
        self.labels = labels
        self.value = 0.0
        self.samples: List[float] = []

    def inc(self, v: float = 1.0) -> None:
        assert self.kind == "counter"
        self.value += v

    def set(self, v: float) -> None:
        assert self.kind == "gauge"
        self.value = float(v)

    def observe(self, v: float) -> None:
        assert self.kind == "histogram"
        self.samples.append(float(v))

    def summary(self) -> dict:
        out = {"kind": self.kind, "labels": dict(self.labels)}
        if self.kind == "histogram":
            s = np.asarray(self.samples) if self.samples else np.zeros(0)
            out.update(count=len(s),
                       sum=float(s.sum()),
                       mean=float(s.mean()) if len(s) else 0.0,
                       max=float(s.max()) if len(s) else 0.0)
        else:
            out["value"] = self.value
        return out


class MetricsRegistry:
    """Label-keyed metric store; one process-wide `DEFAULT_REGISTRY`."""

    def __init__(self):
        self._metrics: Dict[Tuple[str, Tuple], Metric] = {}

    def _get(self, kind: str, name: str, labels: dict) -> Metric:
        key = (name, tuple(sorted((k, str(v))
                                  for k, v in labels.items())))
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = Metric(kind, name, key[1])
        elif m.kind != kind:
            raise ValueError(f"metric {name!r} already registered as "
                             f"{m.kind}, not {kind}")
        return m

    def counter(self, name: str, **labels) -> Metric:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Metric:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, **labels) -> Metric:
        return self._get("histogram", name, labels)

    @contextlib.contextmanager
    def span(self, name: str, **labels):
        """Wall-time a block into histogram ``name``; yields a dict
        whose ``seconds`` key holds the elapsed time on exit.

        Exception-safe: a raising body still records its elapsed time,
        but under an extra ``outcome=error`` label — the sample is
        never dropped and never pollutes the success distribution (the
        success-path histogram keys are unchanged).  Callers that read
        ``out["seconds"]`` after the block (placement anneal, the dse
        sweeps) only do so on success — on error the exception
        propagates before any provenance is stamped, which is the
        audited intent.

        Every span also opens a `profile.phase` of the same name, so
        under ``with obs.profiling():`` the registry's spans double as
        top-level profiler phases at zero extra call-site cost.
        """
        out = {"seconds": 0.0}
        failed = False
        with _profile.phase(name):
            t0 = time.perf_counter()
            try:
                yield out
            except BaseException:
                failed = True
                raise
            finally:
                out["seconds"] = time.perf_counter() - t0
                lbl = dict(labels, outcome="error") if failed else labels
                self.histogram(name, **lbl).observe(out["seconds"])

    def logger(self, name: str, stream=None) -> "MetricsLogger":
        return MetricsLogger(self, name, stream)

    def report(self) -> Dict[str, list]:
        """name -> list of per-label-set summaries (JSON-serialisable)."""
        out: Dict[str, list] = {}
        for (name, _), m in sorted(self._metrics.items()):
            out.setdefault(name, []).append(m.summary())
        return out

    def reset(self) -> None:
        self._metrics.clear()


class MetricsLogger:
    """Structured progress logging that also feeds the registry.

    ``log.info("step 12 done", step=12, ce=1.93)`` prints the message
    (plus the fields) and records: a per-level message counter and a
    gauge per numeric field — so a driver's progress output is
    machine-readable from `MetricsRegistry.report()` instead of lost
    to stdout.
    """

    def __init__(self, registry: MetricsRegistry, name: str, stream=None):
        self.registry = registry
        self.name = name
        self.stream = stream

    def _log(self, level: str, msg: str, **fields) -> None:
        self.registry.counter("log.messages", logger=self.name,
                              level=level).inc()
        for k, v in fields.items():
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                self.registry.gauge(f"{self.name}.{k}").set(v)
        stream = self.stream or sys.stdout
        tail = "".join(f" {k}={v}" for k, v in fields.items()
                       if f"{v}" not in msg)
        prefix = "" if level == "info" else f"{level.upper()}: "
        print(f"{prefix}{msg}{tail}", file=stream)

    def info(self, msg: str, **fields) -> None:
        self._log("info", msg, **fields)

    def warning(self, msg: str, **fields) -> None:
        self._log("warning", msg, **fields)

    def error(self, msg: str, **fields) -> None:
        self._log("error", msg, **fields)


DEFAULT_REGISTRY = MetricsRegistry()


def get_logger(name: str, stream=None) -> MetricsLogger:
    """A `MetricsLogger` on the process-wide default registry."""
    return DEFAULT_REGISTRY.logger(name, stream)


# ---------------------------------------------------------------------------
# timelines
# ---------------------------------------------------------------------------

def utilization_timeline(st: SimTrace, cat: str, n_bins: int = 50,
                         t_end: Optional[float] = None
                         ) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
    """(bin edges, track -> per-bin occupancy fraction) for one plane."""
    t_end = t_end if t_end is not None else st.span()[1]
    edges = np.linspace(0.0, t_end or 1.0, n_bins + 1)
    width = edges[1] - edges[0]
    out: Dict[str, np.ndarray] = {}
    for ev in st.events:
        if ev.cat != cat:
            continue
        util = out.setdefault(ev.track, np.zeros(n_bins))
        # overlap of [ts, ts+dur) with each bin
        lo = np.clip(ev.ts, edges[:-1], edges[1:])
        hi = np.clip(ev.ts + ev.dur, edges[:-1], edges[1:])
        util += np.maximum(hi - lo, 0.0) / width
    return edges, out


# ---------------------------------------------------------------------------
# attribution
# ---------------------------------------------------------------------------

def _trace_of(source) -> SimTrace:
    st = getattr(source, "trace", source)
    if not isinstance(st, SimTrace):
        raise ValueError(
            "attribution needs a recorded trace: run the engine with "
            "record=True (PacketSim(trace, net, record=True)) or pass a "
            "SimTrace")
    return st


def attribution_report(source, cats=RESOURCE_CATS) -> List[dict]:
    """Per (layer, resource) service/queueing/quiescence decomposition.

    ``source`` is an `EventResult` from a recorded run (or a `SimTrace`
    directly).  See the module docstring for the column glossary.
    Empty/degenerate traces return ``[]`` (the shared convention with
    `bottleneck_share`'s ``{}``).
    """
    st = _trace_of(source)
    windows = st.layer_windows()
    groups: Dict[Tuple[int, str], List] = {}
    glob_busy: Dict[Tuple[int, str], float] = {}   # (layer, "ch{c}") ->
    for ev in st.events:
        if ev.cat not in cats:
            continue
        groups.setdefault((ev.layer, ev.track), []).append(ev)
        head, _, sub = ev.track.partition("/")
        if sub == "g":
            key = (ev.layer, head)
            glob_busy[key] = glob_busy.get(key, 0.0) + ev.dur
    rows = []
    for (li, track), evs in sorted(groups.items()):
        start, span = windows.get(li, (min(e.ts for e in evs), 0.0))
        service = sum(e.dur for e in evs)
        finish = max(e.ts + e.dur for e in evs) - start
        queue = sum(e.ts - start for e in evs)
        head, _, sub = track.partition("/")
        quiesce = 0.0
        if sub.startswith("z"):
            # every packet of this zone queued behind the channel's
            # global phase before its own FIFO position
            quiesce = len(evs) * glob_busy.get((li, head), 0.0)
            quiesce = min(quiesce, queue)
        if queue > service:
            why = "queueing"
            if quiesce > 0.5 * queue:
                why = "queueing behind long-range quiesce"
        else:
            why = "service"
        rows.append({
            "layer": li, "track": track, "cat": evs[0].cat,
            "n_events": len(evs),
            "service_s": service, "queue_s": queue, "quiesce_s": quiesce,
            "finish_s": finish, "idle_s": max(span - finish, 0.0),
            "busy_frac": service / finish if finish else 0.0,
            "why": why,
        })
    return rows


def attribution_summary(source,
                        cats=RESOURCE_CATS + ("compute", "noc", "dram-agg")
                        ) -> Dict[str, dict]:
    """bottleneck -> {share, hot resource, why}: the upgraded
    `bottleneck_share`.

    For each bottleneck category of the run, reports its share of total
    time (exactly `bottleneck_share`'s number) plus the latest-draining
    resource among its bottlenecked layers and that resource's dominant
    ``why`` — e.g. ``wireless: 61% — ch0/z2 queueing behind long-range
    quiesce``.  Zero-time runs return ``{}``.
    """
    st = _trace_of(source)
    shares = source.bottleneck_share() if hasattr(
        source, "bottleneck_share") else {}
    rows = attribution_report(source, cats)
    windows = st.layer_windows()
    # layer -> bottleneck name, from the layer span labels "L{i}:{b}"
    layer_bn = {ev.layer: ev.name.split(":", 1)[1]
                for ev in st.events if ev.cat == "layer" and ":" in ev.name}
    cat_of_bn = {"nop": "wired", "wireless": "wireless", "dram": "dram",
                 "compute": "compute", "noc": "noc"}
    if "dram" not in {r["cat"] for r in rows}:   # pooled DRAM model
        cat_of_bn["dram"] = "dram-agg"
    out: Dict[str, dict] = {}
    for bn, share in shares.items():
        if share <= 0.0:
            continue
        layers = {li for li, b in layer_bn.items() if b == bn}
        cand = [r for r in rows
                if r["layer"] in layers and r["cat"] == cat_of_bn.get(bn)]
        entry = {"share": share, "track": None, "why": None}
        if cand:
            weight = {li: windows.get(li, (0, 0))[1] for li in layers}
            hot = max(cand, key=lambda r: (weight.get(r["layer"], 0.0),
                                           r["finish_s"]))
            entry.update(track=hot["track"], why=hot["why"])
        out[bn] = entry
    return out


def format_attribution(rows: List[dict], top: int = 12) -> str:
    """Human-readable table of the heaviest attribution rows."""
    rows = sorted(rows, key=lambda r: -r["finish_s"])[:top]
    if not rows:
        return "(empty trace)"
    hdr = (f"{'layer':>5} {'resource':<12} {'n':>5} {'service':>10} "
           f"{'queueing':>10} {'quiesce':>10} {'finish':>10}  why")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['layer']:>5} {r['track']:<12} {r['n_events']:>5} "
            f"{s_to_ms(r['service_s']):>9.3f}m "
            f"{s_to_ms(r['queue_s']):>9.3f}m "
            f"{s_to_ms(r['quiesce_s']):>9.3f}m "
            f"{s_to_ms(r['finish_s']):>9.3f}m  "
            f"{r['why']}")
    return "\n".join(lines)
