"""Critical-path extraction over a recorded `SimTrace` dependency DAG.

`repro.sim.engine` records every transmission with its blocking edges
(`TraceEvent.deps`): the FIFO predecessor on the same server, the
channel-global quiesce a reuse zone queued behind, or — for an event
with no deps — the layer barrier.  Under the GEMINI execution model the
makespan is the sum of per-layer spans, and each layer's span is the
max over the compute / DRAM / NoC / wired-NoP / wireless terms; the
recorded trace carries all of them (coarse analytic spans for the
aggregate floors, per-packet events for the network planes).

The critical path is therefore assembled layer by layer: the event
whose completion realises the layer's span is the layer's *terminal*;
walking its dependency chain backwards (always to the latest-finishing
dependency) yields the blocking chain from the barrier to the terminal.
Each chain element is charged its *incremental* contribution — its end
minus the previous element's end — so the per-layer charges telescope
to exactly the layer span and the whole decomposition sums to the
makespan (pinned at rtol=1e-12 in tests/test_critpath.py).

The headline observable is `critical_vs_busy`: the share of makespan
each plane *bounds* (critical share) against the share of busy-seconds
it *accumulates* (busy share).  A plane can be busy without ever being
binding — the divergence between the two rankings is what a load
balancer or a bandwidth-reallocation policy should act on (PAPERS.md:
2410.22262's characterization methodology, 2011.04107's agile
reallocation argument).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from .trace import SimTrace, TraceEvent

#: categories that can realise (bound) a layer span.  Raw per-port
#: ``dram`` events are EXCLUDED: under the pooled DRAM model the layer
#: term is the analytic aggregate (recorded as the ``dram-agg`` span),
#: and under the ports model the ``dram-agg`` span equals the max port
#: backlog — either way the agg span is the binding representative.
TERMINAL_CATS = ("compute", "noc", "dram-agg", "wired", "wireless")

#: cat -> plane label used by the share decompositions
PLANE_OF_CAT = {"wired": "wired", "wireless": "wireless",
                "dram": "dram", "dram-agg": "dram",
                "compute": "compute", "noc": "noc"}


def plane_of(cat: str) -> Optional[str]:
    """Plane label for a category (``an:`` analytic prefix stripped)."""
    if cat.startswith("an:"):
        cat = cat[3:]
    return PLANE_OF_CAT.get(cat)


@dataclasses.dataclass
class CritSegment:
    """One critical-path element and its incremental charge.

    ``crit_dur`` is the makespan attributed to this segment: its end
    minus the previous critical end (the layer barrier for a chain
    head).  It can be smaller than the event's own ``dur`` when the
    event overlapped its predecessor's tail, and equals the full layer
    span for a coarse analytic terminal (compute floor etc.).
    """

    eid: int
    track: str
    name: str
    cat: str
    layer: int
    ts: float
    dur: float
    crit_dur: float

    @property
    def plane(self) -> str:
        return plane_of(self.cat) or self.cat


@dataclasses.dataclass
class CriticalPath:
    """The blocking chain from t=0 to the makespan, layer by layer."""

    segments: List[CritSegment]
    makespan: float

    @property
    def total(self) -> float:
        """Sum of critical charges — equals ``makespan`` (rtol 1e-12)."""
        return sum(s.crit_dur for s in self.segments)

    def by_resource(self) -> Dict[str, float]:
        """Critical seconds per track, descending."""
        out: Dict[str, float] = {}
        for s in self.segments:
            out[s.track] = out.get(s.track, 0.0) + s.crit_dur
        return dict(sorted(out.items(), key=lambda kv: -kv[1]))

    def by_plane(self) -> Dict[str, float]:
        """Critical seconds per plane, descending."""
        out: Dict[str, float] = {}
        for s in self.segments:
            out[s.plane] = out.get(s.plane, 0.0) + s.crit_dur
        return dict(sorted(out.items(), key=lambda kv: -kv[1]))

    def critical_shares(self) -> Dict[str, float]:
        """Fraction of makespan each plane bounds (empty when zero)."""
        if not self.makespan:
            return {}
        return {p: v / self.makespan for p, v in self.by_plane().items()}

    def top_segments(self, n: int = 5) -> List[CritSegment]:
        """The ``n`` largest critical charges, descending."""
        return sorted(self.segments, key=lambda s: -s.crit_dur)[:n]


def _layer_geometry(st: SimTrace):
    """(starts, times) per layer, from `place_layers` metadata or — for
    a trace placed some other way — from the recorded layer spans."""
    starts = st.meta.get("layer_starts")
    times = st.meta.get("layer_times")
    if starts is not None and times is not None:
        return list(starts), list(times)
    windows = st.layer_windows()
    if not windows:
        return [], []
    L = max(windows) + 1
    starts = [windows.get(li, (0.0, 0.0))[0] for li in range(L)]
    times = [windows.get(li, (0.0, 0.0))[1] for li in range(L)]
    return starts, times


def critical_path(st: SimTrace) -> CriticalPath:
    """Extract the critical path of one recorded run.

    Degenerate traces follow the repo-wide empty-structure convention:
    zero events (or zero makespan) yield an empty segment list, never
    an exception.
    """
    starts, times = _layer_geometry(st)
    if not st.events or not times:
        return CriticalPath([], 0.0)

    by_eid: Dict[int, TraceEvent] = {}
    candidates: Dict[int, List[TraceEvent]] = {}
    for ev in st.events:
        if ev.eid >= 0:
            by_eid[ev.eid] = ev
        cat = ev.cat[3:] if ev.cat.startswith("an:") else ev.cat
        if cat in TERMINAL_CATS and ev.layer >= 0:
            candidates.setdefault(ev.layer, []).append(ev)

    segments: List[CritSegment] = []
    for li, (lt, ls) in enumerate(zip(times, starts)):
        evs = candidates.get(li)
        if not evs or lt <= 0.0:
            continue
        # terminal: the latest-finishing candidate realises the span.
        # Ties (e.g. the compute floor matching a drained queue) go to
        # the earliest-recorded event, which favours the coarse span —
        # a one-segment chain — over an equal-length queue replay.
        terminal = max(evs, key=lambda e: (e.end, -e.eid))
        chain: List[TraceEvent] = []
        ev: Optional[TraceEvent] = terminal
        seen = set()
        while ev is not None and ev.eid not in seen:
            chain.append(ev)
            seen.add(ev.eid)
            preds = [by_eid[d] for d in ev.deps if d in by_eid]
            ev = max(preds, key=lambda e: e.end) if preds else None
        chain.reverse()
        # incremental charges telescope: they sum to terminal.end - ls,
        # and the terminal realises the span, so the layer's charges
        # sum to the layer time exactly
        prev_end = ls
        for ev in chain:
            segments.append(CritSegment(
                eid=ev.eid, track=ev.track, name=ev.name, cat=ev.cat,
                layer=li, ts=ev.ts, dur=ev.dur,
                crit_dur=ev.end - prev_end))
            prev_end = ev.end
    return CriticalPath(segments, float(sum(times)))


def busy_shares(st: SimTrace) -> Dict[str, float]:
    """Fraction of total busy-seconds accumulated per plane."""
    busy: Dict[str, float] = {}
    for ev in st.events:
        plane = plane_of(ev.cat)
        if plane is not None:
            busy[plane] = busy.get(plane, 0.0) + ev.dur
    total = sum(busy.values())
    if not total:
        return {}
    return dict(sorted(((p, v / total) for p, v in busy.items()),
                       key=lambda kv: -kv[1]))


def critical_vs_busy(st: SimTrace,
                     cp: Optional[CriticalPath] = None) -> Dict[str, object]:
    """The headline divergence: what is *binding* vs what is *busy*.

    Returns ``{"critical": {plane: share}, "busy": {plane: share},
    "divergence": total-variation distance}``.  A divergence of 0 means
    busy time is a faithful proxy for end-to-end impact; large values
    mean a utilization-driven balancer would optimise the wrong plane.
    """
    cp = cp if cp is not None else critical_path(st)
    crit = cp.critical_shares()
    busy = busy_shares(st)
    planes = set(crit) | set(busy)
    div = 0.5 * sum(abs(crit.get(p, 0.0) - busy.get(p, 0.0))
                    for p in planes)
    return {"critical": crit, "busy": busy, "divergence": div}


def mark_critical(st: SimTrace,
                  cp: Optional[CriticalPath] = None) -> CriticalPath:
    """Flag critical events in-place (``ev.args["critical"] = True``).

    `repro.obs.export.chrome_trace_events` renders flagged events as a
    distinct "critpath" Perfetto process so the blocking chain reads as
    one swim-lane.  Returns the (possibly freshly computed) path.
    """
    cp = cp if cp is not None else critical_path(st)
    on_path = {s.eid for s in cp.segments}
    for ev in st.events:
        if ev.eid in on_path:
            ev.args["critical"] = True
    return cp
