"""Observability: time-resolved tracing, metrics, and export.

The fifth layer of the repo — not a modelling plane but the
instrumentation the four planes (analytic `repro.core`, channel/MAC
`repro.net`, event-driven `repro.sim`, heterogeneous `repro.arch`)
share.  Everything here is zero-cost when disabled: the engines run
exactly their pre-instrumentation code paths unless a recorder is
requested (`PacketSim(..., record=True)`) or installed
(`with obs.recording(st): simulate_hybrid(...)`).

- `trace`      — `SimTrace`: per-packet begin/end events on every
  resource (mesh cut/link, wireless channel x reuse zone, DRAM port,
  compute), per-layer spans, derived queue-depth/utilization counters,
  and the active-recorder context the analytic plane emits into.
- `export`     — lossless export to Chrome Trace Event Format JSON
  (open directly in https://ui.perfetto.dev) and a compact ``.npz``
  round-trippable form for programmatic analysis.
- `metrics`    — label-keyed counter/gauge/histogram registry with a
  logging adapter and span timers; time-binned utilization timelines;
  the attribution report that decomposes each layer's span into
  service vs queueing vs quiescence per resource.
- `critpath`   — critical-path extraction over the recorded dependency
  DAG (`TraceEvent.deps`): which busy time actually *bounds* the
  makespan, per resource and per plane, against the raw busy shares.
- `whatif`     — trace-driven what-if projection: replay the recorded
  layer terms under scaled wireless/DRAM/wired resources or a new
  channel plan, with a re-simulation validation harness.
- `profile`    — the framework's *self*-time: a deterministic
  hierarchical phase profiler (`with profiling() as prof:`) with the
  same zero-cost-when-disabled structural guarantee as `SimTrace`;
  `prof.to_trace()` exports the phases as a "framework" Perfetto
  process next to the simulated-time planes.
- `report`     — the cross-run bench observatory (stdlib-only): MAD
  changepoint/drift detection over the `bench_history.jsonl` ledger
  and a self-contained inline-SVG HTML trend report
  (`benchmarks/history.py --detect / --html`).
- `provenance` — `dse.provenance` records (config hash, seed, wall
  time, points evaluated) stamped into every sweep result.
"""

from .critpath import (CriticalPath, CritSegment, busy_shares,
                       critical_path, critical_vs_busy, mark_critical)
from .export import (chrome_trace_events, export_chrome_trace, export_npz,
                     load_npz)
from .metrics import (DEFAULT_REGISTRY, MetricsRegistry, attribution_report,
                      attribution_summary, format_attribution, get_logger,
                      utilization_timeline)
from .profile import (PhaseProfiler, PhaseRecord, active_profiler,
                      note_ndarray, phase, profile_report, profiling)
from .provenance import config_hash, make_provenance
from .report import (build_html, detect_all, detect_series,
                     format_findings, history_series, write_html)
from .trace import SimTrace, TraceEvent, active_recorder, recording
from .whatif import Projection, WhatIf, project, project_grid, validate

__all__ = [
    "SimTrace", "TraceEvent", "active_recorder", "recording",
    "chrome_trace_events", "export_chrome_trace", "export_npz", "load_npz",
    "DEFAULT_REGISTRY", "MetricsRegistry", "attribution_report",
    "attribution_summary", "format_attribution", "get_logger",
    "utilization_timeline",
    "CriticalPath", "CritSegment", "busy_shares", "critical_path",
    "critical_vs_busy", "mark_critical",
    "Projection", "WhatIf", "project", "project_grid", "validate",
    "PhaseProfiler", "PhaseRecord", "active_profiler", "note_ndarray",
    "phase", "profile_report", "profiling",
    "build_html", "detect_all", "detect_series", "format_findings",
    "history_series", "write_html",
    "config_hash", "make_provenance",
]
