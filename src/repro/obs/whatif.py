"""Trace-driven what-if projection: replay a recorded run under scaled
resources without re-simulating.

A recorded `SimTrace` (PacketSim with ``record=True``) carries, per
layer, everything the GEMINI layer-max needs: the analytic compute /
NoC / DRAM floors as coarse spans, and every network transmission as a
per-server event with its bytes, source and hop span.  Projecting a
resource change is then a *re-aggregation*, not a re-simulation:

- **wireless bandwidth x k** — every wireless service time shrinks by
  ``1/k``; exact for the ideal MAC (service = bytes / channel rate).
- **channel count / zoning / policy** — each transmission is
  re-bucketed onto the server the new `ChannelPlan` would give its
  source (``src``/``hops`` args recorded for exactly this), and the
  per-layer wireless term is re-assembled as the planned costing does:
  ``max_c (t_global(c) + max_z t_zone(c, z))``.
- **DRAM / wired scaling** — the aggregate DRAM term and the per-server
  wired backlogs scale inversely with bandwidth.
- **xy -> striped link model** — per-link backlogs fold onto their cut
  (`cut_of_link` metadata) at the cut's parallel-link count; the
  reverse projection is impossible (striping erased the per-link
  assignment) and raises.

The projection is a *model of the model*: FIFO order and the paper's
eligibility/injection decisions are frozen at record time, and
non-ideal MAC overheads scale proportionally rather than being
re-quantised.  `validate` closes the loop — it re-simulates the same
knob with a real `PacketSim` and reports the projection error, and the
benchmark gate pins that error ≤ 10% for ±25% bandwidth perturbations
on every paper workload (tests/test_critpath.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.net.channel import ChannelPlan

from .trace import SimTrace

#: layer-term order, matching `repro.core.simulator.BOTTLENECKS`
TERMS = ("compute", "dram", "noc", "nop", "wireless")


@dataclasses.dataclass(frozen=True)
class WhatIf:
    """One projection knob set (identity by default).

    ``wireless_scale`` multiplies the aggregate wireless bandwidth;
    ``n_channels`` / ``reuse_zones`` / ``channel_policy`` re-bucket the
    recorded transmissions under a new `ChannelPlan` (None keeps the
    recorded plan); ``dram_scale`` / ``wired_scale`` multiply those
    planes' bandwidths; ``link_model="striped"`` re-projects an ``xy``
    trace onto the idealized striped wired plane.
    """

    wireless_scale: float = 1.0
    n_channels: Optional[int] = None
    reuse_zones: Optional[int] = None
    channel_policy: Optional[str] = None
    dram_scale: float = 1.0
    wired_scale: float = 1.0
    link_model: Optional[str] = None

    def describe(self) -> str:
        parts = []
        if self.wireless_scale != 1.0:
            parts.append(f"wl x{self.wireless_scale:g}")
        if self.n_channels is not None:
            parts.append(f"{self.n_channels}ch")
        if self.reuse_zones is not None:
            parts.append(f"x{self.reuse_zones}reuse")
        if self.channel_policy is not None:
            parts.append(self.channel_policy)
        if self.dram_scale != 1.0:
            parts.append(f"dram x{self.dram_scale:g}")
        if self.wired_scale != 1.0:
            parts.append(f"wired x{self.wired_scale:g}")
        if self.link_model is not None:
            parts.append(f"->{self.link_model}")
        return " ".join(parts) or "identity"


@dataclasses.dataclass
class Projection:
    """Projected outcome of one `WhatIf` replay."""

    knobs: WhatIf
    total_time: float
    layer_times: np.ndarray
    base_time: float
    bottleneck: List[str]

    @property
    def speedup(self) -> float:
        """Projected speedup over the recorded run (>1 = faster)."""
        return self.base_time / self.total_time if self.total_time else 1.0


def _layer_busy(st: SimTrace, cat: str, L: int) -> Dict[str, np.ndarray]:
    """track -> (L,) busy-seconds for one event category."""
    out: Dict[str, np.ndarray] = {}
    for ev in st.events:
        if ev.cat == cat and 0 <= ev.layer < L:
            out.setdefault(ev.track, np.zeros(L))[ev.layer] += ev.dur
    return out


def _coarse_terms(st: SimTrace, L: int) -> np.ndarray:
    """(3, L) compute / dram-agg / noc floors from the coarse spans."""
    out = np.zeros((3, L))
    rows = {"compute": 0, "dram-agg": 1, "noc": 2}
    for ev in st.events:
        row = rows.get(ev.cat)
        if row is not None and 0 <= ev.layer < L:
            out[row, ev.layer] += ev.dur
    return out


def _wired_term(st: SimTrace, knobs: WhatIf, L: int) -> np.ndarray:
    meta = st.meta
    busy = _layer_busy(st, "wired", L)
    remodel = (knobs.link_model is not None
               and knobs.link_model != meta.get("link_model"))
    if remodel:
        if knobs.link_model != "striped":
            raise ValueError(
                f"cannot project link model "
                f"{meta.get('link_model')!r} -> {knobs.link_model!r}: "
                "striping erased the per-link assignment; only "
                "xy/adaptive -> 'striped' is recoverable from a trace")
        cut_of_link = meta.get("cut_of_link")
        k_par = meta.get("k_par")
        if cut_of_link is None or k_par is None:
            raise ValueError("trace lacks cut_of_link/k_par metadata "
                             "needed to re-stripe the wired plane")
        folded: Dict[int, np.ndarray] = {}
        for track, b in busy.items():
            head = track.split("/", 1)[0]
            if head.startswith("link"):
                cut = int(cut_of_link[int(head[4:])])
            elif head.startswith("cut"):
                cut = int(head[3:])
            else:
                continue
            folded[cut] = folded.get(cut, np.zeros(L)) + b
        busy = {f"cut{c}": b / max(int(k_par[c]), 1)
                for c, b in folded.items()}
    if not busy:
        return np.zeros(L)
    return np.max(np.stack(list(busy.values())), axis=0) \
        / knobs.wired_scale


def _wireless_term(st: SimTrace, knobs: WhatIf, L: int) -> np.ndarray:
    meta = st.meta
    evs = [ev for ev in st.events
           if ev.cat == "wireless" and 0 <= ev.layer < L]
    if not evs:
        return np.zeros(L)
    rebucket = (knobs.n_channels is not None
                or knobs.reuse_zones is not None
                or knobs.channel_policy is not None)
    if not rebucket:
        # same plan, scaled rates: per-server busy shrinks uniformly,
        # reassembled as max_c (global + max_z zone)
        g: Dict[int, np.ndarray] = {}
        z: Dict[str, np.ndarray] = {}
        for ev in evs:
            head = ev.track.split("/", 1)[0]
            if ev.track.endswith("/g"):
                g.setdefault(int(head[2:]), np.zeros(L))[ev.layer] += ev.dur
            else:
                z.setdefault(ev.track, np.zeros(L))[ev.layer] += ev.dur
        per_ch: Dict[int, np.ndarray] = {}
        for track, b in z.items():
            c = int(track.split("/", 1)[0][2:])
            per_ch[c] = np.maximum(per_ch.get(c, np.zeros(L)), b)
        t = np.zeros(L)
        for c in sorted(set(g) | set(per_ch)):
            t = np.maximum(t, g.get(c, np.zeros(L))
                           + per_ch.get(c, np.zeros(L)))
        return t / knobs.wireless_scale
    # re-bucket each transmission under the new plan
    for key in ("n_nodes", "grid", "bandwidth", "n_channels",
                "reuse_zones", "channel_policy", "node_coords"):
        if key not in meta:
            raise ValueError(f"trace lacks {key!r} metadata needed to "
                             "re-bucket the wireless plane")
    old_plan = ChannelPlan(meta["n_channels"], meta["channel_policy"],
                           reuse_zones=meta["reuse_zones"])
    new_plan = ChannelPlan(
        knobs.n_channels if knobs.n_channels is not None
        else meta["n_channels"],
        knobs.channel_policy if knobs.channel_policy is not None
        else meta["channel_policy"],
        reuse_zones=knobs.reuse_zones if knobs.reuse_zones is not None
        else meta["reuse_zones"])
    bw = meta["bandwidth"]
    rate = (old_plan.channel_bandwidth(bw)
            / new_plan.channel_bandwidth(bw * knobs.wireless_scale))
    n_nodes, grid = meta["n_nodes"], tuple(meta["grid"])
    coords = np.asarray(meta["node_coords"], np.int64)
    ch_of = new_plan.assign(n_nodes)
    Z = new_plan.reuse_zones
    if Z > 1:
        zone_of, rd = new_plan.assign_spatial(grid, coords)
    else:
        zone_of, rd = np.zeros(n_nodes, np.int64), None
    C = new_plan.n_channels
    g = np.zeros((L, C))
    zb = np.zeros((L, C, Z))
    for ev in evs:
        src = ev.args.get("src")
        if src is None:
            raise ValueError("wireless event lacks the src arg needed "
                             "to re-bucket (trace predates deps?)")
        c = int(ch_of[src])
        dur = ev.dur * rate
        if Z > 1 and ev.args.get("hops", 0) > rd:
            g[ev.layer, c] += dur
        else:
            zb[ev.layer, c, int(zone_of[src]) if Z > 1 else 0] += dur
    return (g + zb.max(axis=2)).max(axis=1)


def project(st: SimTrace, knobs: WhatIf) -> Projection:
    """Replay the recorded layer terms under ``knobs``.

    A degenerate (empty) trace projects to a zero-time run rather than
    raising, matching the repo-wide empty-structure convention.
    """
    times = st.meta.get("layer_times") or []
    L = len(times)
    base = float(sum(times))
    if L == 0:
        return Projection(knobs, 0.0, np.zeros(0), base, [])
    coarse = _coarse_terms(st, L)
    stack = np.stack([coarse[0],
                      coarse[1] / knobs.dram_scale,
                      coarse[2],
                      _wired_term(st, knobs, L),
                      _wireless_term(st, knobs, L)])
    layer_times = stack.max(axis=0)
    which = stack.argmax(axis=0)
    return Projection(knobs, float(layer_times.sum()), layer_times, base,
                      [TERMS[i] for i in which])


def project_grid(st: SimTrace,
                 knob_sets: List[WhatIf]) -> List[Projection]:
    """One projection per knob set (ordering preserved)."""
    return [project(st, k) for k in knob_sets]


# ---------------------------------------------------------------------------
# validation harness: projection vs actual re-simulation
# ---------------------------------------------------------------------------

def apply_to_network(net, knobs: WhatIf):
    """The `NetworkConfig` a re-simulation of ``knobs`` should use.

    Only the wireless knobs map onto a network config; DRAM / wired
    scaling and link-model changes alter the *accelerator* geometry and
    are selected on the `PacketSim` itself (``link_model=``) or are not
    re-simulable from a config change — those raise here.
    """
    from repro.net.config import as_network
    if knobs.dram_scale != 1.0 or knobs.wired_scale != 1.0:
        raise ValueError("dram/wired scaling changes the accelerator "
                         "config, not the network config; rebuild the "
                         "trace to validate those knobs")
    net = as_network(net)
    plan = net.channels
    new_plan = ChannelPlan(
        knobs.n_channels if knobs.n_channels is not None
        else plan.n_channels,
        knobs.channel_policy if knobs.channel_policy is not None
        else plan.policy,
        bandwidth_per_channel=plan.bandwidth_per_channel,
        reuse_zones=knobs.reuse_zones if knobs.reuse_zones is not None
        else plan.reuse_zones,
        reuse_distance=plan.reuse_distance)
    return dataclasses.replace(
        net, bandwidth=net.bandwidth * knobs.wireless_scale,
        channels=new_plan)


def validate(traffic, net, knobs: WhatIf, *, policy="static",
             link_model: str = "striped",
             dram_model: str = "pooled") -> Dict[str, float]:
    """Record a base run, project ``knobs``, re-simulate, compare.

    Returns ``{"projected", "actual", "base", "error"}`` where
    ``error = |projected - actual| / actual``.  The re-simulation runs
    the SAME policy under the modified network, so for online policies
    the error includes genuine decision drift, not just model error.
    """
    from repro.sim.engine import PacketSim
    base = PacketSim(traffic, net, link_model=link_model,
                     dram_model=dram_model, record=True).run(policy)
    proj = project(base.trace, knobs)
    actual = PacketSim(traffic, apply_to_network(net, knobs),
                       link_model=link_model,
                       dram_model=dram_model).run(policy)
    err = (abs(proj.total_time - actual.total_time) / actual.total_time
           if actual.total_time else 0.0)
    return {"projected": proj.total_time, "actual": actual.total_time,
            "base": base.total_time, "error": err}
