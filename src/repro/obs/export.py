"""Export a `SimTrace` to Chrome Trace Event Format JSON and ``.npz``.

The JSON form (https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU)
opens directly in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing``: one process per plane (layers / compute / wired
NoP / wireless / DRAM / ...), one thread per resource track, complete
("X") events per transmission, and counter ("C") tracks for queue
depth, per-resource utilization, and per-plane injected bytes.
Timestamps are microseconds (the format's unit) as float64 — Perfetto
renders nanosecond-scale durations fine.

The ``.npz`` form is the lossless programmatic counterpart: raw
float64 seconds, columnar arrays, `load_npz` round-trips exactly
(pinned in tests/test_obs.py).
"""

from __future__ import annotations

import json
from typing import Dict, List, Union

import numpy as np

from repro.units import s_to_us

from .trace import SimTrace, TraceEvent

# plane -> process id (Perfetto sorts by pid; layers on top)
_PLANE_PIDS = {
    "layer": 0, "compute": 1, "noc": 2, "dram-agg": 3,
    "wired": 4, "wireless": 5, "dram": 6, "balancer": 7,
}
_CRIT_PID = 8             # critical-path swim-lane (obs.critpath marks)
_COUNTER_PID = 9
_OTHER_PID = 10           # unrecognised planes (was colliding with
#                           the counter pid when it was len(_PLANE_PIDS))
_FRAMEWORK_PID = 11       # self-profiling phases (obs.profile.to_trace):
#                           the framework's own wall time renders as its
#                           own process under the simulated-time planes
_PLANE_PIDS["framework"] = _FRAMEWORK_PID
_PID_STRIDE = 16          # per-trace offset when merging several traces


def _plane(cat: str) -> str:
    """Fold analytic categories onto their plane (``an:wireless`` ...)."""
    return cat.split(":", 1)[1] if cat.startswith("an:") else cat


def chrome_trace_events(
        traces: Union[SimTrace, Dict[str, SimTrace]]) -> dict:
    """The Chrome Trace Event JSON object for one or several traces.

    A dict merges multiple traces (e.g. ``{"event": ev.trace,
    "analytic": st}``) into one view with per-trace process groups, so
    analytic vs event discrepancies are visually diffable track by
    track.
    """
    if isinstance(traces, SimTrace):
        traces = {traces.label: traces}
    events: List[dict] = []
    for gi, (glabel, st) in enumerate(traces.items()):
        base = gi * _PID_STRIDE
        tids: Dict[tuple, int] = {}
        pids_used: Dict[int, str] = {}

        def tid_of(pid: int, track: str) -> int:
            key = (pid, track)
            if key not in tids:
                tids[key] = len([k for k in tids if k[0] == pid]) + 1
                events.append({"ph": "M", "pid": pid, "tid": tids[key],
                               "name": "thread_name",
                               "args": {"name": track}})
            return tids[key]

        def pid_of(plane: str, pid_override: int | None = None) -> int:
            pid = base + (_PLANE_PIDS.get(plane, _OTHER_PID)
                          if pid_override is None else pid_override)
            if pid not in pids_used:
                pids_used[pid] = plane
                events.append({"ph": "M", "pid": pid, "name": "process_name",
                               "args": {"name": f"{glabel}: {plane}"}})
                events.append({"ph": "M", "pid": pid,
                               "name": "process_sort_index",
                               "args": {"sort_index": pid}})
            return pid

        for ev in st.events:
            pid = pid_of(_plane(ev.cat) or "other")
            args = dict(ev.args)
            if ev.layer >= 0:
                args["layer"] = ev.layer
            events.append({
                "ph": "X", "name": ev.name, "cat": ev.cat or "event",
                "pid": pid, "tid": tid_of(pid, ev.track),
                "ts": s_to_us(ev.ts), "dur": s_to_us(ev.dur),
                "args": args,
            })
            if ev.args.get("critical"):
                # mirror onto the critical-path process so the blocking
                # chain (obs.critpath.mark_critical) reads as one
                # swim-lane in Perfetto
                crit = pid_of("critpath", _CRIT_PID)
                events.append({
                    "ph": "X", "name": f"{ev.name}@{ev.track}",
                    "cat": "critpath", "pid": crit,
                    "tid": tid_of(crit, "critical path"),
                    "ts": s_to_us(ev.ts), "dur": s_to_us(ev.dur),
                    "args": args,
                })
        cpid = base + _COUNTER_PID
        for track, samples in sorted(st.counters.items()):
            if samples and cpid not in pids_used:
                pids_used[cpid] = "counters"
                events.append({"ph": "M", "pid": cpid,
                               "name": "process_name",
                               "args": {"name": f"{glabel}: counters"}})
            for ts, value in samples:
                events.append({"ph": "C", "name": track, "pid": cpid,
                               "tid": 0, "ts": s_to_us(ts),
                               "args": {"value": value}})
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {lbl: st.meta for lbl, st in traces.items()}}


def export_chrome_trace(traces: Union[SimTrace, Dict[str, SimTrace]],
                        path: str) -> dict:
    """Write the Chrome Trace JSON to ``path`` and return the object."""
    obj = chrome_trace_events(traces)
    with open(path, "w") as f:
        json.dump(obj, f)
    return obj


# ---------------------------------------------------------------------------
# compact .npz round trip
# ---------------------------------------------------------------------------

def export_npz(st: SimTrace, path: str) -> None:
    """Columnar, lossless ``.npz`` of one trace (see `load_npz`)."""
    tracks = sorted({ev.track for ev in st.events})
    t_idx = {t: i for i, t in enumerate(tracks)}
    cats = sorted({ev.cat for ev in st.events})
    c_idx = {c: i for i, c in enumerate(cats)}
    args = [json.dumps(ev.args, sort_keys=True) if ev.args else ""
            for ev in st.events]
    ctracks = sorted(st.counters)
    csamples = [np.asarray(st.counters[t], float).reshape(-1, 2)
                for t in ctracks]
    np.savez_compressed(
        path,
        label=np.array(st.label),
        meta=np.array(json.dumps(st.meta, sort_keys=True)),
        tracks=np.array(tracks, dtype=object),
        cats=np.array(cats, dtype=object),
        ev_track=np.array([t_idx[ev.track] for ev in st.events], np.int32),
        ev_cat=np.array([c_idx[ev.cat] for ev in st.events], np.int32),
        ev_name=np.array([ev.name for ev in st.events], dtype=object),
        ev_ts=np.array([ev.ts for ev in st.events]),
        ev_dur=np.array([ev.dur for ev in st.events]),
        ev_layer=np.array([ev.layer for ev in st.events], np.int32),
        ev_args=np.array(args, dtype=object),
        ev_eid=np.array([ev.eid for ev in st.events], np.int64),
        # ragged dependency lists stored flat + per-event lengths
        ev_dep_lens=np.array([len(ev.deps) for ev in st.events], np.int64),
        ev_deps=np.array([d for ev in st.events for d in ev.deps],
                         np.int64),
        counter_tracks=np.array(ctracks, dtype=object),
        counter_lens=np.array([len(s) for s in csamples], np.int64),
        counter_samples=(np.concatenate(csamples) if csamples
                         else np.zeros((0, 2))),
    )


def load_npz(path: str) -> SimTrace:
    """Inverse of `export_npz`, exact to the last float64 bit."""
    with np.load(path, allow_pickle=True) as z:
        st = SimTrace(label=str(z["label"]))
        st.meta = json.loads(str(z["meta"]))
        tracks = list(z["tracks"])
        cats = list(z["cats"])
        n = len(z["ev_ts"])
        # eid/deps columns absent in pre-critpath archives: default to
        # the unrecorded sentinel (-1, no deps)
        eids = z["ev_eid"] if "ev_eid" in z else np.full(n, -1, np.int64)
        if "ev_dep_lens" in z:
            bounds = np.concatenate([[0], np.cumsum(z["ev_dep_lens"])])
            flat = z["ev_deps"]
            deps = [flat[bounds[i]:bounds[i + 1]].tolist()
                    for i in range(n)]
        else:
            deps = [[] for _ in range(n)]
        for i, (ti, ci, name, ts, dur, layer, args) in enumerate(zip(
                z["ev_track"], z["ev_cat"], z["ev_name"], z["ev_ts"],
                z["ev_dur"], z["ev_layer"], z["ev_args"])):
            st.events.append(TraceEvent(
                str(tracks[ti]), str(name), float(ts), float(dur),
                str(cats[ci]), int(layer),
                json.loads(args) if args else {},
                int(eids[i]), [int(d) for d in deps[i]]))
        st._next_eid = int(eids.max()) + 1 if n and eids.max() >= 0 else 0
        pos = 0
        for track, n in zip(z["counter_tracks"], z["counter_lens"]):
            chunk = z["counter_samples"][pos:pos + int(n)]
            st.counters[str(track)] = [(float(a), float(b))
                                       for a, b in chunk]
            pos += int(n)
    return st
