"""`dse.provenance`: reproducibility records stamped into sweep results.

Every expensive search path (`dse.sweep_all`, `policy_sweep_all`,
`scaling_sweep`, the placement annealer, ...) attaches a provenance
dict — stable config hash, seed, points evaluated, wall time — so a
committed result can be traced back to exactly what produced it and
compared run-over-run without diffing float payloads.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Optional

import numpy as np


def _stable(obj: Any) -> Any:
    """A deterministic, order-independent representation of ``obj``."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {"__dataclass__": type(obj).__name__,
                **{f.name: _stable(getattr(obj, f.name))
                   for f in dataclasses.fields(obj)}}
    if isinstance(obj, dict):
        return {str(k): _stable(v) for k, v in sorted(obj.items(),
                                                      key=lambda kv:
                                                      str(kv[0]))}
    if isinstance(obj, (list, tuple)):
        return [_stable(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return {"__ndarray__": str(obj.dtype), "data": obj.tolist()}
    if isinstance(obj, (np.integer, np.floating, np.bool_)):
        return obj.item()
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return repr(obj)


def config_hash(obj: Any) -> str:
    """Short sha256 of the stable representation of any config object."""
    h = hashlib.sha256(repr(_stable(obj)).encode()).hexdigest()
    return h[:16]


def make_provenance(kind: str, config: Any, *,
                    seed: Optional[int] = None, points: int = 0,
                    wall_s: float = 0.0) -> dict:
    """The `dse.provenance` record attached to sweep results."""
    return {
        "kind": kind,
        "config_hash": config_hash(config),
        "seed": seed,
        "points_evaluated": int(points),
        "wall_time_s": float(wall_s),
    }
