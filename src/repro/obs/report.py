"""Cross-run bench observatory: trend report + robust drift detection.

The `benchmarks/run.py` ledger (`experiments/bench_history.jsonl`, one
JSONL entry per (run, row)) is the repo's long-horizon perf memory —
the paper's 10%-mean / 20%-max speedup claims are only trustworthy if
they hold run over run.  This module turns the ledger into:

- `detect_all` / `detect_series` — a robust MAD (median absolute
  deviation) changepoint/drift detector over every (row, metric)
  series.  Medians and MAD instead of mean/stddev: a single outlier
  run must neither trigger nor mask a real shift.  Two finding kinds:

  * ``drift``       — the latest value's robust z-score against the
    history before it exceeds ``threshold``;
  * ``changepoint`` — some split of the series separates two segments
    whose medians differ by more than ``threshold`` robust scales
    (a sustained level shift, not just a bad last run).

  `benchmarks/history.py --detect` exits non-zero when any series is
  flagged.  Wall-time (``us_per_call``) series are *rendered* but not
  *gated* by default — machine-to-machine wall noise must not fail CI;
  pass ``include_wall=True`` (``--include-wall``) to gate them too.

- `build_html` / `write_html` — a self-contained static HTML report:
  one section per row with inline-SVG trend charts per metric, the
  wall-time trajectory, flagged points marked, and a per-entry table
  (UTC timestamp, wall time, derived string, provenance config hash).
  No JavaScript, no external assets, byte-deterministic for the same
  inputs.

Everything here is **pure stdlib** (like `repro.lint`): the observatory
must be able to judge a checkout where the scientific stack is broken —
that is precisely when you need it.  Loading the ledger itself stays in
`benchmarks/run.py` (`load_history`); this module only transforms
already-parsed entries.
"""

from __future__ import annotations

import datetime
import html as _html
import math
from statistics import median
from typing import Dict, List, Optional, Tuple

#: metric key under which an entry's wall time is folded into the
#: series map (distinct from any parse_derived key, which never starts
#: with an underscore-free "us_" today but keep it collision-proof)
WALL_METRIC = "us_per_call"

#: detector defaults: 4 robust scales, at least 5 points of history
DEFAULT_THRESHOLD = 4.0
DEFAULT_MIN_POINTS = 5
#: MAD floor, relative to the series median: a perfectly constant
#: history gets a tiny tolerance band instead of a zero one, so exact
#: repeats stay clean while any genuine move is (correctly) flagged
REL_FLOOR = 1e-9


# ---------------------------------------------------------------------------
# series extraction
# ---------------------------------------------------------------------------

def history_series(entries: List[dict]
                   ) -> Dict[Tuple[str, str], List[dict]]:
    """(row, metric) -> chronological points ``{ts, value, hash}``.

    Includes each entry's wall time as metric `WALL_METRIC`.  Entries
    without a ``row`` or with non-numeric values are skipped — the
    ledger's torn-line tolerance extends to torn fields.
    """
    out: Dict[Tuple[str, str], List[dict]] = {}

    def push(row: str, metric: str, value, e: dict) -> None:
        try:
            v = float(value)
        except (TypeError, ValueError):
            return
        if not math.isfinite(v):
            return
        out.setdefault((row, metric), []).append(
            {"ts": float(e.get("ts") or 0.0), "value": v,
             "hash": str(e.get("hash", ""))})

    for e in entries:
        row = e.get("row")
        if not row:
            continue
        if "us_per_call" in e:
            push(row, WALL_METRIC, e["us_per_call"], e)
        for k, v in (e.get("metrics") or {}).items():
            push(row, k, v, e)
    return out


# ---------------------------------------------------------------------------
# robust detection
# ---------------------------------------------------------------------------

def _mad(values: List[float], center: Optional[float] = None) -> float:
    """Median absolute deviation (unscaled)."""
    if not values:
        return 0.0
    c = median(values) if center is None else center
    return median([abs(v - c) for v in values])


def _scale(values: List[float], center: Optional[float] = None) -> float:
    """MAD as a robust sigma (x1.4826, the normal-consistency factor),
    floored relative to the median so constant series keep a band."""
    c = median(values) if center is None else center
    return max(1.4826 * _mad(values, c), REL_FLOOR * max(abs(c), 1.0))


def detect_series(values: List[float],
                  threshold: float = DEFAULT_THRESHOLD,
                  min_points: int = DEFAULT_MIN_POINTS,
                  min_segment: int = 3) -> List[dict]:
    """Findings for one chronological series (empty list = clean).

    - ``drift``: robust z-score of the last point against all earlier
      points exceeds ``threshold``.
    - ``changepoint``: the best split into two segments (each at least
      ``min_segment`` long) separates medians by more than
      ``threshold`` robust scales; the reported index is the first
      point of the new level.

    Series shorter than ``min_points`` are skipped — a young ledger
    (including the single committed seed entry) is always clean.
    """
    n = len(values)
    if n < min_points:
        return []
    findings = []
    head, last = values[:-1], values[-1]
    med = median(head)
    z = abs(last - med) / _scale(head, med)
    if z > threshold:
        findings.append({"kind": "drift", "index": n - 1, "value": last,
                         "baseline": med, "score": z})
    best = None
    best_cost = math.inf
    for k in range(min_segment, n - min_segment + 1):
        left, right = values[:k], values[k:]
        ml, mr = median(left), median(right)
        spread = max(_scale(left, ml), _scale(right, mr))
        score = abs(mr - ml) / spread
        if score <= threshold:
            continue
        # among above-threshold splits, place the boundary where the
        # two segments are most internally homogeneous (robust L1
        # cost); raw score alone ties on flat segments and would put
        # the boundary at the first admissible split
        cost = (sum(abs(v - ml) for v in left)
                + sum(abs(v - mr) for v in right))
        if best is None or cost < best_cost or (cost == best_cost
                                                and score > best["score"]):
            best = {"kind": "changepoint", "index": k, "value": mr,
                    "baseline": ml, "score": score}
            best_cost = cost
    if best is not None:
        findings.append(best)
    return findings


def detect_all(entries: List[dict],
               threshold: float = DEFAULT_THRESHOLD,
               min_points: int = DEFAULT_MIN_POINTS,
               include_wall: bool = False) -> List[dict]:
    """Detector over every (row, metric) series of the ledger.

    Returns one finding dict per flagged (series, kind):
    ``{row, metric, kind, index, ts, hash, value, baseline, score}``.
    Wall-time series are excluded unless ``include_wall`` (see module
    docstring).
    """
    findings = []
    for (row, metric), pts in sorted(history_series(entries).items()):
        if metric == WALL_METRIC and not include_wall:
            continue
        for f in detect_series([p["value"] for p in pts], threshold,
                               min_points):
            at = pts[f["index"]]
            findings.append(dict(f, row=row, metric=metric,
                                 ts=at["ts"], hash=at["hash"]))
    findings.sort(key=lambda f: -f["score"])
    return findings


def format_findings(findings: List[dict]) -> str:
    """Readable table of `detect_all` findings ('' when clean)."""
    if not findings:
        return ""
    lines = [f"{len(findings)} flagged series "
             f"(robust MAD detector):"]
    for f in findings:
        lines.append(
            f"  {f['row']}.{f['metric']}: {f['kind']} at run "
            f"#{f['index']} — {f['baseline']:g} -> {f['value']:g} "
            f"(score {f['score']:.1f}, hash {f['hash'] or '-'})")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# inline-SVG trend charts
# ---------------------------------------------------------------------------

def _svg_trend(pts: List[dict], flagged: set, width: int = 320,
               height: int = 64) -> str:
    """One series as a self-contained inline SVG: a line through every
    run, points on top, flagged runs highlighted."""
    values = [p["value"] for p in pts]
    lo, hi = min(values), max(values)
    pad = 6.0
    span = (hi - lo) or max(abs(hi), 1.0) * 1e-6

    def x(i: int) -> float:
        return pad + (width - 2 * pad) * (i / max(len(values) - 1, 1))

    def y(v: float) -> float:
        return height - pad - (height - 2 * pad) * ((v - lo) / span)

    path = " ".join(f"{'M' if i == 0 else 'L'}{x(i):.1f},{y(v):.1f}"
                    for i, v in enumerate(values))
    dots = []
    for i, v in enumerate(values):
        flag = i in flagged
        dots.append(
            f'<circle cx="{x(i):.1f}" cy="{y(v):.1f}" '
            f'r="{4 if flag else 2}" '
            f'fill="{"#c0392b" if flag else "#2c5f8a"}">'
            f'<title>run {i}: {v:g}</title></circle>')
    return (
        f'<svg viewBox="0 0 {width} {height}" width="{width}" '
        f'height="{height}" role="img">'
        f'<rect width="{width}" height="{height}" fill="#f7f8fa"/>'
        f'<path d="{path}" fill="none" stroke="#2c5f8a" '
        f'stroke-width="1.5"/>' + "".join(dots) + "</svg>")


# ---------------------------------------------------------------------------
# the HTML observatory
# ---------------------------------------------------------------------------

_CSS = """
body { font: 14px/1.45 -apple-system, 'Segoe UI', Roboto, sans-serif;
       color: #1f2430; margin: 2rem auto; max-width: 70rem;
       padding: 0 1rem; }
h1 { font-size: 1.4rem; } h2 { font-size: 1.1rem; margin-top: 2rem;
       border-bottom: 1px solid #d7dbe2; padding-bottom: .2rem; }
table { border-collapse: collapse; margin: .5rem 0 1rem; width: 100%; }
th, td { text-align: left; padding: .25rem .6rem; font-size: 13px;
       border-bottom: 1px solid #e4e7ec; vertical-align: top; }
th { color: #5a6372; font-weight: 600; }
code { font: 12px ui-monospace, monospace; background: #f0f2f5;
       padding: 0 .25rem; border-radius: 3px; }
.metric { display: inline-block; margin: .4rem 1.2rem .4rem 0;
       vertical-align: top; }
.metric .name { font-size: 12px; color: #5a6372; }
.metric .val { font-size: 13px; }
.flag { color: #c0392b; font-weight: 600; }
.ok { color: #1e7f4f; font-weight: 600; }
.muted { color: #8a93a3; font-size: 12px; }
"""


def _iso(ts: float) -> str:
    if not ts:
        return "-"
    return datetime.datetime.fromtimestamp(
        ts, tz=datetime.timezone.utc).strftime("%Y-%m-%d %H:%M:%SZ")


def build_html(entries: List[dict], results: Optional[dict] = None,
               title: str = "bench observatory",
               threshold: float = DEFAULT_THRESHOLD,
               min_points: int = DEFAULT_MIN_POINTS) -> str:
    """The full self-contained observatory document as a string.

    ``entries`` is the parsed ledger (`benchmarks.run.load_history`);
    ``results`` the committed ``bench_results.json`` object (its
    ``_bench_meta`` block supplies the committed reference line per
    row).  Deterministic: same inputs, same bytes.
    """
    esc = _html.escape
    series = history_series(entries)
    findings = detect_all(entries, threshold, min_points,
                          include_wall=True)
    flagged: Dict[Tuple[str, str], set] = {}
    for f in findings:
        flagged.setdefault((f["row"], f["metric"]), set()).add(f["index"])
    rows = sorted({r for r, _ in series})
    meta = (results or {}).get("_bench_meta", {})
    by_row: Dict[str, List[dict]] = {}
    for e in entries:
        if e.get("row"):
            by_row.setdefault(e["row"], []).append(e)
    last_ts = max((float(e.get("ts") or 0.0) for e in entries),
                  default=0.0)

    out = [
        "<!DOCTYPE html><html><head><meta charset='utf-8'>",
        f"<title>{esc(title)}</title><style>{_CSS}</style></head><body>",
        f"<h1>{esc(title)}</h1>",
        f"<p class='muted'>{len(entries)} ledger entries · "
        f"{len(rows)} rows · latest run {_iso(last_ts)} · robust-MAD "
        f"threshold {threshold:g} (min {min_points} points)</p>",
    ]

    if findings:
        out.append(f"<p class='flag'>{len(findings)} flagged "
                   "series</p><table><tr><th>row</th><th>metric</th>"
                   "<th>kind</th><th>baseline</th><th>value</th>"
                   "<th>score</th><th>hash</th></tr>")
        for f in findings:
            out.append(
                f"<tr><td>{esc(f['row'])}</td><td>{esc(f['metric'])}"
                f"</td><td class='flag'>{esc(f['kind'])}</td>"
                f"<td>{f['baseline']:g}</td><td>{f['value']:g}</td>"
                f"<td>{f['score']:.1f}</td>"
                f"<td><code>{esc(f['hash'] or '-')}</code></td></tr>")
        out.append("</table>")
    else:
        out.append("<p class='ok'>no drift flagged</p>")

    for row in rows:
        committed = meta.get(row, {})
        out.append(f"<h2>{esc(row)}</h2>")
        if committed:
            out.append(
                "<p class='muted'>committed: "
                f"<code>{esc(str(committed.get('derived', '')))}</code>"
                f" · wall {float(committed.get('us_per_call', 0.0)):,.0f}"
                " us/call</p>")
        metrics = sorted(m for r, m in series if r == row)
        # wall-time trend first, then the derived metrics
        metrics.sort(key=lambda m: (m != WALL_METRIC, m))
        for m in metrics:
            pts = series[(row, m)]
            fl = flagged.get((row, m), set())
            label = "wall (us/call)" if m == WALL_METRIC else m
            cls = " flag" if fl else ""
            out.append(
                f"<div class='metric'><div class='name{cls}'>"
                f"{esc(label)}</div>"
                + _svg_trend(pts, fl)
                + f"<div class='val'>{pts[0]['value']:g} &rarr; "
                f"{pts[-1]['value']:g} <span class='muted'>"
                f"(n={len(pts)})</span></div></div>")
        out.append("<table><tr><th>run (UTC)</th><th>wall us/call</th>"
                   "<th>derived</th><th>config hash</th></tr>")
        for e in by_row.get(row, []):
            out.append(
                f"<tr><td>{_iso(float(e.get('ts') or 0.0))}</td>"
                f"<td>{float(e.get('us_per_call') or 0.0):,.0f}</td>"
                f"<td><code>{esc(str(e.get('derived', '')))}</code></td>"
                f"<td><code>{esc(str(e.get('hash', '') or '-'))}</code>"
                "</td></tr>")
        out.append("</table>")

    out.append("<p class='muted'>generated by repro.obs.report — "
               "stdlib-only, deterministic for the same ledger</p>")
    out.append("</body></html>")
    return "".join(out)


def write_html(path: str, entries: List[dict],
               results: Optional[dict] = None, **kwargs) -> str:
    """Write `build_html` to ``path``; returns the document."""
    doc = build_html(entries, results, **kwargs)
    with open(path, "w", encoding="utf-8") as f:
        f.write(doc)
    return doc
