"""`SimTrace`: the time-resolved event recorder shared by all planes.

A trace is a flat list of `TraceEvent`s — one per transmission served
on one resource — plus counter samples and free-form metadata.  Tracks
are resource names; the repo-wide naming convention is

- ``cut{c}``            a mesh cut's striped link bundle (striped model)
- ``cut{c}/l{j}``       parallel slot ``j`` of cut ``c`` (adaptive model)
- ``link{i}``           one directed mesh link (xy model)
- ``ch{c}``             a wireless channel (no spatial reuse)
- ``ch{c}/z{z}``        reuse zone ``z``'s server of channel ``c``
- ``ch{c}/g``           channel ``c``'s global (beyond-reuse-distance)
  phase, which quiesces every zone of the channel
- ``dram{d}``           one DRAM module's port
- ``compute`` / ``noc`` / ``dram(pooled)``   the analytic per-layer
  aggregate floors (package-level, as the GEMINI model costs them)
- ``layers``            one span per layer, named ``L{i}:{bottleneck}``
- ``balance``           the balancer's per-layer stitch decision

Categories (`TraceEvent.cat`) group tracks into planes: ``wired``,
``wireless``, ``dram``, ``compute``, ``noc``, ``dram-agg``, ``layer``,
``balancer``.  Analytic emitters reuse the same tracks with an
``an:`` category prefix (``an:wireless`` ...), so an event-engine
trace and an analytic trace of the same run line up track-for-track
when merged into one Perfetto view.

Both the event engine and the analytic plane know event times only
*relative to their layer's start* until all per-layer maxima are in;
`add_layer_event` therefore records pending (layer, offset) events and
`place_layers(layer_times)` shifts them onto the absolute timeline
under the GEMINI barrier (layer ``l`` starts when layer ``l-1``
drains).

The **active recorder** is how the analytic plane records without
threading a parameter through every signature: ``with recording(st):``
installs ``st``; `repro.net.stack` and `repro.core.balancer` emit
coarse spans into it when present (and suppress their internal trial
evaluations with ``recording(None)``).  When no recorder is installed
the emitters cost one ``None`` check.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

#: resource-plane categories an event-engine trace uses
RESOURCE_CATS = ("wired", "wireless", "dram")


@dataclasses.dataclass
class TraceEvent:
    """One transmission served on one resource (begin + duration).

    ``eid`` is the event's id within its trace (assigned by the
    recorder, dense from 0); ``deps`` lists the eids of the events
    whose completion gates this event's begin — the FIFO predecessor
    on the same server, the channel-global transmission a reuse zone
    queued behind, or the zone transmissions a global quiesce waited
    out.  An event with no deps begins at its layer's barrier.  The
    dependency DAG these edges span is what `repro.obs.critpath`
    walks to extract the critical path.
    """

    track: str
    name: str
    ts: float                 # seconds, absolute (post `place_layers`)
    dur: float                # seconds
    cat: str = ""
    layer: int = -1
    args: dict = dataclasses.field(default_factory=dict)
    eid: int = -1
    deps: List[int] = dataclasses.field(default_factory=list)

    @property
    def end(self) -> float:
        return self.ts + self.dur


class SimTrace:
    """Recorder: events + counters + metadata for one run."""

    def __init__(self, label: str = "sim"):
        self.label = label
        self.events: List[TraceEvent] = []
        # counter track -> [(ts, value)] samples
        self.counters: Dict[str, List[Tuple[float, float]]] = {}
        self.meta: dict = {}
        self._pending: List[TraceEvent] = []
        self._next_eid = 0

    # ------------------------------------------------------------------
    # recording
    # ------------------------------------------------------------------

    def _new_event(self, track, name, ts, dur, cat, layer, deps,
                   args) -> TraceEvent:
        ev = TraceEvent(track, name, float(ts), float(dur), cat,
                        int(layer), args, self._next_eid,
                        [int(d) for d in deps] if deps else [])
        self._next_eid += 1
        return ev

    def add(self, track: str, name: str, ts: float, dur: float,
            cat: str = "", layer: int = -1, deps=(), **args) -> int:
        """One absolutely-placed event; returns its eid."""
        ev = self._new_event(track, name, ts, dur, cat, layer, deps, args)
        self.events.append(ev)
        return ev.eid

    def add_layer_event(self, track: str, name: str, layer: int,
                        rel_start: float, dur: float, cat: str = "",
                        deps=(), **args) -> int:
        """One event at ``rel_start`` seconds after its layer's start.

        Pending until `place_layers` supplies the per-layer maxima that
        fix the layer starts.  Returns the event's eid so emitters can
        thread it into successors' ``deps``.
        """
        ev = self._new_event(track, name, rel_start, dur, cat, layer,
                             deps, args)
        self._pending.append(ev)
        return ev.eid

    def add_layer_matrix(self, mat: np.ndarray, fmt: str, cat: str,
                         name: str = "span") -> None:
        """Pending spans from a (n_layers, n_tracks) duration matrix.

        Column ``c`` goes to track ``fmt.format(c)``; zero durations
        are skipped.  The coarse-span form the analytic plane emits.
        """
        lay, col = np.nonzero(mat)
        for li, c in zip(lay, col):
            self.add_layer_event(fmt.format(c), name, int(li), 0.0,
                                 float(mat[li, c]), cat)

    def add_counter(self, track: str, ts: float, value: float) -> None:
        self.counters.setdefault(track, []).append((float(ts),
                                                    float(value)))

    def place_layers(self, layer_times: np.ndarray) -> None:
        """Shift pending layer-relative events onto the barrier timeline.

        A degenerate call — zero layers, or pending events whose layer
        index is beyond ``layer_times`` — leaves those events at their
        relative offsets instead of raising (the empty-structure
        convention shared with `busy_by_resource` and
        `repro.obs.metrics.utilization_timeline`).
        """
        layer_times = np.asarray(layer_times, float)
        starts = np.concatenate([[0.0], np.cumsum(layer_times)[:-1]]) \
            if layer_times.size else np.zeros(0)
        for ev in self._pending:
            if 0 <= ev.layer < len(starts):
                ev.ts += float(starts[ev.layer])
            self.events.append(ev)
        self._pending.clear()
        self.meta["layer_starts"] = starts.tolist()
        self.meta["layer_times"] = layer_times.tolist()

    # ------------------------------------------------------------------
    # queries
    # ------------------------------------------------------------------

    def tracks(self, cat: Optional[str] = None) -> List[str]:
        seen: Dict[str, None] = {}
        for ev in self.events:
            if cat is None or ev.cat == cat:
                seen.setdefault(ev.track, None)
        return list(seen)

    def busy_time(self, cat: Optional[str] = None) -> Dict[str, float]:
        """Integrated busy-seconds per track (sum of event durations)."""
        out: Dict[str, float] = {}
        for ev in self.events:
            if cat is None or ev.cat == cat:
                out[ev.track] = out.get(ev.track, 0.0) + ev.dur
        return out

    def busy_by_resource(self, cat: str, n: int,
                         prefix: str) -> np.ndarray:
        """(n,) busy-seconds keyed by the integer after ``prefix``.

        Aggregates sub-tracks — ``ch0/z1`` and ``ch0/g`` both fold into
        channel 0, ``cut2/l1`` into cut 2 — so the result is directly
        comparable to `EventResult.cut_busy` / ``channel_busy`` /
        ``dram_busy``.  Tracks that do not parse to an id in
        ``[0, n)`` are skipped (an empty or foreign trace yields
        zeros, never an exception).
        """
        out = np.zeros(n)
        for track, busy in self.busy_time(cat).items():
            head = track.split("/", 1)[0]
            if head.startswith(prefix):
                tail = head[len(prefix):]
                if tail.isdigit() and int(tail) < n:
                    out[int(tail)] += busy
        return out

    def span(self) -> Tuple[float, float]:
        """(first begin, last end) over all events."""
        if not self.events:
            return 0.0, 0.0
        t0 = min(ev.ts for ev in self.events)
        t1 = max(ev.ts + ev.dur for ev in self.events)
        return t0, t1

    def layer_windows(self) -> Dict[int, Tuple[float, float]]:
        """layer -> (start, duration), from the ``layer`` spans."""
        return {ev.layer: (ev.ts, ev.dur) for ev in self.events
                if ev.cat == "layer"}

    # ------------------------------------------------------------------
    # derived counter tracks
    # ------------------------------------------------------------------

    def derive_queue_counters(
            self, cats: Iterable[str] = RESOURCE_CATS) -> None:
        """Queue-depth samples per plane at each event-calendar pop.

        Every packet of a layer enqueues at the layer's start (the
        GEMINI barrier), so the plane's queue depth jumps to the layer's
        packet count there and steps down at each completion.
        """
        windows = self.layer_windows()
        for cat in cats:
            evs = [ev for ev in self.events if ev.cat == cat]
            if not evs:
                continue
            track = f"q:{cat}"
            per_layer: Dict[int, List[TraceEvent]] = {}
            for ev in evs:
                per_layer.setdefault(ev.layer, []).append(ev)
            for li, levs in sorted(per_layer.items()):
                start = windows.get(li, (min(e.ts for e in levs), 0.0))[0]
                depth = len(levs)
                self.add_counter(track, start, depth)
                for end in sorted(e.ts + e.dur for e in levs):
                    depth -= 1
                    self.add_counter(track, end, depth)
            self.counters[track].sort()

    def derive_utilization_counters(
            self, cats: Iterable[str] = RESOURCE_CATS) -> None:
        """Per-resource occupancy fraction, sampled once per layer."""
        windows = self.layer_windows()
        busy: Dict[Tuple[str, int], float] = {}
        for ev in self.events:
            if ev.cat in cats:
                key = (ev.track, ev.layer)
                busy[key] = busy.get(key, 0.0) + ev.dur
        for (track, li), b in sorted(busy.items()):
            start, dur = windows.get(li, (0.0, 0.0))
            self.add_counter(f"util:{track}", start, b / dur if dur else 0.0)

    def __len__(self) -> int:
        return len(self.events)


# ---------------------------------------------------------------------------
# active-recorder context (the analytic plane's hook)
# ---------------------------------------------------------------------------

_STACK: List[Optional[SimTrace]] = []


def active_recorder() -> Optional[SimTrace]:
    """The innermost installed recorder, or None (also when masked)."""
    return _STACK[-1] if _STACK else None


@contextlib.contextmanager
def recording(st: Optional[SimTrace]):
    """Install ``st`` as the active recorder for the block.

    ``recording(None)`` masks an outer recorder — the balancer uses it
    around trial evaluations so only the final timeline is emitted.
    """
    _STACK.append(st)
    try:
        yield st
    finally:
        _STACK.pop()
