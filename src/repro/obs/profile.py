"""Deterministic hierarchical phase profiler: the framework's *own*
wall time, observable the same way `SimTrace` makes simulated time
observable.

`SimTrace` answers "where does the *simulated* run spend its time";
this module answers "where does the *simulator* spend its time" — the
evidence base the ROADMAP's "JAX-compile the sweep and event engines"
item needs before any port.  The design is grown out of
`MetricsRegistry.span()` and mirrors the trace plane's conventions:

- **Nested phases with parent tracking.**  ``with phase("name"):``
  opens a phase under whichever phase is currently open; a phase's
  identity is its slash-joined ``path`` ("dse.sweep_all/
  net.batched.evaluate/net.batched.wired"), so the same stage reached
  through different entry points aggregates separately.
- **Active-profiler context, exactly like `trace.recording`.**
  ``with profiling() as prof:`` installs a `PhaseProfiler` on a module
  stack; every instrumented hot path (`dse.sweep_all`,
  `net.batched.evaluate`, the `sim.engine` event loops, the
  `arch.placement` annealer — plus every `MetricsRegistry.span`)
  records into it.  When no profiler is installed the instrumented
  paths cost one ``None`` check and **construct nothing** — the
  structural zero-cost pin (`tests/test_profile.py` monkeypatches
  `PhaseRecord` to raise and runs the engines disabled).
- **Per-phase wall time / call counts / peak-ndarray-bytes.**
  `note_ndarray(*arrays)` attributes the byte footprint of the arrays
  a stage materialises to the open phase; peaks propagate to parents,
  so a phase's ``peak_bytes`` bounds the largest single allocation
  burst under it.
- **Determinism.**  The profiler reads the wall clock (that is its
  job — the `det-wallclock` allowlist names this file) but never
  influences the instrumented computation: golden numbers stay
  bit-identical with profiling on.

`profile_report` renders the aggregate table; `PhaseProfiler.to_trace`
lifts the phases into a `SimTrace` with category ``"framework"``, which
`obs.export.chrome_trace_events` maps to a dedicated "framework"
Perfetto process — simulated time and self time side by side in one
view.  `coverage()` is the honesty metric: the fraction of the
profiled wall attributed to named top-level phases (the acceptance bar
is >= 0.9 on `sweep_all` and a `PacketSim` run).
"""

from __future__ import annotations

import contextlib
import time
from typing import Dict, List, Optional


class PhaseRecord:
    """One closed phase instance: begin/duration relative to the
    profiler's install time, plus its path and byte peak."""

    __slots__ = ("name", "path", "depth", "ts", "dur", "peak_bytes",
                 "outcome")

    def __init__(self, name: str, path: str, depth: int, ts: float):
        self.name = name
        self.path = path
        self.depth = depth
        self.ts = ts
        self.dur = 0.0
        self.peak_bytes = 0
        self.outcome = "ok"


class PhaseProfiler:
    """Collects `PhaseRecord`s while installed via `profiling`."""

    def __init__(self, label: str = "framework"):
        self.label = label
        self.records: List[PhaseRecord] = []
        self._open: List[PhaseRecord] = []
        self._t0: Optional[float] = None
        self.wall_s = 0.0

    # -- recording (only ever called with the profiler installed) ------

    def _install(self) -> None:
        if self._t0 is None:
            self._t0 = time.perf_counter()

    def _finalize(self) -> None:
        if self._t0 is not None:
            self.wall_s = time.perf_counter() - self._t0

    def _begin(self, name: str) -> PhaseRecord:
        parent = self._open[-1].path if self._open else ""
        rec = PhaseRecord(name, f"{parent}/{name}" if parent else name,
                          len(self._open), time.perf_counter() - self._t0)
        self._open.append(rec)
        return rec

    def _end(self, rec: PhaseRecord, outcome: str = "ok") -> None:
        self._open.pop()
        rec.dur = time.perf_counter() - self._t0 - rec.ts
        rec.outcome = outcome
        if self._open and rec.peak_bytes > self._open[-1].peak_bytes:
            self._open[-1].peak_bytes = rec.peak_bytes
        self.records.append(rec)

    def note_bytes(self, nbytes: int) -> None:
        if self._open and nbytes > self._open[-1].peak_bytes:
            self._open[-1].peak_bytes = int(nbytes)

    # -- analysis -------------------------------------------------------

    def measured_wall_s(self) -> float:
        """Wall seconds between install and finalize (live if open)."""
        if self.wall_s:
            return self.wall_s
        if self._t0 is not None:
            return time.perf_counter() - self._t0
        return 0.0

    def coverage(self) -> float:
        """Fraction of the measured wall attributed to named top-level
        phases — the >=90% acceptance metric."""
        wall = self.measured_wall_s()
        top = sum(r.dur for r in self.records if r.depth == 0)
        return top / wall if wall > 0.0 else 0.0

    def aggregate(self) -> Dict[str, dict]:
        """path -> {name, depth, calls, total_s, self_s, peak_bytes,
        errors}; ``self_s`` excludes named child phases."""
        agg: Dict[str, dict] = {}
        for r in self.records:
            a = agg.setdefault(r.path, {
                "name": r.name, "path": r.path, "depth": r.depth,
                "calls": 0, "total_s": 0.0, "self_s": 0.0,
                "peak_bytes": 0, "errors": 0})
            a["calls"] += 1
            a["total_s"] += r.dur
            if r.peak_bytes > a["peak_bytes"]:
                a["peak_bytes"] = r.peak_bytes
            a["errors"] += r.outcome != "ok"
        for a in agg.values():
            a["self_s"] = a["total_s"]
        for path, a in agg.items():
            parent = path.rsplit("/", 1)[0] if "/" in path else None
            if parent in agg:
                agg[parent]["self_s"] -= a["total_s"]
        return agg

    def to_trace(self):
        """The phases as a `SimTrace` (category ``"framework"``), ready
        for `obs.export.chrome_trace_events` — merge it with a recorded
        sim trace to see simulated time and self time side by side."""
        from .trace import SimTrace
        st = SimTrace(label=self.label)
        st.meta = {"kind": "profile", "wall_s": self.measured_wall_s(),
                   "coverage": self.coverage()}
        for r in sorted(self.records, key=lambda r: (r.ts, -r.dur)):
            st.add("phases", r.name, r.ts, r.dur, cat="framework",
                   path=r.path, peak_ndarray_bytes=r.peak_bytes,
                   outcome=r.outcome)
        return st


# ---------------------------------------------------------------------------
# active-profiler context (the `trace.recording` pattern)
# ---------------------------------------------------------------------------

_STACK: List[Optional[PhaseProfiler]] = []


def active_profiler() -> Optional[PhaseProfiler]:
    """The innermost installed profiler, or None (profiling disabled)."""
    return _STACK[-1] if _STACK else None


@contextlib.contextmanager
def profiling(prof: Optional[PhaseProfiler] = None):
    """Install ``prof`` (a fresh `PhaseProfiler` by default) for the
    block; yields it.  Nests like `trace.recording` — the innermost
    profiler wins; ``profiling(None)`` therefore starts a *new* scope
    rather than masking (self-profiling has no trial-evaluation
    suppression to express)."""
    prof = PhaseProfiler() if prof is None else prof
    prof._install()
    _STACK.append(prof)
    try:
        yield prof
    finally:
        _STACK.pop()
        prof._finalize()


class phase:
    """``with phase("stage"):`` — record the block into the active
    profiler; a no-op (one None check, nothing constructed) when
    profiling is disabled.  A raising body closes the phase with
    ``outcome="error"`` and re-raises."""

    __slots__ = ("name", "prof", "rec")

    def __init__(self, name: str):
        self.name = name

    def __enter__(self) -> "phase":
        prof = _STACK[-1] if _STACK else None
        self.prof = prof
        if prof is not None:
            self.rec = prof._begin(self.name)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if self.prof is not None:
            self.prof._end(self.rec, "error" if exc_type else "ok")
        return False


def note_ndarray(*arrays) -> None:
    """Attribute ``sum(a.nbytes)`` of the given arrays to the open
    phase of the active profiler (peak over notes; propagates to parent
    phases on exit).  Free when profiling is disabled."""
    prof = _STACK[-1] if _STACK else None
    if prof is not None:
        prof.note_bytes(sum(int(getattr(a, "nbytes", 0))
                            for a in arrays if a is not None))


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

def profile_report(prof: PhaseProfiler, top: int = 30) -> str:
    """Human-readable aggregate table, heaviest phases first, with the
    coverage footer (the >=90% attribution acceptance line)."""
    agg = sorted(prof.aggregate().values(), key=lambda a: -a["total_s"])
    wall = prof.measured_wall_s()
    if not agg:
        return "(no phases recorded)"
    wid = max(len(a["path"]) for a in agg[:top])
    hdr = (f"{'phase':<{wid}} {'calls':>7} {'total':>10} {'self':>10} "
           f"{'%wall':>6} {'peak-bytes':>12}")
    lines = [hdr, "-" * len(hdr)]
    for a in agg[:top]:
        pct = 100.0 * a["total_s"] / wall if wall else 0.0
        err = f"  errors={a['errors']}" if a["errors"] else ""
        lines.append(
            f"{a['path']:<{wid}} {a['calls']:>7} {a['total_s']:>9.4f}s "
            f"{a['self_s']:>9.4f}s {pct:>5.1f}% {a['peak_bytes']:>12,}"
            f"{err}")
    lines.append(f"attributed {100.0 * prof.coverage():.1f}% of "
                 f"{wall:.4f}s wall to {len(agg)} named phases")
    return "\n".join(lines)
