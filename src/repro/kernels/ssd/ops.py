"""Jit'd public wrapper for the SSD kernel: pads L to the chunk grid."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ssd import ssd_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, B, C, *, chunk: int = 128, interpret: bool = True):
    """x: (b, L, H, P); dt: (b, L, H); A: (H,); B/C: (b, L, N).
    Returns (y (b, L, H, P), None)."""
    b, L, H, P = x.shape
    pad = (-L) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    y = ssd_kernel(x, dt, A, B, C, chunk=chunk, interpret=interpret)
    return y[:, :L], None
