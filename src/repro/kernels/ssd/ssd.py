"""Mamba2 SSD chunked-scan Pallas TPU kernel.

TPU adaptation of the SSD algorithm (DESIGN.md): the chunk axis is the
innermost, sequential grid dimension; the inter-chunk recurrent state
(H, P, N) lives in VMEM scratch and is carried across grid steps, so HBM
traffic per chunk is exactly the chunk's inputs + outputs (the state never
round-trips).  Within a chunk everything is dense matmul work for the MXU:
the (Q, Q) decay-gated score product and the (Q, N) x (Q, P) state
outer-products, with Q = 128 tokens per chunk by default.

Oracle: ref.py; parity asserted over shapes/dtypes in tests/test_kernels.py
(interpret mode on CPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, state_scr, *,
            nc: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)          # (Q, H, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q, H)
    Bv = b_ref[0].astype(jnp.float32)         # (Q, N)
    Cv = c_ref[0].astype(jnp.float32)         # (Q, N)
    A = a_ref[...].astype(jnp.float32)        # (H,)

    Q = x.shape[0]
    dA = dt * A[None, :]                      # (Q, H)
    dA_cum = jnp.cumsum(dA, axis=0)           # (Q, H)

    # intra-chunk: decay-gated quadratic attention within the chunk
    seg = dA_cum[:, None, :] - dA_cum[None, :, :]          # (Q, Q, H)
    rows = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    tri = rows >= cols
    Lmat = jnp.where(tri[..., None], jnp.exp(seg), 0.0)     # (Q, Q, H)
    scores = jax.lax.dot_general(Cv, Bv, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    gate = scores[..., None] * Lmat                          # (Q, Q, H)
    xdt = x * dt[..., None]                                  # (Q, H, P)
    y_diag = jnp.einsum("qkh,khp->qhp", gate, xdt)

    # inter-chunk: contribution of the carried state
    state_decay = jnp.exp(dA_cum)                            # (Q, H)
    st = state_scr[...]                                      # (H, P, N)
    y_off = jnp.einsum("qn,hpn,qh->qhp", Cv, st, state_decay)

    # state update for the next chunk
    decay_end = jnp.exp(dA_cum[-1:, :] - dA_cum)             # (Q, H)
    new_contrib = jnp.einsum("qn,qh,qhp->hpn", Bv, decay_end * dt, x)
    chunk_decay = jnp.exp(dA_cum[-1, :])                     # (H,)
    state_scr[...] = st * chunk_decay[:, None, None] + new_contrib

    y_ref[0] = (y_diag + y_off).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_kernel(x, dt, A, B, C, *, chunk: int = 128,
               interpret: bool = True) -> jnp.ndarray:
    """x: (b, L, H, P); dt: (b, L, H) (post-softplus); A: (H,) negative;
    B/C: (b, L, N).  L must be a multiple of `chunk` (ops.py pads).
    Returns y: (b, L, H, P)."""
    b, L, H, P = x.shape
    N = B.shape[-1]
    nc = L // chunk
    grid = (b, nc)

    return pl.pallas_call(
        functools.partial(_kernel, nc=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((H,), lambda i, c: (0,)),
            pl.BlockSpec((1, chunk, H, P), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((1, chunk, H), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda i, c: (i, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, H, P), lambda i, c: (i, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, L, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        interpret=interpret,
    )(A, x, dt, B, C)
