"""Pure-jnp oracle for the SSD kernel: the naive O(L) sequential
recurrence — independent of both the kernel and models/ssm.py's chunked
formulation, so it cross-checks both."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, B, C):
    """Sequential state-space recurrence.

    x: (b, L, H, P); dt: (b, L, H); A: (H,); B/C: (b, L, N).
    state_t = exp(dt_t A) state_{t-1} + dt_t x_t B_t^T
    y_t     = C_t . state_t
    Returns (y (b, L, H, P), final state (b, H, P, N)).
    """
    b, L, H, P = x.shape
    N = B.shape[-1]

    def step(state, inp):
        xt, dtt, Bt, Ct = inp
        dA = jnp.exp(dtt * A)                          # (b, H)
        state = state * dA[..., None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dtt, xt, Bt)
        y = jnp.einsum("bhpn,bn->bhp", state, Ct)
        return state, y

    init = jnp.zeros((b, H, P, N), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          B.transpose(1, 0, 2).astype(jnp.float32),
          C.transpose(1, 0, 2).astype(jnp.float32))
    final, ys = jax.lax.scan(step, init, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final.astype(x.dtype)
