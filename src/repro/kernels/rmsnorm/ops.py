"""Jit'd public wrapper: flattens leading dims, pads rows to the block."""

import functools

import jax
import jax.numpy as jnp

from .rmsnorm import BR, rmsnorm_kernel


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-6, interpret: bool = True):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    pad = (-x2.shape[0]) % BR
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    y = rmsnorm_kernel(x2, scale, eps=eps, interpret=interpret)
    return y[:x2.shape[0] - pad].reshape(shape)
