"""Fused RMSNorm Pallas TPU kernel: one HBM read, one write per row block.

Rows are tiled (BR, d) into VMEM; the mean-square reduction and scale
multiply fuse into a single pass (unfused XLA on small models emits a
separate reduce + mul with an intermediate HBM round-trip).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BR = 256


def _kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm_kernel(x, scale, *, eps: float = 1e-6,
                   interpret: bool = True) -> jnp.ndarray:
    """x: (R, d) rows; scale: (d,). R padded to the row block by ops.py."""
    R, d = x.shape
    grid = (R // BR,)
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[pl.BlockSpec((BR, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((BR, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, d), x.dtype),
        interpret=interpret,
    )(x, scale)
