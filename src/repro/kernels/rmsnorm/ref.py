"""Pure-jnp oracle for the fused RMSNorm kernel."""

import jax
import jax.numpy as jnp


def rmsnorm_ref(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)
