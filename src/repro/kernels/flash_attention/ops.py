"""Jit'd public wrapper: layout adaptation + padding around the kernel.

Model code calls `flash_attention(q, k, v, ...)` in (B, S, H, D) layout;
this wrapper transposes to the kernel's (B, H, S, D), pads S to the
128-block grid and D to the lane width, and un-pads the result.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .flash_attention import BK, BQ, flash_attention_kernel


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9))
def flash_attention(q, k, v, q_pos, k_pos, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None, causal: bool = True,
                    interpret: bool = True) -> jnp.ndarray:
    """q: (B, S, H, D); k/v: (B, T, K, D); positions int32. -> (B, S, H, D).

    custom_vjp: the forward pass is the Pallas kernel; the backward pass
    differentiates the reference formulation (a dedicated backward kernel
    is a further optimization — the contract here is correctness parity,
    asserted in tests)."""
    return _flash_attention_fwd_impl(q, k, v, q_pos, k_pos, window, softcap,
                                     scale, causal, interpret)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "scale",
                                             "causal", "interpret"))
def _flash_attention_fwd_impl(q, k, v, q_pos, k_pos, window=None,
                              softcap=None, scale=None, causal=True,
                              interpret=True) -> jnp.ndarray:
    B, S, H, D = q.shape
    T = k.shape[1]
    scale = D ** -0.5 if scale is None else scale

    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    pq = (-S) % BQ
    pk = (-T) % BK
    pd = (-D) % 128
    if pq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pq), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pq), constant_values=0)
    if pk:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pk), (0, 0)))
        # padded keys land at +inf position: masked away by causality
        k_pos = jnp.pad(k_pos, (0, pk),
                        constant_values=jnp.iinfo(jnp.int32).max)
    if pd:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, 0), (0, pd)))
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, 0), (0, pd)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, 0), (0, pd)))

    out = flash_attention_kernel(qt, kt, vt, q_pos, k_pos, scale=scale,
                                 causal=causal, window=window,
                                 softcap=softcap, interpret=interpret)
    out = out[:, :, :S, :D]
    return out.transpose(0, 2, 1, 3)


def _ref_call(q, k, v, q_pos, k_pos, window, softcap, scale, causal):
    from .ref import attention_ref
    D = q.shape[-1]
    return attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), q_pos, k_pos,
        scale=D ** -0.5 if scale is None else scale,
        causal=causal, window=window, softcap=softcap).transpose(0, 2, 1, 3)


def _fa_fwd(q, k, v, q_pos, k_pos, window, softcap, scale, causal,
            interpret):
    out = _flash_attention_fwd_impl(q, k, v, q_pos, k_pos, window, softcap,
                                    scale, causal, interpret)
    return out, (q, k, v, q_pos, k_pos)


def _fa_bwd(window, softcap, scale, causal, interpret, res, g):
    q, k, v, q_pos, k_pos = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _ref_call(q_, k_, v_, q_pos, k_pos, window,
                                     softcap, scale, causal), q, k, v)
    dq, dk, dv = vjp(g)
    return dq, dk, dv, None, None


flash_attention.defvjp(_fa_fwd, _fa_bwd)
