"""Pure-jnp oracle for the flash-attention kernel (no Pallas imports)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def attention_ref(q, k, v, q_pos, k_pos, *, scale: float,
                  causal: bool = True, window: Optional[int] = None,
                  softcap: Optional[float] = None) -> jnp.ndarray:
    """q: (B, H, Sq, D); k/v: (B, K, Sk, D) with K | H (GQA)."""
    B, H, Sq, D = q.shape
    K = k.shape[1]
    G = H // K
    qg = q.reshape(B, K, G, Sq, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgsd,bktd->bkgst", qg, kf) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    if causal:
        m = k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            m &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(m, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,bktd->bkgsd", p, vf)
    return o.reshape(B, H, Sq, D).astype(q.dtype)
