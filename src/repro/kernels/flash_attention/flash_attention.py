"""Flash attention Pallas TPU kernel.

Design for TPU (DESIGN.md hardware-adaptation):
- grid = (batch, q_heads, Sq/BQ, Skv/BK); the KV-block axis is innermost
  and "arbitrary" (sequential) so the online-softmax running state lives
  in VMEM scratch across KV iterations.
- BQ = BK = 128 and the head dim is processed whole: every matmul hits the
  MXU with 128-aligned contraction/output dims.
- GQA without materialising repeated KV: the K/V BlockSpec index_map folds
  the query head -> kv head mapping (h // group), so each KV block is
  fetched once per group from HBM.
- masking (causal + sliding window) is computed from position vectors that
  ride along as tiny VMEM blocks — the kernel never touches an S x S mask.

Oracle: ref.py (pure jnp); parity across shapes/dtypes is asserted in
tests/test_kernels.py with interpret=True on CPU.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30
BQ = 128
BK = 128


def _kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float, causal: bool,
            window: Optional[int], softcap: Optional[float], nk: int):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)            # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)            # (BK, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap

    qp = qpos_ref[...].astype(jnp.int32)           # (BQ,)
    kp = kpos_ref[...].astype(jnp.int32)           # (BK,)
    mask = jnp.ones((q.shape[0], k.shape[0]), jnp.bool_)
    if causal:
        mask = kp[None, :] <= qp[:, None]
        if window is not None:
            mask &= (qp[:, None] - kp[None, :]) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        lse = jnp.maximum(l_scr[...], 1e-37)
        o_ref[0, 0] = (acc_scr[...] / lse[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "causal", "window",
                                             "softcap", "interpret"))
def flash_attention_kernel(q, k, v, q_pos, k_pos, *, scale: float,
                           causal: bool = True,
                           window: Optional[int] = None,
                           softcap: Optional[float] = None,
                           interpret: bool = True) -> jnp.ndarray:
    """q: (B, H, Sq, D); k/v: (B, K, Sk, D); positions int32 (Sq,), (Sk,).

    Sq/Sk must be multiples of 128 and D a multiple of 8 (the ops.py
    wrapper pads).  Returns (B, H, Sq, D).
    """
    B, H, Sq, D = q.shape
    K = k.shape[1]
    Sk = k.shape[2]
    G = H // K
    nq, nk = Sq // BQ, Sk // BK
    grid = (B, H, nq, nk)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          window=window, softcap=softcap, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BQ,), lambda b, h, iq, ik: (iq,)),
            pl.BlockSpec((BK,), lambda b, h, iq, ik: (ik,)),
            pl.BlockSpec((1, 1, BQ, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, BK, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, BK, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, BQ, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BQ,), jnp.float32),     # running max
            pltpu.VMEM((BQ,), jnp.float32),     # running denominator
            pltpu.VMEM((BQ, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q_pos, k_pos, q, k, v)
    return out
