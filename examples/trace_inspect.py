"""Observability walkthrough: record a run, export it, explain it.

Runs one workload through BOTH time-resolving planes with the recorder
on — the event-driven packet simulator (`record=True`) and the analytic
balancer (under `recording(st)`) — then:

- exports a merged Chrome Trace Event JSON (open it at
  https://ui.perfetto.dev: one process per modelling plane, one thread
  per resource, counter tracks for queue depth / bytes moved),
- exports the compact lossless ``.npz`` form of the event trace,
- checks the busy-time invariant (per-resource event durations must sum
  to the engine's own busy aggregates),
- prints the attribution report — the decomposition of each layer span
  into service vs queueing vs quiescence that turns `bottleneck_share`'s
  "which resource" into "why" (see the column glossary printed below),
- dumps the metrics-registry report (span timers, provenance counters).

    PYTHONPATH=src python examples/trace_inspect.py [workload] [--quick]
        [--out=DIR]

``--quick`` switches to the small zfnet CNN for CI smoke runs.
"""

import json
import os
import sys

import numpy as np

from repro.core import (ChannelPlan, LLM_WORKLOADS, NetworkConfig, balance,
                        make_trace)
from repro.core.workloads import WORKLOADS
from repro.obs import (DEFAULT_REGISTRY, SimTrace, attribution_report,
                       attribution_summary, export_chrome_trace, export_npz,
                       format_attribution, recording)
from repro.sim import PacketSim


def main():
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    quick = "--quick" in sys.argv[1:]
    out_dir = next((a.split("=", 1)[1] for a in sys.argv[1:]
                    if a.startswith("--out=")), "experiments/traces")
    wl = args[0] if args else ("zfnet" if quick else "smollm_360m:prefill")
    assert wl in WORKLOADS or wl in LLM_WORKLOADS, \
        f"pick one of {list(WORKLOADS)} or {list(LLM_WORKLOADS)}"
    os.makedirs(out_dir, exist_ok=True)
    safe = wl.replace(":", "_")

    # a 2-channel spatial-reuse plan so the trace shows the global-phase
    # quiesce the attribution report is built to explain
    net = NetworkConfig(bandwidth=96e9 / 8,
                        channels=ChannelPlan(n_channels=2, reuse_zones=4))
    tr = make_trace(wl)

    # -- event plane, recorded ------------------------------------------
    with DEFAULT_REGISTRY.span("example.trace_inspect", workload=wl):
        sim = PacketSim(tr, net, record=True)
        res = sim.run("greedy")
    print(f"== {wl}: event-driven greedy run, recorder on ==")
    print(f"execution time: {res.total_time*1e3:.3f} ms, "
          f"{len(res.trace)} trace events on "
          f"{len(res.trace.tracks())} tracks")
    print("bottleneck shares:",
          {k: f"{v:.0%}" for k, v in res.bottleneck_share().items()
           if v > 0.005})

    # the invariant tests/test_obs.py pins at 1e-12: per-resource event
    # durations must reproduce the engine's own busy aggregates
    wired = res.trace.busy_by_resource("wired", sim.n_cuts, "cut")
    wl_busy = res.trace.busy_by_resource("wireless", net.channels.n_channels,
                                         "ch")
    assert np.allclose(wired, res.cut_busy, rtol=1e-12, atol=0.0)
    assert np.allclose(wl_busy, res.channel_busy, rtol=1e-12, atol=0.0)
    print("busy-time invariant: trace == engine aggregates (1e-12) OK")

    # -- analytic plane, recorded (same workload, balancer timeline) ----
    st_an = SimTrace(label=f"analytic:{wl}")
    with recording(st_an):
        bal = balance(tr, net)
    print(f"analytic balancer: {bal.sim.total_time*1e3:.3f} ms "
          f"({100*(bal.speedup_vs_wired-1):.1f}% over wired), "
          f"{len(st_an)} analytic events")

    # -- exports --------------------------------------------------------
    chrome = os.path.join(out_dir, f"{safe}_trace.json")
    export_chrome_trace({"event": res.trace, "analytic": st_an}, chrome)
    npz = os.path.join(out_dir, f"{safe}_trace.npz")
    export_npz(res.trace, npz)
    print(f"\nwrote {chrome} (open at https://ui.perfetto.dev) and {npz}")

    # -- attribution: from "which resource" to "why" --------------------
    rows = attribution_report(res)
    print("\n== attribution (heaviest rows) ==")
    print("service = payload time on the resource; queueing = packets "
          "waiting for FIFO position;\nquiesce = the slice of queueing "
          "behind the channel's long-range global phase;\nfinish = when "
          "the resource drained within its layer span.")
    print(format_attribution(rows, top=8 if quick else 12))
    print("\n== bottleneck summary ==")
    for bn, e in attribution_summary(res).items():
        why = f" — {e['track']} {e['why']}" if e["track"] else ""
        print(f"  {bn}: {e['share']:.0%}{why}")

    # -- metrics registry -----------------------------------------------
    report = DEFAULT_REGISTRY.report()
    mpath = os.path.join(out_dir, f"{safe}_metrics.json")
    with open(mpath, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True, default=str)
    print(f"\nmetrics report ({len(report)} series) -> {mpath}")


if __name__ == "__main__":
    main()
