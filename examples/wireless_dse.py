"""The paper's experiment, end to end: bottleneck characterisation, the
wireless DSE, the Fig. 5 heatmap, the beyond-paper network sweep (MAC
protocols x channel plans) and the analytic balancer — on the 144-TOPS
3x3-chiplet platform of Table 1.

Accepts the paper's 15 workloads AND the LLM frontier names
("<model>:<phase>", e.g. mixtral_8x22b:prefill — tensor-/expert-
parallel mappings with collective traffic).  ``--quick`` trims the
per-point heatmap AND the heterogeneous co-design search for CI smoke
runs; ``--mix=<name>`` picks the chiplet catalog mix the co-design
section searches (see `repro.arch.MIXES`).

    PYTHONPATH=src python examples/wireless_dse.py [workload] [--quick]
        [--mix=big_little|compute_mem|aimc_edge]
"""

import sys

from repro.core import (ChannelPlan, LLM_WORKLOADS, MacConfig,
                        NetworkConfig, WirelessConfig, balance, make_trace,
                        network_sweep, policy_sweep, simulate_wired, sweep)
from repro.core.dse import INJECTIONS, THRESHOLDS
from repro.core.simulator import simulate_hybrid
from repro.core.workloads import WORKLOADS


def main():
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    quick = "--quick" in sys.argv[1:]
    mix = next((a.split("=", 1)[1] for a in sys.argv[1:]
                if a.startswith("--mix=")), "big_little")
    wl = args[0] if args else "zfnet"
    assert wl in WORKLOADS or wl in LLM_WORKLOADS, \
        f"pick one of {list(WORKLOADS)} or {list(LLM_WORKLOADS)}"
    tr = make_trace(wl)

    base = simulate_wired(tr)
    print(f"== {wl} on 3x3 x 144 TOPS (wired baseline) ==")
    print(f"execution time: {base.total_time*1e3:.3f} ms")
    print("bottleneck shares:",
          {k: f"{v:.0%}" for k, v in base.bottleneck_share().items()
           if v > 0.005})
    coll = sum(m.nbytes for m in tr.messages if m.kind == "coll")
    if coll:
        total = sum(m.nbytes for m in tr.messages)
        mcast = sum(m.nbytes for m in tr.messages
                    if m.kind == "coll" and len(m.dsts) > 1)
        print(f"collective traffic: {coll/total:.0%} of NoP bytes "
              f"({mcast/total:.0%} broadcast-natured multicast)")

    for bw in (64, 96):
        r = sweep(tr, wl, bw)
        print(f"\n== wireless {bw} Gb/s: DSE best speedup "
              f"{100*(r.best_speedup-1):.1f}% "
              f"(threshold={r.best_threshold}, "
              f"injection={r.best_injection}) ==")

    # --quick (CI smoke): a 2x3 corner of the per-point heatmap instead
    # of the full 4x15 grid — every code path, a fraction of the calls
    thresholds = THRESHOLDS[:2] if quick else THRESHOLDS
    injections = INJECTIONS[::5] if quick else INJECTIONS
    print("\nthreshold x injection heatmap (% speedup, 96 Gb/s):")
    b = base.total_time
    header = "thr\\p " + " ".join(f"{p:5.2f}" for p in injections)
    print(header)
    for thr in thresholds:
        row = []
        for p in injections:
            h = simulate_hybrid(tr, WirelessConfig(96e9 / 8, thr, p))
            row.append(100 * (b / h.total_time - 1))
        print(f"  {thr}   " + " ".join(f"{v:5.1f}" for v in row))

    # --- beyond-paper: how much of the idealized speedup survives a
    # real MAC, and whether splitting the band into channels helps ---
    ns = network_sweep(tr, wl)
    table = ns.best_by_network()
    ideal = table[("ideal", "1ch")]
    print("\nnetwork sweep (best % speedup over thr x inj x bw, per "
          "MAC x channel plan; batched engine):")
    plans = sorted({k[1] for k in table})
    print("  mac   " + " ".join(f"{p:>16s}" for p in plans))
    for mac in ("ideal", "tdma", "token"):
        cells = []
        for p in plans:
            sp = table[(mac, p)]
            cells.append(f"{100*(sp-1):7.1f}%"
                         f" ({100*(sp-ideal):+5.1f})")
        print(f"  {mac:5s} " + " ".join(f"{c:>16s}" for c in cells))
    print(f"best network config: {ns.best_config.describe()} "
          f"-> {100*(ns.best_speedup-1):.1f}% "
          f"(idealized optimum keeps {100*(ideal-1):.1f}%)")

    for name, net in (
            ("ideal", NetworkConfig(96e9 / 8)),
            ("tdma 2ch", NetworkConfig(96e9 / 8, mac=MacConfig("tdma"),
                                       channels=ChannelPlan(2,
                                                            "interleaved"))),
    ):
        bal = balance(tr, net)
        print(f"\nbeyond-paper balancer [{name}]: "
              f"{100*(bal.speedup_vs_wired-1):.1f}% "
              f"(injected {bal.injected_fraction:.0%} of eligible volume, "
              f"{bal.sim.wireless_energy_j*1e6:.1f} uJ wireless energy)")

    # --- beyond-paper: the event-driven simulator (repro.sim) makes the
    # paper's named future work runnable — ONLINE wired/wireless load
    # balancing, decided per packet from instantaneous queue backlog,
    # vs the best offline-swept static (threshold x injection) point ---
    ps = policy_sweep(tr, wl)
    print(f"\nevent-driven policy sweep (96 Gb/s, striped links, "
          f"ideal MAC; wired baseline {ps.base_time*1e3:.3f} ms):")
    print(f"  best static grid point        "
          f"{100*(ps.grid_best_speedup-1):6.1f}%")
    for pol in ("static", "greedy", "adaptive", "oracle"):
        sp = ps.policy_speedups[pol]
        mark = " <- beats the swept optimum" \
            if pol in ("greedy", "adaptive") \
            and sp >= ps.grid_best_speedup - 1e-9 else ""
        print(f"  {pol:28s}  {100*(sp-1):6.1f}%{mark}")

    # --- beyond-paper: heterogeneous package co-design (repro.arch) —
    # make the package itself a search variable: a catalog mix of
    # chiplets, jointly placed and mapped by a seeded annealer under
    # the wired and the hybrid objective ---
    from repro.arch import codesign
    r = codesign(wl, mix,
                 steps=40 if quick else 200,
                 restarts=1 if quick else 2,
                 n_samples=4 if quick else 10)
    print(f"\nheterogeneous co-design [mix={mix}, "
          f"{'quick ' if quick else ''}annealed search, "
          f"{r.n_evaluations} placements evaluated]:")
    print(f"  best package               {r.package}")
    print(f"  wired-optimal placement    {r.wired.t_wired*1e3:10.3f} ms")
    print(f"  co-designed hybrid         {r.hybrid.t_hybrid*1e3:10.3f} ms "
          f"({100*(r.speedup_codesigned-1):+.1f}%)")
    print(f"  greedy seed (hybrid plane) {r.greedy.t_hybrid*1e3:10.3f} ms")
    print(f"  placement spread best-vs-worst: "
          f"wired {r.spread_wired:.2f}x -> hybrid {r.spread_hybrid:.2f}x"
          + (" <- wireless shrinks placement sensitivity"
             if r.spread_hybrid < r.spread_wired else ""))


if __name__ == "__main__":
    main()
