"""The paper's experiment, end to end: bottleneck characterisation, the
wireless DSE, the Fig. 5 heatmap, and the beyond-paper balancer — on the
144-TOPS 3x3-chiplet platform of Table 1.

    PYTHONPATH=src python examples/wireless_dse.py [workload]
"""

import sys

from repro.core import (WirelessConfig, balance, make_trace, simulate_wired,
                        sweep)
from repro.core.dse import INJECTIONS, THRESHOLDS
from repro.core.simulator import simulate_hybrid
from repro.core.workloads import WORKLOADS


def main():
    wl = sys.argv[1] if len(sys.argv) > 1 else "zfnet"
    assert wl in WORKLOADS, f"pick one of {list(WORKLOADS)}"
    tr = make_trace(wl)

    base = simulate_wired(tr)
    print(f"== {wl} on 3x3 x 144 TOPS (wired baseline) ==")
    print(f"execution time: {base.total_time*1e3:.3f} ms")
    print("bottleneck shares:",
          {k: f"{v:.0%}" for k, v in base.bottleneck_share().items()
           if v > 0.005})

    for bw in (64, 96):
        r = sweep(tr, wl, bw)
        print(f"\n== wireless {bw} Gb/s: DSE best speedup "
              f"{100*(r.best_speedup-1):.1f}% "
              f"(threshold={r.best_threshold}, "
              f"injection={r.best_injection}) ==")

    print("\nthreshold x injection heatmap (% speedup, 96 Gb/s):")
    b = base.total_time
    header = "thr\\p " + " ".join(f"{p:5.2f}" for p in INJECTIONS)
    print(header)
    for thr in THRESHOLDS:
        row = []
        for p in INJECTIONS:
            h = simulate_hybrid(tr, WirelessConfig(96e9 / 8, thr, p))
            row.append(100 * (b / h.total_time - 1))
        print(f"  {thr}   " + " ".join(f"{v:5.1f}" for v in row))

    bal = balance(tr, WirelessConfig(96e9 / 8))
    print(f"\nbeyond-paper balancer: {100*(bal.speedup_vs_wired-1):.1f}% "
          f"(injected {bal.injected_fraction:.0%} of eligible volume, "
          f"{bal.sim.wireless_energy_j*1e6:.1f} uJ wireless energy)")


if __name__ == "__main__":
    main()
