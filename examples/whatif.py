"""Critical-path + what-if walkthrough: what binds, and what would help.

For one paper workload and one LLM phase this records an event run,
then answers the two questions `repro.obs` exists for:

1. **What actually bounds the makespan?**  The critical path over the
   recorded dependency DAG (`obs.critpath`): the top-5 critical
   segments, the per-plane critical shares, and their divergence from
   the raw busy shares — when the two disagree, utilization is lying
   about what to optimise.
2. **What would happen if a resource got faster?**  Three what-if
   projections (`obs.whatif`) replayed straight from the trace —
   wireless bandwidth x2, a 2-channel x4-reuse-zone plan, DRAM x2 —
   each validated against an actual re-simulation where a network
   re-simulation exists.

The Perfetto export carries the critical path as its own process
("critpath"), so the blocking chain reads as one swim-lane at
https://ui.perfetto.dev.

    PYTHONPATH=src python examples/whatif.py [--quick] [--out=DIR]

``--quick`` drops the LLM phase for CI smoke runs.
"""

import os
import sys

from repro.core import NetworkConfig, make_trace
from repro.obs import (WhatIf, critical_vs_busy, export_chrome_trace,
                       mark_critical, project, validate)
from repro.sim import PacketSim


def inspect(wl: str, out_dir: str) -> None:
    net = NetworkConfig(bandwidth=96e9 / 8)
    tr = make_trace(wl)
    sim = PacketSim(tr, net, record=True)
    res = sim.run("static")
    st = res.trace

    # -- critical path --------------------------------------------------
    cp = mark_critical(st)      # also flags events for the Perfetto lane
    print(f"\n== {wl}: {res.total_time*1e3:.3f} ms over "
          f"{len(st.meta['layer_times'])} layers, "
          f"{len(cp.segments)} critical segments ==")
    print("top-5 critical segments (crit = incremental makespan charge):")
    for s in cp.top_segments(5):
        print(f"  L{s.layer:<3d} {s.track:12s} {s.name:8s} "
              f"crit={s.crit_dur*1e6:9.2f} us  ({s.plane})")
    cvb = critical_vs_busy(st, cp)
    print("plane        critical  busy")
    for p in sorted(set(cvb["critical"]) | set(cvb["busy"]),
                    key=lambda p: -cvb["critical"].get(p, 0.0)):
        print(f"  {p:10s} {cvb['critical'].get(p, 0.0):7.1%} "
              f"{cvb['busy'].get(p, 0.0):7.1%}")
    print(f"divergence (total variation): {cvb['divergence']:.2f} — "
          "how badly busy-share ranking misleads")

    # -- what-if projections --------------------------------------------
    knobs = [WhatIf(wireless_scale=2.0),
             WhatIf(n_channels=2, reuse_zones=4),
             WhatIf(dram_scale=2.0)]
    print("what-if projections (trace replay, no re-simulation):")
    for k in knobs:
        proj = project(st, k)
        line = (f"  {k.describe():20s} -> {proj.total_time*1e3:.3f} ms "
                f"({100*(proj.speedup-1):+.1f}%)")
        try:    # validate where the knob maps onto a network re-sim
            v = validate(tr, net, k)
            line += f"  [re-sim err {100*v['error']:.2f}%]"
        except ValueError:
            line += "  [no network re-sim for this knob]"
        print(line)

    # -- Perfetto export with the critical-path lane --------------------
    path = os.path.join(out_dir,
                        f"{wl.replace(':', '_')}_critpath.json")
    export_chrome_trace(st, path)
    print(f"wrote {path} (critical path = its own process at "
          "https://ui.perfetto.dev)")


def main():
    quick = "--quick" in sys.argv[1:]
    out_dir = next((a.split("=", 1)[1] for a in sys.argv[1:]
                    if a.startswith("--out=")), "experiments/traces")
    os.makedirs(out_dir, exist_ok=True)
    for wl in (["zfnet"] if quick else ["zfnet", "smollm_360m:prefill"]):
        inspect(wl, out_dir)


if __name__ == "__main__":
    main()
