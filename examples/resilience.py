"""Resilience walkthrough: dynamic conditions on the hybrid package.

Three acts, mirroring `repro.fault`'s layers:

1. **Inject** — a chiplet fail-stop plus an SNR fade mid-run; compare
   the wired-only counterfactual, the paper's static filter, and the
   online-reshard policy under the SAME degraded conditions.
2. **Explain** — record the faulted run and show where the critical
   path moved (the dead chip's inflated compute vs the faded wireless
   channel) relative to the fault-free run.
3. **Decide** — the `reshard_run` controller prices degraded mode vs
   a heartbeat-gated placement rebuild, and a retained-speedup
   mini-grid reproduces one row of the `fig_resilience` benchmark.

    PYTHONPATH=src python examples/resilience.py [workload] [--quick]

``--quick`` trims act 3's grid for CI smoke runs.
"""

import sys

from repro.core import NetworkConfig, make_trace
from repro.fault import (ChipFailure, FaultScenario, SnrFade,
                         default_scenario, reshard_run, resilience_sweep)
from repro.obs import critical_path, critical_vs_busy
from repro.sim import PacketSim


def inject(workload: str, net: NetworkConfig) -> FaultScenario:
    tr = make_trace(workload)
    n = tr.topo.config.n_chiplets
    sc = FaultScenario(
        chip_failures=(ChipFailure(n // 2, at_layer=tr.n_layers // 3),),
        snr_fades=(SnrFade(6.0),))
    print(f"== inject: {sc.describe()} on {workload} ==")
    sim0 = PacketSim(tr, net)
    simf = PacketSim(tr, net, faults=sc)
    wired0 = sim0.run_wired().total_time
    wiredf = simf.run_wired().total_time
    print(f"  wired-only:      {wired0 * 1e3:8.3f} ms fault-free -> "
          f"{wiredf * 1e3:8.3f} ms faulted")
    for pol in ("static", "online-reshard"):
        t0 = sim0.run(pol).total_time
        tf = simf.run(pol).total_time
        print(f"  {pol:<15s}  {t0 * 1e3:8.3f} ms fault-free -> "
              f"{tf * 1e3:8.3f} ms faulted  "
              f"(retained {(wiredf / tf) / (wired0 / t0):.1%})")
    return sc


def explain(workload: str, net: NetworkConfig,
            sc: FaultScenario) -> None:
    print("== explain: critical-path shift under the scenario ==")
    tr = make_trace(workload)
    for label, faults in (("fault-free", None), ("faulted", sc)):
        res = PacketSim(tr, net, record=True,
                        faults=faults).run("static")
        cp = critical_path(res.trace)
        crit = critical_vs_busy(res.trace, cp)["critical"]
        top = sorted(crit, key=crit.get, reverse=True)[:3]
        print(f"  {label:<10s} critical share: " + ", ".join(
            f"{k}={crit[k]:.0%}" for k in top))


def decide(workload: str, net: NetworkConfig, quick: bool) -> None:
    print("== decide: reshard controller + retained-speedup row ==")
    tr = make_trace(workload)
    sc = default_scenario(tr, k=1, fade_db=3.0)
    oc = reshard_run(workload, net, sc)
    verdict = "reshard" if oc.resharded else "stay degraded"
    print(f"  degraded {oc.degraded_time * 1e3:.3f} ms vs resharded "
          f"{oc.resharded_time * 1e3:.3f} ms (migration "
          f"{oc.migration_time * 1e3:.3f} ms) -> {verdict}")
    for ev in oc.events:
        print(f"  recovery event: layer {ev.step} {ev.kind} "
              f"workers={ev.workers} new_mesh={ev.new_mesh}")
    ks, fades = ((0, 1), (3.0,)) if quick else ((0, 1, 2), (3.0, 9.0))
    grid = resilience_sweep([workload], net, ks=ks, fades=fades)
    for cell, d in grid[workload]["cells"].items():
        print(f"  {cell:<10s} " + "  ".join(
            f"{p}={d[p]['retained']:.1%}" for p in d))


def main(argv) -> int:
    quick = "--quick" in argv
    args = [a for a in argv if not a.startswith("--")]
    workload = args[0] if args else "zfnet"
    net = NetworkConfig(bandwidth=96e9 / 8)
    sc = inject(workload, net)
    explain(workload, net, sc)
    decide(workload, net, quick)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
