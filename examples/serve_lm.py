"""Serving example: batched requests through prefill-free decode with a
tiny continuous-batching scheduler (slots are refilled as sequences
finish).

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import build_model
from repro.runtime.serve import ServeConfig, make_serve_fns


def main():
    cfg = reduced(ARCHS["smollm-360m"])
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    scfg = ServeConfig(max_len=64)
    _, decode_step, init_cache = make_serve_fns(cfg, scfg)
    dec = jax.jit(decode_step)

    SLOTS, MAX_NEW = 4, 12
    rng = np.random.default_rng(0)
    # request queue: (prompt tokens,)
    queue = [rng.integers(1, cfg.vocab_size, size=rng.integers(2, 6))
             for _ in range(10)]
    cache = init_cache(SLOTS, scfg.max_len)
    active = [None] * SLOTS          # (request_id, prompt, emitted)
    results = {}
    tok = jnp.zeros((SLOTS, 1), jnp.int32)
    pos = 0
    served = 0
    t0 = time.time()

    while (queue or any(active)) and pos < scfg.max_len - 1:
        for s in range(SLOTS):
            if active[s] is None and queue:
                rid = served
                served += 1
                active[s] = [rid, list(queue.pop(0)), []]
        # feed next token per slot (prompt token or generated)
        feed = np.zeros((SLOTS, 1), np.int32)
        for s, a in enumerate(active):
            if a is None:
                continue
            rid, prompt, out = a
            consumed = len(out) and None
            if prompt:
                feed[s, 0] = prompt.pop(0)
            # else keep feeding last generated token (already in `tok`)
            elif len(out):
                feed[s, 0] = out[-1]
        nxt, logits, cache = dec(params, cache, jnp.asarray(feed),
                                 jnp.int32(pos))
        nxt = np.asarray(nxt)
        for s, a in enumerate(active):
            if a is None:
                continue
            rid, prompt, out = a
            if not prompt:               # prompt consumed: we are generating
                out.append(int(nxt[s, 0]))
                if len(out) >= MAX_NEW:
                    results[rid] = out
                    active[s] = None     # slot freed for the next request
        pos += 1

    dt = time.time() - t0
    for rid in sorted(results):
        print(f"request {rid}: {results[rid]}")
    print(f"served {len(results)} requests in {dt:.1f}s "
          f"({pos} decode steps, {SLOTS} slots, continuous batching)")


if __name__ == "__main__":
    main()
