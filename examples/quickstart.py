"""Quickstart: train a reduced config for a few steps on CPU, checkpoint,
restore, and decode a few tokens.

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import restore, save
from repro.configs import ARCHS, reduced
from repro.data.pipeline import DataConfig, batch_for_model
from repro.optim.optimizers import OptimizerConfig
from repro.runtime.serve import ServeConfig, generate
from repro.runtime.train import TrainConfig, make_train_step


def main():
    cfg = reduced(ARCHS["smollm-360m"])
    print(f"arch={cfg.name} (reduced) params~{cfg.param_count()/1e6:.2f}M")

    tcfg = TrainConfig(optimizer=OptimizerConfig(lr=5e-3, warmup_steps=5,
                                                 total_steps=100),
                       remat=False)
    step_fn, init_fn = make_train_step(cfg, tcfg)
    state = init_fn(jax.random.PRNGKey(0))
    jit_step = jax.jit(step_fn)
    dcfg = DataConfig(seq_len=64, global_batch=8, vocab_size=cfg.vocab_size)

    for s in range(20):
        batch = {k: jnp.asarray(v)
                 for k, v in batch_for_model(cfg, dcfg, s).items()}
        state, m = jit_step(state, batch)
        if s % 5 == 0:
            print(f"step {s:3d}  ce={float(m['ce']):.3f}")

    with tempfile.TemporaryDirectory() as d:
        save(d, state, int(state["step"]))
        state = restore(d, state)
        print("checkpoint roundtrip ok, step", int(state["step"]))

    prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    toks = generate(state["params"], cfg, prompt, n_tokens=8,
                    scfg=ServeConfig(max_len=32))
    print("generated:", toks.tolist())


if __name__ == "__main__":
    main()
