"""Meta-observability walkthrough: profile the framework, then audit
the bench ledger.

Two halves, mirroring `repro.obs`'s two self-observation planes:

1. **Self-profiling** (`obs.profile`): run the paper sweep and an
   event-engine simulation under ``with profiling() as prof:``, print
   the hierarchical phase table (wall / calls / peak-ndarray-bytes,
   with the >=90%-attribution coverage footer), and export the phases
   merged with the recorded sim trace — the "framework" process sits
   next to the simulated-time planes at https://ui.perfetto.dev.
2. **The observatory** (`obs.report`): aggregate the committed
   ``experiments/bench_history.jsonl`` ledger + ``bench_results.json``
   into the self-contained HTML trend report, and run the robust MAD
   drift detector over every (row, metric) series.

    PYTHONPATH=src python examples/observatory.py [--quick] [--out=DIR]

``--quick`` trims the profiled sweep to one workload for CI smoke runs.
"""

import json
import os
import sys

from repro.core import NetworkConfig, make_trace
from repro.core.dse import sweep_all
from repro.obs import (build_html, detect_all, export_chrome_trace,
                       format_findings, profile_report, profiling)
from repro.sim import PacketSim


def profile_half(workloads, out_dir: str) -> None:
    traces = {wl: make_trace(wl) for wl in workloads}
    net = NetworkConfig(bandwidth=96e9 / 8)
    with profiling() as prof:
        sweep_all(traces)
        sim = PacketSim(traces[workloads[0]], net, record=True)
        res = sim.run("greedy")
    print("== framework self-profile: paper sweep + one event run ==")
    print(profile_report(prof))

    merged = {"sim": res.trace, "profile": prof.to_trace()}
    path = os.path.join(out_dir, "observatory_profile.json")
    export_chrome_trace(merged, path)
    print(f"\nPerfetto export (sim planes + 'framework' process) -> "
          f"{path}")


def observatory_half(out_dir: str) -> None:
    # reuse the bench tooling's ledger loader (stdlib, torn-tail safe)
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.run import history_path, load_history

    results_file = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "experiments", "bench_results.json")
    entries = load_history(history_path(results_file))
    results = {}
    if os.path.exists(results_file):
        with open(results_file) as f:
            results = json.load(f)

    print(f"\n== bench observatory: {len(entries)} ledger entries ==")
    findings = detect_all(entries)
    print(format_findings(findings) or
          "robust MAD detector: no series flagged")
    path = os.path.join(out_dir, "observatory.html")
    with open(path, "w", encoding="utf-8") as f:
        f.write(build_html(entries, results))
    print(f"HTML trend report -> {path}")


def main() -> int:
    quick = "--quick" in sys.argv
    out_dir = "experiments/traces"
    for a in sys.argv[1:]:
        if a.startswith("--out="):
            out_dir = a.split("=", 1)[1]
    os.makedirs(out_dir, exist_ok=True)
    profile_half(["zfnet"] if quick else ["zfnet", "resnet50", "vgg16"],
                 out_dir)
    observatory_half(out_dir)
    return 0


if __name__ == "__main__":
    sys.exit(main())
