"""End-to-end training driver: ~100M-param model, few hundred steps, with
async checkpointing, failure recovery, and loss reporting.

    PYTHONPATH=src python examples/train_lm.py --steps 200

This is the paper-kind-appropriate end-to-end example (the paper targets
accelerator platforms running DNN workloads; the LM is the workload our
framework trains).  By default uses a ~35M reduced footprint so a few
hundred steps finish on CPU; pass --full-360m to run the real
smollm-360m config if you have the cycles.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import (AsyncCheckpointer, latest_steps,
                                           restore)
from repro.configs import ARCHS, reduced
from repro.data.pipeline import DataConfig, batch_for_model
from repro.optim.optimizers import OptimizerConfig
from repro.runtime.fault_tolerance import StragglerMitigator
from repro.runtime.train import TrainConfig, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--full-360m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.full_360m:
        cfg = ARCHS[args.arch]
    else:
        # ~100M-scale training config: real vocab, shrunk depth/width
        cfg = reduced(ARCHS[args.arch], d_model=512, n_heads=8,
                      n_kv_heads=4, head_dim=64, d_ff=1536, n_layers=8,
                      vocab_size=ARCHS[args.arch].vocab_size)
    print(f"training {cfg.name}: ~{cfg.param_count()/1e6:.0f}M params")

    tcfg = TrainConfig(
        optimizer=OptimizerConfig(lr=3e-4, warmup_steps=20,
                                  total_steps=args.steps),
        remat=False)
    step_fn, init_fn = make_train_step(cfg, tcfg)
    jit_step = jax.jit(step_fn, donate_argnums=0)
    state = init_fn(jax.random.PRNGKey(0))

    ck = AsyncCheckpointer(args.ckpt_dir, keep=2)
    start = 0
    if args.resume and latest_steps(args.ckpt_dir):
        state = restore(args.ckpt_dir, state)
        start = int(jax.device_get(state["step"]))
        print(f"resumed from step {start}")

    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch,
                      vocab_size=cfg.vocab_size)
    straggler = StragglerMitigator()
    t_all = time.time()
    for s in range(start, args.steps):
        batch = {k: jnp.asarray(v)
                 for k, v in batch_for_model(cfg, dcfg, s).items()}
        t0 = time.time()
        state, m = jit_step(state, batch)
        m = jax.device_get(m)
        straggler.record(0, time.time() - t0)
        if s % 20 == 0 or s == args.steps - 1:
            tok_s = args.batch * args.seq / max(1e-9, time.time() - t0)
            print(f"step {s:4d}  ce={float(m['ce']):.4f} "
                  f"loss={float(m['loss']):.4f}  tok/s={tok_s:,.0f}")
        if s and s % 50 == 0:
            ck.save_async(state, s)
    ck.save_async(state, args.steps)
    ck.wait()
    print(f"done in {time.time()-t_all:.1f}s; checkpoints in "
          f"{args.ckpt_dir}: steps {latest_steps(args.ckpt_dir)}")


if __name__ == "__main__":
    main()
