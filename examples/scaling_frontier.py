"""The scale-out frontier, end to end: weak-scaled large-mesh packages
(4x4 .. 16x16 chiplets, per-chiplet Table-1 rates, perimeter-scaled
DRAM, FIXED wireless band) with and without distance-gated spatial
channel reuse.

The paper's 3x3 platform serves its wireless traffic from ONE shared
medium; this sweep shows where that global serialization point
collapses as the mesh grows — and how much of the hybrid speedup
spatially-separated reuse zones (graphene-agile-interconnect style)
recover.  ``--quick`` trims the mesh list and workload set for CI
smoke runs.

    PYTHONPATH=src python examples/scaling_frontier.py [workload ...]
        [--quick] [--bw=96]
"""

import sys

from repro.core import (ChannelPlan, NetworkConfig, reuse_plans,
                        scaled_config, scaling_summary, scaling_sweep,
                        simulate_hybrid, simulate_wired, make_trace)
from repro.core.dse import SCALING_GRIDS, grid_best_speedup


def main():
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    quick = "--quick" in sys.argv[1:]
    bw = float(next((a.split("=", 1)[1] for a in sys.argv[1:]
                     if a.startswith("--bw=")), "96"))
    workloads = args or (["zfnet", "googlenet", "transformer_cell"]
                         if quick else None)
    grids = ((4, 4), (8, 8)) if quick else SCALING_GRIDS

    results = scaling_sweep(workloads=workloads, grids=grids,
                            bandwidth_gbps=bw)
    print(f"== scale-out frontier @ {bw:.0f} Gb/s wireless "
          f"(weak-scaled per-chiplet Table-1 rates) ==")
    print(f"{'mesh':>7s} {'workload':>18s} {'wired ms':>9s} "
          f"{'1ch':>7s} {'reuse':>7s}  winning plan")
    for r in results:
        mark = " <- reuse recovers" if r.recovered > 0.005 else ""
        print(f"{r.grid[0]:>4d}x{r.grid[1]:<2d} {r.workload:>18s} "
              f"{r.wired_time*1e3:9.3f} {100*(r.best_single-1):+6.1f}% "
              f"{100*(r.best_reuse-1):+6.1f}%  {r.best_reuse_plan}{mark}")
    print("\nper-mesh summary (mean over workloads):")
    for mesh, s in scaling_summary(results).items():
        print(f"  {mesh:>7s}: single {100*(s['mean_single']-1):+6.1f}%  "
              f"reuse {100*(s['mean_reuse']-1):+6.1f}%  "
              f"(recovered {100*s['mean_recovered']:+.1f} pts "
              f"over {s['n']} workloads)")

    # one worked point: the largest mesh, best reuse plan vs one channel,
    # through the full analytic stack (same numbers as the batched DSE)
    grid = grids[-1]
    wl = (workloads or ["transformer_cell"])[-1]
    acc = scaled_config(grid)
    tr = make_trace(wl, acc)
    base = simulate_wired(tr).total_time
    plans = (ChannelPlan(1),) + reuse_plans(grid)
    print(f"\nworked point: {wl} on {grid[0]}x{grid[1]} "
          f"({acc.n_chiplets} chiplets, {acc.n_dram} DRAM):")
    for plan in plans:
        net = NetworkConfig(bandwidth=bw * 1e9 / 8, channels=plan)
        sp = grid_best_speedup(tr, net)
        h = simulate_hybrid(tr, net)
        print(f"  {plan.describe():>14s}: DSE-best {100*(sp-1):+6.1f}%  "
              f"(default thr/inj point: {100*(base/h.total_time-1):+6.1f}%)")


if __name__ == "__main__":
    main()
