"""Trend printer + observatory CLI for the bench-history ledger.

``benchmarks/run.py`` appends one JSONL line per (run, row) to
``experiments/bench_history.jsonl``; this tool renders the trajectory
of any metric as a text sparkline per row — the zero-dependency answer
to "did that refactor move the benchmarks?" — and fronts the
`repro.obs.report` observatory:

- ``--detect`` runs the robust MAD changepoint/drift detector over
  every (row, metric) series and **exits non-zero** when any series is
  flagged (the CI drift gate).  Wall-time series are excluded unless
  ``--include-wall`` — machine-to-machine wall noise must not fail CI.
- ``--html PATH`` writes the self-contained inline-SVG observatory
  report (trends per row/metric, wall-time trajectories, per-entry
  config-hash column, flagged points marked).

Usage:
  PYTHONPATH=src python benchmarks/history.py --plot-text
  PYTHONPATH=src python benchmarks/history.py --plot-text \
      --row fig_critpath_whatif --metric mean_div --last 20
  PYTHONPATH=src python benchmarks/history.py --detect
  PYTHONPATH=src python benchmarks/history.py --html \
      experiments/observatory.html
"""

from __future__ import annotations

import argparse
import json
import os
import sys

BARS = "▁▂▃▄▅▆▇█"


def _repo_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def sparkline(values) -> str:
    if not values:
        return ""
    lo, hi = min(values), max(values)
    if hi == lo:
        return BARS[0] * len(values)
    return "".join(BARS[int((v - lo) / (hi - lo) * (len(BARS) - 1))]
                   for v in values)


def plot_text(entries, row=None, metric=None, last=30, file=None):
    """One line per (row, metric): sparkline + first/latest values."""
    file = file if file is not None else sys.stdout   # late-bound for capture
    series = {}
    for e in entries:
        if row and e.get("row") != row:
            continue
        for k, v in (e.get("metrics") or {}).items():
            if metric and k != metric:
                continue
            series.setdefault((e["row"], k), []).append(float(v))
    if not series:
        print("no matching history entries", file=file)
        return
    wid = max(len(f"{r}.{k}") for r, k in series)
    for (r, k), vals in sorted(series.items()):
        vals = vals[-last:]
        print(f"{f'{r}.{k}':{wid}s}  {sparkline(vals)}  "
              f"{vals[0]:g} -> {vals[-1]:g}  (n={len(vals)})", file=file)


def main(argv=None) -> int:
    try:
        from benchmarks.run import history_path, load_history
    except ImportError:    # script run: benchmarks/ itself is sys.path[0]
        sys.path.insert(0, _repo_root())
        from benchmarks.run import history_path, load_history
    results_default = os.path.join(_repo_root(), "experiments",
                                   "bench_results.json")
    default = history_path(results_default)
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--plot-text", action="store_true",
                    help="render each metric's trajectory as a sparkline")
    ap.add_argument("--detect", action="store_true",
                    help="robust MAD drift/changepoint detection; exits "
                         "1 when any (row, metric) series is flagged")
    ap.add_argument("--include-wall", action="store_true",
                    help="also gate the us_per_call wall-time series in "
                         "--detect (off by default: wall noise across "
                         "machines must not fail CI)")
    ap.add_argument("--threshold", type=float, default=None,
                    help="robust z-score threshold for --detect/--html "
                         "(default: repro.obs.report's)")
    ap.add_argument("--html", metavar="PATH", default=None,
                    help="write the self-contained observatory HTML "
                         "report to PATH")
    ap.add_argument("--file", default=default,
                    help="history ledger (default: %(default)s)")
    ap.add_argument("--results", default=results_default,
                    help="committed bench_results.json for the report's "
                         "reference lines (default: %(default)s)")
    ap.add_argument("--row", default=None, help="restrict to one row")
    ap.add_argument("--metric", default=None,
                    help="restrict to one metric key")
    ap.add_argument("--last", type=int, default=30,
                    help="plot at most the last N runs (default 30)")
    args = ap.parse_args(argv)
    entries = load_history(args.file)
    if not entries:
        print(f"no history at {args.file}", file=sys.stderr)
        return 1
    if args.row:
        entries = [e for e in entries if e.get("row") == args.row]

    rc = 0
    if args.detect or args.html:
        from repro.obs import report as obs_report
        kw = {}
        if args.threshold is not None:
            kw["threshold"] = args.threshold
    if args.html:
        results = {}
        if os.path.exists(args.results):
            with open(args.results) as f:
                results = json.load(f)
        obs_report.write_html(args.html, entries, results, **kw)
        print(f"observatory report -> {args.html}")
    if args.detect:
        findings = obs_report.detect_all(
            entries, include_wall=args.include_wall, **kw)
        if findings:
            print(obs_report.format_findings(findings), file=sys.stderr)
            rc = 1
        else:
            print(f"history detect OK ({len(entries)} entries, "
                  "no series flagged)")
    if args.plot_text:
        plot_text(entries, args.row, args.metric, args.last)
    elif not (args.detect or args.html):
        rows = sorted({e.get("row") for e in entries if "row" in e})
        print(f"{len(entries)} entries, {len(rows)} rows: "
              + ", ".join(rows))
    return rc


if __name__ == "__main__":
    sys.exit(main())
