"""Trend printer for the bench-history ledger.

``benchmarks/run.py`` appends one JSONL line per (run, row) to
``experiments/bench_history.jsonl``; this tool renders the trajectory
of any metric as a text sparkline per row — the zero-dependency answer
to "did that refactor move the benchmarks?".

Usage:
  PYTHONPATH=src python benchmarks/history.py --plot-text
  PYTHONPATH=src python benchmarks/history.py --plot-text \
      --row fig_critpath_whatif --metric mean_div --last 20
"""

from __future__ import annotations

import argparse
import os
import sys

BARS = "▁▂▃▄▅▆▇█"


def sparkline(values) -> str:
    lo, hi = min(values), max(values)
    if hi == lo:
        return BARS[0] * len(values)
    return "".join(BARS[int((v - lo) / (hi - lo) * (len(BARS) - 1))]
                   for v in values)


def plot_text(entries, row=None, metric=None, last=30, file=sys.stdout):
    """One line per (row, metric): sparkline + first/latest values."""
    series = {}
    for e in entries:
        if row and e.get("row") != row:
            continue
        for k, v in (e.get("metrics") or {}).items():
            if metric and k != metric:
                continue
            series.setdefault((e["row"], k), []).append(float(v))
    if not series:
        print("no matching history entries", file=file)
        return
    wid = max(len(f"{r}.{k}") for r, k in series)
    for (r, k), vals in sorted(series.items()):
        vals = vals[-last:]
        print(f"{f'{r}.{k}':{wid}s}  {sparkline(vals)}  "
              f"{vals[0]:g} -> {vals[-1]:g}  (n={len(vals)})", file=file)


def main(argv=None) -> int:
    from benchmarks.run import history_path, load_history
    default = history_path(os.path.join(os.path.dirname(__file__), "..",
                                        "experiments",
                                        "bench_results.json"))
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--plot-text", action="store_true",
                    help="render each metric's trajectory as a sparkline")
    ap.add_argument("--file", default=default,
                    help="history ledger (default: %(default)s)")
    ap.add_argument("--row", default=None, help="restrict to one row")
    ap.add_argument("--metric", default=None,
                    help="restrict to one metric key")
    ap.add_argument("--last", type=int, default=30,
                    help="plot at most the last N runs (default 30)")
    args = ap.parse_args(argv)
    entries = load_history(args.file)
    if not entries:
        print(f"no history at {args.file}", file=sys.stderr)
        return 1
    if args.plot_text:
        plot_text(entries, args.row, args.metric, args.last)
    else:
        rows = sorted({e.get("row") for e in entries if "row" in e})
        print(f"{len(entries)} entries, {len(rows)} rows: "
              + ", ".join(rows))
    return 0


if __name__ == "__main__":
    sys.exit(main())
