"""LM-scale benchmarks: roofline table from the dry-run JSONs + the
paper's hybrid-plane schedule applied to each cell's collectives."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from repro.core.hybrid_schedule import balance_cell, sweep_cell

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_cells(dryrun_dir: str = DRYRUN_DIR) -> List[dict]:
    out = []
    for fn in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        if "__h_" in os.path.basename(fn):
            continue  # hillclimb-tagged variants live beside the baselines
        with open(fn) as f:
            out.append(json.load(f))
    return out


def roofline_table(mesh: str = "pod",
                   dryrun_dir: str = DRYRUN_DIR) -> List[dict]:
    """One row per (arch x shape): the three terms + dominant + useful
    ratio (EXPERIMENTS.md SRoofline)."""
    rows = []
    for c in load_cells(dryrun_dir):
        if c.get("mesh") != mesh or c.get("status") != "ok":
            continue
        r = c.get("roofline")
        if not r:
            continue
        rows.append({
            "arch": c["arch"], "shape": c["shape"],
            "t_compute": r["t_compute"], "t_memory": r["t_memory"],
            "t_collective": r["t_collective"], "dominant": r["dominant"],
            "useful_ratio": r.get("useful_ratio", 0.0),
            "step_time": max(r["t_compute"], r["t_memory"],
                             r["t_collective"]),
        })
    return rows


def hybrid_plane_report(mesh: str = "pod",
                        dryrun_dir: str = DRYRUN_DIR,
                        memory: str = "floor") -> List[dict]:
    """The paper's technique on each LM cell's compiled collectives:
    swept decision function + the closed-form balancer.

    memory="floor" uses the analytic HBM floor (resident state bytes from
    memory_analysis / HBM bandwidth) as the memory term — XLA's
    `bytes accessed` is a no-fusion upper bound that would mask every
    collective-bound cell (EXPERIMENTS.md §Roofline); "xla" keeps the raw
    metric for comparison."""
    from repro.launch.roofline import HBM_BW
    rows = []
    for c in load_cells(dryrun_dir):
        if c.get("mesh") != mesh or c.get("status") != "ok":
            continue
        r = c.get("roofline")
        if not r or not r.get("coll_per_op"):
            continue
        if memory == "floor":
            args = c.get("memory", {}).get("argument_size_in_bytes", 0)
            t_mem = args / HBM_BW
        else:
            t_mem = r["t_memory"]
        swept, (thr, p) = sweep_cell(r["coll_per_op"], r["t_compute"],
                                     t_mem)
        bal = balance_cell(r["coll_per_op"], r["t_compute"], t_mem)
        rows.append({
            "arch": c["arch"], "shape": c["shape"],
            "t_compute": r["t_compute"], "t_mem_floor": t_mem,
            "t_coll_wired": swept.t_coll_wired,
            "swept_step_speedup": swept.step_speedup,
            "swept_cfg": {"threshold": thr, "injection": p},
            "balancer_step_speedup": bal.step_speedup,
            "balancer_coll_speedup": bal.coll_speedup,
            "offloaded_GB": bal.offloaded_bytes / 1e9,
        })
    return rows


def dryrun_summary(dryrun_dir: str = DRYRUN_DIR) -> Dict:
    cells = load_cells(dryrun_dir)
    ok = [c for c in cells if c.get("status") == "ok"]
    return {"total": len(cells), "ok": len(ok),
            "failed": [f'{c["arch"]}/{c["shape"]}/{c["mesh"]}'
                       for c in cells if c.get("status") != "ok"]}
