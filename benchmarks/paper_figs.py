"""Benchmarks for the paper's own tables/figures (package-scale sim)."""

from __future__ import annotations

from repro.core import (WirelessConfig, balance, make_trace,
                        network_summary, network_sweep_all, simulate_hybrid,
                        simulate_wired, sweep_all, summary)
from repro.core.dse import INJECTIONS, THRESHOLDS, policy_sweep, sweep
from repro.core.workloads import WORKLOADS


def _traces():
    return {wl: make_trace(wl) for wl in WORKLOADS}


def fig2_bottleneck(traces=None) -> dict:
    """Fig. 2: % of execution time each element is the bottleneck."""
    traces = traces or _traces()
    rows = {}
    for wl, tr in traces.items():
        rows[wl] = simulate_wired(tr).bottleneck_share()
    return rows


def fig4_speedup(traces=None) -> dict:
    """Fig. 4: best speedup per workload at 64 and 96 Gb/s wireless."""
    traces = traces or _traces()
    res = sweep_all(traces)
    out = {}
    for r in res:
        out.setdefault(r.workload, {})[r.bandwidth_gbps] = r.best_speedup
    out["_summary"] = {bw: {"mean": m, "max": mx}
                       for bw, (m, mx) in summary(res).items()}
    return out


def fig5_heatmap(workload: str = "zfnet", bandwidth_gbps: int = 96,
                 traces=None) -> dict:
    """Fig. 5: speedup/degradation vs (distance threshold x injection)."""
    traces = traces or {workload: make_trace(workload)}
    tr = traces[workload]
    base = simulate_wired(tr).total_time
    grid = {}
    for thr in THRESHOLDS:
        row = []
        for p in INJECTIONS:
            cfg = WirelessConfig(bandwidth_gbps * 1e9 / 8, thr, p)
            row.append(round(100 * (base / simulate_hybrid(tr, cfg)
                                    .total_time - 1), 2))
        grid[thr] = row
    return {"workload": workload, "bandwidth_gbps": bandwidth_gbps,
            "injections": list(INJECTIONS), "grid": grid}


def fig4_mac_channels(traces=None) -> dict:
    """Beyond Fig. 4: how much of the idealized speedup survives a real
    MAC / a multi-channel plan.  Per workload and per (MAC protocol,
    channel plan): best speedup over the (threshold x injection x
    bandwidth) grid, via the batched engine."""
    traces = traces or _traces()
    results = network_sweep_all(traces)
    out = {}
    for r in results:
        table = r.best_by_network()
        ideal = table[("ideal", "1ch")]
        out[r.workload] = {
            f"{mac}/{plan}": {"best_speedup": sp,
                              "vs_ideal": sp - ideal}
            for (mac, plan), sp in table.items()}
        out[r.workload]["_best"] = {
            "config": r.best_config.describe(),
            "speedup": r.best_speedup}
    out["_summary"] = {f"{mac}/{plan}": {"mean": m, "max": mx}
                       for (mac, plan), (m, mx)
                       in network_summary(results).items()}
    return out


def balancer_vs_sweep(traces=None) -> dict:
    """Beyond-paper: analytic balancer vs the paper's DSE grid."""
    traces = traces or _traces()
    out = {}
    for wl, tr in traces.items():
        sw = sweep(tr, wl, 96)
        b = balance(tr, WirelessConfig(96e9 / 8))
        out[wl] = {"swept_best": sw.best_speedup,
                   "balancer": b.speedup_vs_wired,
                   "injected_fraction": b.injected_fraction}
    return out


def fig_sim_fidelity(traces=None) -> dict:
    """Beyond-paper fidelity figure: event-driven vs analytic, per
    workload.  The striped link model must reproduce the analytic
    hybrid speedup (the paper's cut idealization, time-resolved); the
    adaptive and fixed-XY models quantify how much network time that
    idealization hides."""
    from repro.sim import fidelity_report
    return fidelity_report(traces or _traces())


def fig_sim_policies(traces=None) -> dict:
    """Beyond-paper policy figure: the paper's offline-swept static
    optimum vs online wired/wireless load-balancing policies (greedy
    per-packet, adaptive per-layer) and the offline water-filling
    oracle, all event-driven on the same traces."""
    from repro.sim import policy_report
    return policy_report(traces or _traces())


#: LLM phases the critical-path/what-if figure adds to the 15 paper
#: workloads (the cheapest LLM pair — the row runs in the --check gate)
CRITPATH_LLM_WORKLOADS = ("smollm_360m:prefill", "smollm_360m:decode")

#: pinned workloads the guided sweep is validated on (same set as
#: tests/test_critpath.py)
GUIDED_WORKLOADS = ("zfnet", "resnet50", "gnmt")


def fig_critpath_whatif(traces=None) -> dict:
    """Beyond-paper decision figure: what is *binding* vs what is *busy*,
    and how far trace-driven projection can be trusted.

    Per workload (15 paper + 2 LLM phases), one recorded event run at
    96 Gb/s: the critical-path share vs raw busy share per plane (their
    total-variation divergence is the headline — a large value means a
    utilization-driven balancer would optimise the wrong plane), the
    critical-path-sum == makespan invariant, and the what-if projection
    error against actual re-simulation for ±25% wireless bandwidth.
    Also reports `dse.whatif_guided` vs exhaustive `sweep_all` on the
    pinned golden workloads: same best point, fraction of the grid
    evaluated.
    """
    from repro.core import NetworkConfig
    from repro.core.dse import whatif_guided
    from repro.obs import WhatIf, critical_path, critical_vs_busy, validate
    from repro.sim import PacketSim
    traces = dict(traces or _traces())    # copy: rows share the cache
    for wl in CRITPATH_LLM_WORKLOADS:
        traces.setdefault(wl, make_trace(wl))
    net = NetworkConfig(bandwidth=96e9 / 8)
    out = {}
    for wl, tr in traces.items():
        r = PacketSim(tr, net, record=True).run("static")
        cp = critical_path(r.trace)
        cvb = critical_vs_busy(r.trace, cp)
        errs = {s: validate(tr, net, WhatIf(wireless_scale=s))["error"]
                for s in (0.75, 1.25)}
        out[wl] = {
            "divergence": cvb["divergence"],
            "critical_top": max(cvb["critical"], key=cvb["critical"].get),
            "busy_top": max(cvb["busy"], key=cvb["busy"].get),
            "proj_err_bw075": errs[0.75],
            "proj_err_bw125": errs[1.25],
            "critpath_sum_ok": bool(
                abs(cp.total - r.total_time) <= 1e-12 * r.total_time),
        }
    golden = {wl: traces[wl] for wl in GUIDED_WORKLOADS}
    guided = whatif_guided(golden)
    exhaustive = sweep_all(golden)
    ex_best = {(r.workload, r.bandwidth_gbps):
               (r.best_threshold, r.best_injection) for r in exhaustive}
    g_best = {(r.workload, r.bandwidth_gbps):
              (r.best_threshold, r.best_injection) for r in guided.results}
    rows = [v for v in out.values() if isinstance(v, dict)]
    out["_summary"] = {
        "mean_divergence": sum(r["divergence"] for r in rows) / len(rows),
        "max_divergence": max(r["divergence"] for r in rows),
        "worst_proj_err": max(max(r["proj_err_bw075"],
                                  r["proj_err_bw125"]) for r in rows),
        "all_sum_ok": all(r["critpath_sum_ok"] for r in rows),
        "guided_matches_exhaustive": ex_best == g_best,
        "guided_fraction": guided.evaluated_fraction,
    }
    return out


LLM_FIG_WORKLOADS = (
    "smollm_360m:prefill", "smollm_360m:decode",
    "gemma2_2b:prefill", "gemma2_2b:decode",
    "chatglm3_6b:prefill", "chatglm3_6b:decode",
    "qwen2p5_32b:prefill", "qwen2p5_32b:decode",
    "mixtral_8x22b:prefill", "mixtral_8x22b:decode",
    "kimi_k2:prefill", "kimi_k2:decode",
)


def fig_llm_collectives(traces=None) -> dict:
    """Beyond-paper LLM-collectives figure: wired vs hybrid speedup on
    collective-heavy LLM traffic.

    Per LLM workload (dense/MoE x prefill/decode, tensor-/expert-
    parallel mappings with their all-reduce / all-to-all boundaries):
    the collective share of NoP bytes, the wireless-eligible multicast
    share, the DSE-best hybrid speedup at 64/96 Gb/s, and the adaptive
    event-driven policy — the scenario frontier's headline table.
    """
    traces = traces or {wl: make_trace(wl) for wl in LLM_FIG_WORKLOADS}
    res = sweep_all(traces)
    best = {}
    for r in res:
        best.setdefault(r.workload, {})[r.bandwidth_gbps] = r.best_speedup
    out = {}
    for wl, tr in traces.items():
        total = sum(m.nbytes for m in tr.messages) or 1.0
        coll = sum(m.nbytes for m in tr.messages if m.kind == "coll")
        mcast = sum(m.nbytes for m in tr.messages
                    if m.kind == "coll" and len(m.dsts) > 1)
        ps = policy_sweep(tr, wl)
        out[wl] = {
            "wired_ms": simulate_wired(tr).total_time * 1e3,
            "collective_byte_share": coll / total,
            "broadcast_natured_share": mcast / total,
            "best_speedup_64": best[wl][64],
            "best_speedup_96": best[wl][96],
            "adaptive_policy_speedup": ps.policy_speedups["adaptive"],
        }
    for phase in ("prefill", "decode"):
        rows = [v for wl, v in out.items() if wl.endswith(phase)]
        if not rows:            # caller passed a single-phase subset
            continue
        out[f"_summary_{phase}"] = {
            "mean_best_96": sum(r["best_speedup_96"] for r in rows) / len(rows),
            "max_best_96": max(r["best_speedup_96"] for r in rows),
            "mean_collective_share": sum(r["collective_byte_share"]
                                         for r in rows) / len(rows),
        }
    return out


def fig_scaling_frontier(traces=None) -> dict:
    """Beyond-paper scale-out figure: large-mesh packages x spatial
    channel reuse.

    Per mesh in `dse.SCALING_GRIDS` (weak-scaled: per-chiplet Table-1
    rates, perimeter-scaled DRAM, FIXED wireless band) and per paper
    workload: the best DSE speedup with (i) the single shared wireless
    channel and (ii) distance-gated spatial reuse zones — where the
    global serialization point collapses at scale and how much speedup
    reuse recovers.  (``traces`` is unused: every mesh re-derives its
    own traces.)
    """
    from repro.core.dse import scaling_sweep, scaling_summary
    results = scaling_sweep()
    out = {}
    for r in results:
        out.setdefault(f"{r.grid[0]}x{r.grid[1]}", {})[r.workload] = {
            "wired_ms": r.wired_time * 1e3,
            "best_single": r.best_single,
            "best_reuse": r.best_reuse,
            "recovered": r.recovered,
            "reuse_plan": r.best_reuse_plan,
        }
    out["_summary"] = scaling_summary(results)
    return out


def hetero_codesign(traces=None) -> dict:
    """Beyond-paper heterogeneity figure: placement/co-design search on
    heterogeneous packages (repro.arch), per catalog mix x paper
    workload.

    Headline numbers per cell: the hybrid-vs-wired speedup at the
    co-designed placement, and the best-vs-worst placement spread with
    and without the wireless plane — does the single-hop broadcast
    medium make heterogeneous packages placement-insensitive, and does
    the hybrid speedup survive heterogeneity (vs the paper's
    homogeneous 10% mean / 20% max)?  (``traces`` is unused: each
    placement re-derives its own trace.)
    """
    from repro.core.dse import hetero_sweep, hetero_summary
    results = hetero_sweep()
    out = {}
    for r in results:
        out.setdefault(r.mix, {})[r.workload] = {
            "package": r.package,
            "wired_best_ms": r.wired.t_wired * 1e3,
            "hybrid_best_ms": r.hybrid.t_hybrid * 1e3,
            "speedup_hybrid": r.speedup_hybrid,
            "speedup_codesigned": r.speedup_codesigned,
            "spread_wired": r.spread_wired,
            "spread_hybrid": r.spread_hybrid,
            "evaluations": r.n_evaluations,
        }
    out["_summary"] = hetero_summary(results)
    return out


def mapping_sensitivity(traces=None) -> dict:
    """The paper stresses mapping optimality (optimally-mapped workloads
    are a precondition of its study): communication-aware stage boundaries
    vs MAC-only balancing, wired execution time."""
    from repro.core.mapper import pipeline_mapping
    from repro.core.topology import build_topology
    from repro.core.traffic import build_trace
    from repro.core.workloads import get_workload
    topo = build_topology()
    out = {}
    for wl in ("resnet50", "googlenet", "transformer", "zfnet"):
        layers = get_workload(wl)
        t_aware = simulate_wired(build_trace(
            layers, pipeline_mapping(layers, topo), topo)).total_time
        t_naive = simulate_wired(build_trace(
            layers, pipeline_mapping(layers, topo, refine=False),
            topo)).total_time
        out[wl] = {"comm_aware_ms": t_aware * 1e3,
                   "mac_only_ms": t_naive * 1e3,
                   "ratio": t_naive / t_aware}
    return out


RESILIENCE_POLICIES = ("static", "adaptive", "online-reshard")


def fig_resilience(traces=None) -> dict:
    """Beyond-paper resilience figure: speedup retained under dynamic
    conditions — chiplet fail-stops and SNR-degraded channels.

    Per workload (15 paper + 2 LLM phases) and per (k fail-stops x
    package fade) cell: how much of each policy's fault-free hybrid
    speedup survives, with the wired-only counterfactual degraded by
    the same chip events.  The online-reshard row routes through the
    `repro.fault` controller (heartbeat detection, `ElasticPlan` gate,
    rate-derated placement rebuild, migration-priced min-anchor); by
    construction it is never slower than the static or adaptive rows
    on any cell — ``_summary["reshard_never_slower"]`` asserts it.
    (``traces`` is unused beyond naming: the sweep re-derives per-era
    traces itself.)
    """
    from repro.core.dse import resilience_sweep_all
    names = list(traces or WORKLOADS)
    for wl in CRITPATH_LLM_WORKLOADS:
        if wl not in names:
            names.append(wl)
    res = resilience_sweep_all(names)
    out = {}
    cells = []
    for wl in names:
        row = res[wl]
        out[wl] = {cell: {p: d[p]["retained"]
                          for p in RESILIENCE_POLICIES}
                   for cell, d in row["cells"].items()}
        cells.extend(row["cells"].values())
    out["_summary"] = {
        "mean_retained": {p: sum(c[p]["retained"] for c in cells)
                          / len(cells) for p in RESILIENCE_POLICIES},
        "worst_retained": {p: min(c[p]["retained"] for c in cells)
                           for p in RESILIENCE_POLICIES},
        "reshard_never_slower": all(
            c["online-reshard"]["time"] <= c[p]["time"] * (1 + 1e-9)
            for c in cells for p in RESILIENCE_POLICIES),
        "resharded_cells": int(sum(c["online-reshard"]["resharded"]
                                   for c in cells)),
        "n_cells": len(cells),
    }
    return out


def edp_report(traces=None) -> dict:
    """EDP (the GEMINI objective) wired vs hybrid-at-DSE-optimum."""
    from repro.core.dse import sweep
    traces = traces or _traces()
    out = {}
    for wl, tr in traces.items():
        w = simulate_wired(tr)
        r = sweep(tr, wl, 96)
        h = simulate_hybrid(tr, WirelessConfig(
            96e9 / 8, r.best_threshold, r.best_injection))
        out[wl] = {"wired_edp_uJs": w.edp * 1e6,
                   "hybrid_edp_uJs": h.edp * 1e6,
                   "edp_gain": w.edp / h.edp if h.edp else 1.0}
    return out
