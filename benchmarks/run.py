"""Benchmark driver: one function per paper table/figure + the LM-scale
reports.  Prints ``name,us_per_call,derived`` CSV rows and writes the full
structured results to experiments/bench_results.json (keys sorted, and
``--only <row>`` merges into the existing file — so adding or refreshing
one row churns only that row's diff).  Each run also persists a
``_bench_meta`` block — per-row wall time and the derived-metric string —
so the perf trajectory is machine-readable from the committed file.

``--check`` turns the driver into a regression gate: it re-runs the
requested rows (all rows with committed metrics when no ``--only`` is
given), parses each derived metric numerically, and exits non-zero with
a readable delta table if anything drifts beyond the row's tolerance
from the committed ``bench_results.json``.  Check mode never writes.

Every write run also appends one line per row to
``experiments/bench_history.jsonl`` (wall time, derived metrics,
provenance hash) — the long-horizon perf ledger ``benchmarks/history.py
--plot-text`` renders, and the fallback ``--check`` gates against when
the results file lacks ``_bench_meta``."""

from __future__ import annotations

import argparse
import json
import os
import re
import sys
import time

#: the _bench_meta key holding per-row wall time + derived metrics
META_KEY = "_bench_meta"

#: Per-row relative tolerance overrides for ``--check``.  CI gates
#: EVERY row with committed ``_bench_meta`` (``--check`` with no
#: ``--only``), so this table is the one place that documents how much
#: drift each row may absorb and why.  Every row is seeded and
#: deterministic; the default only needs to cover float jitter across
#: BLAS builds / platforms.  The full row table:
#:
#:   row                       rtol     nature of the row
#:   ------------------------  -------  -----------------------------
#:   fig2_bottleneck           default  closed-form GEMINI shares
#:   fig4_speedup              default  batched sweep, closed form
#:   fig5_heatmap              default  batched sweep, closed form
#:   fig4_mac_channels         default  batched sweep, closed form
#:   sim_fidelity              default  event engine vs analytic
#:   sim_policies              default  seeded policies, deterministic
#:   fig_critpath_whatif       default  DAG replay, exact arithmetic
#:   llm_collectives           default  collective lowering, closed form
#:   scaling_frontier          default  batched sweep, closed form
#:   hetero_codesign           1e-4     seeded annealer: accept/reject
#:                                      branches sit on float compares,
#:                                      so cross-platform reassociation
#:                                      can flip a late SA step
#:   balancer_vs_sweep         default  integer win counts
#:   mapping_sensitivity       default  closed-form ratio
#:   edp_report                default  closed-form energy-delay
#:   roofline_table_*          default  integer cell counts
#:   hybrid_plane_report       default  dryrun-derived, deterministic
#:   dryrun_summary            default  integer ok counts
#:   fig_resilience            1e-6     deterministic scenario grid
#:                                      (explicit entry: retained
#:                                      ratios divide two engine
#:                                      totals, so float jitter
#:                                      compounds — keep at default
#:                                      unless a platform drifts)
#:
#: Raise a row's entry here (with a rationale line above it) if a
#: legitimate source of run-to-run variance ever lands; never widen
#: "default" to paper over a real regression.
CHECK_RTOL = {
    "default": 1e-6,
    "hetero_codesign": 1e-4,
    "fig_resilience": 1e-6,
}
CHECK_ATOL = 1e-12

_NUM = r"[-+]?(?:\d+\.?\d*|\.\d+)(?:[eE][-+]?\d+)?"


def parse_derived(derived: str) -> dict:
    """``"k=v;k=v"`` -> {key: float} for every numerically-comparable v.

    Handles plain/scientific floats, ``12.3%``, ``2.29x``, ``13/15``
    fractions (compared as a/b), and ``True``/``False`` booleans.
    """
    out = {}
    for part in str(derived).split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        v = v.strip()
        if v in ("True", "False"):
            out[k] = float(v == "True")
            continue
        m = re.fullmatch(rf"({_NUM})\s*/\s*({_NUM})", v)
        if m:
            a, b = float(m.group(1)), float(m.group(2))
            out[k] = a / b if b else a
            continue
        m = re.fullmatch(rf"({_NUM})\s*[%x]?", v)
        if m:
            out[k] = float(m.group(1))
    return out


def _run(name, fn, derived_fn):
    t0 = time.perf_counter()
    result = fn()
    us = (time.perf_counter() - t0) * 1e6
    derived = derived_fn(result)
    print(f"{name},{us:.0f},{derived}")
    return result, {"us_per_call": round(us, 1), "derived": derived}


# ---------------------------------------------------------------------------
# run history: one JSONL line per (run, row), appended on every write run
# ---------------------------------------------------------------------------

def history_path(results_file: str) -> str:
    """The history ledger lives next to the results file."""
    return os.path.join(os.path.dirname(results_file) or ".",
                        "bench_history.jsonl")


def append_history(path: str, meta: dict) -> None:
    """Append one line per row: wall time, derived metrics (raw string
    and parsed), and the provenance hash of the row's outcome."""
    from repro.obs.provenance import config_hash
    ts = time.time()
    with open(path, "a") as f:
        for name, m in sorted(meta.items()):
            f.write(json.dumps({
                "ts": round(ts, 3),
                "row": name,
                "us_per_call": m["us_per_call"],
                "derived": m["derived"],
                "metrics": parse_derived(m["derived"]),
                "hash": config_hash({"row": name,
                                     "derived": m["derived"]}),
            }, sort_keys=True) + "\n")


def load_history(path: str) -> list:
    """All parseable entries of the ledger, oldest first."""
    entries = []
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    entries.append(json.loads(line))
                except json.JSONDecodeError:
                    continue       # a torn tail line never blocks --check
    return entries


def latest_by_row(entries: list) -> dict:
    """row -> its most recent ledger entry."""
    out = {}
    for e in entries:
        if "row" in e:
            out[e["row"]] = e
    return out


def check_rows(rows, committed: dict, rtol: float | None = None) -> int:
    """Regression gate: re-run ``rows``, diff against ``committed``.

    Returns the number of drifted metrics (0 = pass) and prints a delta
    table for anything out of tolerance.
    """
    meta = committed.get(META_KEY, {})
    deltas = []
    for name, fn, derived_fn in rows:
        if name not in meta:
            deltas.append((name, "(row)", "missing from committed "
                           f"{META_KEY}", "", ""))
            continue
        want = parse_derived(meta[name]["derived"])
        result, m = _run(name, fn, derived_fn)
        got = parse_derived(m["derived"])
        tol = rtol if rtol is not None else CHECK_RTOL.get(
            name, CHECK_RTOL["default"])
        for k, w in want.items():
            if k not in got:
                deltas.append((name, k, f"{w:g}", "(missing)", ""))
                continue
            g = got[k]
            if abs(g - w) > CHECK_ATOL + tol * abs(w):
                rel = abs(g - w) / (abs(w) or 1.0)
                deltas.append((name, k, f"{w:g}", f"{g:g}",
                               f"{100 * rel:.3g}%"))
        for k in got.keys() - want.keys():
            deltas.append((name, k, "(missing)", f"{got[k]:g}", ""))
    if deltas:
        hdrs = ("row", "metric", "committed", "got", "drift")
        wid = [max(len(str(r[i])) for r in deltas + [hdrs])
               for i in range(len(hdrs))]
        print("\nBENCH CHECK FAILED — metrics out of tolerance:",
              file=sys.stderr)
        for r in [hdrs] + deltas:
            print("  " + "  ".join(str(c).ljust(w)
                                   for c, w in zip(r, wid)),
                  file=sys.stderr)
    else:
        print(f"bench check OK ({len(rows)} row(s) within tolerance)")
    return len(deltas)


def main(argv=None) -> int:
    from benchmarks import lm_scale, paper_figs
    from repro.core import make_trace
    from repro.core.workloads import WORKLOADS

    # traces are built on first use: --only rows that never read them
    # (hetero_codesign, roofline/dryrun) and the unknown-row error path
    # skip the 15-workload build entirely
    _traces = {}

    def traces():
        if not _traces:
            _traces.update({wl: make_trace(wl) for wl in WORKLOADS})
        return _traces

    results = {}
    rows = [
        ("fig2_bottleneck",
         lambda: paper_figs.fig2_bottleneck(traces()),
         lambda r: "mean_nop_share=%.2f" % (
             sum(v["nop"] for v in r.values()) / len(r))),
        ("fig4_speedup",
         lambda: paper_figs.fig4_speedup(traces()),
         lambda r: "mean64=%.1f%%;mean96=%.1f%%;max96=%.1f%%" % (
             100 * (r["_summary"][64]["mean"] - 1),
             100 * (r["_summary"][96]["mean"] - 1),
             100 * (r["_summary"][96]["max"] - 1))),
        ("fig5_heatmap",
         lambda: paper_figs.fig5_heatmap(traces=traces()),
         lambda r: "peak=%.1f%%;worst=%.1f%%" % (
             max(max(v) for v in r["grid"].values()),
             min(min(v) for v in r["grid"].values()))),
        ("fig4_mac_channels",
         lambda: paper_figs.fig4_mac_channels(traces()),
         lambda r: "ideal_mean=%.1f%%;tdma_mean=%.1f%%;token_mean=%.1f%%" % (
             100 * (r["_summary"]["ideal/1ch"]["mean"] - 1),
             100 * (r["_summary"]["tdma/1ch"]["mean"] - 1),
             100 * (r["_summary"]["token/1ch"]["mean"] - 1))),
        ("sim_fidelity",
         lambda: paper_figs.fig_sim_fidelity(traces()),
         lambda r: "striped_err=%.1e;adaptive_err=%.1f%%;xy_err=%.1f%%" % (
             r["_summary"]["striped"]["worst_speedup_rel_err"],
             100 * r["_summary"]["adaptive"]["worst_speedup_rel_err"],
             100 * r["_summary"]["xy"]["worst_speedup_rel_err"])),
        ("sim_policies",
         lambda: paper_figs.fig_sim_policies(traces()),
         lambda r: "adaptive_beats_grid=%s;greedy_beats_grid=%s;"
         "mean_adaptive=%.1f%%" % (
             r["_summary"]["adaptive"]["beats_grid"],
             r["_summary"]["greedy"]["beats_grid"],
             100 * (r["_summary"]["adaptive"]["mean_speedup"] - 1))),
        ("fig_critpath_whatif",
         lambda: paper_figs.fig_critpath_whatif(traces()),
         lambda r: "mean_div=%.3f;max_div=%.3f;worst_proj_err=%.2f%%;"
         "sum_ok=%s;guided_match=%s;guided_frac=%.2f" % (
             r["_summary"]["mean_divergence"],
             r["_summary"]["max_divergence"],
             100 * r["_summary"]["worst_proj_err"],
             r["_summary"]["all_sum_ok"],
             r["_summary"]["guided_matches_exhaustive"],
             r["_summary"]["guided_fraction"])),
        ("llm_collectives",
         paper_figs.fig_llm_collectives,
         lambda r: "prefill_mean96=%.1f%%;decode_mean96=%.1f%%;"
         "prefill_coll_share=%.2f" % (
             100 * (r["_summary_prefill"]["mean_best_96"] - 1),
             100 * (r["_summary_decode"]["mean_best_96"] - 1),
             r["_summary_prefill"]["mean_collective_share"])),
        ("scaling_frontier",
         paper_figs.fig_scaling_frontier,
         lambda r: "mean8x8_single=%.1f%%;mean8x8_reuse=%.1f%%;"
         "mean16x16_single=%.1f%%;mean16x16_reuse=%.1f%%" % (
             100 * (r["_summary"]["8x8"]["mean_single"] - 1),
             100 * (r["_summary"]["8x8"]["mean_reuse"] - 1),
             100 * (r["_summary"]["16x16"]["mean_single"] - 1),
             100 * (r["_summary"]["16x16"]["mean_reuse"] - 1))),
        ("hetero_codesign",
         paper_figs.hetero_codesign,
         lambda r: "mean_codesign=%.1f%%;max_codesign=%.1f%%;"
         "spread_shrunk=%d/%d" % (
             100 * (r["_summary"]["_overall"]["mean_speedup_codesigned"]
                    - 1),
             100 * (r["_summary"]["_overall"]["max_speedup_codesigned"]
                    - 1),
             r["_summary"]["_overall"]["spread_shrunk"],
             r["_summary"]["_overall"]["n"])),
        ("balancer_vs_sweep",
         lambda: paper_figs.balancer_vs_sweep(traces()),
         lambda r: "balancer_wins=%d/%d" % (
             sum(v["balancer"] >= v["swept_best"] - 1e-9
                 for v in r.values()), len(r))),
        ("mapping_sensitivity",
         paper_figs.mapping_sensitivity,
         lambda r: "mac_only/comm_aware=%.2fx" % (
             sum(v["ratio"] for v in r.values()) / len(r))),
        ("edp_report",
         lambda: paper_figs.edp_report(traces()),
         lambda r: "mean_edp_gain=%.3f;max=%.3f" % (
             sum(v["edp_gain"] for v in r.values()) / len(r),
             max(v["edp_gain"] for v in r.values()))),
        ("fig_resilience",
         paper_figs.fig_resilience,
         lambda r: "static_ret=%.3f;adaptive_ret=%.3f;reshard_ret=%.3f;"
         "never_slower=%s;resharded=%d/%d" % (
             r["_summary"]["mean_retained"]["static"],
             r["_summary"]["mean_retained"]["adaptive"],
             r["_summary"]["mean_retained"]["online-reshard"],
             r["_summary"]["reshard_never_slower"],
             r["_summary"]["resharded_cells"],
             r["_summary"]["n_cells"])),
        ("roofline_table_baseline",
         lm_scale.roofline_table,
         lambda r: "cells=%d" % len(r)),
        ("roofline_table_optimized",
         lambda: lm_scale.roofline_table(
             "pod", lm_scale.DRYRUN_DIR + "_opt"),
         lambda r: "cells=%d" % len(r)),
        ("hybrid_plane_report",
         lambda: lm_scale.hybrid_plane_report(
             "pod", lm_scale.DRYRUN_DIR + "_opt"),
         lambda r: "cells=%d;max_coll_speedup=%.2f;mean_step_speedup=%.3f"
         % (len(r), max((x["balancer_coll_speedup"] for x in r),
                        default=1.0),
            (sum(x["balancer_step_speedup"] for x in r) / max(1, len(r))))),
        ("dryrun_summary",
         lm_scale.dryrun_summary,
         lambda r: "ok=%d/%d" % (r["ok"], r["total"])),
    ]
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", action="append", metavar="ROW",
                    help="run only the named row (repeatable); the "
                         "result is merged into bench_results.json")
    ap.add_argument("--check", action="store_true",
                    help="regression gate: re-run the requested rows and "
                         "fail if any derived metric drifts beyond "
                         "tolerance from the committed results file "
                         "(never writes)")
    ap.add_argument("--rtol", type=float, default=None,
                    help="override the per-row relative tolerance for "
                         "--check")
    ap.add_argument("--file", default=None, metavar="PATH",
                    help="results file (default: "
                         "experiments/bench_results.json)")
    args = ap.parse_args(argv)
    if args.only:
        known = {name for name, _, _ in rows}
        unknown = sorted(set(args.only) - known)
        if unknown:
            ap.error(f"unknown row(s) {unknown}; pick from {sorted(known)}")
        rows = [r for r in rows if r[0] in set(args.only)]

    out = args.file or os.path.join(os.path.dirname(__file__), "..",
                                    "experiments", "bench_results.json")

    if args.check:
        committed = {}
        if os.path.exists(out):
            with open(out) as f:
                committed = json.load(f)
        if not committed.get(META_KEY):
            # results file absent or predates _bench_meta: fall back to
            # the latest bench_history.jsonl entry per row
            hist = latest_by_row(load_history(history_path(out)))
            if not hist:
                print(f"bench check: no committed results at {out} and "
                      f"no history at {history_path(out)}",
                      file=sys.stderr)
                return 2
            print(f"bench check: {out} lacks {META_KEY}; falling back "
                  "to the latest bench_history.jsonl entries",
                  file=sys.stderr)
            committed[META_KEY] = {
                row: {"derived": e["derived"],
                      "us_per_call": e.get("us_per_call", 0.0)}
                for row, e in hist.items()}
        if not args.only:   # default: gate every row with committed meta
            rows = [r for r in rows
                    if r[0] in committed.get(META_KEY, {})]
        print("name,us_per_call,derived")
        return 1 if check_rows(rows, committed, args.rtol) else 0

    meta = {}
    print("name,us_per_call,derived")
    for name, fn, d in rows:
        results[name], meta[name] = _run(name, fn, d)

    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    merged = {}
    if args.only and os.path.exists(out):   # --only refreshes rows in place
        with open(out) as f:                # (full runs rewrite the file,
            merged = json.load(f)           # so removed rows don't linger)
    merged.update(results)
    merged.setdefault(META_KEY, {}).update(meta)
    with open(out, "w") as f:
        json.dump(merged, f, indent=1, sort_keys=True, default=str)
    append_history(history_path(out), meta)
    return 0


if __name__ == "__main__":
    sys.exit(main())
