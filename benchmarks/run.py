"""Benchmark driver: one function per paper table/figure + the LM-scale
reports.  Prints ``name,us_per_call,derived`` CSV rows and writes the full
structured results to experiments/bench_results.json (keys sorted, and
``--only <row>`` merges into the existing file — so adding or refreshing
one row churns only that row's diff)."""

from __future__ import annotations

import argparse
import json
import os
import time


def _run(name, fn, derived_fn):
    t0 = time.time()
    result = fn()
    us = (time.time() - t0) * 1e6
    derived = derived_fn(result)
    print(f"{name},{us:.0f},{derived}")
    return name, result


def main(argv=None) -> None:
    from benchmarks import lm_scale, paper_figs
    from repro.core import make_trace
    from repro.core.workloads import WORKLOADS

    # traces are built on first use: --only rows that never read them
    # (hetero_codesign, roofline/dryrun) and the unknown-row error path
    # skip the 15-workload build entirely
    _traces = {}

    def traces():
        if not _traces:
            _traces.update({wl: make_trace(wl) for wl in WORKLOADS})
        return _traces

    results = {}
    rows = [
        ("fig2_bottleneck",
         lambda: paper_figs.fig2_bottleneck(traces()),
         lambda r: "mean_nop_share=%.2f" % (
             sum(v["nop"] for v in r.values()) / len(r))),
        ("fig4_speedup",
         lambda: paper_figs.fig4_speedup(traces()),
         lambda r: "mean64=%.1f%%;mean96=%.1f%%;max96=%.1f%%" % (
             100 * (r["_summary"][64]["mean"] - 1),
             100 * (r["_summary"][96]["mean"] - 1),
             100 * (r["_summary"][96]["max"] - 1))),
        ("fig5_heatmap",
         lambda: paper_figs.fig5_heatmap(traces=traces()),
         lambda r: "peak=%.1f%%;worst=%.1f%%" % (
             max(max(v) for v in r["grid"].values()),
             min(min(v) for v in r["grid"].values()))),
        ("fig4_mac_channels",
         lambda: paper_figs.fig4_mac_channels(traces()),
         lambda r: "ideal_mean=%.1f%%;tdma_mean=%.1f%%;token_mean=%.1f%%" % (
             100 * (r["_summary"]["ideal/1ch"]["mean"] - 1),
             100 * (r["_summary"]["tdma/1ch"]["mean"] - 1),
             100 * (r["_summary"]["token/1ch"]["mean"] - 1))),
        ("sim_fidelity",
         lambda: paper_figs.fig_sim_fidelity(traces()),
         lambda r: "striped_err=%.1e;adaptive_err=%.1f%%;xy_err=%.1f%%" % (
             r["_summary"]["striped"]["worst_speedup_rel_err"],
             100 * r["_summary"]["adaptive"]["worst_speedup_rel_err"],
             100 * r["_summary"]["xy"]["worst_speedup_rel_err"])),
        ("sim_policies",
         lambda: paper_figs.fig_sim_policies(traces()),
         lambda r: "adaptive_beats_grid=%s;greedy_beats_grid=%s;"
         "mean_adaptive=%.1f%%" % (
             r["_summary"]["adaptive"]["beats_grid"],
             r["_summary"]["greedy"]["beats_grid"],
             100 * (r["_summary"]["adaptive"]["mean_speedup"] - 1))),
        ("llm_collectives",
         paper_figs.fig_llm_collectives,
         lambda r: "prefill_mean96=%.1f%%;decode_mean96=%.1f%%;"
         "prefill_coll_share=%.2f" % (
             100 * (r["_summary_prefill"]["mean_best_96"] - 1),
             100 * (r["_summary_decode"]["mean_best_96"] - 1),
             r["_summary_prefill"]["mean_collective_share"])),
        ("scaling_frontier",
         paper_figs.fig_scaling_frontier,
         lambda r: "mean8x8_single=%.1f%%;mean8x8_reuse=%.1f%%;"
         "mean16x16_single=%.1f%%;mean16x16_reuse=%.1f%%" % (
             100 * (r["_summary"]["8x8"]["mean_single"] - 1),
             100 * (r["_summary"]["8x8"]["mean_reuse"] - 1),
             100 * (r["_summary"]["16x16"]["mean_single"] - 1),
             100 * (r["_summary"]["16x16"]["mean_reuse"] - 1))),
        ("hetero_codesign",
         paper_figs.hetero_codesign,
         lambda r: "mean_codesign=%.1f%%;max_codesign=%.1f%%;"
         "spread_shrunk=%d/%d" % (
             100 * (r["_summary"]["_overall"]["mean_speedup_codesigned"]
                    - 1),
             100 * (r["_summary"]["_overall"]["max_speedup_codesigned"]
                    - 1),
             r["_summary"]["_overall"]["spread_shrunk"],
             r["_summary"]["_overall"]["n"])),
        ("balancer_vs_sweep",
         lambda: paper_figs.balancer_vs_sweep(traces()),
         lambda r: "balancer_wins=%d/%d" % (
             sum(v["balancer"] >= v["swept_best"] - 1e-9
                 for v in r.values()), len(r))),
        ("mapping_sensitivity",
         paper_figs.mapping_sensitivity,
         lambda r: "mac_only/comm_aware=%.2fx" % (
             sum(v["ratio"] for v in r.values()) / len(r))),
        ("edp_report",
         lambda: paper_figs.edp_report(traces()),
         lambda r: "mean_edp_gain=%.3f;max=%.3f" % (
             sum(v["edp_gain"] for v in r.values()) / len(r),
             max(v["edp_gain"] for v in r.values()))),
        ("roofline_table_baseline",
         lm_scale.roofline_table,
         lambda r: "cells=%d" % len(r)),
        ("roofline_table_optimized",
         lambda: lm_scale.roofline_table(
             "pod", lm_scale.DRYRUN_DIR + "_opt"),
         lambda r: "cells=%d" % len(r)),
        ("hybrid_plane_report",
         lambda: lm_scale.hybrid_plane_report(
             "pod", lm_scale.DRYRUN_DIR + "_opt"),
         lambda r: "cells=%d;max_coll_speedup=%.2f;mean_step_speedup=%.3f"
         % (len(r), max((x["balancer_coll_speedup"] for x in r),
                        default=1.0),
            (sum(x["balancer_step_speedup"] for x in r) / max(1, len(r))))),
        ("dryrun_summary",
         lm_scale.dryrun_summary,
         lambda r: "ok=%d/%d" % (r["ok"], r["total"])),
    ]
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", action="append", metavar="ROW",
                    help="run only the named row (repeatable); the "
                         "result is merged into bench_results.json")
    args = ap.parse_args(argv)
    if args.only:
        known = {name for name, _, _ in rows}
        unknown = sorted(set(args.only) - known)
        if unknown:
            ap.error(f"unknown row(s) {unknown}; pick from {sorted(known)}")
        rows = [r for r in rows if r[0] in set(args.only)]

    print("name,us_per_call,derived")
    for name, fn, d in rows:
        n, res = _run(name, fn, d)
        results[n] = res

    out = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "bench_results.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    merged = {}
    if args.only and os.path.exists(out):   # --only refreshes rows in place
        with open(out) as f:                # (full runs rewrite the file,
            merged = json.load(f)           # so removed rows don't linger)
    merged.update(results)
    with open(out, "w") as f:
        json.dump(merged, f, indent=1, sort_keys=True, default=str)


if __name__ == "__main__":
    main()
