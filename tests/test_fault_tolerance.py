"""Fault tolerance: injected failure -> bit-exact continuation, straggler
detection, elastic mesh planning."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import restore, save, latest_steps
from repro.configs import ARCHS, reduced
from repro.data.pipeline import DataConfig, batch_for_model
from repro.optim.optimizers import OptimizerConfig
from repro.runtime.fault_tolerance import (ElasticPlan, Heartbeat,
                                           StragglerMitigator,
                                           run_with_recovery)
from repro.runtime.train import TrainConfig, make_train_step


def test_heartbeat_marks_dead():
    hb = Heartbeat(timeout_s=10)
    hb.beat(0, now=0.0)
    hb.beat(1, now=0.0)
    hb.beat(0, now=8.0)
    assert hb.dead(now=12.0) == [1]
    assert hb.alive(now=12.0) == [0]


def test_elastic_plan_shrinks_data_axis_only():
    p = ElasticPlan.plan(256, model_parallel=16)
    assert p.mesh_shape == (16, 16)
    p2 = ElasticPlan.plan(200, model_parallel=16)   # lost chips
    assert p2.mesh_shape == (8, 16)                 # data halved, TP kept
    with pytest.raises(RuntimeError):
        ElasticPlan.plan(8, model_parallel=16)


def test_heartbeat_evict_stops_rereporting():
    """Regression: without evict(), dead() re-reports the same failed
    worker on every poll and the restart policy re-fires forever."""
    hb = Heartbeat(timeout_s=10)
    hb.beat(0, now=0.0)
    hb.beat(1, now=0.0)
    hb.beat(0, now=20.0)
    assert hb.dead(now=25.0) == [1]
    hb.evict(1)
    assert hb.dead(now=25.0) == []          # acted on: not reported again
    assert hb.alive(now=25.0) == [0]
    hb.evict(1)                             # idempotent


def test_elastic_plan_shrinks_pods_before_raising():
    """Regression: with pods > 1 the old guard ignored the pod factor and
    could claim more workers than there are alive chips
    (model=2, pods=2, alive=3 -> claimed 4)."""
    p = ElasticPlan.plan(3, model_parallel=2, pods=2)
    assert p.n_workers <= 3
    assert p.mesh_shape == (1, 2)           # pods shrunk to 1 -> 2-axis mesh
    # pods kept when they fit
    p2 = ElasticPlan.plan(8, model_parallel=2, pods=2)
    assert p2.mesh_shape == (2, 2, 2) and p2.n_workers == 8
    # partial shrink: 3 pods -> 2 pods of 2x2
    p3 = ElasticPlan.plan(11, model_parallel=2, pods=3)
    assert p3.n_workers <= 11
    with pytest.raises(ValueError):
        ElasticPlan.plan(4, model_parallel=2, pods=0)


def test_elastic_plan_lattice_never_overcommits():
    """Every feasible (alive, model, pods) cell yields a plan that fits
    the survivors, keeps the model axis, and is internally consistent."""
    for alive in range(1, 33):
        for model in (1, 2, 4, 8):
            for pods in (1, 2, 3, 4):
                if alive < model:
                    with pytest.raises(RuntimeError):
                        ElasticPlan.plan(alive, model, pods=pods)
                    continue
                p = ElasticPlan.plan(alive, model, pods=pods)
                assert p.n_workers <= alive, (alive, model, pods)
                assert p.mesh_shape[-1] == model
                assert int(np.prod(p.mesh_shape)) == p.n_workers
                assert len(p.mesh_axes) == len(p.mesh_shape)


def test_straggler_detection():
    sm = StragglerMitigator(threshold=1.5, min_steps=3)
    for step in range(6):
        for w in range(8):
            sm.record(w, 1.0 if w != 5 else 2.5)
    assert sm.stragglers() == [5]


def _counter_loop(n_steps, injector, checkpoint_every=4, **kw):
    """Minimal host-only harness for run_with_recovery: state is a step
    counter, metrics are the batch index, checkpoints are dict snapshots."""
    ckpt = {"state": {"step": 0}, "step": 0}

    def step_fn(state, batch):
        return {"step": state["step"] + 1}, batch["idx"]

    def batch_fn(step):
        return {"idx": step}

    def save_fn(state, step):
        ckpt["state"], ckpt["step"] = dict(state), step

    def restore_fn():
        return dict(ckpt["state"]), ckpt["step"]

    return run_with_recovery(step_fn, {"step": 0}, n_steps,
                             batch_fn, save_fn, restore_fn,
                             checkpoint_every=checkpoint_every,
                             failure_injector=injector, **kw)


def test_metrics_log_truncated_on_restore():
    """Regression: restore_fn() rewinds `step` but the old loop kept the
    metrics recorded past the checkpoint, so replayed steps appended
    duplicates (len 16 for a 12-step run failing at step 7 with
    checkpoints every 4).  Post-fix the log is exactly one entry per
    step, in order."""
    fired = {"done": False}

    def injector(step):
        if step == 7 and not fired["done"]:
            fired["done"] = True
            return True
        return False

    state, events, metrics = _counter_loop(12, injector)
    assert state["step"] == 12
    assert len(events) == 1 and events[0].step == 4
    assert metrics == list(range(12))       # no duplicates, right order
    assert len(metrics) == 12


def test_max_restarts_bounds_deterministic_injector():
    """Regression: a deterministic injector firing again at the restored
    step used to loop forever; now the loop raises after max_restarts
    with an actionable message."""
    with pytest.raises(RuntimeError, match="max_restarts"):
        _counter_loop(12, lambda step: step == 5, max_restarts=3)


def test_injected_failure_bitexact_continuation(tmp_path):
    """Kill the run mid-training; the recovered run must produce exactly
    the same final state as an uninterrupted run (stateless data pipeline
    + checkpoint restore)."""
    cfg = reduced(ARCHS["smollm-360m"])
    tcfg = TrainConfig(optimizer=OptimizerConfig(lr=1e-3, warmup_steps=1,
                                                 total_steps=50),
                       remat=False)
    step_fn, init_fn = make_train_step(cfg, tcfg)
    jit_step = jax.jit(step_fn)
    dcfg = DataConfig(seq_len=16, global_batch=2,
                      vocab_size=cfg.vocab_size)

    def batch_fn(step):
        return {k: jnp.asarray(v)
                for k, v in batch_for_model(cfg, dcfg, step).items()}

    # ---- uninterrupted reference
    state = init_fn(jax.random.PRNGKey(0))
    for s in range(12):
        state, _ = jit_step(state, batch_fn(s))
    ref = state

    # ---- interrupted run with recovery
    ckdir = str(tmp_path)
    state2 = init_fn(jax.random.PRNGKey(0))
    save(ckdir, state2, 0)
    failed = {"done": False}

    def injector(step):
        if step == 7 and not failed["done"]:
            failed["done"] = True
            return True
        return False

    def save_fn(st, step):
        save(ckdir, st, step)

    def restore_fn():
        steps = latest_steps(ckdir)
        st = restore(ckdir, state2, step=steps[-1])
        return st, int(np.asarray(st["step"]))

    final, events, _ = run_with_recovery(
        jit_step, state2, 12, batch_fn, save_fn, restore_fn,
        checkpoint_every=5, failure_injector=injector)

    assert len(events) == 1 and events[0].kind == "failure"
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(final)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
