"""Fault tolerance: injected failure -> bit-exact continuation, straggler
detection, elastic mesh planning."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import restore, save, latest_steps
from repro.configs import ARCHS, reduced
from repro.data.pipeline import DataConfig, batch_for_model
from repro.optim.optimizers import OptimizerConfig
from repro.runtime.fault_tolerance import (ElasticPlan, Heartbeat,
                                           StragglerMitigator,
                                           run_with_recovery)
from repro.runtime.train import TrainConfig, make_train_step


def test_heartbeat_marks_dead():
    hb = Heartbeat(timeout_s=10)
    hb.beat(0, now=0.0)
    hb.beat(1, now=0.0)
    hb.beat(0, now=8.0)
    assert hb.dead(now=12.0) == [1]
    assert hb.alive(now=12.0) == [0]


def test_elastic_plan_shrinks_data_axis_only():
    p = ElasticPlan.plan(256, model_parallel=16)
    assert p.mesh_shape == (16, 16)
    p2 = ElasticPlan.plan(200, model_parallel=16)   # lost chips
    assert p2.mesh_shape == (8, 16)                 # data halved, TP kept
    with pytest.raises(RuntimeError):
        ElasticPlan.plan(8, model_parallel=16)


def test_straggler_detection():
    sm = StragglerMitigator(threshold=1.5, min_steps=3)
    for step in range(6):
        for w in range(8):
            sm.record(w, 1.0 if w != 5 else 2.5)
    assert sm.stragglers() == [5]


def test_injected_failure_bitexact_continuation(tmp_path):
    """Kill the run mid-training; the recovered run must produce exactly
    the same final state as an uninterrupted run (stateless data pipeline
    + checkpoint restore)."""
    cfg = reduced(ARCHS["smollm-360m"])
    tcfg = TrainConfig(optimizer=OptimizerConfig(lr=1e-3, warmup_steps=1,
                                                 total_steps=50),
                       remat=False)
    step_fn, init_fn = make_train_step(cfg, tcfg)
    jit_step = jax.jit(step_fn)
    dcfg = DataConfig(seq_len=16, global_batch=2,
                      vocab_size=cfg.vocab_size)

    def batch_fn(step):
        return {k: jnp.asarray(v)
                for k, v in batch_for_model(cfg, dcfg, step).items()}

    # ---- uninterrupted reference
    state = init_fn(jax.random.PRNGKey(0))
    for s in range(12):
        state, _ = jit_step(state, batch_fn(s))
    ref = state

    # ---- interrupted run with recovery
    ckdir = str(tmp_path)
    state2 = init_fn(jax.random.PRNGKey(0))
    save(ckdir, state2, 0)
    failed = {"done": False}

    def injector(step):
        if step == 7 and not failed["done"]:
            failed["done"] = True
            return True
        return False

    def save_fn(st, step):
        save(ckdir, st, step)

    def restore_fn():
        steps = latest_steps(ckdir)
        st = restore(ckdir, state2, step=steps[-1])
        return st, int(np.asarray(st["step"]))

    final, events, _ = run_with_recovery(
        jit_step, state2, 12, batch_fn, save_fn, restore_fn,
        checkpoint_every=5, failure_injector=injector)

    assert len(events) == 1 and events[0].kind == "failure"
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(final)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
