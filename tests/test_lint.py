"""repro.lint: golden fixtures per rule, suppression/baseline
round-trips, CLI contract, and the tier-1 self-clean gate.

The fixture convention (tests/lint_fixtures/README.md): one
``<rule>_bad.py`` that must produce >= 1 finding of exactly that rule
and one ``<rule>_good.py`` that must stay clean under it.
"""

import pathlib
import subprocess
import sys

import pytest

from repro.lint import (ALL_RULES, FAMILIES, iter_py_files,
                        load_baseline, run_rules, write_baseline)
from repro.lint.cli import main

REPO = pathlib.Path(__file__).resolve().parents[1]
SRC = REPO / "src"
FIXTURES = pathlib.Path(__file__).parent / "lint_fixtures"

RULES_BY_NAME = {r.name: r for r in ALL_RULES}

# rule name -> (bad fixture, good fixture), relative to FIXTURES
FIXTURE_CASES = {
    "units-mixed-arith": ("units_mixed_arith_bad.py",
                          "units_mixed_arith_good.py"),
    "units-magic-literal": ("units_magic_literal_bad.py",
                            "units_magic_literal_good.py"),
    "units-call-mix": ("units_call_mix_bad.py", "units_call_mix_good.py"),
    "det-unseeded-rng": ("det_unseeded_rng_bad.py",
                         "det_unseeded_rng_good.py"),
    "det-wallclock": ("det_wallclock_bad.py", "det_wallclock_good.py"),
    "det-set-iteration": ("det_set_iteration_bad.py",
                          "det_set_iteration_good.py"),
    "obs-bare-print": ("obs_bare_print_bad.py", "obs_bare_print_good.py"),
    "obs-unplaced-layer-events": ("obs_unplaced_layer_events_bad.py",
                                  "obs_unplaced_layer_events_good.py"),
    "obs-recording-no-with": ("obs_recording_no_with_bad.py",
                              "obs_recording_no_with_good.py"),
    "cfg-unvalidated-dataclass": ("cfg_unvalidated_dataclass_bad.py",
                                  "cfg_unvalidated_dataclass_good.py"),
    "cfg-provenance-compare": ("cfg_provenance_compare_bad.py",
                               "cfg_provenance_compare_good.py"),
    "cfg-lazy-export-mismatch": ("lazy_bad/__init__.py",
                                 "lazy_good/__init__.py"),
}


def _run_one(rule_name, relpath):
    return run_rules((RULES_BY_NAME[rule_name],), [FIXTURES / relpath],
                     search_roots=[FIXTURES], cwd=FIXTURES)


def test_every_rule_has_a_fixture_pair():
    assert set(FIXTURE_CASES) == set(RULES_BY_NAME)


@pytest.mark.parametrize("rule_name", sorted(FIXTURE_CASES))
def test_rule_flags_bad_fixture(rule_name):
    bad, _ = FIXTURE_CASES[rule_name]
    report = _run_one(rule_name, bad)
    assert report.findings, f"{rule_name} missed {bad}"
    assert {f.rule for f in report.findings} == {rule_name}
    assert all(f.path == bad and f.line > 0 for f in report.findings)


@pytest.mark.parametrize("rule_name", sorted(FIXTURE_CASES))
def test_rule_passes_good_fixture(rule_name):
    _, good = FIXTURE_CASES[rule_name]
    report = _run_one(rule_name, good)
    assert [f.render_text() for f in report.findings] == []


def test_set_iteration_sorted_consumer_regression():
    """A generator fed straight to sorted()/sum() is order-safe —
    pinned against the dse.scaling false positive."""
    report = _run_one("det-set-iteration", "det_set_iteration_good.py")
    assert report.findings == [] and report.suppressed == 0


# ---------------------------------------------------------------------------
# suppressions and baseline
# ---------------------------------------------------------------------------

def test_inline_suppression_round_trip(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text("def report(x):\n"
                 "    print(x)  # lint: disable=obs-bare-print\n"
                 "    print(x)  # lint: disable\n"
                 "    print(x)  # lint: disable=det-wallclock\n")
    report = run_rules((RULES_BY_NAME["obs-bare-print"],), [f],
                       cwd=tmp_path)
    # line 2 (named) and line 3 (blanket) suppress; line 4 names the
    # wrong rule so its finding still lands
    assert report.suppressed == 2
    assert [f_.line for f_ in report.findings] == [4]


def test_baseline_round_trip(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text("def report(x):\n    print(x)\n")
    rule = (RULES_BY_NAME["obs-bare-print"],)
    first = run_rules(rule, [f], cwd=tmp_path)
    assert len(first.findings) == 1

    bl = tmp_path / "baseline.txt"
    write_baseline(bl, first.findings)
    fingerprints = load_baseline(bl)
    assert fingerprints == {"mod.py:obs-bare-print:2"}

    second = run_rules(rule, [f], baseline=fingerprints, cwd=tmp_path)
    assert second.findings == [] and second.baselined == 1


def test_checked_in_baseline_is_empty():
    """Policy: the repo baseline exists (the mechanism is exercised)
    but carries zero grandfathered fingerprints."""
    assert load_baseline(REPO / "lint_baseline.txt") == set()


def test_unit_tag_annotation_drives_inference(tmp_path):
    """A ``# unit: <tag>`` comment tags the names on its line — the
    untagged `window` below would never flag on its own."""
    f = tmp_path / "mod.py"
    f.write_text("def f(configure, window):\n"
                 "    return configure(bandwidth=window)  # unit: gbps\n")
    report = run_rules((RULES_BY_NAME["units-call-mix"],), [f],
                       cwd=tmp_path)
    assert len(report.findings) == 1
    assert "gbps" in report.findings[0].message

    untagged = tmp_path / "untagged.py"
    untagged.write_text("def f(configure, window):\n"
                        "    return configure(bandwidth=window)\n")
    assert run_rules((RULES_BY_NAME["units-call-mix"],), [untagged],
                     cwd=tmp_path).findings == []


def test_parse_error_becomes_finding(tmp_path):
    f = tmp_path / "broken.py"
    f.write_text("def f(:\n")
    report = run_rules(ALL_RULES, [f], cwd=tmp_path)
    assert [x.rule for x in report.findings] == ["parse-error"]


# ---------------------------------------------------------------------------
# CLI contract
# ---------------------------------------------------------------------------

def test_cli_list_rules(capsys):
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in ALL_RULES:
        assert rule.name in out
    for fam in FAMILIES:
        assert f"[{fam}]" in out


def test_cli_exit_codes_and_formats(tmp_path, capsys, monkeypatch):
    monkeypatch.chdir(tmp_path)
    f = tmp_path / "mod.py"
    f.write_text("print('hi')\n")
    assert main([str(f)]) == 1
    text = capsys.readouterr().out
    assert "mod.py:1:0: obs-bare-print" in text

    assert main([str(f), "--format=github"]) == 1
    gh = capsys.readouterr().out
    assert gh.startswith("::error file=mod.py,line=1,")
    assert "title=repro.lint obs-bare-print" in gh

    clean = tmp_path / "ok.py"
    clean.write_text("x = 1\n")
    assert main([str(clean)]) == 0
    assert main([str(tmp_path / "nope")]) == 2


def test_cli_select_and_write_baseline(tmp_path, capsys, monkeypatch):
    monkeypatch.chdir(tmp_path)
    f = tmp_path / "mod.py"
    f.write_text("import time\n\n"
                 "def f():\n"
                 "    print(time.time())\n")
    # family select: only determinism runs, the bare print passes
    assert main([str(f), "--select=determinism"]) == 1
    out = capsys.readouterr().out
    assert "det-wallclock" in out and "obs-bare-print" not in out
    with pytest.raises(SystemExit):
        main([str(f), "--select=not-a-rule"])
    capsys.readouterr()

    # --write-baseline grandfathers everything, next run is clean
    assert main([str(f), "--write-baseline"]) == 0
    capsys.readouterr()
    assert main([str(f)]) == 0
    assert main([str(f), "--baseline=/dev/null"]) == 1


def test_module_entrypoint_runs_pure_stdlib(tmp_path):
    """`python -m repro.lint` must work without numpy/jax on the path
    (the CI lint-domain job runs it in a bare container)."""
    f = tmp_path / "mod.py"
    f.write_text("x = 1\n")
    guard = ("import sys\n"
             "sys.modules['numpy'] = None\n"
             "sys.modules['jax'] = None\n"
             "from repro.lint.cli import main\n"
             f"sys.exit(main([{str(f)!r}]))\n")
    proc = subprocess.run([sys.executable, "-c", guard],
                          capture_output=True, text=True,
                          env={"PYTHONPATH": str(SRC), "PATH": "/usr/bin"},
                          cwd=tmp_path)
    assert proc.returncode == 0, proc.stderr


# ---------------------------------------------------------------------------
# tier-1 gate: the repo's own source is lint-clean
# ---------------------------------------------------------------------------

def test_src_is_lint_clean():
    """Pinned: `python -m repro.lint src/` exits 0 with the empty
    baseline — every finding in src/ is fixed or inline-justified."""
    report = run_rules(ALL_RULES, iter_py_files([SRC]),
                       search_roots=[SRC], cwd=REPO)
    assert [f.render_text() for f in report.findings] == []
    assert report.files_scanned > 90
