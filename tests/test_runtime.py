"""Runtime substrate tests: optimizers, compression, data pipeline,
sharding rules — including hypothesis property tests on the invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: deterministic smoke-subset fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.data.pipeline import DataConfig, synthetic_batch
from repro.optim.optimizers import (OptimizerConfig, build_optimizer,
                                    clip_by_global_norm, cosine_lr)
from repro.runtime.compression import (CompressionConfig,
                                       compress_decompress,
                                       compress_with_error_feedback,
                                       init_residual)
from repro.runtime.sharding import batch_spec, cache_spec, param_spec
from repro.launch.mesh import make_auto_mesh


# --------------------------------------------------------------------------
# optimizers
# --------------------------------------------------------------------------

def _toy_params():
    k = jax.random.PRNGKey(0)
    return {"w": jax.random.normal(k, (256, 256)),
            "b": jnp.zeros((256,)),
            "nested": {"u": jax.random.normal(k, (128, 512))}}


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_reduces_quadratic(name):
    cfg = OptimizerConfig(name=name, lr=0.05, warmup_steps=0,
                          total_steps=200, weight_decay=0.0)
    opt = build_optimizer(cfg)
    params = _toy_params()
    target = jax.tree.map(lambda p: jnp.ones_like(p) * 0.5, params)
    state = opt.init(params)

    def loss(p):
        return sum(jnp.sum((a - b) ** 2) for a, b in
                   zip(jax.tree.leaves(p), jax.tree.leaves(target)))

    l0 = float(loss(params))
    step = jnp.zeros((), jnp.int32)
    for i in range(60):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params, step + i)
    assert float(loss(params)) < 0.2 * l0


def test_adafactor_state_is_factored():
    cfg = OptimizerConfig(name="adafactor")
    opt = build_optimizer(cfg)
    st_ = opt.init({"w": jnp.zeros((256, 512)), "b": jnp.zeros((64,))})
    assert set(st_["v"]["w"]) == {"vr", "vc"}
    assert st_["v"]["w"]["vr"].shape == (256,)
    assert st_["v"]["w"]["vc"].shape == (512,)
    assert set(st_["v"]["b"]) == {"v"}        # small: unfactored


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(jnp.sum(clipped["a"] ** 2))
    assert float(total) == pytest.approx(1.0, rel=1e-5)
    assert float(norm) == pytest.approx(np.sqrt(10) * 100, rel=1e-5)


def test_cosine_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(cosine_lr(cfg, jnp.int32(0))) == pytest.approx(0.1)
    assert float(cosine_lr(cfg, jnp.int32(0))) > 0.0  # first step trains
    assert float(cosine_lr(cfg, jnp.int32(9))) == pytest.approx(1.0)
    assert float(cosine_lr(cfg, jnp.int32(100))) == pytest.approx(0.0,
                                                                  abs=1e-6)


# --------------------------------------------------------------------------
# gradient compression
# --------------------------------------------------------------------------

@given(st.integers(1, 5), st.floats(0.1, 100.0))
@settings(max_examples=20, deadline=None)
def test_compression_error_bounded(seed, scale):
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.standard_normal((512,)) * scale,
                          jnp.float32)}
    out = compress_decompress(g, CompressionConfig(block=128))
    err = np.abs(np.asarray(out["w"]) - np.asarray(g["w"]))
    # int8 block quantisation: error <= blockmax/127 per element
    bm = np.abs(np.asarray(g["w"]).reshape(-1, 128)).max(1, keepdims=True)
    assert (err.reshape(-1, 128) <= bm / 127 + 1e-6).all()


def test_error_feedback_reduces_bias():
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal((1024,)), jnp.float32)
    grads = {"w": g_true}
    cfg = CompressionConfig(block=256)
    res = init_residual(grads)
    acc_ef = np.zeros(1024)
    acc_nf = np.zeros(1024)
    for _ in range(50):
        out, res = compress_with_error_feedback(grads, res, cfg)
        acc_ef += np.asarray(out["w"])
        acc_nf += np.asarray(compress_decompress(grads, cfg)["w"])
    true_sum = np.asarray(g_true) * 50
    assert np.abs(acc_ef - true_sum).mean() <= \
        np.abs(acc_nf - true_sum).mean() + 1e-6


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------

def test_data_deterministic_and_step_indexed():
    cfg = DataConfig(seed=7, seq_len=32, global_batch=4, vocab_size=1000)
    b1 = synthetic_batch(cfg, 13)
    b2 = synthetic_batch(cfg, 13)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = synthetic_batch(cfg, 14)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    assert b1["tokens"].shape == b1["labels"].shape == (4, 32)
    assert (b1["tokens"] < 1000).all() and (b1["tokens"] >= 0).all()


# --------------------------------------------------------------------------
# sharding rules: hypothesis property tests
# --------------------------------------------------------------------------

def _mesh():
    return make_auto_mesh(
        (1, len(jax.devices())), ("data", "model"))


@given(st.sampled_from(["wq", "wk", "wv", "wo", "w_up", "w_down", "table",
                        "unembed", "router", "in_proj", "out_proj",
                        "scale", "conv_w"]),
       st.integers(1, 4),
       st.sampled_from([64, 96, 128, 15, 384, 1000]))
@settings(max_examples=60, deadline=None)
def test_param_spec_always_divisible(name, rank, dim):
    """INVARIANT: whatever axis the rule assigns, the dimension size is
    divisible by the mesh axis size (no silent GSPMD padding)."""
    mesh = _mesh()
    shape = tuple([dim] * rank)
    spec = param_spec(mesh, f"units/b0/attn/{name}", shape)
    assert len(spec) <= rank
    for d, ax in zip(shape, tuple(spec) + (None,) * (rank - len(spec))):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        assert d % size == 0, (name, shape, spec)


@given(st.integers(1, 512), st.integers(1, 8192))
@settings(max_examples=40, deadline=None)
def test_batch_spec_divisible(batch, seq):
    mesh = _mesh()
    spec = batch_spec(mesh, (batch, seq))
    for d, ax in zip((batch, seq),
                     tuple(spec) + (None,) * (2 - len(spec))):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        assert d % size == 0


@given(st.tuples(st.integers(1, 64), st.integers(1, 64),
                 st.integers(128, 4096), st.integers(1, 64),
                 st.integers(32, 256)))
@settings(max_examples=40, deadline=None)
def test_cache_spec_divisible(shape):
    mesh = _mesh()
    spec = cache_spec(mesh, shape)
    padded = tuple(spec) + (None,) * (len(shape) - len(spec))
    for d, ax in zip(shape, padded):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        assert d % size == 0
