"""The phase profiler: structural zero cost, nesting/attribution,
>=90% wall coverage on the instrumented hot paths, span integration,
and the "framework" Perfetto process."""

import numpy as np
import pytest

from repro.core import NetworkConfig, make_trace
from repro.core.dse import sweep_all
from repro.obs import (MetricsRegistry, chrome_trace_events, phase,
                       profile_report, profiling)
from repro.obs import profile as profile_mod
from repro.sim import PacketSim

NET96 = NetworkConfig(bandwidth=96e9 / 8)


# ---------------------------------------------------------------------------
# core mechanics
# ---------------------------------------------------------------------------

def test_nested_phases_paths_parents_and_self_time():
    with profiling() as prof:
        with phase("outer"):
            with phase("inner"):
                pass
            with phase("inner"):
                pass
        with phase("outer2"):
            pass
    paths = [r.path for r in prof.records]
    # children close before parents (post-order append)
    assert paths == ["outer/inner", "outer/inner", "outer", "outer2"]
    assert [r.depth for r in prof.records] == [1, 1, 0, 0]
    agg = prof.aggregate()
    assert agg["outer/inner"]["calls"] == 2
    assert agg["outer"]["calls"] == 1
    # self time excludes named children, never negative here
    assert 0.0 <= agg["outer"]["self_s"] <= agg["outer"]["total_s"]
    total_inner = agg["outer/inner"]["total_s"]
    assert agg["outer"]["self_s"] == pytest.approx(
        agg["outer"]["total_s"] - total_inner)


def test_phase_error_outcome_and_unwind():
    with profiling() as prof:
        with pytest.raises(RuntimeError):
            with phase("outer"):
                with phase("bad"):
                    raise RuntimeError("boom")
        with phase("after"):
            pass
    by_path = {r.path: r for r in prof.records}
    assert by_path["outer/bad"].outcome == "error"
    assert by_path["outer"].outcome == "error"
    assert by_path["after"].outcome == "ok"
    assert prof._open == []          # fully unwound
    assert prof.aggregate()["outer/bad"]["errors"] == 1


def test_note_ndarray_peak_propagates_to_parents():
    a = np.zeros(1000)              # 8000 bytes
    b = np.zeros(10)
    with profiling() as prof:
        with phase("outer"):
            profile_mod.note_ndarray(b)
            with phase("inner"):
                profile_mod.note_ndarray(a, b)
    by_path = {r.path: r for r in prof.records}
    assert by_path["outer/inner"].peak_bytes == a.nbytes + b.nbytes
    # the child's larger peak propagates up
    assert by_path["outer"].peak_bytes == a.nbytes + b.nbytes


def test_phases_outside_profiling_record_nothing():
    with phase("ignored"):
        profile_mod.note_ndarray(np.zeros(4))
    assert profile_mod.active_profiler() is None


def test_disabled_profiling_is_structurally_zero_cost(monkeypatch):
    """With no profiler installed the hot paths must never even
    construct a PhaseRecord — the SimTrace structural pin, applied to
    self-profiling."""
    def boom(*a, **k):
        raise AssertionError("PhaseRecord built while disabled")

    monkeypatch.setattr(profile_mod, "PhaseRecord", boom)
    tr = make_trace("zfnet")
    sweep_all({"zfnet": tr})                      # dse + net.batched
    PacketSim(tr, NET96).run("greedy")            # sim engine
    with pytest.raises(AssertionError):
        with profiling():
            with phase("x"):
                pass


def test_profiling_does_not_perturb_results():
    tr = make_trace("zfnet")
    plain = sweep_all({"zfnet": tr})
    sim = PacketSim(tr, NET96)
    t_plain = sim.run("greedy").total_time
    with profiling():
        profiled = sweep_all({"zfnet": make_trace("zfnet")})
        t_prof = PacketSim(make_trace("zfnet"), NET96) \
            .run("greedy").total_time
    assert t_prof == t_plain                       # bit-identical
    for a, b in zip(plain, profiled):
        assert np.array_equal(a.grid, b.grid)


# ---------------------------------------------------------------------------
# span integration (satellite: exception-safe span)
# ---------------------------------------------------------------------------

def test_span_opens_a_profiler_phase():
    reg = MetricsRegistry()
    with profiling() as prof:
        with reg.span("work", stage="x"):
            pass
    assert [r.path for r in prof.records] == ["work"]


def test_span_records_error_outcome_label():
    reg = MetricsRegistry()
    with pytest.raises(ValueError):
        with reg.span("work", stage="x") as t:
            raise ValueError("boom")
    rep = reg.report()["work"]
    assert len(rep) == 1
    assert rep[0]["labels"] == {"outcome": "error", "stage": "x"}
    assert rep[0]["count"] == 1
    assert t["seconds"] > 0.0                      # sample not dropped
    # the success path keeps its pre-PR-9 histogram key
    with reg.span("work", stage="x"):
        pass
    labels = [m["labels"] for m in reg.report()["work"]]
    assert {"stage": "x"} in labels


# ---------------------------------------------------------------------------
# coverage acceptance: >=90% of measured wall attributed to phases
# ---------------------------------------------------------------------------

def test_coverage_sweep_all():
    traces = {wl: make_trace(wl) for wl in ("zfnet", "resnet50")}
    with profiling() as prof:
        sweep_all(traces)
    assert prof.coverage() >= 0.9, profile_report(prof)


def test_coverage_packetsim_run():
    tr = make_trace("zfnet")
    with profiling() as prof:
        PacketSim(tr, NET96).run("greedy")
    assert prof.coverage() >= 0.9, profile_report(prof)


def test_annealer_phases_count_evaluations():
    from repro.arch import PlacementProblem, anneal
    prob = PlacementProblem("zfnet", net=NET96)
    with profiling() as prof:
        anneal(prob, steps=20, seed=0)
    agg = prof.aggregate()
    anneal_keys = [p for p in agg if p.endswith("arch.anneal")]
    assert anneal_keys, sorted(agg)
    evals = [p for p in agg if p.endswith("arch.evaluate")]
    assert evals
    # each phase is one *distinct* (memo-miss) evaluation
    assert agg[evals[0]]["calls"] == prob.evaluations


# ---------------------------------------------------------------------------
# report + export
# ---------------------------------------------------------------------------

def test_profile_report_table_and_footer():
    with profiling() as prof:
        with phase("alpha"):
            with phase("beta"):
                profile_mod.note_ndarray(np.zeros(100))
    txt = profile_report(prof)
    assert "alpha/beta" in txt
    assert "attributed" in txt and "% of" in txt


def test_perfetto_export_has_distinct_framework_process():
    tr = make_trace("zfnet")
    sim = PacketSim(tr, NET96, record=True)
    res = sim.run("static")
    with profiling() as prof:
        PacketSim(tr, NET96).run("static")
    merged = chrome_trace_events({"sim": res.trace,
                                  "profile": prof.to_trace()})
    procs = {e["pid"]: e["args"]["name"]
             for e in merged["traceEvents"]
             if e.get("name") == "process_name"}
    fw_pids = {p for p, n in procs.items() if "framework" in n}
    sim_pids = {p for p, n in procs.items() if "framework" not in n}
    assert fw_pids and not fw_pids & sim_pids
    fw_events = [e for e in merged["traceEvents"]
                 if e.get("cat") == "framework" and e.get("ph") == "X"]
    assert fw_events
    assert all(e["pid"] in fw_pids for e in fw_events)
    assert all("path" in e["args"] for e in fw_events)


def test_to_trace_meta_carries_coverage():
    with profiling() as prof:
        with phase("a"):
            pass
    st = prof.to_trace()
    assert st.meta["kind"] == "profile"
    assert 0.0 < st.meta["coverage"] <= 1.0
    assert st.meta["wall_s"] == prof.wall_s
