"""The LM-scale hybrid plane scheduler: paper decision function, overlay
saturation, balancer optimality (mirrors the package-scale properties)."""

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: deterministic smoke-subset fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.hybrid_schedule import (PlaneConfig, balance_cell,
                                        flows_from_coll_per_op,
                                        schedule_cell, sweep_cell,
                                        eligible_volume)


COLL = {"all-gather": 4e9, "all-reduce": 8e9, "reduce-scatter": 2e9,
        "all-to-all": 3e9}


def test_multicast_classification():
    flows = flows_from_coll_per_op(COLL)
    mc = {f.op: f.multicast for f in flows}
    assert mc["all-gather"] and mc["all-to-all"]
    assert not mc["all-reduce"] and not mc["reduce-scatter"]


def test_offload_reduces_collective_time():
    s = schedule_cell(COLL, t_compute=1e-3, t_memory=1e-3,
                      pcfg=PlaneConfig(injection_prob=0.5))
    assert s.t_coll_hybrid < s.t_coll_wired
    assert s.coll_speedup > 1.0


def test_overlay_saturates_at_high_injection():
    """Mirror of paper Fig. 5: past some injection rate the overlay is the
    new bottleneck and more injection stops helping."""
    times = []
    for p in (0.1, 0.4, 1.0):
        s = schedule_cell(COLL, 0.0, 0.0,
                          PlaneConfig(overlay_bw=60e9, injection_prob=p))
        times.append(s.t_coll_hybrid)
    assert times[1] < times[0]            # more helps at first
    assert times[-1] > times[-2]          # then the overlay saturates


def test_no_speedup_when_compute_bound():
    s = schedule_cell(COLL, t_compute=10.0, t_memory=0.0,
                      pcfg=PlaneConfig(injection_prob=0.5))
    assert s.step_speedup == pytest.approx(1.0)


@given(st.floats(1e6, 1e11), st.floats(1e6, 1e11), st.floats(1e6, 1e11))
@settings(max_examples=30, deadline=None)
def test_balancer_dominates_sweep(ag, ar, a2a):
    coll = {"all-gather": ag, "all-reduce": ar, "all-to-all": a2a}
    swept, _ = sweep_cell(coll, 1e-4, 1e-4)
    bal = balance_cell(coll, 1e-4, 1e-4)
    assert bal.step_speedup >= swept.step_speedup - 1e-9


@given(st.floats(1e6, 1e12))
@settings(max_examples=30, deadline=None)
def test_balancer_never_degrades(vol):
    coll = {"all-gather": vol}
    bal = balance_cell(coll, 0.0, 0.0)
    assert bal.step_speedup >= 1.0 - 1e-12


def test_threshold_filters_eligibility():
    flows = flows_from_coll_per_op(COLL, ring_radius=4)
    v_lo = eligible_volume(flows, PlaneConfig(distance_threshold=1,
                                              ring_radius=4))
    v_hi = eligible_volume(flows, PlaneConfig(distance_threshold=8,
                                              ring_radius=4))
    assert v_lo > v_hi  # radius-4 flows drop out above the threshold
