"""The dynamic-conditions resilience plane (repro.fault).

Covers the PR's acceptance properties:

- a golden hand-computed 2-chiplet / 2-layer trace with a mid-run mesh
  link failure: the victim packet is force-failed-over to the wireless
  plane at exactly its hand-derived service time, while the wired-only
  counterfactual pays an infinite cut;
- golden chip fail-stop / slow-down derating numbers on the same trace
  (share absorption, weight-restream DRAM term, emergency absorber);
- the zero-degradation differential pin: a scenario of zero-magnitude
  events (slow-down factor 1.0, 0 dB fade) is BIT-IDENTICAL to the
  fault-free run on every paper workload;
- the online-reshard property: under seeded random fault scenarios the
  online-reshard policy is never slower than static or adaptive, and
  the reshard controller never ships worse than degraded mode;
- the SNR/fading channel model's closed forms, scenario validation,
  and the adaptive-link-model refusal.
"""

import numpy as np
import pytest

from repro.core import AcceleratorConfig, NetworkConfig, build_topology, \
    make_trace
from repro.core.traffic import TrafficTrace
from repro.core.workloads import WORKLOADS
from repro.fault import (ChipFailure, ChipSlowdown, FaultScenario,
                         LinkFailure, SnrFade, default_scenario,
                         derate_trace, reshard_run)
from repro.net.channel import SnrProfile, shannon_capacity
from repro.sim import FixedPolicy, PacketSim

NET96 = NetworkConfig(bandwidth=96e9 / 8)


# ---------------------------------------------------------------------------
# golden trace: 2 chiplets, 2 layers, mid-run link failure, done by hand
# ---------------------------------------------------------------------------

def _golden_trace(with_exec: bool = False) -> TrafficTrace:
    """Two chiplets side by side, the same traffic in each of 2 layers.

    Per layer: one 4 MB eligible multicast chiplet0 -> chiplet1 on
    link 0 (1 ms at the 4 GB/s link rate) and one 2 MB ineligible
    unicast chiplet1 -> chiplet0 on link 1 (0.5 ms).  Compute floor
    1 ms per layer; DRAM and NoC free.  ``with_exec`` attaches the
    exec-set metadata chip faults need: both chips split every layer
    50/50, layer 1 holds 8 MB of weights.
    """
    topo = build_topology(AcceleratorConfig(grid=(1, 2), n_dram=1))
    extra = {}
    if with_exec:
        extra = dict(exec_chips=[(0, 1), (0, 1)],
                     exec_shares=[np.array([0.5, 0.5])] * 2,
                     weight_bytes=np.array([0.0, 8e6]))
    return TrafficTrace(
        topo=topo, n_layers=2,
        link_index={((0, 0), (0, 1)): 0, ((0, 1), (0, 0)): 1},
        layer=np.array([0, 0, 1, 1], np.int32),
        nbytes=np.array([4e6, 2e6, 4e6, 2e6]),
        src=np.array([0, 1, 0, 1], np.int32),
        is_multicast=np.array([True, False, True, False]),
        is_multichip=np.array([True, True, True, True]),
        max_hops=np.array([1, 1, 1, 1], np.int32),
        dram_node=np.array([-1, -1, -1, -1], np.int32),
        inc_msg=np.array([0, 1, 2, 3], np.int32),
        inc_link=np.array([0, 1, 0, 1], np.int32),
        t_compute=np.array([1e-3, 1e-3]),
        t_dram=np.array([0.0, 0.0]),
        t_noc=np.array([0.0, 0.0]),
        dram_bytes=np.array([0.0, 0.0]),
        messages=[],
        **extra,
    )


ALL_WIRED = FixedPolicy([False, False, False, False])

#: link 0 dies at layer 1 (one-way: the reverse link stays up)
LINK0_DOWN = FaultScenario(link_failures=(
    LinkFailure((0, 0), (0, 1), at_layer=1, both_directions=False),))


def test_golden_link_failure_forces_wireless_failover():
    """Layer 1's multicast MUST take the wireless plane: its only wired
    link is dead.  Hand numbers: layer 0 unchanged (1 ms compute tie);
    layer 1 = max(1 ms compute, 0.5 ms link 1, 4 MB / 12 GB/s wireless
    = 1/3 ms) = 1 ms."""
    sim = PacketSim(_golden_trace(), NET96, faults=LINK0_DOWN)
    res = sim.run(ALL_WIRED)
    assert res.total_time == pytest.approx(2e-3)
    assert list(res.injected) == [False, False, True, False]
    assert res.wireless_bytes == pytest.approx(4e6)
    np.testing.assert_allclose(res.layer_times, [1e-3, 1e-3])


def test_golden_link_failure_wired_only_pays_infinity():
    """The wired-only counterfactual has no failover plane: the dead
    cut's service time is infinite — wireless-as-failover is the
    resilience headline, and this is its denominator."""
    sim = PacketSim(_golden_trace(), NET96, faults=LINK0_DOWN)
    res = sim.run_wired()
    assert np.isinf(res.total_time)
    # the pre-failure layer is still finite and exact
    assert res.layer_times[0] == pytest.approx(1e-3)


def test_golden_link_failure_online_path_matches():
    """The per-packet (greedy) path agrees with the batched path on the
    forced-failover trace: same total, same injected set."""
    sim = PacketSim(_golden_trace(), NET96, faults=LINK0_DOWN)
    res = sim.run("greedy")
    assert res.total_time == pytest.approx(2e-3)
    assert bool(res.injected[2])   # the dead-cut packet went wireless


def test_golden_chip_failure_derating():
    """Fail chiplet 1 at layer 1: layer 0 untouched; layer 1's compute
    doubles (half the shares at zero rate -> total/capacity = 2) and
    the dead half of the 8 MB weight slice restreams from DRAM."""
    tr = _golden_trace(with_exec=True)
    sc = FaultScenario(chip_failures=(ChipFailure(1, at_layer=1),))
    d = derate_trace(tr, sc)
    assert d is not tr
    np.testing.assert_allclose(d.t_compute, [1e-3, 2e-3])
    dram = tr.topo.config.dram_bw_total
    np.testing.assert_allclose(d.t_dram, [0.0, 0.5 * 8e6 / dram])
    # traffic geometry is untouched: the absorber adopts the router
    np.testing.assert_array_equal(d.nbytes, tr.nbytes)


def test_golden_chip_slowdown_derating():
    """Halve chiplet 0's rate from layer 0: capacity = 0.5*0.5 + 0.5 =
    0.75 -> every layer's compute inflates by 4/3.  No DRAM term — the
    chip still holds its weights."""
    tr = _golden_trace(with_exec=True)
    sc = FaultScenario(chip_slowdowns=(ChipSlowdown(0, 2.0),))
    d = derate_trace(tr, sc)
    np.testing.assert_allclose(d.t_compute, [4e-3 / 3, 4e-3 / 3])
    np.testing.assert_allclose(d.t_dram, [0.0, 0.0])


def test_golden_fully_dead_exec_set_uses_emergency_absorber():
    """Both chips dead: the layer falls back to one absorber at
    single-chiplet rate -> total/max_share = 1/0.5 = 2x."""
    tr = _golden_trace(with_exec=True)
    sc = FaultScenario(chip_failures=(ChipFailure(0), ChipFailure(1)))
    d = derate_trace(tr, sc)
    np.testing.assert_allclose(d.t_compute, [2e-3, 2e-3])


def test_chip_fault_without_exec_metadata_is_an_error():
    tr = _golden_trace(with_exec=False)
    sc = FaultScenario(chip_failures=(ChipFailure(0),))
    with pytest.raises(ValueError, match="exec_chips"):
        derate_trace(tr, sc)


def test_unknown_link_raises():
    tr = _golden_trace()
    sc = FaultScenario(link_failures=(LinkFailure((0, 0), (5, 5)),))
    with pytest.raises(ValueError, match="no mesh link"):
        PacketSim(tr, NET96, faults=sc).run(ALL_WIRED)


# ---------------------------------------------------------------------------
# zero-degradation differential pin: bit-identical to fault-free
# ---------------------------------------------------------------------------

ZERO_MAGNITUDE = FaultScenario(
    chip_slowdowns=(ChipSlowdown(0, 1.0),),
    snr_fades=(SnrFade(0.0),))


@pytest.mark.parametrize("wl", sorted(WORKLOADS))
def test_zero_degradation_is_bit_identical(wl, traces_all=None):
    """A scenario of zero-magnitude events (slow-down x1.0, 0 dB fade)
    must reproduce the fault-free run EXACTLY — same floats, not just
    close — on every paper workload.  This pins the engine's fault
    threading as a pure no-op when nothing is degraded."""
    tr = make_trace(wl)
    base = PacketSim(tr, NET96).run("static")
    faulted = PacketSim(tr, NET96, faults=ZERO_MAGNITUDE).run("static")
    assert faulted.total_time == base.total_time
    np.testing.assert_array_equal(faulted.layer_times, base.layer_times)
    np.testing.assert_array_equal(faulted.injected, base.injected)


def test_empty_scenario_short_circuits():
    """`FaultScenario()` is null: the engine keeps no fault state at
    all (the same code path as faults=None)."""
    tr = make_trace("zfnet")
    sim = PacketSim(tr, NET96, faults=FaultScenario())
    assert sim.faults is None
    assert sim.run("static").total_time == \
        PacketSim(tr, NET96).run("static").total_time


def test_adaptive_link_model_refuses_faults():
    tr = make_trace("zfnet")
    sc = FaultScenario(snr_fades=(SnrFade(3.0),))
    with pytest.raises(NotImplementedError, match="adaptive"):
        PacketSim(tr, NET96, link_model="adaptive", faults=sc)


# ---------------------------------------------------------------------------
# online-reshard domination: never slower than static or adaptive
# ---------------------------------------------------------------------------

def _random_scenario(tr, rng) -> FaultScenario:
    n = tr.topo.config.n_chiplets
    fails = tuple(ChipFailure(int(c), at_layer=int(rng.integers(
        1, max(2, tr.n_layers))))
        for c in rng.choice(n, size=rng.integers(0, 3), replace=False))
    slows = (ChipSlowdown(int(rng.integers(0, n)),
                          float(rng.uniform(1.5, 4.0)),
                          at_layer=int(rng.integers(0, tr.n_layers))),)
    fades = (SnrFade(float(rng.uniform(0.5, 12.0))),)
    links = ()
    if rng.random() < 0.5:
        a, b = list(tr.link_index)[int(rng.integers(len(tr.link_index)))]
        links = (LinkFailure(a, b, at_layer=int(
            rng.integers(0, tr.n_layers))),)
    return FaultScenario(chip_failures=fails, chip_slowdowns=slows,
                         link_failures=links, snr_fades=fades)


@pytest.mark.parametrize("wl,seed", [("zfnet", s) for s in range(4)]
                         + [("gnmt", s) for s in range(2)])
def test_online_reshard_never_slower(wl, seed):
    """Property: under any injected scenario, the online-reshard
    stitch is <= static's and <= adaptive's total.  Structural — its
    candidate pool is a superset and the per-layer projections are
    exact — but this guards the plumbing that keeps it true."""
    tr = make_trace(wl)
    sc = _random_scenario(tr, np.random.default_rng(seed))
    sim = PacketSim(tr, NET96, faults=sc)
    t_static = sim.run("static").total_time
    t_adaptive = sim.run("adaptive").total_time
    t_reshard = sim.run("online-reshard").total_time
    assert t_reshard <= t_static * (1 + 1e-12)
    assert t_reshard <= t_adaptive * (1 + 1e-12)


def test_reshard_controller_never_ships_worse_than_degraded():
    tr = make_trace("zfnet")
    sc = default_scenario(tr, k=2, fade_db=9.0)
    oc = reshard_run("zfnet", NET96, sc)
    assert oc.total_time <= oc.degraded_time
    assert oc.total_time == min(oc.resharded_time, oc.degraded_time)
    # the heartbeat detected and evicted exactly the failed chips
    fail_events = [e for e in oc.events if e.kind == "failure"]
    detected = sorted(w for e in fail_events for w in e.workers)
    assert detected == sorted(ev.chip for ev in sc.chip_failures)


def test_reshard_infeasible_when_all_chips_die():
    tr = make_trace("zfnet")
    n = tr.topo.config.n_chiplets
    sc = FaultScenario(chip_failures=tuple(
        ChipFailure(c, at_layer=2) for c in range(n)))
    oc = reshard_run("zfnet", NET96, sc)
    assert not oc.resharded
    assert oc.total_time == oc.degraded_time


# ---------------------------------------------------------------------------
# SNR / fading channel model closed forms and validation
# ---------------------------------------------------------------------------

def test_shannon_capacity_closed_form():
    assert shannon_capacity(0.0) == pytest.approx(1.0)       # SNR = 1
    assert shannon_capacity(10.0) == pytest.approx(np.log2(11.0))


def test_capacity_scale_closed_form_and_zero_fade_identity():
    prof = SnrProfile(ref_snr_db=15.0)
    d = prof.ref_distance_mm
    # 0 dB fade is EXACTLY 1.0 (the differential pin's wireless leg)
    assert prof.capacity_scale(d, 0.0) == 1.0
    want = shannon_capacity(15.0 - 6.0) / shannon_capacity(15.0)
    assert prof.capacity_scale(d, 6.0) == pytest.approx(want)
    assert 0.0 < prof.capacity_scale(d, 6.0) < 1.0


def test_snr_path_loss_monotone_in_distance():
    prof = SnrProfile()
    d = np.array([10.0, 20.0, 40.0])
    snr = prof.snr_db_at(d)
    assert snr[0] == pytest.approx(prof.ref_snr_db)
    assert np.all(np.diff(snr) < 0)
    # inverse-square law: doubling distance costs ~6 dB
    assert snr[0] - snr[1] == pytest.approx(20 * np.log10(2.0))


def test_scenario_validation():
    with pytest.raises(ValueError):
        ChipSlowdown(0, 0.5)          # factor < 1 is a speedup
    with pytest.raises(ValueError):
        SnrFade(-1.0)                 # negative fade
    with pytest.raises(ValueError):
        SnrFade(float("inf"))
    with pytest.raises(ValueError):
        SnrProfile(ref_snr_db=0.0, path_loss_exp=-1.0)
    sc = default_scenario(make_trace("zfnet"), k=2, fade_db=3.0)
    assert len(sc.chip_failures) == 2
    assert len({ev.chip for ev in sc.chip_failures}) == 2
    with pytest.raises(ValueError, match="fail-stops"):
        default_scenario(make_trace("zfnet"), k=99)


def test_fade_reduces_wireless_only():
    """A heavy package fade slows the hybrid run but leaves the wired
    counterfactual untouched (fades live on the wireless plane)."""
    tr = make_trace("zfnet")
    sc = FaultScenario(snr_fades=(SnrFade(9.0),))
    sim_f = PacketSim(tr, NET96, faults=sc)
    sim_0 = PacketSim(tr, NET96)
    assert sim_f.run_wired().total_time == sim_0.run_wired().total_time
    assert sim_f.run("static").total_time >= \
        sim_0.run("static").total_time
