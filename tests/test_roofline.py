"""Roofline extraction: HLO collective parser, scan-counted-once
verification, term arithmetic.  These tests pin the methodology DESIGN.md
S7 relies on."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import make_auto_mesh, use_mesh
from repro.launch.roofline import (Roofline, collective_bytes,
                                   cost_analysis, _type_bytes)


def test_type_bytes():
    assert _type_bytes("bf16[128,256]{1,0}") == 128 * 256 * 2
    assert _type_bytes("f32[8]") == 32
    assert _type_bytes("(bf16[4,4]{1,0}, f32[2])") == 32 + 8
    assert _type_bytes("pred[]") == 0 or _type_bytes("pred[]") >= 0


def _mesh2():
    if len(jax.devices()) < 2:
        pytest.skip("needs >=2 devices")
    return make_auto_mesh((1, 2), ("data", "model"))


def test_collective_parser_finds_allreduce():
    mesh = _mesh2()
    sh = NamedSharding(mesh, P(None, "model"))

    def f(x):
        return jnp.sum(x @ x.T)  # contraction over the sharded dim -> AR

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    with use_mesh(mesh):
        compiled = jax.jit(f, in_shardings=sh).lower(x).compile()
    stats = collective_bytes(compiled.as_text())
    assert stats.payload_bytes > 0
    assert any(op.startswith("all-reduce") for op in stats.per_op)


def test_scan_body_counted_once():
    """The methodology's load-bearing assumption: cost_analysis() counts a
    scan body once, independent of trip count."""
    def make(n):
        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, None, length=n)
            return y
        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        return cost_analysis(jax.jit(f).lower(x, w).compile())["flops"]

    assert make(2) == make(8)


def test_extrapolation_math():
    full = Roofline(100.0, 1000.0, 10.0, {"all-reduce": 10.0})
    # fabricate a unit result and extrapolate manually like dryrun does
    unit = Roofline(7.0, 70.0, 1.0, {"all-gather": 1.0})
    k = 9
    total = Roofline(full.flops + k * unit.flops,
                     full.hbm_bytes + k * unit.hbm_bytes,
                     full.coll_link_bytes + k * unit.coll_link_bytes, {})
    assert total.flops == 163.0
    assert total.hbm_bytes == 1630.0
    assert total.t_compute < total.t_memory  # sanity on constants


def test_dominant_term():
    r = Roofline(flops=197e12, hbm_bytes=1.0, coll_link_bytes=1.0,
                 coll_per_op={})
    assert r.dominant == "compute" and r.step_time == pytest.approx(1.0)
    r2 = Roofline(flops=1.0, hbm_bytes=819e9 * 2, coll_link_bytes=1.0,
                  coll_per_op={})
    assert r2.dominant == "memory" and r2.step_time == pytest.approx(2.0)
