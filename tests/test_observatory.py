"""The cross-run bench observatory: robust MAD detection, the HTML
report, the `history.py` CLI gate, and ledger-tooling edge cases."""

import json
import subprocess
import sys

import pytest

import benchmarks.history as hist_mod
from repro.obs import report as obs_report
from repro.obs import build_html, detect_all, detect_series

run_mod = pytest.importorskip("benchmarks.run")


def _entry(row, ts, metrics, wall=100.0, h="abc123"):
    return {"row": row, "ts": ts, "us_per_call": wall,
            "derived": " ".join(f"{k}={v:g}" for k, v in metrics.items()),
            "metrics": metrics, "hash": h}


def _ledger(values, row="r", metric="m"):
    return [_entry(row, 1700000000.0 + i, {metric: v})
            for i, v in enumerate(values)]


# ---------------------------------------------------------------------------
# detector
# ---------------------------------------------------------------------------

def test_detect_series_flags_injected_regression():
    findings = detect_series([1.0] * 10 + [2.0])
    kinds = {f["kind"] for f in findings}
    assert "drift" in kinds
    f = next(f for f in findings if f["kind"] == "drift")
    assert f["index"] == 10 and f["value"] == 2.0


def test_detect_series_flags_sustained_level_shift():
    findings = detect_series([1.0] * 8 + [2.0] * 8)
    f = next(f for f in findings if f["kind"] == "changepoint")
    assert f["index"] == 8
    assert f["baseline"] == 1.0 and f["value"] == 2.0


def test_detect_series_clean_on_constant_noisy_and_short():
    assert detect_series([1.0] * 20) == []
    # jitter well inside 4 robust scales (varied levels, so MAD > 0)
    noisy = [1.0 + 0.001 * ((i * 7) % 11) for i in range(20)]
    assert detect_series(noisy) == []
    # below min_points: a young ledger is always clean
    assert detect_series([1.0, 100.0]) == []
    assert detect_series([1.0, 1.0, 1.0, 100.0]) == []


def test_detect_series_outlier_does_not_mask_shift():
    # one early outlier must not inflate the scale enough to hide a
    # genuine 2x level shift (the median/MAD rationale)
    vals = [1.0] * 4 + [50.0] + [1.0] * 3 + [2.0] * 8
    assert any(f["kind"] == "changepoint" for f in detect_series(vals))


def test_detect_all_wall_series_excluded_by_default():
    entries = [_entry("r", 1700000000.0 + i, {"m": 1.0},
                      wall=100.0 * (2 ** i)) for i in range(12)]
    assert detect_all(entries) == []
    walled = detect_all(entries, include_wall=True)
    assert walled and all(f["metric"] == obs_report.WALL_METRIC
                          for f in walled)


def test_detect_all_annotates_ts_and_hash():
    entries = _ledger([1.0] * 10 + [2.0])
    entries[-1]["hash"] = "deadbeef"
    f = detect_all(entries)[0]
    assert f["row"] == "r" and f["metric"] == "m"
    assert f["hash"] == "deadbeef"
    assert f["ts"] == entries[-1]["ts"]


def test_detect_all_clean_on_committed_ledger():
    """The acceptance pin: --detect must pass on the repo's own ledger."""
    path = run_mod.history_path("experiments/bench_results.json")
    entries = run_mod.load_history(path)
    assert entries, "committed ledger missing"
    assert detect_all(entries) == []


def test_history_series_skips_torn_fields():
    entries = [
        {"row": "r", "ts": 1.0, "us_per_call": "nan",
         "metrics": {"m": 1.0, "bad": "oops", "inf": float("inf")}},
        {"ts": 2.0, "metrics": {"m": 9.0}},      # no row: skipped
    ]
    series = obs_report.history_series(entries)
    assert set(series) == {("r", "m")}


def test_format_findings_empty_and_filled():
    assert obs_report.format_findings([]) == ""
    txt = obs_report.format_findings(detect_all(_ledger([1.0] * 10
                                                        + [2.0])))
    assert "r.m" in txt and "drift" in txt


# ---------------------------------------------------------------------------
# HTML report
# ---------------------------------------------------------------------------

def test_build_html_contents_and_determinism():
    entries = _ledger([1.0] * 10 + [2.0], row="fig2_bottleneck")
    results = {"_bench_meta": {"fig2_bottleneck": {
        "derived": "x=1", "us_per_call": 123.0}}}
    doc = build_html(entries, results)
    assert doc.startswith("<!DOCTYPE html>")
    assert "<svg" in doc and "fig2_bottleneck" in doc
    assert "abc123" in doc                    # config-hash column
    assert "flagged series" in doc            # the injected drift
    assert "#c0392b" in doc                   # flagged point marker
    assert doc == build_html(entries, results)   # byte-deterministic


def test_build_html_clean_ledger_says_so():
    doc = build_html(_ledger([1.0] * 3))
    assert "no drift flagged" in doc
    assert "wall (us/call)" in doc            # wall rendered regardless


def test_report_module_is_stdlib_only():
    """report.py must import (by path) with numpy poisoned — the
    observatory has to work on a checkout with a broken science stack."""
    code = (
        "import importlib.util, sys\n"
        "sys.modules['numpy'] = None\n"
        "spec = importlib.util.spec_from_file_location("
        "'obsreport', sys.argv[1])\n"
        "m = importlib.util.module_from_spec(spec)\n"
        "spec.loader.exec_module(m)\n"
        "assert m.detect_series([1.0]*10 + [2.0])\n"
        "print('ok')\n")
    out = subprocess.run(
        [sys.executable, "-c", code, obs_report.__file__],
        capture_output=True, text=True)
    assert out.returncode == 0, out.stderr
    assert out.stdout.strip() == "ok"


# ---------------------------------------------------------------------------
# history.py CLI (the CI gate)
# ---------------------------------------------------------------------------

def _write_ledger(path, entries):
    with open(path, "w") as f:
        for e in entries:
            f.write(json.dumps(e) + "\n")


def test_cli_detect_flags_synthetic_regression(tmp_path, capsys):
    led = tmp_path / "h.jsonl"
    _write_ledger(led, _ledger([1.0] * 10 + [2.0]))
    rc = hist_mod.main(["--detect", "--file", str(led)])
    assert rc == 1
    assert "drift" in capsys.readouterr().err


def test_cli_detect_clean_exits_zero(tmp_path, capsys):
    led = tmp_path / "h.jsonl"
    _write_ledger(led, _ledger([1.0] * 10))
    assert hist_mod.main(["--detect", "--file", str(led)]) == 0
    assert "history detect OK" in capsys.readouterr().out


def test_cli_detect_clean_on_committed_ledger(capsys):
    assert hist_mod.main(["--detect"]) == 0
    assert "history detect OK" in capsys.readouterr().out


def test_cli_html_writes_report(tmp_path, capsys):
    led = tmp_path / "h.jsonl"
    _write_ledger(led, _ledger([1.0] * 6, row="fig2_bottleneck"))
    out = tmp_path / "obs.html"
    rc = hist_mod.main(["--html", str(out), "--file", str(led),
                        "--results", str(tmp_path / "missing.json")])
    assert rc == 0
    doc = out.read_text()
    assert "<svg" in doc and "fig2_bottleneck" in doc and "abc123" in doc


def test_cli_threshold_passthrough(tmp_path):
    led = tmp_path / "h.jsonl"
    # modest last step: clean at the default threshold, flagged at 1
    _write_ledger(led, _ledger([1.0 + 0.01 * (i % 3) for i in range(10)]
                               + [1.05]))
    assert hist_mod.main(["--detect", "--file", str(led)]) == 0
    assert hist_mod.main(["--detect", "--file", str(led),
                          "--threshold", "1"]) == 1


# ---------------------------------------------------------------------------
# satellite: ledger-tooling edge cases
# ---------------------------------------------------------------------------

def test_sparkline_edges():
    assert hist_mod.sparkline([]) == ""
    assert hist_mod.sparkline([5.0]) == hist_mod.BARS[0]
    assert hist_mod.sparkline([2.0] * 7) == hist_mod.BARS[0] * 7
    line = hist_mod.sparkline([0.0, 1.0])
    assert line == hist_mod.BARS[0] + hist_mod.BARS[-1]


def test_plot_text_filters(capsys):
    entries = (_ledger([1.0, 2.0], row="a", metric="x")
               + _ledger([3.0], row="b", metric="y"))
    hist_mod.plot_text(entries, row="a")
    out = capsys.readouterr().out
    assert "a.x" in out and "b.y" not in out
    hist_mod.plot_text(entries, metric="y")
    out = capsys.readouterr().out
    assert "b.y" in out and "a.x" not in out
    hist_mod.plot_text(entries, row="nope")
    assert "no matching" in capsys.readouterr().out


def test_load_history_tolerates_torn_tail(tmp_path):
    led = tmp_path / "h.jsonl"
    with open(led, "w") as f:
        f.write(json.dumps(_entry("r", 1.0, {"m": 1.0})) + "\n")
        f.write('{"row": "r", "ts": 2.0, "metr')      # torn write
    entries = run_mod.load_history(str(led))
    assert len(entries) == 1 and entries[0]["row"] == "r"


def test_latest_by_row_dedups_to_newest():
    entries = [_entry("r", 1.0, {"m": 1.0}),
               _entry("r", 9.0, {"m": 2.0}),
               _entry("s", 5.0, {"m": 3.0})]
    latest = run_mod.latest_by_row(entries)
    assert set(latest) == {"r", "s"}
    assert latest["r"]["metrics"]["m"] == 2.0
