"""The scale-out frontier: large meshes, spatial channel reuse, and the
edge cases of the wireless network primitives.

Covers the PR's acceptance properties:
- parametric topology: perimeter DRAM placement (legacy-identical up to
  four modules), vectorized hop matrices, construction-time validation;
- the spatial-reuse model: zone tiling (square and non-square grids),
  the K=1 degenerate case, per-point dominance of reuse over the
  single shared channel under the ideal MAC;
- net.mac / net.channel edge cases: zero wireless traffic, a single
  packet, a saturated channel, 1-channel vs N-channel equivalence;
- `dse.scaling_sweep`: batched == naive loop engine, >=10x faster,
  and the frontier shape (reuse recovers speedup at 8x8 where the
  shared channel degrades).
"""

import time

import numpy as np
import pytest

from repro.core import (AcceleratorConfig, ChannelPlan, MacConfig,
                        NetworkConfig, build_topology, make_trace,
                        scaled_config, scaling_sweep, simulate_hybrid,
                        simulate_wired)
from repro.core.dse import SCALING_GRIDS, batched_design_space, reuse_plans
from repro.core.topology import dram_positions, node_grid_coords
from repro.net.batched import GridSpec
from repro.net.stack import network_layer_times
from repro.sim import PacketSim


# ---------------------------------------------------------------------------
# parametric topology
# ---------------------------------------------------------------------------

def test_dram_positions_legacy_prefix():
    """Up to four modules the placement is the paper's Fig. 1 exactly."""
    for n in range(1, 5):
        assert dram_positions(3, 3, n) == \
            ((-1, 1), (3, 1), (1, -1), (1, 3))[:n]


def test_dram_positions_large_perimeter():
    pos = dram_positions(16, 16, 16)
    assert len(pos) == len(set(pos)) == 16
    # four per side, evenly spread
    top = sorted(c for r, c in pos if r == -1)
    assert len(top) == 4 and top[0] < 8 <= top[-1]
    # one coordinate off-grid on every module (perimeter placement)
    for r, c in pos:
        assert (r in (-1, 16)) != (c in (-1, 16))


def test_hop_matrix_matches_route_walk():
    topo = build_topology(AcceleratorConfig(grid=(4, 6), n_dram=6,
                                            tops_total=24 * 16e12))
    H = topo.hop_matrix()
    for a in range(topo.n_nodes):
        for b in range(topo.n_nodes):
            assert H[a, b] == len(topo.route(a, b)), (a, b)


def test_online_and_planned_channel_busy_agree_under_reuse():
    """Replaying an online run's injected set through the planned path
    reports the same per-channel wireless airtime — a global packet's
    service counts once, not once per quiesced zone server."""
    from repro.sim import FixedPolicy
    tr = make_trace("zfnet")
    net = NetworkConfig(bandwidth=96e9 / 8,
                        channels=ChannelPlan(1, reuse_zones=4))
    sim = PacketSim(tr, net)
    online = sim.run("greedy")
    replay = sim.run(FixedPolicy(online.injected))
    np.testing.assert_allclose(online.channel_busy, replay.channel_busy,
                               rtol=1e-12)
    np.testing.assert_allclose(online.layer_times, replay.layer_times,
                               rtol=1e-12)


def test_accelerator_config_accepts_numpy_ints():
    acc = AcceleratorConfig(grid=(np.int64(4), np.int64(4)),
                            n_dram=np.int64(4), tops_total=16 * 16e12)
    assert acc.n_chiplets == 16


def test_accelerator_config_validates_at_construction():
    with pytest.raises(ValueError, match="grid"):
        AcceleratorConfig(grid=(0, 3))
    with pytest.raises(ValueError, match="n_dram"):
        AcceleratorConfig(n_dram=0)
    with pytest.raises(ValueError, match="chiplet_tops.*per chiplet"):
        AcceleratorConfig(chiplet_tops=(1e12,) * 8)
    with pytest.raises(ValueError, match="positive"):
        AcceleratorConfig(chiplet_sram=(0,) * 9)


def test_scaled_config_weak_scaling():
    base = AcceleratorConfig()
    for grid in SCALING_GRIDS:
        acc = scaled_config(grid)
        assert acc.tops_per_chiplet == pytest.approx(base.tops_per_chiplet)
        assert acc.n_dram >= 4
        assert acc.wireless_bw == base.wireless_bw   # the fixed resource
    assert scaled_config((16, 16)).n_dram == 16
    assert scaled_config((3, 3)).n_dram == 4


# ---------------------------------------------------------------------------
# spatial reuse: zone assignment
# ---------------------------------------------------------------------------

def test_zone_tiling_square_and_nonsquare():
    assert ChannelPlan(reuse_zones=4).zone_tiling((8, 8)) == (2, 2)
    assert ChannelPlan(reuse_zones=16).zone_tiling((16, 16)) == (4, 4)
    # non-square grids tile along the long axis
    assert ChannelPlan(reuse_zones=4).zone_tiling((2, 8)) == (1, 4)
    assert ChannelPlan(reuse_zones=4).zone_tiling((8, 2)) == (4, 1)
    assert ChannelPlan(reuse_zones=6).zone_tiling((4, 6)) == (2, 3)
    with pytest.raises(ValueError, match="factorization"):
        ChannelPlan(reuse_zones=5).zone_tiling((2, 2))


def test_zone_assignment_partitions_nodes():
    topo = build_topology(AcceleratorConfig(grid=(4, 8), n_dram=8,
                                            tops_total=32 * 16e12))
    coords = node_grid_coords(topo)
    plan = ChannelPlan(reuse_zones=8)
    zone, rd = plan.assign_spatial((4, 8), coords)
    assert set(zone) == set(range(8))          # every zone populated
    assert rd >= 1
    # nodes sharing a grid position share a zone; zone of a chiplet is
    # monotone in its coordinates within the tiling
    kr, kc = plan.zone_tiling((4, 8))
    for i, (r, c) in enumerate(topo.chiplet_coords):
        assert zone[i] == (r * kr // 4) * kc + (c * kc // 8)


def test_single_zone_is_the_shared_medium():
    """K=1 derives a reuse distance covering every route, so every
    packet is zone-local and the plan is bit-identical to today."""
    topo = build_topology(AcceleratorConfig())
    coords = node_grid_coords(topo)
    zone, rd = ChannelPlan(1).assign_spatial((3, 3), coords)
    assert np.all(zone == 0)
    assert rd == 4                              # 3x3 package diameter
    # an explicit reuse_distance is ignored at K=1 (one zone IS the
    # shared medium)
    _, rd2 = ChannelPlan(1, reuse_distance=0).assign_spatial((3, 3), coords)
    assert rd2 == rd


def test_channel_plan_validation():
    with pytest.raises(ValueError):
        ChannelPlan(reuse_zones=0)
    with pytest.raises(ValueError):
        ChannelPlan(reuse_distance=-1)
    assert ChannelPlan(2, "interleaved", reuse_zones=4).describe() \
        == "2ch-interleaved-x4reuse"
    assert ChannelPlan(1).describe() == "1ch"


# ---------------------------------------------------------------------------
# net.mac / net.channel edge cases through the analytic stack
# ---------------------------------------------------------------------------

def _stack_time(nbytes, src, net, n_nodes=4, grid=(2, 2), hops=None):
    """One-layer wireless time for synthetic per-packet arrays."""
    nbytes = np.asarray(nbytes, float)
    src = np.asarray(src, np.int64)
    layer = np.zeros(len(nbytes), np.int64)
    injected = np.ones(len(nbytes), bool)
    coords = np.array([(r, c) for r in range(grid[0])
                       for c in range(grid[1])], np.int64)[:n_nodes]
    hops = np.ones(len(nbytes), np.int64) if hops is None \
        else np.asarray(hops, np.int64)
    t, by, extra = network_layer_times(
        1, layer, nbytes, src, n_nodes, injected, net,
        grid=grid, node_coords=coords, max_hops=hops)
    return float(t[0]), float(by[0]), extra


@pytest.mark.parametrize("proto", ["ideal", "tdma", "token"])
@pytest.mark.parametrize("plan", [ChannelPlan(1), ChannelPlan(2),
                                  ChannelPlan(1, reuse_zones=2)])
def test_zero_wireless_traffic_costs_zero(proto, plan):
    net = NetworkConfig(8e9, channels=plan, mac=MacConfig(proto))
    t, by, extra = _stack_time([], [], net)
    assert t == 0.0 and by == 0.0 and extra == 0.0


def test_single_packet_times():
    v = 64 * 1024.0
    for plan in (ChannelPlan(1), ChannelPlan(1, reuse_zones=2),
                 ChannelPlan(2)):
        net = NetworkConfig(8e9, channels=plan)
        t, by, _ = _stack_time([v], [0], net)
        assert by == v
        assert t == pytest.approx(v / plan.channel_bandwidth(8e9))


def test_one_vs_n_channel_equivalence():
    """A single transmitter served at the same per-channel rate sees
    identical times whatever the channel count (its traffic lands on
    exactly one channel), for every MAC protocol."""
    rng = np.random.default_rng(7)
    v = rng.uniform(1e3, 1e6, 16)
    for proto in ("ideal", "tdma", "token"):
        ref = None
        for n_ch in (1, 2, 4):
            net = NetworkConfig(
                8e9, mac=MacConfig(proto),
                channels=ChannelPlan(n_ch, bandwidth_per_channel=8e9))
            t, _, _ = _stack_time(v, [0] * 16, net)
            ref = t if ref is None else ref
            assert t == pytest.approx(ref), (proto, n_ch)


def test_saturated_channel_degrades_speedup():
    """A starved wireless band (0.1 Gb/s) makes every injected packet a
    liability: the hybrid run is SLOWER than wired, on the analytic and
    the event plane alike — and the planes still agree exactly."""
    tr = make_trace("zfnet")
    net = NetworkConfig(bandwidth=0.1e9 / 8, distance_threshold=1,
                        injection_prob=0.8)
    an = simulate_hybrid(tr, net)
    base = simulate_wired(tr).total_time
    assert an.total_time > base           # saturation: a net slowdown
    sim = PacketSim(tr, net)
    ev = sim.run("static")
    np.testing.assert_allclose(ev.layer_times, an.layer_times, rtol=1e-12)
    # ...while the greedy online policy refuses the starved channel
    assert sim.run("greedy").total_time <= base * (1 + 1e-9)


def test_reuse_dominates_single_channel_pointwise_ideal():
    """Provable: splitting a layer's volume into a global part plus
    concurrent zone-local parts can only shrink the ideal-MAC channel
    time (Vg + max_z Vz <= V), so at every (threshold, injection) grid
    point the reuse plan's speedup >= the shared channel's."""
    acc = scaled_config((8, 8))
    for wl in ("zfnet", "googlenet"):
        tr = make_trace(wl, acc)
        spec = GridSpec(bandwidths_gbps=(96,),
                        plans=(ChannelPlan(1),) + reuse_plans((8, 8)))
        sp = batched_design_space(tr).evaluate(spec).speedup[0, :, 0]
        assert np.all(sp[1:] >= sp[:1] - 1e-12), wl


# ---------------------------------------------------------------------------
# the scaling sweep: engines agree, batched is >=10x faster
# ---------------------------------------------------------------------------

WLS = ("zfnet", "googlenet", "transformer_cell")


def test_scaling_sweep_engines_agree_and_batched_is_10x_faster():
    grids = ((8, 8),)
    t0 = time.perf_counter()
    loop = scaling_sweep(workloads=WLS, grids=grids, engine="loop")
    t_loop = time.perf_counter() - t0
    scaling_sweep(workloads=WLS, grids=grids)      # warm-up
    t_batched = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        batched = scaling_sweep(workloads=WLS, grids=grids)
        t_batched = min(t_batched, time.perf_counter() - t0)
    for a, b in zip(loop, batched):
        assert (a.workload, a.grid) == (b.workload, b.grid)
        assert b.best_single == pytest.approx(a.best_single, rel=1e-9)
        assert b.best_reuse == pytest.approx(a.best_reuse, rel=1e-9)
    assert t_loop / t_batched >= 10.0, (t_loop, t_batched)


def test_scaling_frontier_shape():
    """The acceptance story: at 8x8 the shared channel underperforms
    its reuse counterpart on every tested workload, and the recovered
    speedup is material (>= 2 points mean)."""
    res = scaling_sweep(workloads=WLS, grids=((8, 8),))
    assert all(r.best_reuse >= r.best_single for r in res)
    assert np.mean([r.recovered for r in res]) >= 0.02
    assert all(r.best_reuse > 1.0 for r in res)


def test_scaling_sweep_rejects_unknown_engine():
    with pytest.raises(ValueError, match="engine"):
        scaling_sweep(workloads=("zfnet",), grids=((4, 4),), engine="nope")
