"""Fixture: config validates in __post_init__ (and private
configs are out of scope)."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class FixtureConfig:
    bandwidth: float = 1.0

    def __post_init__(self):
        if not self.bandwidth > 0:
            raise ValueError("bandwidth must be positive")


@dataclasses.dataclass
class _ScratchConfig:
    debug: bool = False
