"""Fixture: inline scale factors instead of repro.units."""


def to_bytes_per_s(rate_gbps, payload_bytes):
    bw = rate_gbps * 1e9 / 8
    bits = payload_bytes * 8
    return bw, bits
