"""Fixture: recording() as a context manager."""


def run(recording, st, sim):
    with recording(st):
        return sim()
