"""Fixture: Gb/s handed to a bytes/s keyword."""


def build(configure, peak_gbps):
    return configure(bandwidth=peak_gbps)
