"""Fixture: adds seconds to bytes."""


def budget(window_s, payload_bytes):
    return window_s + payload_bytes
