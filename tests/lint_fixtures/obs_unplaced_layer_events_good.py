"""Fixture: layer events placed on the absolute timeline."""


def record(SimTrace, times):
    st = SimTrace(label="fixture")
    for li, t in enumerate(times):
        st.add_layer_event("layers", f"L{li}", li, 0.0, t, "layer")
    st.place_layers(times)
    return st
