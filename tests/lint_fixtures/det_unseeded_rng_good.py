"""Fixture: explicitly seeded generators only."""

import random

import numpy as np


def jitter(n, seed):
    rng = np.random.default_rng(seed)
    r = random.Random(seed)
    return rng.random(n), r.random()
