"""Fixture: raw wall-clock reads in model code."""

import time


def elapsed():
    t0 = time.perf_counter()
    return time.time() - t0
