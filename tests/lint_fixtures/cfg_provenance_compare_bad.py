"""Fixture: provenance participates in equality."""

import dataclasses


@dataclasses.dataclass
class Result:
    value: float = 0.0
    provenance: dict = None
