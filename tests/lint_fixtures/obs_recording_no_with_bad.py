"""Fixture: recording() called without `with`."""


def run(recording, st, sim):
    recording(st)
    return sim()
