"""Fixture: provenance excluded from equality."""

import dataclasses
from typing import Optional


@dataclasses.dataclass
class Result:
    value: float = 0.0
    provenance: Optional[dict] = dataclasses.field(default=None,
                                                   compare=False)
