"""Fixture: same-unit arithmetic and unknown units stay clean."""


def total(warmup_s, run_s, count):
    elapsed_s = warmup_s + run_s
    return elapsed_s, count + 1
