"""Fixture submodule: exports run_model only."""

__all__ = ["run_model"]


def run_model():
    return 0
