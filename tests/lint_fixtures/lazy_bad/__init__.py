"""Fixture package: lazy re-export table out of sync."""

_SIM_EXPORTS = ("run_model", "does_not_exist")


def __getattr__(name):
    if name in _SIM_EXPORTS:
        import lazy_bad.simmod
        return getattr(lazy_bad.simmod, name)
    raise AttributeError(name)
