"""Fixture: global-stream RNG draws."""

import random

import numpy as np


def jitter(n):
    a = np.random.rand(n)
    b = random.random()
    c = np.random.default_rng()
    return a, b, c
