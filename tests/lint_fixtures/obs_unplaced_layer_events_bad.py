"""Fixture: layer events recorded but never placed."""


def record(SimTrace, times):
    st = SimTrace(label="fixture")
    for li, t in enumerate(times):
        st.add_layer_event("layers", f"L{li}", li, 0.0, t, "layer")
    return st
