"""Fixture: bare print in model code."""


def report(x):
    print(f"result: {x}")
