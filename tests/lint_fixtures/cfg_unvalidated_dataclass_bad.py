"""Fixture: public config dataclass with no validation."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class FixtureConfig:
    bandwidth: float = 1.0
    retries: int = 3
