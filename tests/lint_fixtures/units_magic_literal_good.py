"""Fixture: conversions routed through repro.units helpers."""

from repro.units import bytes_to_bits, gbps_to_bytes_per_s


def to_bytes_per_s(rate_gbps, payload_bytes):
    bw = gbps_to_bytes_per_s(rate_gbps)
    bits = bytes_to_bits(payload_bytes)
    return bw, bits
