"""Fixture: injectable clock; no ambient reads."""


def elapsed(now, t0):
    return now - t0
