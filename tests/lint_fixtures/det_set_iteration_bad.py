"""Fixture: set iteration feeding ordered output."""


def collect(items, extra):
    out = []
    for x in set(items) | set(extra):
        out.append(x)
    return out, [v for v in {1, 2, 3}]
