"""Fixture package: lazy re-export table in sync."""

_SIM_EXPORTS = ("run_model", "reset")


def __getattr__(name):
    if name in _SIM_EXPORTS:
        import lazy_good.simmod
        return getattr(lazy_good.simmod, name)
    raise AttributeError(name)
