"""Fixture submodule: exports both lazy names."""

__all__ = ["run_model", "reset"]


def run_model():
    return 0


def reset():
    return None
