"""Fixture: keyword and value agree on bytes/s."""


def build(configure, link_bw):
    return configure(bandwidth=link_bw)
