"""Fixture: output routed through the metrics logger."""


def report(log, x):
    log.info(f"result: {x}", value=x)
