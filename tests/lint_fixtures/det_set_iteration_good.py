"""Fixture: sorted before iterating; order-insensitive
consumers (sorted/sum) take the comprehension directly."""


def collect(items, extra, hi):
    out = []
    for x in sorted(set(items) | set(extra)):
        out.append(x)
    lows = sorted((b for b in set(items) if b != hi), reverse=True)
    total = sum(b for b in set(extra))
    return out, lows, total
