"""Per-architecture smoke tests (reduced configs) + model invariants.

Every assigned arch: instantiate the reduced config of the same family,
run one forward and one train step on CPU, assert output shapes and
finiteness.  Plus decode-vs-forward consistency (the KV-cache/SSM-state
decode path must reproduce the full-sequence forward logits) and causality
(future tokens cannot influence past logits).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import build_model
from repro.optim.optimizers import OptimizerConfig
from repro.runtime.train import TrainConfig, make_train_step

ARCH_NAMES = list(ARCHS)


def _batch(cfg, B=2, S=32, key=0):
    rng = np.random.default_rng(key)
    batch = {}
    if cfg.is_encdec:
        batch["src_embeds"] = jnp.asarray(
            rng.standard_normal((B, 16, cfg.d_model)), jnp.bfloat16)
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    elif cfg.frontend == "embed":
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.bfloat16)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward(arch):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = model.apply(params, batch)
    S = 32
    assert logits.shape == (2, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch
    assert bool(jnp.isfinite(aux)), arch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_train_step(arch):
    cfg = reduced(ARCHS[arch])
    tcfg = TrainConfig(optimizer=OptimizerConfig(lr=1e-3, warmup_steps=1,
                                                 total_steps=10),
                       remat=False)
    step_fn, init_fn = make_train_step(cfg, tcfg)
    state = init_fn(jax.random.PRNGKey(0))
    state2, metrics = jax.jit(step_fn)(state, _batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(state2["step"]) == 1
    # parameters actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        state["params"], state2["params"])
    assert max(jax.tree.leaves(moved)) > 0


# bf16 decode-vs-forward tolerance.  Attention caches are read-only, so
# decode differs from forward only in reduction *order* and stays within
# a few bf16 ulps of the ~[2,4)-binade logits (ulp 2^-7): 0.15 covers it.
# Recurrent SSM state is different: decode updates the state token by
# token while the forward pass runs a blocked scan, so the state drifts
# by O(ulp) per step and the drift compounds over the sequence before
# the vocab projection amplifies it.  For the zamba2 hybrid (a mamba
# block per layer feeding a shared attention block) the observed error
# grows roughly linearly in t up to ~0.42 at S=12; we bound it by
# S * n_layers * ulp = 12 * 4 * 2^-6 = 0.75 (one sign-flip of a 2-ulp
# state perturbation per layer per step, at the [4,8) logit binade).
_DECODE_TOL = {"zamba2-2.7b": 0.75}


@pytest.mark.parametrize("arch", ["smollm-360m", "gemma2-2b",
                                  "mixtral-8x22b", "mamba2-130m",
                                  "zamba2-2.7b", "chatglm3-6b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode must reproduce the full forward logits."""
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg, impl="naive", remat=False)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 1, 12
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    full_logits, _ = model.apply(params, {"tokens": toks})

    cache = model.init_cache(B, S + 1)
    dec = jax.jit(model.decode)
    errs = []
    for t in range(S):
        lg, cache = dec(params, cache, toks[:, t:t + 1], jnp.int32(t))
        errs.append(float(jnp.abs(
            lg[:, 0] - full_logits[:, t]).max()))
    tol = _DECODE_TOL.get(arch, 0.15)  # bf16 accumulation tolerance
    assert max(errs) < tol, (arch, errs)


def test_causality():
    """Perturbing future tokens must not change past logits."""
    cfg = reduced(ARCHS["smollm-360m"])
    model = build_model(cfg, impl="naive", remat=False)
    params = model.init(jax.random.PRNGKey(2))
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 16)), jnp.int32)
    toks2 = toks.at[0, 12:].set((toks[0, 12:] + 7) % cfg.vocab_size)
    l1, _ = model.apply(params, {"tokens": toks})
    l2, _ = model.apply(params, {"tokens": toks2})
    np.testing.assert_allclose(np.asarray(l1[:, :12]),
                               np.asarray(l2[:, :12]), atol=1e-5)


def test_sliding_window_limits_context():
    """With window w, logits at t depend only on tokens in [t-w+1, t]."""
    import dataclasses
    base = reduced(ARCHS["mixtral-8x22b"])
    cfg = dataclasses.replace(base, sliding_window=4, unit=())
    model = build_model(cfg, impl="naive", remat=False)
    params = model.init(jax.random.PRNGKey(4))
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 16)), jnp.int32)
    # change token 0: positions >= layers*window away cannot see it.
    toks2 = toks.at[0, 0].set((toks[0, 0] + 3) % cfg.vocab_size)
    l1, _ = model.apply(params, {"tokens": toks})
    l2, _ = model.apply(params, {"tokens": toks2})
    # information propagates at most `window-1` per attention layer
    # (moe-family units pair every attention with an expert block, so the
    # attention count equals n_layers)
    n_attn = cfg.n_layers
    horizon = n_attn * (cfg.sliding_window - 1) + 1
    if horizon < 16:
        np.testing.assert_allclose(np.asarray(l1[:, horizon:]),
                                   np.asarray(l2[:, horizon:]), atol=1e-5)


def test_chunked_equals_naive_attention():
    cfg = reduced(ARCHS["qwen2.5-32b"])
    model_n = build_model(cfg, impl="naive", remat=False)
    model_c = build_model(cfg, impl="chunked", remat=False)
    params = model_n.init(jax.random.PRNGKey(6))
    toks = jnp.asarray(np.arange(64)[None, :] % cfg.vocab_size, jnp.int32)
    l1, _ = model_n.apply(params, {"tokens": toks})
    l2, _ = model_c.apply(params, {"tokens": toks})
    # Chunked attention renormalises its accumulator with the *running*
    # row max (online softmax), so whenever the max moves between chunks
    # the partial sums are rescaled in bf16 — a few-ulp reordering drift
    # on the affected logits.  Bound: 2 ulps at the top logit binade
    # [8, 16), i.e. 2 * 8 * 2^-8 = 0.125 (observed worst offender: one
    # logit in 16384 off by 0.0547 = 7 ulps at [2, 4)).
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=0.125, rtol=1e-2)


def test_param_count_analytic_matches_tree():
    """ModelConfig.param_count() (used for MODEL_FLOPS) vs the real tree."""
    from repro.models import param_count
    for arch in ["smollm-360m", "gemma2-2b", "mixtral-8x22b",
                 "mamba2-130m", "seamless-m4t-large-v2"]:
        cfg = reduced(ARCHS[arch])
        model = build_model(cfg, remat=False)
        params = model.init(jax.random.PRNGKey(0))
        real = param_count(params)
        pred = cfg.param_count()
        assert abs(real - pred) / real < 0.12, (arch, real, pred)
