"""Per-architecture smoke tests (reduced configs) + model invariants.

Every assigned arch: instantiate the reduced config of the same family,
run one forward and one train step on CPU, assert output shapes and
finiteness.  Plus decode-vs-forward consistency (the KV-cache/SSM-state
decode path must reproduce the full-sequence forward logits) and causality
(future tokens cannot influence past logits).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.data.pipeline import DataConfig, batch_for_model
from repro.models import build_model
from repro.optim.optimizers import OptimizerConfig
from repro.runtime.train import TrainConfig, make_train_step

ARCH_NAMES = list(ARCHS)


def _batch(cfg, B=2, S=32, key=0):
    rng = np.random.default_rng(key)
    batch = {}
    if cfg.is_encdec:
        batch["src_embeds"] = jnp.asarray(
            rng.standard_normal((B, 16, cfg.d_model)), jnp.bfloat16)
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    elif cfg.frontend == "embed":
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.bfloat16)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward(arch):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = model.apply(params, batch)
    S = 32
    assert logits.shape == (2, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch
    assert bool(jnp.isfinite(aux)), arch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_train_step(arch):
    cfg = reduced(ARCHS[arch])
    tcfg = TrainConfig(optimizer=OptimizerConfig(lr=1e-3, warmup_steps=1,
                                                 total_steps=10),
                       remat=False)
    step_fn, init_fn = make_train_step(cfg, tcfg)
    state = init_fn(jax.random.PRNGKey(0))
    state2, metrics = jax.jit(step_fn)(state, _batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(state2["step"]) == 1
    # parameters actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        state["params"], state2["params"])
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ["smollm-360m", "gemma2-2b",
                                  "mixtral-8x22b", "mamba2-130m",
                                  "zamba2-2.7b", "chatglm3-6b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode must reproduce the full forward logits."""
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg, impl="naive", remat=False)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 1, 12
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    full_logits, _ = model.apply(params, {"tokens": toks})

    cache = model.init_cache(B, S + 1)
    dec = jax.jit(model.decode)
    errs = []
    for t in range(S):
        lg, cache = dec(params, cache, toks[:, t:t + 1], jnp.int32(t))
        errs.append(float(jnp.abs(
            lg[:, 0] - full_logits[:, t]).max()))
    assert max(errs) < 0.15, (arch, errs)  # bf16 accumulation tolerance


def test_causality():
    """Perturbing future tokens must not change past logits."""
    cfg = reduced(ARCHS["smollm-360m"])
    model = build_model(cfg, impl="naive", remat=False)
    params = model.init(jax.random.PRNGKey(2))
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 16)), jnp.int32)
    toks2 = toks.at[0, 12:].set((toks[0, 12:] + 7) % cfg.vocab_size)
    l1, _ = model.apply(params, {"tokens": toks})
    l2, _ = model.apply(params, {"tokens": toks2})
    np.testing.assert_allclose(np.asarray(l1[:, :12]),
                               np.asarray(l2[:, :12]), atol=1e-5)


def test_sliding_window_limits_context():
    """With window w, logits at t depend only on tokens in [t-w+1, t]."""
    import dataclasses
    base = reduced(ARCHS["mixtral-8x22b"])
    cfg = dataclasses.replace(base, sliding_window=4, unit=())
    model = build_model(cfg, impl="naive", remat=False)
    params = model.init(jax.random.PRNGKey(4))
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 16)), jnp.int32)
    # change token 0: positions >= layers*window away cannot see it.
    toks2 = toks.at[0, 0].set((toks[0, 0] + 3) % cfg.vocab_size)
    l1, _ = model.apply(params, {"tokens": toks})
    l2, _ = model.apply(params, {"tokens": toks2})
    # information propagates at most `window-1` per attention layer
    # (moe-family units pair every attention with an expert block, so the
    # attention count equals n_layers)
    n_attn = cfg.n_layers
    horizon = n_attn * (cfg.sliding_window - 1) + 1
    if horizon < 16:
        np.testing.assert_allclose(np.asarray(l1[:, horizon:]),
                                   np.asarray(l2[:, horizon:]), atol=1e-5)


def test_chunked_equals_naive_attention():
    cfg = reduced(ARCHS["qwen2.5-32b"])
    model_n = build_model(cfg, impl="naive", remat=False)
    model_c = build_model(cfg, impl="chunked", remat=False)
    params = model_n.init(jax.random.PRNGKey(6))
    toks = jnp.asarray(np.arange(64)[None, :] % cfg.vocab_size, jnp.int32)
    l1, _ = model_n.apply(params, {"tokens": toks})
    l2, _ = model_c.apply(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=5e-2, rtol=1e-2)


def test_param_count_analytic_matches_tree():
    """ModelConfig.param_count() (used for MODEL_FLOPS) vs the real tree."""
    from repro.models import param_count
    for arch in ["smollm-360m", "gemma2-2b", "mixtral-8x22b",
                 "mamba2-130m", "seamless-m4t-large-v2"]:
        cfg = reduced(ARCHS[arch])
        model = build_model(cfg, remat=False)
        params = model.init(jax.random.PRNGKey(0))
        real = param_count(params)
        pred = cfg.param_count()
        assert abs(real - pred) / real < 0.12, (arch, real, pred)
