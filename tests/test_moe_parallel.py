"""Expert-parallel / TP-ff MoE vs the GSPMD oracle.

The shard_map paths need >1 device, and jax pins the device count at
first init, so the comparison runs in a subprocess with
xla_force_host_platform_device_count=8 (per the no-global-flags rule).
"""

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, dataclasses, numpy as np
from repro.configs import ARCHS, reduced
from repro.models.moe import (moe_block_gspmd, moe_block_expert_parallel,
                              moe_block_tp_ff, moe_init)
from repro.runtime.parallel import ParallelContext
from repro.launch.mesh import make_auto_mesh, use_mesh

cfg = dataclasses.replace(reduced(ARCHS["kimi-k2-1t-a32b"]), n_experts=8,
                          experts_per_token=2, moe_d_ff=32, d_model=64,
                          unit=())
params = moe_init(jax.random.PRNGKey(0), cfg)
mesh = make_auto_mesh((2, 4), ("data", "model"))
x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64), jnp.float32)
ctx = ParallelContext(capacity_factor=8.0)   # high capacity: no drops
with use_mesh(mesh):
    y_ref, _ = jax.jit(lambda p, x: moe_block_gspmd(p, x, cfg))(params, x)
    y_ep, _ = jax.jit(
        lambda p, x: moe_block_expert_parallel(p, x, cfg, ctx))(params, x)
    y_tp, _ = jax.jit(
        lambda p, x: moe_block_tp_ff(p, x, cfg, ctx))(params, x)
    # gradients flow through the shard_map paths
    g = jax.jit(jax.grad(
        lambda p: moe_block_expert_parallel(p, x, cfg, ctx)[0].astype(
            jnp.float32).sum()))(params)
ep = float(jnp.abs(y_ep - y_ref).max())
tp = float(jnp.abs(y_tp - y_ref).max())
assert ep < 1e-5, f"expert-parallel mismatch {ep}"
assert tp < 1e-4, f"tp-ff mismatch {tp}"
gn = max(float(jnp.abs(v).max()) for v in jax.tree.leaves(g))
assert np.isfinite(gn) and gn > 0
print("MOE_PARALLEL_OK")
"""


@pytest.mark.slow
def test_moe_parallel_matches_oracle():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert "MOE_PARALLEL_OK" in out.stdout, out.stdout + out.stderr
