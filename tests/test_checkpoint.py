"""Checkpointer: roundtrip, async, integrity, garbage collection,
elastic restore under different shardings."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import (AsyncCheckpointer, latest_steps,
                                           restore, save)
from repro.launch.mesh import make_auto_mesh


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"params": {"w": jax.random.normal(k, (32, 16)),
                       "units": {"b0": jnp.arange(12.0).reshape(3, 4)}},
            "step": jnp.int32(5)}


def test_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), t, 5)
    out = restore(str(tmp_path), t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_steps_and_gc(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        ck.save_async(t, s)
        ck.wait()
    assert latest_steps(str(tmp_path)) == [3, 4]


def test_async_overlaps_and_is_complete(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    t = _tree()
    ck.save_async(t, 7)
    ck.wait()
    out = restore(str(tmp_path), t, step=7)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(t["params"]["w"]))


def test_corruption_detected(tmp_path):
    t = _tree()
    d = save(str(tmp_path), t, 3)
    shard = [f for f in os.listdir(d) if f.startswith("shard_")][0]
    with open(os.path.join(d, shard), "r+b") as f:
        f.seek(100)
        f.write(b"\x42\x42\x42")
    with pytest.raises(IOError):
        restore(str(tmp_path), t)


def test_elastic_restore_new_shardings(tmp_path):
    """Checkpoint written once, restored under a different mesh's
    shardings (elastic restart)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    t = _tree()
    save(str(tmp_path), t, 1)
    mesh = make_auto_mesh((1, 1), ("data", "model"))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    out = restore(str(tmp_path), t, shardings=sh)
    assert out["params"]["w"].sharding == NamedSharding(mesh, P())
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(t["params"]["w"]))


def test_atomic_no_partial_checkpoint(tmp_path):
    """Temp dirs never surface as checkpoints."""
    t = _tree()
    save(str(tmp_path), t, 9)
    assert all(not d.startswith(".tmp") for d in os.listdir(tmp_path)
               if os.path.isdir(os.path.join(tmp_path, d))
               and d.startswith("step_"))
    assert latest_steps(str(tmp_path)) == [9]
