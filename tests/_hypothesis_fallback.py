"""Deterministic stand-in for `hypothesis` when it is not installed.

The property-test modules prefer the real library (see
requirements-dev.txt); in environments without it (e.g. offline
containers) this fallback keeps them collectable and runs each property
against a small low-discrepancy sample of the strategy domain —
boundary values first, golden-ratio-spaced interior points after — so
the invariants still get exercised deterministically instead of the
whole module erroring out at import.

Only the strategy surface this repo uses is implemented:
`integers`, `floats`, `sampled_from`, `tuples`.
"""

from __future__ import annotations

import math

_PHI = 0.6180339887498949
_MAX_FALLBACK_EXAMPLES = 12


class _Strategy:
    def __init__(self, sample_fn):
        self._sample = sample_fn

    def example_at(self, i: int):
        return self._sample(i)


def _lowdisc(i: int) -> float:
    """i-th golden-ratio point in (0, 1)."""
    return math.modf((i + 1) * _PHI)[0]


class strategies:  # noqa: N801 - mirrors `hypothesis.strategies` module
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        span = max_value - min_value

        def sample(i):
            if i == 0:
                return min_value
            if i == 1:
                return max_value
            return min_value + int(_lowdisc(i) * (span + 1)) % (span + 1)
        return _Strategy(sample)

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        def sample(i):
            if i == 0:
                return min_value
            if i == 1:
                return max_value
            return min_value + _lowdisc(i) * (max_value - min_value)
        return _Strategy(sample)

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        elements = list(elements)
        return _Strategy(lambda i: elements[i % len(elements)])

    @staticmethod
    def tuples(*strats: _Strategy) -> _Strategy:
        return _Strategy(lambda i: tuple(s.example_at(i) for s in strats))


st = strategies


def settings(max_examples: int = 100, deadline=None, **_kw):
    """Records the example budget; the fallback caps it (smoke subset)."""
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strats: _Strategy):
    def deco(fn):
        budget = getattr(fn, "_fallback_max_examples", _MAX_FALLBACK_EXAMPLES)
        n = min(budget, _MAX_FALLBACK_EXAMPLES)

        # zero-arg wrapper: every parameter is strategy-supplied, and the
        # signature must not leak them or pytest would hunt for fixtures
        def wrapper():
            for i in range(n):
                fn(*(s.example_at(i) for s in strats))
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
