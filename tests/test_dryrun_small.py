"""Dry-run machinery smoke: a reduced arch lowers+compiles on a tiny mesh
within this process (the full 512-device sweep runs via the module CLI;
its 66-cell results are recorded in experiments/)."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, reduced
from repro.runtime.sharding import (logical_batch_shardings,
                                    state_shardings)
from repro.runtime.train import TrainConfig, make_train_step
from repro.optim.optimizers import OptimizerConfig
from repro.launch.mesh import make_auto_mesh, use_mesh
from repro.launch.roofline import cost_analysis


def test_lower_compile_reduced_arch():
    cfg = reduced(ARCHS["chatglm3-6b"])
    tcfg = TrainConfig(optimizer=OptimizerConfig(), remat=True)
    step_fn, init_fn = make_train_step(cfg, tcfg)
    abstract_state = jax.eval_shape(lambda: init_fn(jax.random.PRNGKey(0)))
    n = len(jax.devices())
    mesh = make_auto_mesh((1, n), ("data", "model"))
    st_sh = state_shardings(mesh, abstract_state, "adamw")
    batch = {"tokens": jax.ShapeDtypeStruct((2, 32), jnp.int32),
             "labels": jax.ShapeDtypeStruct((2, 32), jnp.int32)}
    b_sh = logical_batch_shardings(mesh, batch)
    with use_mesh(mesh):
        compiled = jax.jit(step_fn, in_shardings=(st_sh, b_sh),
                           out_shardings=(st_sh, NamedSharding(mesh, P()))
                           ).lower(abstract_state, batch).compile()
    ma = compiled.memory_analysis()
    assert ma.argument_size_in_bytes > 0
    assert cost_analysis(compiled).get("flops", 0) > 0


def test_dryrun_results_complete():
    """The recorded 66-cell sweep must be complete and all-ok."""
    import glob
    import json
    import os
    d = os.path.join(os.path.dirname(__file__), "..", "experiments",
                     "dryrun")
    files = glob.glob(os.path.join(d, "*.json"))
    files = [f for f in files if "__h_" not in f]   # exclude hillclimb tags
    if len(files) < 66:
        pytest.skip("full sweep artifacts not present")
    cells = [json.load(open(f)) for f in files]
    ok = [c for c in cells if c.get("status") == "ok"]
    assert len(ok) >= 66, [c["arch"] + c["shape"] for c in cells
                           if c.get("status") != "ok"]
