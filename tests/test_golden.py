"""Golden regression fixtures: the canonical reproduction numbers.

Every value below was produced by the pre-scale-out codebase (PR 4
state) and frozen verbatim.  They pin:

- the paper reproduction: per-workload DSE-best speedups at 64 and
  96 Gb/s on the 3x3 single-shared-channel platform, plus the wired
  baseline times (bit-identical — refactors of the four modelling
  planes must not drift the reproduction by one ulp);
- one LLM prefill row (smollm_360m, tensor-parallel collectives);
- one heterogeneous co-design cell (big_little x zfnet, seeded
  annealer) — relative tolerance only, the annealer's arithmetic is
  not part of the bit-identity contract.

If a change legitimately moves these numbers (a modelling fix, a new
calibration), re-freeze them in the same commit and say why in the
commit message — that is the point: drift must be *loud*.
"""

import pytest

from repro.core import make_trace, simulate_wired, sweep_all
from repro.core.workloads import WORKLOADS

# (workload) -> 64 Gb/s best, 96 Gb/s best, wired seconds — frozen from
# the pre-PR-5 sweep (`sweep_all` over the 15 Table-1 workloads).
GOLDEN_3X3 = {
    "darknet19": (1.1285674185605385, 1.160245163271627,
                  0.0026794993777777775),
    "densenet": (1.0978122385674596, 1.1230413331911508,
                 0.008150938862222222),
    "gnmt": (1.071231559503024, 1.104201987532278, 0.0072250026666666675),
    "googlenet": (1.2267725618874894, 1.2874793001126876,
                  0.004548881786666667),
    "iresnet": (1.0000000000000002, 1.0000000000000002,
                0.01638859084166667),
    "lstm": (1.0763193826547977, 1.10758553644244, 0.003446101333333335),
    "pnasnet": (1.0400932780039358, 1.0421005504937488,
                0.02431194239999999),
    "resnet101": (1.0035815035839937, 1.0044536698785353,
                  0.028196189297777775),
    "resnet152": (1.001547699176585, 1.0015601000865226,
                  0.041231635342222225),
    "resnet50": (1.0105166877941327, 1.013359418804327,
                 0.016969354808888885),
    "resnext50": (1.0343098723922148, 1.04337228033245,
                  0.018392309191111116),
    "transformer": (1.016344914991447, 1.01912569723256,
                    0.04068464867555557),
    "transformer_cell": (1.213666147837697, 1.2628085185440174,
                         0.0043759106874074055),
    "vgg": (1.0751631898915248, 1.0884493036951224, 0.015393355093333335),
    "zfnet": (1.0686450816258646, 1.0813850875070279,
              0.0024527366826666663),
}

# smollm_360m:prefill (tensor-parallel mapping, tree all-reduces)
GOLDEN_LLM_PREFILL = {
    "best_speedup_64": 1.6871591926426304,
    "best_speedup_96": 1.8809018838393576,
    "collective_byte_share": 0.5348837209302325,
    "wired_time": 0.01006347757037037,
}

# repro.arch codesign("zfnet", "big_little", seed=0, steps=40,
# restarts=1, n_samples=4)
GOLDEN_HETERO = {
    "package": "3x3[3xbig+6xlittle]",
    "wired_best": 0.005145934506666673,
    "hybrid_best": 0.0041301585145946005,
    "speedup_codesigned": 1.2459411638760738,
}


@pytest.fixture(scope="module")
def traces():
    return {wl: make_trace(wl) for wl in WORKLOADS}


def test_golden_covers_all_paper_workloads():
    assert set(GOLDEN_3X3) == set(WORKLOADS)


def test_paper_workload_speedups_bit_identical(traces):
    """3x3 single-channel DSE results must equal the frozen values
    EXACTLY — the scale-out refactor's degenerate case is the paper."""
    results = sweep_all(traces)
    got = {}
    for r in results:
        got.setdefault(r.workload, {})[r.bandwidth_gbps] = r.best_speedup
    for wl, (s64, s96, _) in GOLDEN_3X3.items():
        assert got[wl][64] == s64, wl
        assert got[wl][96] == s96, wl


def test_wired_baselines_bit_identical(traces):
    for wl, (_, _, wired) in GOLDEN_3X3.items():
        assert simulate_wired(traces[wl]).total_time == wired, wl


def test_llm_prefill_row_bit_identical():
    tr = make_trace("smollm_360m:prefill")
    total = sum(m.nbytes for m in tr.messages)
    coll = sum(m.nbytes for m in tr.messages if m.kind == "coll")
    assert coll / total == GOLDEN_LLM_PREFILL["collective_byte_share"]
    assert simulate_wired(tr).total_time == GOLDEN_LLM_PREFILL["wired_time"]
    results = sweep_all({"smollm_360m:prefill": tr})
    for r in results:
        key = f"best_speedup_{r.bandwidth_gbps}"
        assert r.best_speedup == GOLDEN_LLM_PREFILL[key]


@pytest.mark.slow
def test_hetero_codesign_cell_stable():
    """Seeded annealer cell: same package and same makespans to float
    tolerance (the search is deterministic; the tolerance only shields
    against BLAS-level reassociation across platforms)."""
    from repro.arch import codesign
    r = codesign("zfnet", "big_little", seed=0, steps=40, restarts=1,
                 n_samples=4)
    assert str(r.package) == GOLDEN_HETERO["package"]
    assert r.wired.t_wired == pytest.approx(GOLDEN_HETERO["wired_best"],
                                            rel=1e-9)
    assert r.hybrid.t_hybrid == pytest.approx(GOLDEN_HETERO["hybrid_best"],
                                              rel=1e-9)
    assert r.speedup_codesigned == pytest.approx(
        GOLDEN_HETERO["speedup_codesigned"], rel=1e-9)
