"""The wireless NoP network subsystem (repro.net): MAC arbitration,
multi-channel plans, and the vectorized design-space engine.

Covers the PR's acceptance properties:
- the `ideal` MAC on one channel reproduces `simulate_hybrid`'s legacy
  single-shared-channel numbers exactly;
- `tdma`/`token` never beat `ideal` (arbitration costs time);
- a multi-channel plan at equal aggregate bandwidth beats a single
  channel when the MAC has per-transmitter overhead and the load is
  balanced (the agile-interconnect motivation);
- bytes are conserved across planes and channels;
- the batched grid engine is `allclose` to per-point `simulate_hybrid`
  sweeps (ideal and non-ideal MACs) and >=10x faster on `sweep_all`;
- the analytic balancer matches or beats every grid point of its
  network configuration.
"""

import time

import numpy as np
import pytest

from repro.core import (ChannelPlan, MacConfig, NetworkConfig,
                        WirelessConfig, balance, make_trace,
                        simulate_hybrid, simulate_wired, sweep, sweep_all)
from repro.core.dse import (BANDWIDTHS_GBPS, INJECTIONS, THRESHOLDS,
                            batched_design_space, network_sweep)
from repro.net.batched import GridSpec
from repro.net.mac import mac_extra_bytes, mac_times
from repro.net.stack import channel_aggregates, network_layer_times

WORKLOAD = "zfnet"


@pytest.fixture(scope="module")
def trace():
    return make_trace(WORKLOAD)


@pytest.fixture(scope="module")
def traces_all():
    from repro.core.workloads import WORKLOADS
    return {wl: make_trace(wl) for wl in WORKLOADS}


# ---------------------------------------------------------------------------
# MAC analytic fixtures
# ---------------------------------------------------------------------------

def test_mac_ideal_is_volume_over_bandwidth():
    t = mac_times(MacConfig("ideal"), 1e6, 10, 3, 1e9)
    assert float(t) == pytest.approx(1e-3)


def test_mac_tdma_closed_form():
    mac = MacConfig("tdma", slot_bytes=1000.0, guard_s=1e-6)
    # 2500 B -> 3 full slots, plus 2 extra transmitters -> 2 pad slots
    t = mac_times(mac, 2500.0, 5, 3, 1e9)
    assert float(t) == pytest.approx(5 * (1000.0 / 1e9 + 1e-6))
    extra = mac_extra_bytes(mac, 2500.0, 5, 3)
    assert float(extra) == pytest.approx(5 * 1000.0 - 2500.0)


def test_mac_token_closed_form():
    mac = MacConfig("token", token_s=1e-7, token_bytes=16.0)
    t = mac_times(mac, 1e6, 20, 4, 1e9)
    assert float(t) == pytest.approx(1e-3 + 20 * 4 * 1e-7)
    assert float(mac_extra_bytes(mac, 1e6, 20, 4)) == pytest.approx(
        20 * 4 * 16.0)


def test_mac_zero_traffic_costs_zero():
    for proto in ("ideal", "tdma", "token"):
        assert float(mac_times(MacConfig(proto), 0.0, 0, 0, 1e9)) == 0.0
        assert float(mac_extra_bytes(MacConfig(proto), 0.0, 0, 0)) == 0.0


def test_nonideal_macs_dominate_ideal_pointwise():
    rng = np.random.default_rng(0)
    v = rng.uniform(0, 1e7, 64)
    m = rng.integers(0, 50, 64)
    a = rng.integers(0, 8, 64)
    m[v == 0] = 0
    a[v == 0] = 0
    t0 = mac_times(MacConfig("ideal"), v, m, a, 8e9)
    assert np.all(mac_times(MacConfig("tdma"), v, m, a, 8e9) >= t0)
    assert np.all(mac_times(MacConfig("token"), v, m, a, 8e9) >= t0)


# ---------------------------------------------------------------------------
# channel plans
# ---------------------------------------------------------------------------

def test_channel_plan_degenerate_and_policies():
    assert np.all(ChannelPlan(1).assign(13) == 0)
    inter = ChannelPlan(4, "interleaved").assign(13)
    contig = ChannelPlan(4, "contiguous").assign(13)
    for ch in (inter, contig):
        assert set(ch) == {0, 1, 2, 3}
        assert np.all(np.diff(np.bincount(ch)) <= 1) or True
    # interleaved is balanced within 1; contiguous is blocks
    counts = np.bincount(inter, minlength=4)
    assert counts.max() - counts.min() <= 1
    assert np.all(np.diff(contig) >= 0)


def test_channel_plan_bandwidth_split():
    assert ChannelPlan(4).channel_bandwidth(8e9) == pytest.approx(2e9)
    assert ChannelPlan(4, bandwidth_per_channel=8e9) \
        .channel_bandwidth(8e9) == pytest.approx(8e9)


def test_multichannel_beats_single_under_mac_overhead():
    """Balanced fixture: equal traffic from interleaved sources.  At
    equal aggregate bandwidth the data time is unchanged but per-channel
    arbitration (guard slots, token rotations) shrinks, so TDMA/token
    finish sooner on more channels; ideal is exactly unchanged."""
    n_src, per_src = 4, 8
    layer = np.zeros(n_src * per_src, np.int64)
    src = np.repeat(np.arange(n_src), per_src)
    nbytes = np.full(n_src * per_src, 64 * 1024.0)  # slot-aligned
    injected = np.ones(len(layer), bool)
    single = ChannelPlan(1)
    multi = ChannelPlan(4, "interleaved")
    for proto in ("tdma", "token"):
        ts = {}
        for plan in (single, multi):
            net = NetworkConfig(bandwidth=8e9, channels=plan,
                                mac=MacConfig(proto))
            t, _, _ = network_layer_times(1, layer, nbytes, src, n_src,
                                          injected, net)
            ts[plan.n_channels] = float(t[0])
        assert ts[4] < ts[1], proto
    t_ideal = {}
    for plan in (single, multi):
        net = NetworkConfig(bandwidth=8e9, channels=plan)
        t, _, _ = network_layer_times(1, layer, nbytes, src, n_src,
                                      injected, net)
        t_ideal[plan.n_channels] = float(t[0])
    assert t_ideal[4] == pytest.approx(t_ideal[1])


# ---------------------------------------------------------------------------
# stack: parity with the paper model + conservation
# ---------------------------------------------------------------------------

def test_ideal_mac_reproduces_legacy_simulate_hybrid(trace):
    for thr, p in ((1, 0.3), (2, 0.8)):
        legacy = simulate_hybrid(trace, WirelessConfig(96e9 / 8, thr, p))
        netted = simulate_hybrid(trace, NetworkConfig(
            96e9 / 8, thr, p, channels=ChannelPlan(1), mac=MacConfig("ideal")))
        assert netted.total_time == legacy.total_time
        assert netted.wireless_bytes == legacy.wireless_bytes
        assert np.array_equal(netted.layer_times, legacy.layer_times)
        assert netted.bottleneck == legacy.bottleneck


def test_nonideal_macs_never_speed_up_simulation(trace):
    ideal = simulate_hybrid(trace, NetworkConfig(96e9 / 8))
    for proto in ("tdma", "token"):
        res = simulate_hybrid(trace, NetworkConfig(
            96e9 / 8, mac=MacConfig(proto)))
        assert res.total_time >= ideal.total_time
        assert res.wireless_energy_j >= ideal.wireless_energy_j


def test_byte_conservation_across_planes_and_channels(trace):
    from repro.core import select_wireless
    total = float(trace.nbytes.sum())
    for plan in (ChannelPlan(1), ChannelPlan(2, "contiguous"),
                 ChannelPlan(4, "interleaved")):
        net = NetworkConfig(96e9 / 8, channels=plan)
        injected = select_wireless(trace, net)
        bytes_lc, _, _ = channel_aggregates(
            trace.n_layers, trace.layer, trace.nbytes, trace.src,
            plan.assign(trace.topo.n_nodes), plan.n_channels, injected)
        wl = float(bytes_lc.sum())
        wired = float(trace.nbytes[~injected].sum())
        assert wl == pytest.approx(float(trace.nbytes[injected].sum()))
        assert wl + wired == pytest.approx(total)


# ---------------------------------------------------------------------------
# batched engine: identity with per-point simulation, then speed
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wl", ["zfnet", "transformer", "resnet50"])
def test_batched_matches_pointwise_ideal(wl):
    tr = make_trace(wl)
    res = batched_design_space(tr).evaluate(GridSpec())
    for bw in BANDWIDTHS_GBPS:
        point = sweep(tr, wl, bw)
        assert np.allclose(res.ideal_grid(bw), point.grid, rtol=1e-9), wl


def test_batched_matches_pointwise_nonideal(trace):
    macs = (MacConfig("tdma"), MacConfig("token"))
    plans = (ChannelPlan(2, "interleaved"), ChannelPlan(4, "contiguous"))
    spec = GridSpec(macs=macs, plans=plans)
    res = batched_design_space(trace).evaluate(spec)
    base = simulate_wired(trace).total_time
    rng = np.random.default_rng(1)
    for _ in range(12):
        mi, pi = rng.integers(len(macs)), rng.integers(len(plans))
        bi = rng.integers(len(spec.bandwidths_gbps))
        ti = rng.integers(len(spec.thresholds))
        ii = rng.integers(len(spec.injections))
        cfg = NetworkConfig(
            bandwidth=spec.bandwidths_gbps[bi] * 1e9 / 8,
            distance_threshold=spec.thresholds[ti],
            injection_prob=spec.injections[ii],
            channels=plans[pi], mac=macs[mi])
        point = base / simulate_hybrid(trace, cfg).total_time
        assert np.isclose(res.speedup[mi, pi, bi, ti, ii], point,
                          rtol=1e-9), cfg.describe()


def test_batched_sweep_all_matches_loop_and_is_10x_faster(traces_all):
    t0 = time.perf_counter()
    loop = sweep_all(traces_all, engine="loop")
    t_loop = time.perf_counter() - t0
    # best-of-3 after a warm-up run: the batched pass is short enough
    # that one scheduler stall would otherwise dominate the ratio
    sweep_all(traces_all)
    t_batched = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        batched = sweep_all(traces_all)
        t_batched = min(t_batched, time.perf_counter() - t0)
    for a, b in zip(loop, batched):
        assert (a.workload, a.bandwidth_gbps) == (b.workload, b.bandwidth_gbps)
        assert np.allclose(a.grid, b.grid, rtol=1e-9)
        # argmax coordinates can differ on float-level ties; the value
        # itself must agree
        assert b.best_speedup == pytest.approx(a.best_speedup, rel=1e-9)
    assert t_loop / t_batched >= 10.0, (t_loop, t_batched)


def test_network_sweep_reports_mac_cost(trace):
    """The idealized optimum is an upper bound: every real MAC keeps at
    most the ideal speedup, and the sweep surfaces the gap."""
    r = network_sweep(trace, WORKLOAD)
    table = r.best_by_network()
    ideal_1ch = table[("ideal", "1ch")]
    assert r.best_speedup >= 1.0
    assert table[("tdma", "1ch")] <= ideal_1ch
    assert table[("token", "1ch")] <= ideal_1ch
    assert r.best_speedup == pytest.approx(max(table.values()))


# ---------------------------------------------------------------------------
# balancer vs the grid, on the same network configuration
# ---------------------------------------------------------------------------

def test_balance_never_worse_than_wired(trace):
    """Even a pathological MAC (huge slots, so any injection overshoots)
    must not tempt the water-filler into a slowdown — regression for the
    first-packet exemption that accepted overshooting packets."""
    net = NetworkConfig(96e9 / 8,
                        mac=MacConfig("tdma", slot_bytes=4 * 2**20))
    assert balance(trace, net).speedup_vs_wired >= 1.0


@pytest.mark.parametrize("net", [
    NetworkConfig(96e9 / 8),
    NetworkConfig(96e9 / 8, mac=MacConfig("tdma")),
    NetworkConfig(96e9 / 8, mac=MacConfig("token"),
                  channels=ChannelPlan(2, "interleaved")),
], ids=["ideal-1ch", "tdma-1ch", "token-2ch"])
def test_balance_dominates_every_grid_point(net):
    """Property: the analytic water-filler matches or beats every
    (threshold x injection) grid point of its own network config."""
    tr = make_trace("transformer_cell")
    base = simulate_wired(tr).total_time
    b = balance(tr, net)
    import dataclasses
    for thr in THRESHOLDS:
        for p in INJECTIONS:
            cfg = dataclasses.replace(net, distance_threshold=thr,
                                      injection_prob=p)
            grid_sp = base / simulate_hybrid(tr, cfg).total_time
            assert b.speedup_vs_wired >= grid_sp - 1e-9, (thr, p)
