"""Cross-plane differential fuzz harness.

The codebase now carries four coupled implementations of the same
network semantics: the analytic per-layer model (`simulate_hybrid`),
the vectorized design-space engine (`net.batched`), the event-driven
packet simulator (`repro.sim`, three link models) and the analytic
balancer.  This harness generates random cases — random (non-square)
grids, DRAM counts, layer graphs with multicast fan-out / streamed
weights / MoE + collective shapes, random mappings, and random network
configs including multi-channel and spatial-reuse plans — and asserts
the cross-plane contracts on every one:

- `simulate_hybrid` <-> striped event engine: layer-time parity to
  machine precision (ideal MAC), and wired-baseline parity;
- non-ideal MACs and the `adaptive`/`xy` link models only ever ADD
  time over the analytic lower bound;
- the batched grid engine agrees with per-point `simulate_hybrid` at
  the same configuration;
- bytes are conserved across planes;
- the balancer matches or beats the anchored grid optimum.

Runs under `hypothesis` when installed; otherwise the deterministic
low-discrepancy fallback exercises a fixed seed subset.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: deterministic smoke-subset fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (ChannelPlan, MacConfig, NetworkConfig, balance,
                        build_topology, simulate_hybrid, simulate_wired)
from repro.core.dse import batched_design_space, grid_best_speedup
from repro.core.mapper import (expert_parallel_mapping, pipeline_mapping,
                               spatial_mapping, tensor_parallel_mapping)
from repro.core.topology import AcceleratorConfig
from repro.core.traffic import WEIGHT_SRAM_BYTES, build_trace
from repro.core.workloads import Layer
from repro.net.batched import GridSpec
from repro.sim import PacketSim

MACS = ("ideal", "tdma", "token")


def random_case(seed: int):
    """(trace, net) pair derived deterministically from one seed."""
    rng = np.random.default_rng(seed)
    rows = int(rng.integers(1, 5))
    cols = int(rng.integers(2, 6))
    cfg = AcceleratorConfig(
        grid=(rows, cols),
        n_dram=int(rng.integers(1, 7)),
        tops_total=16e12 * rows * cols,
        wireless_bw=float(rng.uniform(16, 128)) * 1e9 / 8,
    )
    topo = build_topology(cfg)

    # --- random layer graph: fan-out multicasts, streamed weights,
    # spills, and (sometimes) collective-hinted / MoE layers ---
    n_layers = int(rng.integers(2, 9))
    layers = []
    has_moe = False
    for i in range(n_layers):
        weights = int(rng.uniform(0, 3) * WEIGHT_SRAM_BYTES)
        hint = None
        n_exp = ept = 0
        u = rng.uniform()
        if u < 0.2:
            hint = "all_reduce"
        elif u < 0.35:
            hint, has_moe = "moe", True
            n_exp = int(rng.integers(2, 9))
            ept = int(rng.integers(1, min(4, n_exp) + 1))
        layers.append(Layer(
            name=f"l{i}",
            macs=float(rng.uniform(0, 2e9)),
            act_in=int(rng.uniform(1e4, 5e6)),
            weights=weights,
            act_out=int(rng.uniform(1e4, 5e6)),
            consumers=sorted(rng.choice(
                np.arange(i + 1, n_layers),
                size=min(int(rng.integers(0, 4)), n_layers - i - 1),
                replace=False).tolist()),
            collective=hint, n_experts=n_exp, experts_per_token=ept))

    mappers = [pipeline_mapping, spatial_mapping, tensor_parallel_mapping]
    if has_moe:
        mappers.append(expert_parallel_mapping)
    mapping = mappers[int(rng.integers(len(mappers)))](layers, topo)
    trace = build_trace(layers, mapping, topo)

    # --- random network: channels, MAC, spatial reuse (when it fits) ---
    n_ch = int(rng.choice([1, 1, 2, 4]))
    policy = str(rng.choice(["contiguous", "interleaved"]))
    fitting = [1]
    for k in (2, 3, 4, 6):
        try:
            ChannelPlan(reuse_zones=k).zone_tiling((rows, cols))
            fitting.append(k)
        except ValueError:
            pass
    plan = ChannelPlan(n_ch, policy,
                       reuse_zones=int(rng.choice(fitting)))
    net = NetworkConfig(
        bandwidth=cfg.wireless_bw,
        distance_threshold=int(rng.integers(1, 5)),
        injection_prob=float(rng.uniform(0.05, 0.85)),
        channels=plan,
        mac=MacConfig(str(rng.choice(MACS))))
    return trace, net


def check_case(seed: int):
    trace, net = random_case(seed)
    an_wired = simulate_wired(trace)
    an = simulate_hybrid(trace, net)
    sim = PacketSim(trace, net)
    ev_wired = sim.run_wired()
    ev = sim.run("static")
    ctx = (seed, trace.topo.config.grid, net.describe())

    # wired plane parity is MAC-independent
    np.testing.assert_allclose(ev_wired.layer_times, an_wired.layer_times,
                               rtol=1e-12, err_msg=str(ctx))
    # bytes conserved across planes
    total = float(trace.nbytes.sum())
    wired_bytes = float(trace.nbytes[~ev.injected].sum())
    assert wired_bytes + ev.wireless_bytes == pytest.approx(total), ctx

    if net.mac.protocol == "ideal":
        # striped event engine == analytic model, layer by layer
        # (bottleneck LABELS may differ on exact cross-plane ties —
        # the argmax over ulp-identical values is not part of the
        # contract, the times are)
        np.testing.assert_allclose(ev.layer_times, an.layer_times,
                                   rtol=1e-12, err_msg=str(ctx))
    else:
        # arbitration only ever adds time over the ideal MAC, within
        # each plane (the tdma event/aggregate forms do not bound each
        # other across planes — see net/mac.py)
        import dataclasses
        ideal = dataclasses.replace(net, mac=MacConfig("ideal"))
        ev_ideal = PacketSim(trace, ideal).run("static")
        an_ideal = simulate_hybrid(trace, ideal)
        assert ev.total_time >= ev_ideal.total_time * (1 - 1e-9), ctx
        assert an.total_time >= an_ideal.total_time * (1 - 1e-9), ctx

    # adaptive/xy wired realism dominates the striped idealization
    # (identical wireless plane, same injected set)
    for model in ("adaptive", "xy"):
        evm = PacketSim(trace, net, link_model=model).run("static")
        assert evm.total_time >= ev.total_time * (1 - 1e-9), (ctx, model)
        if net.mac.protocol == "ideal":
            # ...and therefore the analytic lower bound
            assert evm.total_time >= an.total_time * (1 - 1e-9), (ctx, model)

    # batched grid point == per-point simulate_hybrid on this exact net
    spec = GridSpec(bandwidths_gbps=(net.bandwidth * 8 / 1e9,),
                    thresholds=(net.distance_threshold,),
                    injections=(net.injection_prob,),
                    macs=(net.mac,), plans=(net.channels,))
    res = batched_design_space(trace, thresholds=(
        net.distance_threshold,)).evaluate(spec)
    point = an_wired.total_time / an.total_time
    assert np.isclose(float(res.speedup.squeeze()), point,
                      rtol=1e-9), ctx

    # the balancer's per-layer stitch dominates the anchored grid best
    b = balance(trace, net)
    assert b.speedup_vs_wired >= grid_best_speedup(trace, net) - 1e-9, ctx
    assert b.speedup_vs_wired >= 1 - 1e-12, ctx


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_differential_random_cases(seed):
    check_case(seed)


def test_differential_known_seeds():
    """A fixed regression subset that runs identically with and without
    hypothesis (the fallback may sample different seeds)."""
    for seed in (0, 1, 2, 3, 5, 8, 13, 21, 34, 55):
        check_case(seed)
