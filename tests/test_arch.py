"""`repro.arch` validation: homogeneous parity, per-chiplet energy,
and the placement/co-design search engine.

The parity tests are the subsystem's contract: a `HeteroPackage` built
from identical "standard" chiplets must reproduce the homogeneous paper
reproduction TO MACHINE PRECISION on every paper workload plus an LLM
graph — the heterogeneity refactor is not allowed to drift the numbers
behind Figs. 2/4/5.  The search tests pin the annealer's determinism
and validate it against exhaustive enumeration on a small package.
"""

import numpy as np
import pytest

from repro.arch import (CATALOG, MIXES, HeteroPackage, PlacementProblem,
                        anneal, balanced_stages, codesign, exhaustive,
                        greedy_seed)
from repro.core import (WirelessConfig, make_trace, simulate_hybrid,
                        simulate_wired, sweep_all)
from repro.core.dse import hetero_summary, hetero_sweep, sweep
from repro.core.mapper import pipeline_mapping, spatial_mapping
from repro.core.simulator import (PJ_PER_BIT_NOC, PJ_PER_MAC, mac_energy_pj)
from repro.core.topology import build_topology
from repro.core.workloads import WORKLOADS, GraphBuilder, get_workload

UNIFORM_CFG = HeteroPackage.uniform().to_config()
PARITY_WORKLOADS = list(WORKLOADS) + ["smollm_360m:prefill"]
NET = WirelessConfig(96e9 / 8, 1, 0.5)


@pytest.fixture(scope="module")
def pairs():
    """(default-platform trace, uniform-HeteroPackage trace) per workload."""
    return {wl: (make_trace(wl), make_trace(wl, acc=UNIFORM_CFG))
            for wl in PARITY_WORKLOADS}


def _tiny_layers():
    """8-layer synthetic graph for exhaustive-search validation."""
    g = GraphBuilder()
    for i, (cin, cout, hw) in enumerate(
            [(3, 32, 64), (32, 64, 32), (64, 64, 32), (64, 128, 16),
             (128, 128, 16), (128, 256, 8), (256, 256, 8)]):
        g.conv(f"c{i}", cin, cout, 3, hw)
    g.fc("fc", 256, 100)
    return g.layers


def _tiny_problem():
    return PlacementProblem(_tiny_layers(),
                            mix=("big", "big", "little", "little"),
                            grid=(2, 2))


# ---------------------------------------------------------------------------
# homogeneous parity: the refactor cannot drift the paper reproduction
# ---------------------------------------------------------------------------

def test_uniform_package_is_the_paper_platform():
    assert UNIFORM_CFG.grid == (3, 3)
    assert UNIFORM_CFG.tops_total == 144e12
    assert UNIFORM_CFG.chiplet_tops == (16e12,) * 9
    std = CATALOG["standard"]
    assert std.pj_per_mac == PJ_PER_MAC
    assert std.pj_per_bit_noc == PJ_PER_BIT_NOC


@pytest.mark.parametrize("wl", PARITY_WORKLOADS)
def test_homogeneous_parity_wired(pairs, wl):
    tr0, tr1 = pairs[wl]
    r0, r1 = simulate_wired(tr0), simulate_wired(tr1)
    assert r0.total_time == r1.total_time
    assert np.array_equal(r0.layer_times, r1.layer_times)
    assert r0.bottleneck == r1.bottleneck
    assert r0.energy_j == r1.energy_j


@pytest.mark.parametrize("wl", PARITY_WORKLOADS)
def test_homogeneous_parity_hybrid(pairs, wl):
    tr0, tr1 = pairs[wl]
    h0, h1 = simulate_hybrid(tr0, NET), simulate_hybrid(tr1, NET)
    assert h0.total_time == h1.total_time
    assert h0.wireless_bytes == h1.wireless_bytes
    assert h0.energy_j == h1.energy_j


def test_homogeneous_parity_sweep_all(pairs):
    """The full paper DSE (batched engine) is placement-refactor-proof."""
    res0 = sweep_all({wl: p[0] for wl, p in pairs.items()})
    res1 = sweep_all({wl: p[1] for wl, p in pairs.items()})
    for a, b in zip(res0, res1):
        assert (a.workload, a.bandwidth_gbps) == (b.workload, b.bandwidth_gbps)
        assert np.array_equal(a.grid, b.grid)
        assert a.best_speedup == b.best_speedup


def test_homogeneous_parity_per_point_sweep(pairs):
    """Per-point (simulate_hybrid loop) grid equality on a sample."""
    for wl in ("zfnet", "googlenet"):
        tr0, tr1 = pairs[wl]
        g0 = sweep(tr0, wl, 96).grid
        g1 = sweep(tr1, wl, 96).grid
        assert np.array_equal(g0, g1)


def test_homogeneous_parity_event_engine(pairs):
    """The event-driven plane sees identical numbers too."""
    from repro.sim import PacketSim
    from repro.net.config import NetworkConfig
    net = NetworkConfig(96e9 / 8)
    for wl in ("zfnet", "gnmt"):
        tr0, tr1 = pairs[wl]
        e0 = PacketSim(tr0, net).run("adaptive")
        e1 = PacketSim(tr1, net).run("adaptive")
        assert e0.total_time == e1.total_time
        assert e0.energy_j == e1.energy_j


# ---------------------------------------------------------------------------
# per-chiplet energy + SRAM semantics
# ---------------------------------------------------------------------------

def test_hetero_energy_charges_per_chiplet_coefficients():
    """An AIMC-heavy package must cost less compute energy; a uniform
    coefficient vector must collapse to the legacy global product."""
    tr_std = make_trace("zfnet", acc=UNIFORM_CFG)
    assert mac_energy_pj(tr_std) == tr_std.total_macs * PJ_PER_MAC
    cfg = HeteroPackage.from_mix("aimc_edge").to_config()
    tr_mix = make_trace("zfnet", acc=cfg)
    assert mac_energy_pj(tr_mix) < mac_energy_pj(tr_std)
    # per-chiplet MAC accounting is conserved
    assert np.isclose(tr_mix.macs_per_chiplet.sum(), tr_mix.total_macs)


def test_mem_chiplets_keep_weights_resident():
    """gnmt's 16-MiB LSTM gate matrices stream on 4-MiB standard SRAM
    but stay resident on 32-MiB "mem" chiplets: less DRAM traffic."""
    tr_std = make_trace("gnmt", acc=UNIFORM_CFG)
    tr_mem = make_trace("gnmt", acc=HeteroPackage.uniform("mem").to_config())
    n_stream = sum(m.kind == "wstream" for m in tr_std.messages)
    n_stream_mem = sum(m.kind == "wstream" for m in tr_mem.messages)
    assert n_stream_mem < n_stream
    assert tr_mem.dram_bytes.sum() < tr_std.dram_bytes.sum()


def test_hetero_mappings_are_rate_aware():
    """Non-uniform packages get rate-proportional shares; a uniform
    package reproduces the legacy mapping exactly."""
    layers = get_workload("googlenet")
    topo_het = HeteroPackage.from_mix("big_little").build_topology()
    topo_uni = build_topology(UNIFORM_CFG)
    topo_def = build_topology()
    m_het = spatial_mapping(layers, topo_het)
    assert not np.allclose(m_het.shares[0], m_het.shares[0][0])
    assert np.isclose(m_het.shares[0].sum(), 1.0)
    m_uni = pipeline_mapping(layers, topo_uni)
    m_def = pipeline_mapping(layers, topo_def)
    assert [tuple(c) for c in m_uni.chiplets] == \
        [tuple(c) for c in m_def.chiplets]
    for a, b in zip(m_uni.shares, m_def.shares):
        assert np.array_equal(a, b)


# ---------------------------------------------------------------------------
# placement search engine
# ---------------------------------------------------------------------------

def test_balanced_stages_non_empty_and_contiguous():
    macs = [lyr.macs for lyr in _tiny_layers()]
    stages = balanced_stages(macs, [2.0, 1.0, 1.0])
    assert stages == sorted(stages)               # contiguous
    assert set(stages) == {0, 1, 2}               # all non-empty
    stages_tail = balanced_stages([1.0] * 4, [1.0] * 4)
    assert stages_tail == [0, 1, 2, 3]


def test_annealer_is_deterministic():
    """Same seed => identical placement, segmentation and makespan."""
    r1 = anneal(_tiny_problem(), "hybrid", seed=3, steps=80, restarts=2)
    r2 = anneal(_tiny_problem(), "hybrid", seed=3, steps=80, restarts=2)
    assert r1 == r2
    r3 = anneal(_tiny_problem(), "hybrid", seed=4, steps=80, restarts=2)
    assert r3.makespan <= r1.makespan * 1.25      # different seed, sane


def test_annealer_beats_greedy_and_matches_exhaustive():
    """anneal >= greedy always; on a <= 6-slot package the annealer
    finds the exhaustive joint optimum."""
    p = _tiny_problem()
    ex = exhaustive(p, "hybrid")
    an = anneal(p, "hybrid", seed=0, steps=150, restarts=2)
    gr = p.cost(greedy_seed(p), "hybrid")
    assert an.makespan <= gr
    assert an.makespan == ex.makespan
    # wired objective too
    exw = exhaustive(p, "wired")
    anw = anneal(p, "wired", seed=0, steps=150, restarts=2)
    assert anw.makespan == exw.makespan


def test_codesign_reports_are_consistent():
    r = codesign("zfnet", "big_little", steps=40, restarts=1, n_samples=4)
    assert r.package.startswith("3x3[")
    assert r.spread_wired >= 1.0 and r.spread_hybrid >= 1.0
    # cross-polish guarantee: co-design never loses to the wired optimum
    assert r.speedup_codesigned >= 1.0 - 1e-12
    assert r.hybrid.t_hybrid <= r.greedy.t_hybrid + 1e-15
    assert r.hybrid.hybrid_speedup == pytest.approx(r.speedup_hybrid)


def test_hetero_sweep_summary_shape():
    res = hetero_sweep(workloads=["zfnet", "googlenet"],
                       mixes=("big_little",), steps=30, restarts=1,
                       n_samples=3)
    assert len(res) == 2
    s = hetero_summary(res)
    assert s["_overall"]["n"] == 2
    assert s["big_little"]["mean_speedup_codesigned"] >= 1.0 - 1e-12
    assert 0 <= s["_overall"]["spread_shrunk"] <= 2


def test_mix_registry_covers_grid():
    for name in MIXES:
        pkg = HeteroPackage.from_mix(name)
        assert pkg.n_slots == 9, name
        assert not pkg.is_uniform, name


def test_unknown_mix_and_spec_raise_friendly_errors():
    with pytest.raises(KeyError, match="big_little"):
        HeteroPackage.from_mix("big_litle")      # typo lists the choices
    with pytest.raises(KeyError, match="standard"):
        HeteroPackage.uniform("standrd")


def test_pipeline_spread_uses_per_chiplet_sram():
    """The weight-spread remedy follows the slot SRAM budget: gnmt's
    16-MiB gate matrices force spreading on standard (4-MiB) chiplets
    but fit a single 32-MiB "mem" chiplet's stage."""
    layers = get_workload("gnmt")
    m_std = pipeline_mapping(layers, build_topology(UNIFORM_CFG))
    m_mem = pipeline_mapping(
        layers, HeteroPackage.uniform("mem").build_topology())
    n_std = max(len(c) for c in m_std.chiplets)
    widest_lstm = max(len(m_mem.chiplets[i]) for i, lyr in enumerate(layers)
                      if 0 < lyr.weights <= 32 * 2**20)
    assert n_std > widest_lstm


def test_hetero_summary_empty_is_empty():
    assert hetero_summary([]) == {}
