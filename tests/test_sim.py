"""The event-driven packet-level simulator (repro.sim).

Covers the PR's acceptance properties:
- a golden hand-computed 2-chiplet / 3-packet trace, checked event by
  event against pencil-and-paper numbers (batched and per-packet paths);
- the default (striped, ideal-MAC, single-channel) engine reproduces
  the analytic model's layer times exactly, and its hybrid speedup is
  within 5% of the analytic speedup on EVERY paper workload;
- the event-driven total time dominates the analytic per-layer lower
  bound on every workload and link model, with equality when a
  non-network term (compute) is the bottleneck everywhere;
- the adaptive per-layer policy matches or beats the best static
  (threshold x injection) grid point on every workload; greedy never
  slows a run down; the oracle replay agrees with the offline balancer;
- per-packet MAC variants and the per-port DRAM model only ever add
  time, and bytes are conserved across planes.
"""

import numpy as np
import pytest

from repro.core import (AcceleratorConfig, NetworkConfig, balance,
                        build_topology, make_trace, simulate_hybrid,
                        simulate_wired)
from repro.core.dse import batched_design_space, policy_sweep
from repro.core.traffic import TrafficTrace
from repro.core.workloads import WORKLOADS
from repro.net.batched import GridSpec
from repro.net.mac import MacConfig
from repro.sim import (FixedPolicy, PacketSim, get_policy,
                       simulate_events)

NET96 = NetworkConfig(bandwidth=96e9 / 8)


@pytest.fixture(scope="module")
def traces_all():
    return {wl: make_trace(wl) for wl in WORKLOADS}


@pytest.fixture(scope="module")
def trace(traces_all):
    return traces_all["zfnet"]


# ---------------------------------------------------------------------------
# golden trace: 2 chiplets, 1 layer, 3 packets, numbers done by hand
# ---------------------------------------------------------------------------

def _golden_trace() -> TrafficTrace:
    """Two chiplets side by side, one directed link each way.

    Link bandwidth 4 GB/s (32 Gb/s); both cuts have one parallel link,
    so every link model agrees.  Three packets in layer 0:

    - p0: 4 MB multicast chiplet0 -> chiplet1 (link 0), eligible
    - p1: 4 MB multicast chiplet0 -> chiplet1 (link 0), eligible
    - p2: 2 MB unicast   chiplet1 -> chiplet0 (link 1), 1 hop: not
      eligible (the paper's distance threshold is exclusive for
      unicasts)

    Compute floor 1 ms; DRAM and NoC free.
    """
    topo = build_topology(AcceleratorConfig(grid=(1, 2), n_dram=1))
    return TrafficTrace(
        topo=topo, n_layers=1,
        link_index={((0, 0), (0, 1)): 0, ((0, 1), (0, 0)): 1},
        layer=np.array([0, 0, 0], np.int32),
        nbytes=np.array([4e6, 4e6, 2e6]),
        src=np.array([0, 0, 1], np.int32),
        is_multicast=np.array([True, True, False]),
        is_multichip=np.array([True, True, True]),
        max_hops=np.array([1, 1, 1], np.int32),
        dram_node=np.array([-1, -1, -1], np.int32),
        inc_msg=np.array([0, 1, 2], np.int32),
        inc_link=np.array([0, 0, 1], np.int32),
        t_compute=np.array([1e-3]),
        t_dram=np.array([0.0]),
        t_noc=np.array([0.0]),
        dram_bytes=np.array([0.0]),
        messages=[],
    )


def test_golden_wired_baseline():
    tr = _golden_trace()
    sim = PacketSim(tr, NET96)
    res = sim.run_wired()
    # link 0 serves 8 MB at 4 GB/s -> 2 ms; compute floor is 1 ms
    assert res.total_time == pytest.approx(2e-3)
    assert res.bottleneck == ["nop"]
    np.testing.assert_allclose(res.cut_busy, [2e-3, 0.5e-3])
    assert res.wireless_bytes == 0.0


def test_golden_fixed_injection():
    tr = _golden_trace()
    sim = PacketSim(tr, NET96)
    res = sim.run(FixedPolicy([False, True, False]))
    # p1 offloaded: link 0 now 4 MB -> 1 ms; wireless 4 MB at 12 GB/s
    # -> 1/3 ms; the 1 ms compute floor ties the wired plane and wins
    # the argmax
    assert res.total_time == pytest.approx(1e-3)
    assert res.bottleneck == ["compute"]
    assert res.wireless_bytes == pytest.approx(4e6)
    np.testing.assert_allclose(res.channel_busy, [4e6 / (96e9 / 8)])


def test_golden_greedy_event_by_event():
    """Per-packet trace of the greedy decisions, done by hand:

    - p0: wired finish 0+1 ms vs wireless 1/3 ms -> wireless
    - p1: wired finish 0+1 ms vs wireless 2/3 ms -> wireless
    - p2: ineligible -> wired (0.5 ms on link 1)
    layer = max(1 ms floor, 0.5 ms wired, 2/3 ms wireless) = 1 ms.
    """
    tr = _golden_trace()
    sim = PacketSim(tr, NET96)
    res = sim.run("greedy")
    assert list(res.injected) == [True, True, False]
    assert res.total_time == pytest.approx(1e-3)
    np.testing.assert_allclose(res.channel_busy, [8e6 / (96e9 / 8)])
    np.testing.assert_allclose(res.cut_busy, [0.0, 0.5e-3])
    # adaptive per-layer planning finds the same optimum
    assert sim.run("adaptive").total_time == pytest.approx(1e-3)


# ---------------------------------------------------------------------------
# fidelity: the default engine reproduces the analytic model
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wl", ["zfnet", "transformer", "resnet50"])
def test_striped_static_matches_analytic_exactly(traces_all, wl):
    tr = traces_all[wl]
    ev = simulate_events(tr, NET96, policy="static")
    an = simulate_hybrid(tr, NET96)
    np.testing.assert_allclose(ev.layer_times, an.layer_times, rtol=1e-12)
    assert ev.bottleneck == an.bottleneck
    assert ev.wireless_bytes == pytest.approx(an.wireless_bytes)
    evw = PacketSim(tr, NET96).run_wired()
    anw = simulate_wired(tr)
    np.testing.assert_allclose(evw.layer_times, anw.layer_times, rtol=1e-12)


def test_event_speedup_within_5pct_of_analytic_everywhere(traces_all):
    """Acceptance: event-driven hybrid speedup within 5% of analytic on
    the ideal-MAC single-channel config, for every paper workload."""
    for wl, tr in traces_all.items():
        an = simulate_wired(tr).total_time / \
            simulate_hybrid(tr, NET96).total_time
        sim = PacketSim(tr, NET96)
        ev = sim.run_wired().total_time / sim.run("static").total_time
        assert abs(ev - an) / an < 0.05, wl
        # the default (striped) model is in fact exact
        assert abs(ev - an) / an < 1e-9, wl


# ---------------------------------------------------------------------------
# property: event time >= analytic per-layer lower bound
# ---------------------------------------------------------------------------

def test_event_time_dominates_analytic_lower_bound(traces_all):
    """The analytic layer time is a lower bound under every link model:
    each mesh cut must serve its bytes, and pigeonhole puts at least
    one of its k links at >= load/k."""
    for wl, tr in traces_all.items():
        an = simulate_hybrid(tr, NET96).total_time
        for model in ("striped", "adaptive", "xy"):
            ev = PacketSim(tr, NET96, link_model=model).run("static")
            assert ev.total_time >= an * (1 - 1e-9), (wl, model)


def test_event_equals_analytic_when_compute_bound():
    """With compute 10^4x slower, every layer with any work at all is
    compute-bound, and the event-driven total collapses to the analytic
    sum exactly on every link model (no network term can surface)."""
    tr = make_trace("zfnet", AcceleratorConfig(tops_total=144e8))
    an = simulate_hybrid(tr, NET96)
    assert an.total_time == pytest.approx(float(tr.t_compute.sum()))
    for model in ("striped", "adaptive", "xy"):
        ev = PacketSim(tr, NET96, link_model=model).run("static")
        assert ev.total_time == pytest.approx(an.total_time), model
        assert set(ev.bottleneck) == {"compute"}


def test_event_equals_analytic_layerwise_when_non_network_dominates(
        traces_all):
    """Whenever the event engine reports a non-network bottleneck for a
    layer, its layer time equals the analytic one exactly: the floors
    are shared, and the event network terms it beat dominate the
    analytic ones."""
    for wl in ("transformer_cell", "resnet50"):
        tr = traces_all[wl]
        an = simulate_hybrid(tr, NET96)
        for model in ("striped", "xy"):
            ev = PacketSim(tr, NET96, link_model=model).run("static")
            mask = np.array([b in ("compute", "dram", "noc")
                             for b in ev.bottleneck])
            assert mask.any(), (wl, model)
            np.testing.assert_allclose(ev.layer_times[mask],
                                       an.layer_times[mask], rtol=1e-12)


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------

def _grid_best(tr, net=NET96) -> float:
    bw = int(round(net.bandwidth * 8 / 1e9))
    spec = GridSpec(bandwidths_gbps=(bw,), macs=(net.mac,),
                    plans=(net.channels,))
    return float(batched_design_space(tr).evaluate(spec).speedup.max())


def test_adaptive_policy_beats_every_static_grid_point(traces_all):
    """Acceptance: a dynamic policy matches or beats the best static
    (threshold x injection) grid point on every paper workload."""
    for wl, tr in traces_all.items():
        sim = PacketSim(tr, NET96)
        assert sim.speedup("adaptive") >= _grid_best(tr) - 1e-9, wl


def test_greedy_never_slows_down(traces_all):
    """Join-shortest-plane injects only below the wired backlog, so no
    layer can end later than all-wired."""
    for wl, tr in traces_all.items():
        assert PacketSim(tr, NET96).speedup("greedy") >= 1 - 1e-12, wl


def test_oracle_replay_matches_balancer(trace):
    b = balance(trace, NET96)
    ev = PacketSim(trace, NET96).run("oracle")
    assert np.array_equal(ev.injected, b.injected)
    assert ev.total_time == pytest.approx(b.sim.total_time)


def test_online_and_batched_paths_agree(trace):
    """Replaying an online run's injection set through the batched
    per-layer pop reproduces it exactly (busy totals are independent of
    the serving path)."""
    sim = PacketSim(trace, NET96)
    online = sim.run("greedy")
    replay = sim.run(FixedPolicy(online.injected))
    np.testing.assert_allclose(replay.layer_times, online.layer_times,
                               rtol=1e-12)
    np.testing.assert_allclose(replay.cut_busy, online.cut_busy)
    np.testing.assert_allclose(replay.channel_busy, online.channel_busy)


def test_policy_registry_and_sweep(trace):
    assert get_policy("greedy").name == "greedy"
    with pytest.raises(ValueError):
        get_policy("nope")
    ps = policy_sweep(trace, "zfnet")
    assert set(ps.policy_speedups) == {"static", "greedy", "adaptive",
                                       "oracle"}
    assert ps.policy_speedups["adaptive"] >= ps.grid_best_speedup - 1e-9
    name, sp = ps.best_policy()
    assert sp == max(ps.policy_speedups.values())


# ---------------------------------------------------------------------------
# realism knobs: per-packet MACs, per-port DRAM
# ---------------------------------------------------------------------------

def test_event_mac_variants_only_add_time(trace):
    ideal = PacketSim(trace, NET96).run("static")
    total = float(trace.nbytes.sum())
    for proto in ("tdma", "token"):
        net = NetworkConfig(96e9 / 8, mac=MacConfig(proto))
        res = PacketSim(trace, net).run("static")
        assert res.total_time >= ideal.total_time - 1e-15, proto
        assert res.wireless_energy_j >= ideal.wireless_energy_j, proto
        # bytes conserved across planes
        wired = float(trace.nbytes[~res.injected].sum())
        assert wired + res.wireless_bytes == pytest.approx(total)


def test_dram_ports_model_dominates_pooled(trace):
    pooled = PacketSim(trace, NET96).run("static")
    ports = PacketSim(trace, NET96, dram_model="ports").run("static")
    assert ports.total_time >= pooled.total_time - 1e-15
    # every DRAM byte is accounted on some port at the pin rate
    cfg = trace.topo.config
    expect = float(trace.dram_bytes.sum()) / cfg.dram_bw_per_chiplet
    assert float(ports.dram_busy.sum()) == pytest.approx(expect)
