"""Property tests on deeper system invariants (hypothesis where useful):
RoPE norm preservation, segsum correctness, decode ring-buffer
wraparound, topology routing, workload graph consistency, traffic
conservation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: deterministic smoke-subset fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs import ARCHS, reduced
from repro.core.topology import build_topology, nearest_dram
from repro.core.traffic import build_trace
from repro.core.mapper import pipeline_mapping
from repro.core.workloads import WORKLOADS, get_workload
from repro.models import build_model
from repro.models.layers import apply_rope, rope_frequencies
from repro.models.ssm import _segsum


# --------------------------------------------------------------------------
# model-layer properties
# --------------------------------------------------------------------------

@given(st.integers(2, 32), st.sampled_from([32, 64, 128]),
       st.floats(0.25, 1.0))
@settings(max_examples=20, deadline=None)
def test_rope_preserves_norm(seq, dim, frac):
    """Rotations preserve per-head vector norms."""
    x = jax.random.normal(jax.random.PRNGKey(0), (1, seq, 2, dim))
    pos = jnp.arange(seq, dtype=jnp.int32)
    cos, sin = rope_frequencies(dim, frac, 1e4, pos)
    y = apply_rope(x, cos, sin, frac)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-4)


def test_rope_relative_property():
    """<rope(q,m), rope(k,n)> depends only on m-n (the RoPE property)."""
    dim = 64
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, dim))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, dim))

    def score(m, n):
        cm, sm = rope_frequencies(dim, 1.0, 1e4,
                                  jnp.array([m], jnp.int32))
        cn, sn = rope_frequencies(dim, 1.0, 1e4,
                                  jnp.array([n], jnp.int32))
        qm = apply_rope(q, cm, sm)
        kn = apply_rope(k, cn, sn)
        return float(jnp.sum(qm * kn))

    assert score(3, 1) == pytest.approx(score(10, 8), rel=1e-4)
    assert score(5, 5) == pytest.approx(score(0, 0), rel=1e-4)


def test_segsum_matches_naive():
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8))
    out = np.asarray(_segsum(x))
    xn = np.asarray(x)
    for i in range(8):
        for j in range(8):
            if j > i:
                assert out[0, i, j] == -np.inf
            else:
                assert out[0, i, j] == pytest.approx(
                    xn[0, j + 1:i + 1].sum(), abs=1e-5)


def test_decode_ring_buffer_wraparound():
    """SWA decode past the window: ring buffer must keep only the last
    `window` tokens and still match a fresh full forward."""
    import dataclasses
    base = reduced(ARCHS["mixtral-8x22b"])
    cfg = dataclasses.replace(base, sliding_window=8, unit=())
    model = build_model(cfg, impl="naive", remat=False)
    params = model.init(jax.random.PRNGKey(4))
    S = 20   # > 2x window: the ring wraps more than once
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (1, S)), jnp.int32)
    full, _ = model.apply(params, {"tokens": toks})
    cache = model.init_cache(1, S + 1)
    dec = jax.jit(model.decode)
    for t in range(S):
        lg, cache = dec(params, cache, toks[:, t:t + 1], jnp.int32(t))
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full[:, -1]), atol=0.2)


# --------------------------------------------------------------------------
# package-scale sim invariants
# --------------------------------------------------------------------------

def test_topology_routes_are_connected_and_minimal():
    topo = build_topology()
    for a in range(topo.n_nodes):
        for b in range(topo.config.n_chiplets):
            if a == b:
                continue
            route = topo.route(a, b)
            if not route:
                continue
            # connected: each link starts where the previous ended
            for l1, l2 in zip(route, route[1:]):
                assert l1[1] == l2[0]
            # each hop is unit manhattan distance
            for (x1, y1), (x2, y2) in route:
                assert abs(x1 - x2) + abs(y1 - y2) == 1


def test_nearest_dram_is_nearest():
    topo = build_topology()
    n_chip = topo.config.n_chiplets
    for c in range(n_chip):
        best = nearest_dram(topo, c)
        d_best = topo.nop_hops(c, best)
        for d in range(n_chip, topo.n_nodes):
            assert d_best <= topo.nop_hops(c, d)


@pytest.mark.parametrize("wl", ["resnet50", "densenet", "transformer"])
def test_workload_graph_consistency(wl):
    layers = get_workload(wl)
    for i, lyr in enumerate(layers):
        for c in lyr.consumers:
            assert i < c < len(layers), (wl, i, c)
        assert lyr.macs >= 0 and lyr.act_out >= 0


def test_all_workloads_have_positive_work():
    for wl in WORKLOADS:
        layers = get_workload(wl)
        assert sum(lyr.macs for lyr in layers) > 0, wl


@given(st.sampled_from(["resnet50", "googlenet", "zfnet"]))
@settings(max_examples=6, deadline=None)
def test_traffic_bytes_conservation(wl):
    """Every packet's bytes appear exactly once per link it traverses; the
    per-layer link loads equal the scatter of packet bytes."""
    topo = build_topology()
    layers = get_workload(wl)
    tr = build_trace(layers, pipeline_mapping(layers, topo), topo)
    loads = tr.baseline_link_loads()
    assert loads.sum() == pytest.approx(
        float(tr.nbytes[tr.inc_msg].sum()), rel=1e-9)
    assert (loads >= -1e-9).all()


def test_message_volume_matches_packet_volume():
    topo = build_topology()
    layers = get_workload("googlenet")
    tr = build_trace(layers, pipeline_mapping(layers, topo), topo)
    msg_vol = sum(m.nbytes for m in tr.messages)
    assert float(tr.nbytes.sum()) == pytest.approx(msg_vol, rel=1e-9)
