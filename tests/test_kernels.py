"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret
mode on CPU): shapes x dtypes x feature flags."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ssd_ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,T,H,K,D,causal,window,softcap", [
    (1, 128, 128, 4, 2, 64, True, None, None),
    (2, 256, 256, 8, 4, 64, True, None, 50.0),
    (1, 200, 200, 4, 4, 48, True, 128, None),     # unpadded + window
    (1, 128, 384, 4, 2, 64, True, None, None),    # longer KV (decode-ish)
    (1, 128, 128, 4, 1, 64, False, None, None),   # MQA + non-causal
    (1, 130, 130, 2, 2, 32, True, None, None),    # awkward sizes
])
def test_flash_attention_matches_ref(dtype, B, S, T, H, K, D, causal,
                                     window, softcap):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, T, K, D), dtype)
    v = jax.random.normal(ks[2], (B, T, K, D), dtype)
    qp = jnp.arange(T - S, T, dtype=jnp.int32)
    kp = jnp.arange(T, dtype=jnp.int32)
    out = flash_attention(q, k, v, qp, kp, window=window, softcap=softcap,
                          causal=causal)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), qp, kp, scale=D ** -0.5,
                        causal=causal, window=window,
                        softcap=softcap).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,L,H,P,N,chunk", [
    (1, 64, 4, 16, 16, 16),
    (2, 256, 8, 32, 32, 128),
    (1, 100, 4, 16, 32, 32),       # L not a chunk multiple
    (1, 128, 1, 64, 128, 64),      # single head, wide state
])
def test_ssd_matches_sequential_ref(dtype, b, L, H, P, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    x = (jax.random.normal(ks[0], (b, L, H, P)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B = (jax.random.normal(ks[3], (b, L, N)) * 0.5).astype(dtype)
    C = (jax.random.normal(ks[0], (b, L, N)) * 0.5).astype(dtype)
    y, _ = ssd(x, dt, A, B, C, chunk=chunk)
    y_ref, _ = ssd_ref(x.astype(jnp.float32), dt, A,
                       B.astype(jnp.float32), C.astype(jnp.float32))
    tol = 1e-3 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(2, 64, 128), (300, 96), (1, 1, 256),
                                   (257, 384)])
def test_rmsnorm_matches_ref(dtype, shape):
    x = jax.random.normal(jax.random.PRNGKey(2), shape, dtype)
    s = jnp.asarray(np.linspace(0.5, 1.5, shape[-1]), dtype)
    out = rmsnorm(x, s)
    ref = rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=2e-2)


def test_flash_attention_grad_flows():
    """The kernel participates in autodiff (interpret mode lowers to
    differentiable lax ops)."""
    q = jax.random.normal(jax.random.PRNGKey(3), (1, 128, 2, 64))
    kv = jax.random.normal(jax.random.PRNGKey(4), (1, 128, 2, 64))
    pos = jnp.arange(128, dtype=jnp.int32)

    def f(q):
        return flash_attention(q, kv, kv, pos, pos).sum()

    g = jax.grad(f)(q)
    assert bool(jnp.isfinite(g).all()) and float(jnp.abs(g).max()) > 0
