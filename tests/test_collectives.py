"""Collective-traffic plane + LLM workload frontier (+ bugfix sweep).

- a hand-computed golden ring-all-reduce on a 2x2 grid, validated
  message by message and packet by packet against pencil-and-paper
  numbers (chunk sizes, link loads, cut times, eligibility);
- tree all-reduce: the reduce result fan-out is one wireless-eligible
  multicast; the MoE dispatch multicast / combine unicast split;
- the LLM acceptance path: dense + MoE, prefill + decode workloads run
  through `simulate_hybrid`, `policy_sweep` and `sweep_all` unchanged,
  and the striped event engine reproduces the analytic layer times to
  machine precision on the new traces;
- regression tests for the satellite bugfixes: `GraphBuilder.add`
  treating `inputs=[]` as falsy, `pipeline_mapping` idling remainder
  chiplets, `dse.grid_best_speedup` rounding fractional Gb/s, empty
  `summary`/`network_summary`, and the `wireless.eligibility`
  boundary-value semantics (multicast >= vs unicast >).
"""

import warnings

import numpy as np
import pytest

from repro.core import (AcceleratorConfig, CollectiveSpec, NetworkConfig,
                        PacketSim, build_topology, make_trace,
                        simulate_hybrid, simulate_wired, sweep_all)
from repro.core.collectives import lower
from repro.core.dse import (NetworkSweepResult, grid_best_speedup,
                            network_summary, policy_sweep, summary)
from repro.core.mapper import (Mapping, expert_parallel_mapping,
                               pipeline_mapping, tensor_parallel_mapping)
from repro.core.traffic import PACKET_BYTES, TrafficTrace, build_trace
from repro.core.wireless import eligibility
from repro.core.workloads import GraphBuilder, get_workload
from repro.core.workloads_llm import (LLM_WORKLOADS, auto_packet_bytes,
                                      llm_layers, llm_workload)
from repro.configs import ARCHS
from repro.net.batched import GridSpec

NET96 = NetworkConfig(bandwidth=96e9 / 8)

# the four acceptance workloads: dense/MoE x prefill/decode
ACCEPTANCE = ("smollm_360m:prefill", "smollm_360m:decode",
              "mixtral_8x22b:prefill", "mixtral_8x22b:decode")


@pytest.fixture(scope="module")
def llm_traces():
    return {wl: make_trace(wl) for wl in ACCEPTANCE}


# ---------------------------------------------------------------------------
# golden ring all-reduce: 2x2 grid, numbers done by hand
# ---------------------------------------------------------------------------
#
# Snake order on a 2x2 grid is [0, 1, 3, 2] (coords (0,0),(0,1),(1,1),
# (1,0)), so the ring 0->1->3->2->0 is mesh-adjacent on every edge
# (1 hop each).  Ring all-reduce of a 256 KiB tensor over k=4:
# 2(k-1) = 6 rounds, each round every participant unicasts one
# nbytes/k = 64 KiB chunk to its ring successor -> 24 messages of
# exactly one 64 KiB packet, 6 chunks per directed ring link.

RING = (0, 1, 3, 2)
NBYTES = 4 * PACKET_BYTES     # 256 KiB: chunk == one 64 KiB packet


def _one_layer_collective_trace(spec) -> TrafficTrace:
    """A graph with one traffic-free layer carrying only `spec`."""
    from repro.core.workloads import Layer
    topo = build_topology(AcceleratorConfig(grid=(2, 2), n_dram=1))
    layers = [Layer("x", 0.0, 0, 0, 0)]
    mapping = Mapping([(0, 1, 2, 3)], [np.full(4, 0.25)], 4, [spec])
    return build_trace(layers, mapping, topo)


def test_golden_ring_all_reduce_messages():
    msgs = lower(CollectiveSpec("all_reduce", 0, RING, NBYTES))
    assert len(msgs) == 2 * 3 * 4                      # 2(k-1) rounds x k
    assert all(m.kind == "coll" for m in msgs)
    assert all(m.nbytes == NBYTES / 4 for m in msgs)   # 64 KiB chunks
    assert all(len(m.dsts) == 1 for m in msgs)         # ring = unicasts
    # every message goes to the ring successor
    succ = {RING[i]: RING[(i + 1) % 4] for i in range(4)}
    assert all(m.dsts == (succ[m.src],) for m in msgs)
    # total wire volume: 2(k-1)/k x nbytes per participant
    assert sum(m.nbytes for m in msgs) == 6 * NBYTES


def test_golden_ring_all_reduce_packetisation():
    tr = _one_layer_collective_trace(
        CollectiveSpec("all_reduce", 0, RING, NBYTES))
    # 24 chunk messages -> 24 single-packet entries of 64 KiB
    assert len(tr.nbytes) == 24
    np.testing.assert_allclose(tr.nbytes, PACKET_BYTES)
    assert not tr.is_multicast.any()
    assert tr.is_multichip.all()
    np.testing.assert_array_equal(tr.max_hops, 1)      # mesh-adjacent ring
    np.testing.assert_array_equal(tr.dram_node, -1)
    # per-link loads: each of the 4 directed ring links carries 6 chunks
    loads = tr.baseline_link_loads()
    assert loads.shape == (1, 4)
    np.testing.assert_allclose(loads, 6 * PACKET_BYTES)
    # neighbour unicasts are NOT wireless-eligible (strict > for unicasts)
    assert not eligibility(tr, 1).any()
    # wired time: each directed cut of the 2x2 mesh has 2 parallel links
    # and serves one ring link's 6 chunks -> 6 x 64 KiB / (2 x 4 GB/s)
    cfg = tr.topo.config
    expect = 6 * PACKET_BYTES / (2 * cfg.nop_bw_per_side)
    w = simulate_wired(tr)
    assert w.total_time == pytest.approx(expect)
    # nothing eligible -> the hybrid run collapses onto the wired one
    assert simulate_hybrid(tr, NET96).total_time == pytest.approx(expect)


def test_golden_ring_chunks_split_into_multiple_packets():
    tr = _one_layer_collective_trace(
        CollectiveSpec("all_reduce", 0, RING, 4 * NBYTES))
    # 256 KiB chunks -> 4 packets each, 96 packets, volume conserved
    assert len(tr.nbytes) == 96
    np.testing.assert_allclose(tr.nbytes, PACKET_BYTES)
    assert float(tr.nbytes.sum()) == 6 * 4 * NBYTES


def test_golden_tree_all_reduce_fanout_is_wireless_eligible():
    msgs = lower(CollectiveSpec("all_reduce", 0, RING, NBYTES,
                                algorithm="tree"))
    # k-1 up-tree unicasts + 1 root multicast, all full-tensor sized
    ups = [m for m in msgs if len(m.dsts) == 1]
    fan = [m for m in msgs if len(m.dsts) > 1]
    assert len(ups) == 3 and len(fan) == 1
    assert all(m.nbytes == NBYTES for m in msgs)
    assert fan[0].src == RING[0] and fan[0].dsts == (1, 2, 3)
    tr = _one_layer_collective_trace(
        CollectiveSpec("all_reduce", 0, RING, NBYTES, algorithm="tree"))
    # the result fan-out multicast reaches node 2, two hops from the
    # root: eligible at thresholds 1 AND 2 (multicast criterion is >=)
    for thr in (1, 2):
        elig = eligibility(tr, thr)
        assert elig[tr.is_multicast].all(), thr
    # the hybrid plane serves it: wireless bytes appear, time never grows
    h = simulate_hybrid(tr, NetworkConfig(96e9 / 8, injection_prob=1.0))
    assert h.wireless_bytes > 0
    assert h.total_time <= simulate_wired(tr).total_time


def test_moe_dispatch_multicast_and_combine_unicast():
    # dispatch: fanout=2 -> each source multicasts its block once
    disp = lower(CollectiveSpec("all_to_all", 0, RING, NBYTES, fanout=2))
    assert len(disp) == 4
    assert all(len(m.dsts) == 2 and m.nbytes == NBYTES for m in disp)
    # combine: distinct shards -> k(k-1) unicasts of nbytes/k
    comb = lower(CollectiveSpec("all_to_all", 0, RING, NBYTES))
    assert len(comb) == 12
    assert all(len(m.dsts) == 1 and m.nbytes == NBYTES / 4 for m in comb)


def test_collective_spec_validation():
    with pytest.raises(ValueError):
        CollectiveSpec("all_mangle", 0, RING, 1.0)
    with pytest.raises(ValueError):
        CollectiveSpec("all_reduce", 0, (0, 0, 1), 1.0)
    # algorithm typos must not silently lower as ring
    with pytest.raises(ValueError):
        CollectiveSpec("all_reduce", 0, RING, 1.0, algorithm="Tree")
    with pytest.raises(ValueError):
        CollectiveSpec("all_reduce", 0, RING, 1.0, algorithm="bcast")
    with pytest.raises(ValueError):
        CollectiveSpec("all_to_all", 0, RING, 1.0, algorithm="tree")
    with pytest.raises(ValueError):
        CollectiveSpec("broadcast", 0, RING, 1.0, root=7)
    assert lower(CollectiveSpec("broadcast", 0, (3,), 1.0)) == []


# ---------------------------------------------------------------------------
# LLM workload frontier acceptance
# ---------------------------------------------------------------------------

def test_llm_registry_covers_dense_and_moe_phases():
    assert set(ACCEPTANCE) <= set(LLM_WORKLOADS)
    with pytest.raises(KeyError):
        get_workload("mixtral_8x22b:train")
    with pytest.raises(KeyError):
        llm_workload("resnet50")


def test_llm_graphs_are_consistent_and_hinted():
    for wl in ACCEPTANCE:
        layers = llm_workload(wl)
        for i, lyr in enumerate(layers):
            for c in lyr.consumers:
                assert i < c < len(layers), (wl, i, c)
        assert sum(lyr.macs for lyr in layers) > 0
        assert any(lyr.collective == "all_reduce" for lyr in layers), wl
    moe = llm_workload("mixtral_8x22b:prefill")
    assert any(lyr.collective == "moe" for lyr in moe)
    cfg = ARCHS["mixtral-8x22b"]
    hinted = [lyr for lyr in moe if lyr.collective == "moe"]
    assert all(lyr.n_experts == cfg.n_experts
               and lyr.experts_per_token == cfg.experts_per_token
               for lyr in hinted)


def test_llm_workloads_flow_through_simulate_hybrid(llm_traces):
    for wl, tr in llm_traces.items():
        w, h = simulate_wired(tr), simulate_hybrid(tr, NET96)
        assert w.total_time > 0 and h.total_time > 0
        assert h.total_time <= w.total_time * (1 + 1e-9), wl


def test_llm_prefill_is_collective_heavy_decode_is_not(llm_traces):
    def coll_share(tr):
        tot = sum(m.nbytes for m in tr.messages)
        return sum(m.nbytes for m in tr.messages if m.kind == "coll") / tot
    assert coll_share(llm_traces["smollm_360m:prefill"]) > 0.3
    assert coll_share(llm_traces["smollm_360m:decode"]) < 0.1
    # and the hybrid plane pays off exactly where collectives dominate
    def sp(tr):
        return simulate_wired(tr).total_time / \
            simulate_hybrid(tr, NET96).total_time
    assert sp(llm_traces["smollm_360m:prefill"]) > 1.2
    assert sp(llm_traces["smollm_360m:decode"]) < 1.2


def test_llm_workloads_flow_through_sweep_all(llm_traces):
    results = sweep_all(llm_traces)
    assert len(results) == 2 * len(llm_traces)       # 64 and 96 Gb/s
    for r in results:
        assert r.best_speedup >= 1.0, r.workload
    s = summary(results)
    assert s[96][0] >= s[64][0] - 1e-9               # more bw never hurts


def test_llm_workloads_flow_through_policy_sweep(llm_traces):
    for wl, tr in llm_traces.items():
        ps = policy_sweep(tr, wl)
        assert set(ps.policy_speedups) == {"static", "greedy", "adaptive",
                                           "oracle"}
        # the PR-2 policy invariants hold on the collective traces
        assert ps.policy_speedups["greedy"] >= 1 - 1e-12, wl
        assert ps.policy_speedups["adaptive"] >= ps.grid_best_speedup - 1e-9, wl


def test_llm_striped_event_parity_is_machine_precision(llm_traces):
    for wl, tr in llm_traces.items():
        sim = PacketSim(tr, NET96)
        ev, an = sim.run("static"), simulate_hybrid(tr, NET96)
        np.testing.assert_allclose(ev.layer_times, an.layer_times,
                                   rtol=1e-12, err_msg=wl)
        evw, anw = sim.run_wired(), simulate_wired(tr)
        np.testing.assert_allclose(evw.layer_times, anw.layer_times,
                                   rtol=1e-12, err_msg=wl)


def test_llm_auto_packet_bytes_keeps_traces_tractable(llm_traces):
    for wl, tr in llm_traces.items():
        assert len(tr.nbytes) < 60_000, wl
    # granularity never drops below the 64 KiB NoP packet
    assert auto_packet_bytes(llm_workload("smollm_360m:decode")) \
        >= PACKET_BYTES


def test_llm_mapping_variants_and_family_defaults():
    layers = llm_layers(ARCHS["smollm-360m"], "prefill", units=1)
    topo = build_topology()
    tree = tensor_parallel_mapping(layers, topo)
    ring = tensor_parallel_mapping(layers, topo, algorithm="ring")
    assert all(s.algorithm == "tree" for s in tree.collectives)
    assert all(s.algorithm == "ring" for s in ring.collectives)
    # dense graphs have no moe layers -> expert-parallel refuses
    with pytest.raises(ValueError):
        expert_parallel_mapping(layers, topo)
    # MoE default mapping emits the dispatch/combine all-to-all pair
    moe_tr = make_trace("mixtral_8x22b:decode")
    assert any(m.kind == "coll" and len(m.dsts) > 1 for m in moe_tr.messages)
    with pytest.raises(ValueError):
        make_trace("smollm_360m:prefill", mapping="hexagonal")


def test_unhinted_graph_gets_per_layer_all_reduce():
    """CNN graphs (no hints) fall back to all-reducing every MAC layer."""
    layers = get_workload("zfnet")
    topo = build_topology()
    m = tensor_parallel_mapping(layers, topo)
    macs = sum(1 for lyr in layers if lyr.macs > 0 and lyr.act_out > 0)
    assert len(m.collectives) == macs
    tr = build_trace(layers, m, topo)
    assert simulate_wired(tr).total_time > 0


# ---------------------------------------------------------------------------
# bugfix sweep regressions
# ---------------------------------------------------------------------------

def test_graphbuilder_explicit_empty_inputs_is_source_node():
    g = GraphBuilder()
    a = g.add("a", 1.0, 0, 0, 16)
    b = g.add("b", 1.0, 0, 0, 16, inputs=[])   # a true source, mid-graph
    c = g.add("c", 1.0, 16, 0, 16)             # implicit chain to b
    assert g.layers[a].consumers == []          # [] must NOT chain to a
    assert g.layers[b].consumers == [c]
    d = g.add("d", 1.0, 32, 0, 16, inputs=[a, b])
    assert g.layers[a].consumers == [d]


def test_pipeline_mapping_uses_all_chiplets_on_non_divisible_grid():
    """8 layers on 3x3 -> 2 stages; 9 % 2 == 1 chiplet used to sit idle."""
    layers = get_workload("zfnet")
    topo = build_topology()
    m = pipeline_mapping(layers, topo)
    used = set()
    for group in m.chiplets:
        used.update(group)
    assert used == set(range(topo.config.n_chiplets))
    # base stage groups differ by at most one chiplet (weight-heavy
    # layers legitimately widen beyond their stage group)
    from repro.core.traffic import WEIGHT_SRAM_BYTES
    sizes = {len(set(g)) for g, lyr in zip(m.chiplets, layers)
             if lyr.weights <= WEIGHT_SRAM_BYTES}
    assert max(sizes) - min(sizes) <= 1


def test_grid_best_speedup_honours_fractional_bandwidth():
    tr = make_trace("zfnet")
    net = NetworkConfig(bandwidth=65.5e9 / 8)
    got = grid_best_speedup(tr, net)
    from repro.core.dse import batched_design_space
    ds = batched_design_space(tr)
    exact = ds.evaluate(GridSpec(bandwidths_gbps=(65.5,)))
    rounded = ds.evaluate(GridSpec(bandwidths_gbps=(66,)))
    assert got == float(exact.speedup.max())
    # the 66 Gb/s grid the old rounding anchored against is a different
    # surface — the exact grid must not silently collapse onto it
    assert not np.allclose(exact.speedup, rounded.speedup)


def test_summary_guards_against_empty_results():
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # NaN mean used to warn
        assert summary([]) == {}
        assert network_summary([]) == {}
    assert isinstance(network_summary([]), dict)
    assert NetworkSweepResult is not None


def test_eligibility_boundary_semantics():
    """Multicast qualifies AT the threshold (>=), unicast only beyond
    it (>) — the Fig. 4 calibration's asymmetric boundary."""
    topo = build_topology(AcceleratorConfig(grid=(1, 2), n_dram=1))
    n = 4
    tr = TrafficTrace(
        topo=topo, n_layers=1, link_index={((0, 0), (0, 1)): 0},
        layer=np.zeros(n, np.int32),
        nbytes=np.full(n, 1e6),
        src=np.zeros(n, np.int32),
        #             mc@thr  uni@thr  uni@thr+1  mc-below-thr
        is_multicast=np.array([True, False, False, True]),
        is_multichip=np.ones(n, bool),
        max_hops=np.array([2, 2, 3, 1], np.int32),
        dram_node=np.full(n, -1, np.int32),
        inc_msg=np.arange(n, dtype=np.int32),
        inc_link=np.zeros(n, np.int32),
        t_compute=np.zeros(1), t_dram=np.zeros(1), t_noc=np.zeros(1),
        dram_bytes=np.zeros(1), messages=[])
    np.testing.assert_array_equal(
        eligibility(tr, 2), [True, False, True, False])
    # at threshold 1 everything multichip qualifies except 1-hop unicasts
    np.testing.assert_array_equal(
        eligibility(tr, 1), [True, True, True, True])
