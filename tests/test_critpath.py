"""Critical-path extraction and trace-driven what-if projection.

Pins the PR's acceptance properties:
- a hand-built golden dependency DAG on the 2-chiplet/3-packet trace
  from tests/test_sim.py yields the expected blocking chain, with the
  FIFO edge recorded and the incremental charges done by hand;
- the critical-path charges sum to the makespan at rtol=1e-12 on all
  three link models, with and without channel reuse, on the batched
  planned path AND the per-packet online path;
- the what-if projection replayed from the trace is within 10% of an
  actual re-simulation for +-25% wireless bandwidth on EVERY paper
  workload (it is exact for ideal-MAC static runs), including channel
  / reuse-zone re-bucketing in both directions;
- `whatif_guided` finds the same best design point as the exhaustive
  `sweep_all` on pinned golden workloads with strictly fewer grid
  evaluations;
- degenerate traces follow the repo-wide empty-structure convention,
  the unsupported striped->xy re-projection raises, and
  `mark_critical` surfaces the chain as a distinct Perfetto process.
"""

import pytest
from test_sim import NET96, _golden_trace

from repro.core import ChannelPlan, NetworkConfig, make_trace, sweep_all
from repro.core.dse import whatif_guided
from repro.core.workloads import WORKLOADS
from repro.obs import (SimTrace, WhatIf, busy_shares, chrome_trace_events,
                       critical_path, critical_vs_busy, mark_critical,
                       project, validate)
from repro.sim import FixedPolicy, PacketSim

REUSE_NET = NetworkConfig(bandwidth=96e9 / 8,
                          channels=ChannelPlan(n_channels=2, reuse_zones=4))


@pytest.fixture(scope="module")
def traces_all():
    return {wl: make_trace(wl) for wl in WORKLOADS}


# ---------------------------------------------------------------------------
# golden DAG: the 2-chiplet/3-packet trace, chain built by hand
# ---------------------------------------------------------------------------

def test_golden_wired_fifo_chain():
    """Wired baseline: cut 0 serves p0 then p1 FIFO (1 ms each), which
    is the 2 ms NoP bottleneck — so the critical path is exactly the
    two-event FIFO chain, each charged its full 1 ms."""
    sim = PacketSim(_golden_trace(), NET96, record=True)
    res = sim.run_wired()
    cp = critical_path(res.trace)

    assert cp.makespan == pytest.approx(2e-3)
    assert [(s.track, s.name) for s in cp.segments] == [("cut0", "p0"),
                                                        ("cut0", "p1")]
    assert [s.crit_dur for s in cp.segments] == [pytest.approx(1e-3)] * 2
    # the FIFO edge itself is recorded: p1 depends on p0, p0 on nothing
    p0, p1 = cp.segments
    by_eid = {ev.eid: ev for ev in res.trace.events}
    assert by_eid[p1.eid].deps == [p0.eid]
    assert by_eid[p0.eid].deps == []
    assert cp.by_resource() == {"cut0": pytest.approx(2e-3)}
    assert cp.critical_shares() == {"wired": pytest.approx(1.0)}


def test_golden_fixed_injection_single_segment():
    """Offloading p1 leaves cut 0 with one 1 ms packet, tying the 1 ms
    compute floor: the chain collapses to a single full-span segment."""
    sim = PacketSim(_golden_trace(), NET96, record=True)
    res = sim.run(FixedPolicy([False, True, False]))
    cp = critical_path(res.trace)
    assert cp.makespan == pytest.approx(1e-3)
    assert len(cp.segments) == 1
    assert cp.segments[0].crit_dur == pytest.approx(1e-3)


def test_golden_online_greedy_compute_floor():
    """Greedy offloads both multicasts; the 1 ms compute floor binds
    and the path is the single coarse compute span."""
    sim = PacketSim(_golden_trace(), NET96, record=True)
    res = sim.run("greedy")
    cp = critical_path(res.trace)
    assert cp.makespan == pytest.approx(1e-3)
    assert [(s.track, s.plane) for s in cp.segments] == [("compute",
                                                          "compute")]


# ---------------------------------------------------------------------------
# invariant: charges telescope to the makespan, rtol 1e-12
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("link_model", ["striped", "adaptive", "xy"])
@pytest.mark.parametrize("net", [NET96, REUSE_NET],
                         ids=["1ch", "2ch-reuse"])
def test_critpath_sum_equals_makespan(traces_all, link_model, net):
    for wl in ("zfnet", "transformer"):
        sim = PacketSim(traces_all[wl], net, record=True,
                        link_model=link_model)
        res = sim.run("static")
        cp = critical_path(res.trace)
        assert cp.makespan == pytest.approx(res.total_time, rel=1e-12)
        assert cp.total == pytest.approx(cp.makespan, rel=1e-12), \
            (wl, link_model)


def test_critpath_sum_online_path(traces_all):
    """The per-packet online recorder threads the same dep structure."""
    for policy in ("greedy", "adaptive"):
        sim = PacketSim(traces_all["zfnet"], REUSE_NET, record=True)
        res = sim.run(policy)
        cp = critical_path(res.trace)
        assert cp.total == pytest.approx(res.total_time, rel=1e-12)


def test_critical_vs_busy_is_a_distribution(traces_all):
    sim = PacketSim(traces_all["resnet50"], NET96, record=True)
    cvb = critical_vs_busy(sim.run("static").trace)
    for key in ("critical", "busy"):
        assert sum(cvb[key].values()) == pytest.approx(1.0)
    assert 0.0 <= cvb["divergence"] <= 1.0


# ---------------------------------------------------------------------------
# what-if projection vs actual re-simulation
# ---------------------------------------------------------------------------

def test_projection_within_10pct_on_every_workload(traces_all):
    """+-25% wireless bandwidth, projected from ONE recorded run,
    matches a from-scratch re-simulation on all paper workloads."""
    for wl, tr in traces_all.items():
        for scale in (0.75, 1.25):
            v = validate(tr, NET96, WhatIf(wireless_scale=scale))
            assert v["error"] <= 0.10, (wl, scale, v)


def test_projection_rebuckets_channels_and_zones():
    tr = make_trace("resnet50")
    # single channel -> 2ch x 4 reuse zones, and the reverse direction
    v_up = validate(tr, NET96, WhatIf(n_channels=2, reuse_zones=4))
    assert v_up["error"] <= 0.10
    v_dn = validate(tr, REUSE_NET, WhatIf(n_channels=1, reuse_zones=1))
    assert v_dn["error"] <= 0.10


def test_projection_speedup_sign():
    """Doubling wireless bandwidth never slows a run; halving never
    speeds one up (the wireless term is monotone in bandwidth)."""
    sim = PacketSim(make_trace("gnmt"), NET96, record=True)
    st = sim.run("static").trace
    assert project(st, WhatIf(wireless_scale=2.0)).speedup >= 1 - 1e-12
    assert project(st, WhatIf(wireless_scale=0.5)).speedup <= 1 + 1e-12


def test_striped_to_xy_projection_raises():
    sim = PacketSim(make_trace("zfnet"), NET96, record=True)
    st = sim.run("static").trace
    with pytest.raises(ValueError, match="striping"):
        project(st, WhatIf(link_model="xy"))


# ---------------------------------------------------------------------------
# whatif-guided DSE pruning
# ---------------------------------------------------------------------------

def test_whatif_guided_matches_exhaustive(traces_all):
    golden = {wl: traces_all[wl] for wl in ("zfnet", "resnet50", "gnmt")}
    guided = whatif_guided(golden)
    exhaustive = sweep_all(golden)
    assert guided.points_evaluated < guided.points_exhaustive
    best = {(r.workload, r.bandwidth_gbps):
            (r.best_threshold, r.best_injection, r.best_speedup)
            for r in exhaustive}
    for r in guided.results:
        bt, bi, bs = best[(r.workload, r.bandwidth_gbps)]
        assert (r.best_threshold, r.best_injection) == (bt, bi), \
            (r.workload, r.bandwidth_gbps)
        assert r.best_speedup == pytest.approx(bs, rel=1e-12)
    # the projected incumbents exist for every pruned band
    assert guided.projected_best
    assert guided.provenance is not None


# ---------------------------------------------------------------------------
# degenerate traces, marking, export
# ---------------------------------------------------------------------------

def test_empty_trace_conventions():
    st = SimTrace(label="empty")
    cp = critical_path(st)
    assert cp.segments == [] and cp.makespan == 0.0
    assert cp.critical_shares() == {}
    assert busy_shares(st) == {}
    cvb = critical_vs_busy(st)
    assert cvb["divergence"] == 0.0
    proj = project(st, WhatIf(wireless_scale=2.0))
    assert proj.total_time == 0.0 and proj.speedup == 1.0


def test_mark_critical_exports_distinct_track():
    sim = PacketSim(make_trace("zfnet"), REUSE_NET, record=True)
    st = sim.run("static").trace
    cp = mark_critical(st)
    events = chrome_trace_events(st)["traceEvents"]
    mirrors = [e for e in events if e.get("cat") == "critpath"]
    # every per-packet critical segment is mirrored onto the lane
    assert len(mirrors) == sum(1 for ev in st.events
                               if ev.args.get("critical"))
    assert len(mirrors) >= len([s for s in cp.segments if s.eid >= 0]) > 0
    crit_pids = {e["pid"] for e in mirrors}
    other_pids = {e["pid"] for e in events
                  if e.get("ph") == "X" and e.get("cat") != "critpath"}
    assert len(crit_pids) == 1 and not (crit_pids & other_pids)
