"""Validation of the paper reproduction against the paper's own claims.

Targets (paper SIV-B, Figs. 2/4/5):
- mean best speedup ~7.5% @64 Gb/s and ~10% @96 Gb/s (we allow a band);
- 96 Gb/s >= 64 Gb/s on average;
- max speedup ~20% (band: >=15%);
- resnet152 gains ~0 (compute/NoC bound per Fig. 2);
- Fig. 5 shape: at threshold 1, speedup rises with injection probability
  then turns NEGATIVE past saturation; raising the threshold recovers a
  positive speedup at high injection.
"""

import numpy as np
import pytest

from repro.core import (WirelessConfig, balance, make_trace, simulate_hybrid,
                        simulate_wired, sweep, sweep_all, summary)
from repro.core.dse import BANDWIDTHS_GBPS
from repro.core.workloads import WORKLOADS

ALL = list(WORKLOADS)


@pytest.fixture(scope="module")
def traces():
    return {wl: make_trace(wl) for wl in ALL}


@pytest.fixture(scope="module")
def results(traces):
    return sweep_all(traces)


def test_all_fifteen_workloads_present():
    assert len(ALL) == 15


def test_mean_speedups_in_paper_band(results):
    s = summary(results)
    mean64, max64 = s[64]
    mean96, max96 = s[96]
    # paper: ~7.5% (64 Gb/s) and ~10% (96 Gb/s) mean, ~20% max
    assert 1.04 <= mean64 <= 1.12, mean64
    assert 1.055 <= mean96 <= 1.145, mean96
    assert max96 >= 1.15
    assert mean96 >= mean64  # more wireless bandwidth never hurts on average


def test_resnet152_gains_nothing(results):
    for r in results:
        if r.workload == "resnet152":
            assert r.best_speedup < 1.02  # paper: ~0 speedup


def test_resnet152_is_compute_noc_bound(traces):
    shares = simulate_wired(traces["resnet152"]).bottleneck_share()
    assert shares["compute"] + shares["noc"] > 0.8
    assert shares["nop"] < 0.1


def test_nop_is_a_major_bottleneck_overall(traces):
    """Fig. 2: the NoP is a significant limiting factor across workloads."""
    shares = [simulate_wired(t).bottleneck_share()["nop"]
              for t in traces.values()]
    assert np.mean(shares) > 0.15
    assert max(shares) > 0.5


def test_fig5_saturation_shape(traces):
    """zfnet: gain rises with injection, collapses past saturation, and a
    larger distance threshold recovers it (paper Fig. 5)."""
    tr = traces["zfnet"]
    base = simulate_wired(tr).total_time

    def sp(thr, p):
        return base / simulate_hybrid(
            tr, WirelessConfig(96e9 / 8, thr, p)).total_time

    low = sp(1, 0.10)
    mid = sp(1, 0.50)
    high = sp(1, 0.80)
    assert mid > low            # more injection helps at first
    assert high < 1.0           # ...then saturates into a slowdown
    assert sp(2, 0.80) > high   # larger threshold relieves the pressure
    assert sp(2, 0.80) > 1.0


def test_speedup_never_below_best_of_p01(results):
    """The swept optimum is at least as good as the most conservative
    configuration; the DSE never returns a degraded 'best'."""
    for r in results:
        assert r.best_speedup >= 1.0


def test_balancer_dominates_sweep(traces, results):
    """Beyond-paper: the analytic balancer matches or beats the paper's
    (threshold x injection) sweep on every workload at 96 Gb/s."""
    for wl, tr in traces.items():
        swept = [r.best_speedup for r in results
                 if r.workload == wl and r.bandwidth_gbps == 96][0]
        b = balance(tr, WirelessConfig(96e9 / 8))
        assert b.speedup_vs_wired >= swept - 1e-9, wl


def test_wireless_energy_accounting(traces):
    tr = traces["googlenet"]
    res = simulate_hybrid(tr, WirelessConfig(96e9 / 8, 1, 0.5))
    assert res.wireless_bytes > 0
    # ~1 pJ/bit
    assert res.wireless_energy_j == pytest.approx(
        res.wireless_bytes * 8 * 1e-12, rel=1e-6)


def test_bandwidths_match_table1():
    assert BANDWIDTHS_GBPS == (64, 96)


def test_energy_and_edp(traces):
    """Energy accounting: hybrid must not cost more energy than wired
    (wireless ~1 pJ/bit vs multi-hop wired ~1.5 pJ/bit/hop), and the EDP
    (GEMINI's objective) improves wherever latency does."""
    tr = traces["googlenet"]
    w = simulate_wired(tr)
    h = simulate_hybrid(tr, WirelessConfig(96e9 / 8, 1, 0.5))
    assert w.energy_j > 0 and h.energy_j > 0
    assert h.energy_j <= w.energy_j * 1.01
    assert h.edp < w.edp
