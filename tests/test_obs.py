"""Observability plane: recorder, exports, metrics, attribution, gate.

- golden hand-computed event timestamps on the 2-chiplet/3-packet trace
  from tests/test_sim.py,
- the busy-time invariant: per-resource trace durations == the engine's
  own busy aggregates to 1e-12 for every link model,
- Chrome Trace Event JSON schema validity + lossless .npz round trip,
- record=False is structurally zero-cost (no SimTrace is ever built),
- the shared degenerate convention (`bottleneck_share` -> {},
  attribution -> []),
- metrics registry / logger / provenance stamps,
- the benchmarks/run.py --check regression gate.
"""

import json

import numpy as np
import pytest
from test_sim import NET96, _golden_trace

from repro.core import ChannelPlan, NetworkConfig, balance, make_trace
from repro.core.dse import policy_sweep_all
from repro.core.simulator import SimResult, simulate_wired
from repro.obs import (SimTrace, attribution_report, attribution_summary,
                       chrome_trace_events, config_hash, export_npz,
                       format_attribution, load_npz, make_provenance,
                       recording, utilization_timeline)
from repro.obs.metrics import MetricsRegistry
from repro.sim import EventResult, PacketSim
from repro.sim.policies import FixedPolicy

REUSE_NET = NetworkConfig(bandwidth=96e9 / 8,
                          channels=ChannelPlan(n_channels=2, reuse_zones=4))


# ---------------------------------------------------------------------------
# golden hand-trace: exact event timestamps
# ---------------------------------------------------------------------------

def test_golden_wired_event_timestamps():
    """Wired baseline: p0 then p1 FIFO on cut 0, p2 alone on cut 1.

    4 MB at 4 GB/s = 1 ms per eligible packet, 2 MB = 0.5 ms."""
    sim = PacketSim(_golden_trace(), NET96, record=True)
    res = sim.run_wired()
    st = res.trace
    assert st is not None and st.label == "event:wired:striped"

    by_track = {}
    for ev in st.events:
        if ev.cat == "wired":
            by_track.setdefault(ev.track, []).append(ev)
    c0 = sorted(by_track["cut0"], key=lambda e: e.ts)
    assert [(e.name, e.ts, e.dur) for e in c0] == [
        ("p0", 0.0, pytest.approx(1e-3)),
        ("p1", pytest.approx(1e-3), pytest.approx(1e-3)),
    ]
    (c1,) = by_track["cut1"]
    assert (c1.name, c1.ts, c1.dur) == ("p2", 0.0, pytest.approx(0.5e-3))
    # the layer span covers the 2 ms NoP bottleneck
    assert st.layer_windows() == {0: (0.0, pytest.approx(2e-3))}
    assert st.meta["policy"] == "wired"


def test_golden_fixed_wireless_event():
    """[False, True, False]: p1 rides channel 0 for 4 MB / 12 GB/s."""
    sim = PacketSim(_golden_trace(), NET96, record=True)
    res = sim.run(FixedPolicy([False, True, False]))
    st = res.trace
    wl = [ev for ev in st.events if ev.cat == "wireless"]
    assert [(e.track, e.name, e.ts) for e in wl] == [("ch0", "p1", 0.0)]
    assert wl[0].dur == pytest.approx(4e6 / (96e9 / 8))
    # p0 now has cut 0 to itself; compute floor (1 ms) wins the layer
    c0 = [ev for ev in st.events if ev.track == "cut0"]
    assert [(e.name, e.ts, e.dur) for e in c0] == [
        ("p0", 0.0, pytest.approx(1e-3))]
    assert st.layer_windows()[0][1] == pytest.approx(1e-3)


# ---------------------------------------------------------------------------
# busy-time invariant: trace == engine aggregates, to 1e-12
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("workload", ["zfnet", "smollm_360m:prefill"])
@pytest.mark.parametrize("link_model", ["striped", "adaptive", "xy"])
def test_busy_invariant(workload, link_model):
    tr = make_trace(workload)
    for net in (NET96, REUSE_NET):
        sim = PacketSim(tr, net, link_model=link_model, record=True)
        for run in (sim.run_wired(), sim.run("greedy")):
            st = run.trace
            if link_model == "xy":
                link = st.busy_by_resource("wired", len(run.link_busy),
                                           "link")
                np.testing.assert_allclose(link, run.link_busy,
                                           rtol=1e-12, atol=0.0)
                wired = np.bincount(sim.cut_of_link, weights=link,
                                    minlength=sim.n_cuts)
            else:
                wired = st.busy_by_resource("wired", sim.n_cuts, "cut")
            np.testing.assert_allclose(wired, run.cut_busy,
                                       rtol=1e-12, atol=0.0)
            ch = st.busy_by_resource("wireless",
                                     net.channels.n_channels, "ch")
            np.testing.assert_allclose(ch, run.channel_busy,
                                       rtol=1e-12, atol=0.0)
            dram = st.busy_by_resource("dram", len(run.dram_busy), "dram")
            np.testing.assert_allclose(dram, run.dram_busy,
                                       rtol=1e-12, atol=0.0)


def test_recording_does_not_change_results():
    tr = make_trace("zfnet")
    for policy in ("static", "greedy"):
        off = PacketSim(tr, REUSE_NET).run(policy)
        on = PacketSim(tr, REUSE_NET, record=True).run(policy)
        assert off.trace is None and on.trace is not None
        assert off.total_time == on.total_time
        np.testing.assert_array_equal(off.layer_times, on.layer_times)
        np.testing.assert_array_equal(off.injected, on.injected)


def test_disabled_mode_is_structurally_zero_cost(monkeypatch):
    """record=False must never even construct a SimTrace."""
    from repro.sim import engine

    def boom(*a, **k):
        raise AssertionError("SimTrace built with record=False")

    monkeypatch.setattr(engine.obs_trace, "SimTrace", boom)
    sim = PacketSim(_golden_trace(), NET96)
    assert sim.run("greedy").trace is None
    assert sim.run_wired().trace is None
    with pytest.raises(AssertionError):
        PacketSim(_golden_trace(), NET96, record=True).run("greedy")


# ---------------------------------------------------------------------------
# exports
# ---------------------------------------------------------------------------

def _recorded_run():
    sim = PacketSim(make_trace("zfnet"), REUSE_NET, record=True)
    return sim.run("static")


def test_chrome_trace_schema():
    res = _recorded_run()
    st_an = SimTrace(label="analytic")
    with recording(st_an):
        simulate_wired(make_trace("zfnet"))
    obj = chrome_trace_events({"event": res.trace, "analytic": st_an})
    assert obj["displayTimeUnit"] == "ms"
    assert json.loads(json.dumps(obj)) is not None   # serialisable
    phases = {"M": 0, "X": 0, "C": 0}
    for ev in obj["traceEvents"]:
        assert ev["ph"] in phases
        phases[ev["ph"]] += 1
        assert isinstance(ev["pid"], int)
        if ev["ph"] == "X":
            assert {"name", "ts", "dur", "tid", "cat", "args"} <= set(ev)
            assert ev["ts"] >= 0.0 and ev["dur"] >= 0.0
        elif ev["ph"] == "M":
            assert ev["name"] in ("process_name", "thread_name",
                                  "process_sort_index")
        else:
            assert "value" in ev["args"]
    assert phases["X"] > 0 and phases["M"] > 0 and phases["C"] > 0
    # merged traces land in distinct process-id blocks
    an_pids = {ev["pid"] for ev in obj["traceEvents"]
               if ev.get("cat", "").startswith("an:")}
    ev_pids = {ev["pid"] for ev in obj["traceEvents"]
               if ev.get("cat", "") in ("wired", "wireless", "dram")}
    assert not (an_pids & ev_pids)


def test_npz_round_trip_is_lossless(tmp_path):
    st = _recorded_run().trace
    path = tmp_path / "trace.npz"
    export_npz(st, str(path))
    back = load_npz(str(path))
    assert back.label == st.label
    assert back.meta == st.meta
    assert len(back.events) == len(st.events)
    for a, b in zip(st.events, back.events):
        assert a.__dict__ == b.__dict__
    assert back.counters == st.counters


# ---------------------------------------------------------------------------
# degenerate convention: {} / []
# ---------------------------------------------------------------------------

def test_zero_time_bottleneck_share_is_empty():
    ev = EventResult(
        total_time=0.0, layer_times=np.zeros(0), layer_finish=np.zeros(0),
        bottleneck=[], injected=np.zeros(0, bool), wireless_bytes=0.0,
        wireless_energy_j=0.0, energy_j=0.0, cut_busy=np.zeros(2),
        channel_busy=np.zeros(1), dram_busy=np.zeros(1), link_busy=None,
        policy="static", link_model="striped", dram_model="pooled")
    assert ev.bottleneck_share() == {}
    assert SimResult(0.0, np.zeros(0), []).bottleneck_share() == {}
    assert attribution_report(SimTrace()) == []
    assert format_attribution([]) == "(empty trace)"


# ---------------------------------------------------------------------------
# attribution + timelines
# ---------------------------------------------------------------------------

def test_attribution_golden_wired():
    res = PacketSim(_golden_trace(), NET96, record=True).run_wired()
    rows = {r["track"]: r for r in attribution_report(res)}
    c0 = rows["cut0"]
    # p0 waits 0, p1 waits 1 ms; both serve 1 ms each
    assert c0["n_events"] == 2
    assert c0["service_s"] == pytest.approx(2e-3)
    assert c0["queue_s"] == pytest.approx(1e-3)
    assert c0["finish_s"] == pytest.approx(2e-3)
    assert c0["why"] == "service"
    assert rows["cut1"]["idle_s"] == pytest.approx(1.5e-3)
    summary = attribution_summary(res)
    assert summary["nop"]["share"] == pytest.approx(1.0)
    assert summary["nop"]["track"] == "cut0"
    assert "cut0" in format_attribution(attribution_report(res))


def test_attribution_reuse_quiesce_column():
    """Reuse-zone runs expose the global-phase quiesce decomposition."""
    res = PacketSim(make_trace("smollm_360m:prefill"), REUSE_NET,
                    record=True).run("greedy")
    zone_rows = [r for r in attribution_report(res)
                 if "/z" in r["track"]]
    assert zone_rows, "reuse run should produce zone-server rows"
    assert any(r["quiesce_s"] > 0.0 for r in zone_rows)
    for r in zone_rows:
        assert 0.0 <= r["quiesce_s"] <= r["queue_s"] + 1e-15


def test_utilization_timeline_golden():
    res = PacketSim(_golden_trace(), NET96, record=True).run_wired()
    edges, util = utilization_timeline(res.trace, "wired", n_bins=4)
    assert edges[-1] == pytest.approx(2e-3)
    np.testing.assert_allclose(util["cut0"], [1, 1, 1, 1])
    np.testing.assert_allclose(util["cut1"], [1, 0, 0, 0])


def test_queue_and_utilization_counters():
    st = PacketSim(_golden_trace(), NET96, record=True).run_wired().trace
    # wired queue: both cut queues drain 0-deep by the layer end
    q = dict(st.counters)["q:wired"]
    assert q[0] == (0.0, 3.0) and q[-1][1] == 0.0
    assert any(t.startswith("util:cut") for t in st.counters)


# ---------------------------------------------------------------------------
# analytic plane recording
# ---------------------------------------------------------------------------

def test_analytic_recorder_layer_windows():
    tr = make_trace("zfnet")
    st = SimTrace(label="analytic")
    with recording(st):
        res = simulate_wired(tr)
    windows = st.layer_windows()
    assert len(windows) == tr.n_layers
    assert sum(w[1] for w in windows.values()) == pytest.approx(
        res.total_time)
    assert st.tracks("an:compute") == ["compute"]


def test_balancer_emits_one_timeline():
    """Trial evaluations are masked: exactly one span per layer."""
    tr = _golden_trace()
    st = SimTrace(label="balancer")
    with recording(st):
        bal = balance(tr, NET96)
    layer_spans = [ev for ev in st.events if ev.cat == "layer"]
    decisions = [ev for ev in st.events if ev.track == "balance"]
    assert len(layer_spans) == tr.n_layers
    assert len(decisions) == tr.n_layers
    assert {"t_grid", "t_greedy"} <= set(decisions[0].args)
    assert sum(w[1] for w in st.layer_windows().values()) == pytest.approx(
        bal.sim.total_time)


def test_recording_none_masks_outer_recorder():
    st = SimTrace()
    with recording(st), recording(None):
        simulate_wired(_golden_trace())
    assert len(st) == 0


# ---------------------------------------------------------------------------
# metrics registry + logger
# ---------------------------------------------------------------------------

def test_metrics_registry_kinds_and_report():
    reg = MetricsRegistry()
    reg.counter("hits", route="a").inc()
    reg.counter("hits", route="a").inc(2.0)
    reg.gauge("depth").set(7)
    reg.histogram("lat").observe(0.25)
    with reg.span("work", stage="x") as t:
        pass
    assert t["seconds"] >= 0.0
    rep = reg.report()
    assert rep["hits"][0]["value"] == 3.0
    assert rep["depth"][0]["value"] == 7.0
    assert rep["lat"][0]["count"] == 1
    assert rep["work"][0]["labels"] == {"stage": "x"}
    with pytest.raises(ValueError):
        reg.gauge("hits", route="a")
    reg.reset()
    assert reg.report() == {}


def test_metrics_logger(capsys):
    reg = MetricsRegistry()
    log = reg.logger("driver")
    log.info("step done", step=3, loss=1.5)
    log.warning("slow")
    out = capsys.readouterr().out
    assert "step done step=3 loss=1.5" in out
    assert "WARNING: slow" in out
    rep = reg.report()
    levels = {tuple(sorted(m["labels"].items())): m["value"]
              for m in rep["log.messages"]}
    assert levels[(("level", "info"), ("logger", "driver"))] == 1.0
    assert rep["driver.step"][0]["value"] == 3.0


# ---------------------------------------------------------------------------
# provenance
# ---------------------------------------------------------------------------

def test_config_hash_deterministic():
    cfg = {"net": NET96, "grid": np.arange(4), "k": (1, 2)}
    h1, h2 = config_hash(cfg), config_hash(cfg)
    assert h1 == h2 and len(h1) == 16
    assert config_hash({**cfg, "k": (1, 3)}) != h1


def test_provenance_stamped_on_sweeps():
    tr = _golden_trace()
    (r,) = policy_sweep_all({"golden": tr}, NET96, policies=("static",))
    prov = r.provenance
    assert prov["kind"] == "dse.policy_sweep_all"
    assert prov["points_evaluated"] == 2      # static + wired baseline
    assert prov["wall_time_s"] > 0.0
    assert len(prov["config_hash"]) == 16


def test_provenance_stamped_on_anneal():
    from test_arch import _tiny_problem

    from repro.arch.placement import anneal
    r = anneal(_tiny_problem(), "hybrid", seed=3, steps=20, restarts=1)
    prov = r.provenance
    assert prov["kind"] == "arch.anneal"
    assert prov["seed"] == 3
    assert prov["points_evaluated"] > 0
    assert make_provenance("x", {})["points_evaluated"] == 0


# ---------------------------------------------------------------------------
# bench regression gate
# ---------------------------------------------------------------------------

run_mod = pytest.importorskip("benchmarks.run")


def test_parse_derived():
    got = run_mod.parse_derived(
        "a=1.25;b=12.3%;c=2.29x;d=13/15;e=True;f=False;g=1.1e-16")
    assert got == {"a": 1.25, "b": 12.3, "c": 2.29,
                   "d": pytest.approx(13 / 15), "e": 1.0, "f": 0.0,
                   "g": 1.1e-16}


def _fake_rows(value):
    return [("row", lambda: value,
             lambda v: "m=%.2f;frac=%d/%d" % (v, 1, 2))]


def test_check_rows_pass_and_fail(capsys):
    committed = {"_bench_meta": {"row": {"derived": "m=1.00;frac=1/2"}}}
    assert run_mod.check_rows(_fake_rows(1.0), committed) == 0
    assert run_mod.check_rows(_fake_rows(1.2), committed) == 1
    err = capsys.readouterr().err
    assert "BENCH CHECK FAILED" in err and "m" in err
    # a row absent from the committed meta is itself a failure
    assert run_mod.check_rows(_fake_rows(1.0), {"_bench_meta": {}}) == 1


def test_bench_check_end_to_end(tmp_path, capsys):
    path = tmp_path / "bench.json"
    assert run_mod.main(["--only", "mapping_sensitivity",
                         "--file", str(path)]) == 0
    assert run_mod.main(["--check", "--only", "mapping_sensitivity",
                         "--file", str(path)]) == 0
    data = json.loads(path.read_text())
    meta = data[run_mod.META_KEY]["mapping_sensitivity"]
    assert meta["us_per_call"] > 0.0
    meta["derived"] = "mac_only/comm_aware=9.99x"
    path.write_text(json.dumps(data))
    assert run_mod.main(["--check", "--only", "mapping_sensitivity",
                         "--file", str(path)]) == 1
    capsys.readouterr()


# ---------------------------------------------------------------------------
# multi-trace merge: colliding names stay separate, counters ordered
# ---------------------------------------------------------------------------

def _tiny_trace(label, dur):
    st = SimTrace(label=label)
    st.add("cut0", "p0", 0.0, dur, "wired", layer=0)
    st.add("compute", "span", 0.0, dur, "compute", layer=0)
    st.add_counter("queue/cut0", 0.0, 1.0)
    st.add_counter("queue/cut0", dur, 0.0)
    return st


def test_merge_keeps_colliding_tracks_separate():
    """Two traces both with a 'cut0' wired track and a 'queue/cut0'
    counter merge into disjoint per-trace process groups."""
    from repro.obs.export import _PID_STRIDE
    obj = chrome_trace_events({"a": _tiny_trace("a", 1e-3),
                               "b": _tiny_trace("b", 2e-3)})
    evs = obj["traceEvents"]
    xs = [e for e in evs if e["ph"] == "X"]
    a_pids = {e["pid"] for e in xs if e["pid"] < _PID_STRIDE}
    b_pids = {e["pid"] for e in xs if e["pid"] >= _PID_STRIDE}
    assert a_pids and b_pids and not (a_pids & b_pids)
    # identical plane -> same pid offset, one stride apart
    assert {p + _PID_STRIDE for p in a_pids} == b_pids
    # process names carry the trace label, so collisions are readable
    names = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert {"a: wired", "b: wired", "a: counters", "b: counters"} <= names
    # both 'cut0' threads exist, each under its own trace's pid
    threads = [(e["pid"], e["args"]["name"]) for e in evs
               if e["ph"] == "M" and e["name"] == "thread_name"]
    assert len([t for t in threads if t[1] == "cut0"]) == 2


def test_merge_counter_tracks_sorted_and_sample_ordered():
    st = SimTrace(label="c")
    st.add("cut0", "p0", 0.0, 1e-3, "wired", layer=0)
    for tr in ("z/depth", "a/depth", "m/depth"):
        st.add_counter(tr, 0.0, 1.0)
        st.add_counter(tr, 1e-3, 0.0)
    evs = chrome_trace_events(st)["traceEvents"]
    cs = [e for e in evs if e["ph"] == "C"]
    # counter tracks are emitted in sorted order...
    firsts = [e["name"] for e in cs if e["ts"] == 0.0]
    assert firsts == sorted(firsts)
    # ...and each track's samples keep their time order
    by_name = {}
    for e in cs:
        by_name.setdefault(e["name"], []).append(e["ts"])
    for name, ts in by_name.items():
        assert ts == sorted(ts), name
    # counters never share a pid with X events
    assert not ({e["pid"] for e in cs}
                & {e["pid"] for e in evs if e["ph"] == "X"})


def test_npz_string_labels_round_trip(tmp_path):
    """Track/cat/name/label strings (incl. non-ASCII and separator
    characters) come back as real Python str, not numpy scalars."""
    st = SimTrace(label="unicode-λ:trace")
    st.add("ch0/z3", "p1,αβ", 0.0, 1e-3, "wireless", layer=0, note="x;y")
    st.add("dram(pooled)", "span", 0.0, 2e-3, "an:dram-agg", layer=0)
    st.add_counter("util/ch0 λ", 0.0, 0.5)
    path = tmp_path / "t.npz"
    export_npz(st, str(path))
    back = load_npz(str(path))
    assert back.label == "unicode-λ:trace"
    assert [(type(e.track), type(e.name), type(e.cat))
            for e in back.events] == [(str, str, str)] * 2
    assert back.__dict__ == st.__dict__
    assert list(back.counters) == ["util/ch0 λ"]


# ---------------------------------------------------------------------------
# seed-era prints routed through MetricsLogger (PR satellite)
# ---------------------------------------------------------------------------

def test_no_bare_prints_in_src():
    """Everything under src/repro reports via obs.metrics.

    Thin wrapper over `repro.lint`'s ``obs-bare-print`` rule (the
    seed-era substring scan this replaces lives on as that rule, with
    an AST-accurate call check and the allowlist in
    `repro.lint.registry.PRINT_ALLOWED_SUFFIXES`).
    """
    import pathlib

    from repro.lint import iter_py_files, run_rules
    from repro.lint.rules_trace import BarePrintRule

    root = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"
    report = run_rules((BarePrintRule(),), iter_py_files([root]),
                       cwd=root.parents[1])
    assert [f.render_text() for f in report.findings] == []


# ---------------------------------------------------------------------------
# bench history ledger (benchmarks/run.py + benchmarks/history.py)
# ---------------------------------------------------------------------------

def test_history_append_load_latest(tmp_path):
    results = str(tmp_path / "bench.json")
    hist = run_mod.history_path(results)
    meta = {"row": {"us_per_call": 1.0, "derived": "m=1.00",
                    "hash": "x", "ts": "t"}}
    run_mod.append_history(hist, meta)
    run_mod.append_history(hist, {"row": {"us_per_call": 2.0,
                                          "derived": "m=2.00",
                                          "hash": "y", "ts": "t2"}})
    with open(hist, "a") as f:
        f.write("{torn json line\n")      # crash-truncated entry
    entries = run_mod.load_history(hist)
    assert len(entries) == 2              # torn line skipped
    assert all(e["metrics"] == {"m": e["us_per_call"]} for e in entries)
    latest = run_mod.latest_by_row(entries)
    assert latest["row"]["derived"] == "m=2.00"


def test_check_falls_back_to_history(tmp_path, capsys):
    """--check on a results file with no _bench_meta uses the latest
    history entry per row instead of returning 'nothing to check'."""
    results = tmp_path / "bench.json"
    assert run_mod.main(["--only", "mapping_sensitivity",
                         "--file", str(results)]) == 0
    hist = run_mod.history_path(str(results))
    assert len(run_mod.load_history(hist)) == 1
    data = json.loads(results.read_text())
    del data[run_mod.META_KEY]            # simulate a pre-meta commit
    results.write_text(json.dumps(data))
    assert run_mod.main(["--check", "--only", "mapping_sensitivity",
                         "--file", str(results)]) == 0
    assert "falling back" in capsys.readouterr().err
    # with neither meta nor history there is genuinely nothing to check
    import os
    os.unlink(hist)
    assert run_mod.main(["--check", "--only", "mapping_sensitivity",
                         "--file", str(results)]) == 2


def test_history_plot_text(tmp_path, capsys):
    import benchmarks.history as hist_mod
    path = str(tmp_path / "h.jsonl")
    for v in (1.0, 3.0, 2.0):
        run_mod.append_history(path, {"r": {"us_per_call": v,
                                            "derived": "m=%.2f" % v,
                                            "hash": "h", "ts": "t"}})
    assert hist_mod.main(["--plot-text", "--file", path]) == 0
    out = capsys.readouterr().out
    assert "r.m" in out and "1 -> 2" in out
    assert any(b in out for b in hist_mod.BARS)
    assert hist_mod.main(["--plot-text", "--file",
                          str(tmp_path / "none.jsonl")]) == 1
